"""Data-parallel sharded serving (DESIGN.md §6): sharded `run_plan` /
`Engine` logits must be bit-identical to the single-device reference across
mesh sizes 1/2/4 and ragged (padded) buckets, the occupancy statistic must
aggregate globally across shards, and the device-count sweep benchmark must
emit its JSON artifact.

Every test runs in a subprocess seeing 4 virtual CPU devices (the
`virtual_devices` conftest fixture —
`XLA_FLAGS=--xla_force_host_platform_device_count=4` only takes effect
before jax initializes, and the in-process suite must keep ONE device).
"""
import json
import textwrap

import pytest

pytestmark = pytest.mark.sharding

# Shared dead-channel band across all samples: the condition under which the
# shared-union compaction permutation — and with it the summation order — is
# identical for ANY batch slice, so shard-local execution is bit-exact
# against the whole-batch reference (all-zero pads never perturb the union).
SETUP = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.vgg19_sparse import CNNConfig
from repro.models.cnn import init_cnn
from repro.parallel import data_mesh
from repro.pipeline import plan_network, run_plan, run_plan_sharded

TINY = CNNConfig(name="vgg-serve-tiny", in_channels=16, img_size=12,
                 plan=((8, 1), (16, 1)), n_classes=4)
params = init_cnn(jax.random.PRNGKey(0), TINY)

def img(seed, dead=8):
    x = np.array(jax.random.uniform(jax.random.PRNGKey(seed), (16, 12, 12)),
                 np.float32)
    if dead:
        x[16 - dead:] = 0.0
    return jnp.asarray(x)

calib = jnp.stack([img(900), img(901)])
plan = plan_network(params, calib, TINY, occ_threshold=0.9, block_c=8)
assert any(lp.impl != "dense" for lp in plan.layers)  # sparse kernels in play
"""


def test_run_plan_sharded_bit_identical_across_mesh_sizes(virtual_devices):
    virtual_devices(SETUP + textwrap.dedent("""
    assert jax.device_count() == 4
    # ragged bucket: 6 real samples + 2 all-zero pads, and a full batch
    full = jnp.stack([img(i) for i in range(8)])
    ragged = jnp.concatenate([full[:6], jnp.zeros_like(full[:2])])
    for imgs, nv in ((full, None), (ragged, 6)):
        ref, ref_occs = run_plan(plan, params, imgs, collect_occupancy=True,
                                 n_valid=nv)
        ref, ref_occs = np.asarray(ref), np.asarray(ref_occs)
        for n_dev in (1, 2, 4):
            out, occs = run_plan_sharded(plan, params, imgs, data_mesh(n_dev),
                                         collect_occupancy=True, n_valid=nv)
            assert np.array_equal(np.asarray(out), ref), \\
                (n_dev, nv, np.abs(np.asarray(out) - ref).max())
            # every shard shares the dead band, so the shard-local stats and
            # their valid-weighted aggregate equal the global measurement
            np.testing.assert_allclose(np.asarray(occs), ref_occs,
                                       rtol=1e-6, atol=1e-6)
    # logits-only path (no occupancy collection) shards identically
    out = run_plan_sharded(plan, params, full, data_mesh(4))
    assert np.array_equal(np.asarray(out), np.asarray(run_plan(plan, params, full)))
    # an indivisible batch must raise, never silently replicate
    try:
        run_plan_sharded(plan, params, full[:6], data_mesh(4))
    except ValueError as e:
        assert "divide" in str(e)
    else:
        raise AssertionError("expected ValueError on 6 % 4 != 0")
    print("OK")
    """))


def test_sharded_engine_matches_single_device_reference(virtual_devices):
    virtual_devices(SETUP + textwrap.dedent("""
    from repro.serving import Engine, SimClock, plan_key

    def build(mesh):
        return Engine(params, TINY, plan=plan, max_batch=8, deadline_s=0.005,
                      clock=SimClock(), mesh=mesh)

    imgs = [img(i) for i in range(6)]  # ragged: pads 6 -> 8-bucket
    ref = np.asarray(run_plan(plan, params, jnp.stack(imgs), TINY))

    sharded = build(data_mesh(4))
    assert sharded.n_devices == 4
    assert sharded.batcher.exec_buckets() == (8,)  # 8/4 = 2 per-shard floor
    served = sharded.serve(imgs)
    assert np.array_equal(served, ref)
    stats = sharded.stats()
    assert stats["devices"] == 4 and stats["pad_samples"] == 2
    assert all(np.isfinite(v) for v in stats["occ_ema"])  # pmean'd stat landed

    single = build(None)  # explicit single-device engine under the same env
    assert single.n_devices == 1
    assert np.array_equal(single.serve(imgs), ref)

    # one shared cache serves the 1..N-device layouts without collisions
    keys = {plan_key(8, plan), plan_key(8, plan, data_mesh(2)),
            plan_key(8, plan, data_mesh(4))}
    assert len(keys) == 3
    assert plan_key(8, plan, data_mesh(1)) == plan_key(8, plan)

    # steady-state sharded serving never compiles after warmup
    eng = build(data_mesh(2))
    eng.warmup()
    compiles = eng.cache.stats()["compiles"]
    for wave in range(3):
        eng.serve([img(100 + 10 * wave + i) for i in range(5)])
    assert eng.cache.stats()["compiles"] == compiles

    # autotune times candidates through the sharded executor (the calib
    # batch of 2 must divide the device count, hence the 2-device mesh)
    from repro.serving import autotune
    res = autotune(params, calib, TINY, thresholds=(0.0, 0.9), block_cs=(8,),
                   iters=1, mode="time", mesh=data_mesh(2))
    assert len(res.candidates) == 2 and res.plan is not None
    print("OK")
    """))


def test_auto_mesh_degrades_on_awkward_device_counts(virtual_devices):
    """mesh="auto" on a host whose device count does not divide max_batch
    must fall back to the largest count that does (never refuse to
    construct); an EXPLICIT mismatched mesh still raises."""
    virtual_devices(SETUP + textwrap.dedent("""
    from repro.serving import Engine, SimClock, auto_mesh
    assert jax.device_count() == 3
    assert auto_mesh(8).size == 2  # 8 % 3 != 0 -> degrade to 2 devices
    assert auto_mesh(6).size == 3
    assert auto_mesh(1).size == 1
    # the min_bucket floor binds too: 2 devices over max_batch=2 would run
    # M=1 shards, so auto stays single-device unless the floor is lowered
    assert auto_mesh(2).size == 1
    assert auto_mesh(2, min_bucket=1).size == 2
    eng = Engine(params, TINY, plan=plan, max_batch=8, clock=SimClock())
    assert eng.n_devices == 2  # default mesh="auto" constructed and degraded
    out = eng.serve([img(i) for i in range(5)])
    assert out.shape == (5, 4) and np.all(np.isfinite(out))
    try:
        Engine(params, TINY, plan=plan, max_batch=8, clock=SimClock(),
               mesh=data_mesh(3))
    except ValueError as e:
        assert "multiple of" in str(e)
    else:
        raise AssertionError("explicit 3-device mesh with max_batch=8 must raise")
    print("OK")
    """), n=3)


def test_sharded_occupancy_aggregates_valid_weighted(virtual_devices):
    """A ragged bucket whose tail shard holds ONLY pad samples: the weighted
    cross-shard aggregation must ignore the empty shard (weight 0) and still
    reproduce the global n_valid-masked statistic."""
    virtual_devices(SETUP + textwrap.dedent("""
    full = jnp.stack([img(i) for i in range(4)])
    imgs = jnp.concatenate([full, jnp.zeros_like(full)])  # 4 real + 4 pads
    # mesh=4: shards 2 and 3 hold only pads -> local weight 0
    _, occs = run_plan_sharded(plan, params, imgs, data_mesh(4),
                               collect_occupancy=True, n_valid=4)
    _, ref = run_plan(plan, params, imgs, collect_occupancy=True, n_valid=4)
    occs, ref = np.asarray(occs), np.asarray(ref)
    assert np.all(np.isfinite(occs))
    np.testing.assert_allclose(occs, ref, rtol=1e-6, atol=1e-6)
    assert occs[0] < 1.0  # the dead band really registered, not washed out
    print("OK")
    """))


def test_serve_sharded_benchmark_emits_json(virtual_devices, tmp_path):
    """Acceptance: benchmarks/serve_sharded.py sweeps device count x request
    rate and emits BENCH_serve_sharded.json with throughput per device count."""
    virtual_devices(textwrap.dedent(f"""
    import json
    from benchmarks import serve_sharded

    path = serve_sharded.main(reduced=True, json_dir={str(tmp_path)!r},
                              device_counts=(1, 2, 4), rates=(100.0,),
                              n_requests=8)
    data = json.loads(open(path).read())
    assert data["name"] == "serve_sharded"
    devs = sorted(p["devices"] for p in data["points"])
    assert devs == [1, 2, 4]
    for p in data["points"]:
        assert p["throughput_rps"] > 0
        assert p["p95_ms"] >= p["p50_ms"] > 0
        assert p["stream_compiles"] == 0  # steady-state never compiles
    print("OK:" + path)
    """))
    out = list(tmp_path.glob("BENCH_serve_sharded.json"))
    assert len(out) == 1
    data = json.loads(out[0].read_text())
    assert {p["devices"] for p in data["points"]} == {1, 2, 4}
