"""Chunkwise mLSTM == sequential mLSTM (the §Perf hillclimb for xlstm train)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.layers import unzip_params
from repro.models.xlstm import (
    _mlstm_chunkwise,
    _mlstm_sequential,
    init_mlstm,
    init_mlstm_state,
    mlstm_block,
)

KEY = jax.random.PRNGKey(0)


def _qkvif(b, s, h, dh, seed=0, scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, s, h, dh)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, dh)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, dh)) * 0.5
    ig = jax.random.normal(ks[3], (b, s, h)) * scale
    fg = jax.random.normal(ks[4], (b, s, h)) * scale + 2.0
    return q, k, v, ig, fg


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("seed", [0, 1])
def test_chunkwise_matches_sequential(chunk, seed):
    cfg = get_config("xlstm-125m", reduced=True)
    b, s, h, dh = 2, 32, 2, 16
    q, k, v, ig, fg = _qkvif(b, s, h, dh, seed)
    st = init_mlstm_state(dataclasses.replace(cfg, n_heads=h, d_model=dh * h // 2), b)
    st = type(st)(c=jnp.zeros((b, h, dh, dh)), n=jnp.zeros((b, h, dh)),
                  m=jnp.full((b, h), -1e30))
    (c1, n1, m1), y1 = _mlstm_sequential(q, k, v, ig, fg, st)
    (c2, n2, m2), y2 = _mlstm_chunkwise(q, k, v, ig, fg, st, chunk)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c1), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(n2), np.asarray(n1), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(m1), rtol=1e-5, atol=1e-5)


def test_chunkwise_with_nonzero_initial_state():
    """Carried state across a prefill boundary (prefill -> more prefill)."""
    b, s, h, dh = 1, 16, 2, 8
    q, k, v, ig, fg = _qkvif(b, 2 * s, h, dh, seed=3)
    st0 = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)), jnp.full((b, h), -1e30))
    from repro.models.xlstm import MLSTMState

    st0 = MLSTMState(*st0)
    # run first half sequentially, second half chunkwise with the carried state
    (c1, n1, m1), _ = _mlstm_sequential(q[:, :s], k[:, :s], v[:, :s], ig[:, :s], fg[:, :s], st0)
    st_mid = MLSTMState(c=c1, n=n1, m=m1)
    (_, _, _), y_seq = _mlstm_sequential(q[:, s:], k[:, s:], v[:, s:], ig[:, s:], fg[:, s:], st_mid)
    (_, _, _), y_chk = _mlstm_chunkwise(q[:, s:], k[:, s:], v[:, s:], ig[:, s:], fg[:, s:], st_mid, 8)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq), rtol=2e-4, atol=2e-4)


def test_block_level_dispatch():
    """mlstm_block uses chunkwise for long sequences, sequential for decode;
    both agree with each other end-to-end."""
    cfg = get_config("xlstm-125m", reduced=True)
    px = init_mlstm(KEY, cfg)
    p, _ = unzip_params(px)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.3
    out_chunk, st_c = mlstm_block(p, x, cfg, chunk=16)
    out_seq, st_s = mlstm_block(p, x, cfg, chunk=9999)  # falls back to sequential
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_seq),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_c.c), np.asarray(st_s.c), rtol=3e-4, atol=3e-4)
