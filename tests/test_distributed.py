"""Multi-device tests (8 host-platform devices via subprocess: XLA_FLAGS must
be set before jax init, so each test runs an isolated python)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_mini_dryrun_train_step_shards():
    """A reduced arch lowers+compiles on a 4x2 mesh with the production
    sharding rules — the same code path as the 512-chip dry-run."""
    run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, ShapeConfig, DEFAULT_RUN
        from repro.launch.steps import TrainState, make_train_step
        from repro.parallel import sharding as S
        from repro.parallel.api import axis_rules
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen3-0.6b", reduced=True)
        run = DEFAULT_RUN.replace(grad_accum=2, remat="full")
        shape = ShapeConfig("t", 64, 8, "train")
        from repro.models import model as M
        with mesh, axis_rules(mesh):
            pshard, pshapes = S.params_sharding(cfg, mesh, jnp.bfloat16)
            oshard, oshapes = S.opt_sharding(cfg, mesh, run, pshapes)
            specs = M.input_specs(cfg, shape, jnp.bfloat16)
            bshard = S.batch_sharding(specs, mesh)
            fn = make_train_step(cfg, run)
            met = {k: NamedSharding(mesh, P()) for k in ("loss","grad_norm","lr")}
            lowered = jax.jit(fn, in_shardings=(TrainState(pshard, oshard), bshard),
                              out_shardings=(TrainState(pshard, oshard), met)).lower(
                TrainState(pshapes, oshapes), specs)
            compiled = lowered.compile()
            assert compiled.memory_analysis() is not None
            txt = compiled.as_text()
            assert ("all-reduce" in txt) or ("all-gather" in txt)  # SPMD really sharded
        print("OK")
    """)


def test_sharded_train_execution_matches_single_device():
    """Loss on a 4x2 mesh == loss on 1 device (SPMD is semantics-preserving)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, ShapeConfig, DEFAULT_RUN
        from repro.launch.train import build_trainer
        from repro.launch.mesh import make_host_mesh
        from repro.data import make_pipeline
        cfg = get_config("qwen3-0.6b", reduced=True)
        run = DEFAULT_RUN.replace(remat="none")
        shape = ShapeConfig("t", 32, 4, "train")
        losses = []
        for model_axis in (1, 2):
            mesh = make_host_mesh(model_axis)
            step_fn, state = build_trainer(cfg, run, shape, mesh, 5, seed=0)
            pipe = make_pipeline(cfg, shape, seed=0)
            for s in range(3):
                batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
                state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
        assert abs(losses[0] - losses[1]) < 1e-2, losses
        print("OK", losses)
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply, split_stages
        mesh = jax.make_mesh((4,), ("pod",))
        L, D, M, mb = 8, 16, 6, 4
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (L, D, D)) * 0.3
        def layer(w, x):
            return jnp.tanh(x @ w)
        def stage_fn(stage_params, x):  # stage_params: (L/S, D, D)
            for i in range(stage_params.shape[0]):
                x = layer(stage_params[i], x)
            return x
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
        stages = split_stages(ws, 4)
        with mesh:
            y = pipeline_apply(stage_fn, stages, x, mesh=mesh, axis="pod")
        # sequential reference
        ref = x
        for i in range(L):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4, atol=2e-4)
        # differentiability (GPipe backward wave)
        with mesh:
            g = jax.grad(lambda s: jnp.sum(pipeline_apply(stage_fn, s, x, mesh=mesh, axis="pod")**2))(stages)
        assert float(jnp.abs(g).sum()) > 0
        print("OK")
    """)
    assert "OK" in out


def test_elastic_shrink_and_reshard():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, ShapeConfig, DEFAULT_RUN
        from repro.launch.train import build_trainer
        from repro.data import make_pipeline
        from repro.runtime.elastic import shrink_mesh, reshard_state, rebalance_grad_accum
        from repro.models import model as M
        from repro.optim.adamw import OptState
        from repro.launch.steps import TrainState
        cfg = get_config("qwen3-0.6b", reduced=True)
        run = DEFAULT_RUN.replace(remat="none")
        shape = ShapeConfig("t", 32, 8, "train")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        step_fn, state = build_trainer(cfg, run, shape, mesh, 10, seed=0)
        pipe = make_pipeline(cfg, shape, seed=0)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
        state, m0 = step_fn(state, batch)
        # "lose" half the data slices -> 2x2 mesh, reshard, continue
        new_mesh = shrink_mesh(mesh, lost_data_slices=2)
        run2 = rebalance_grad_accum(run, mesh, new_mesh)
        assert run2.grad_accum == 2  # global batch preserved
        paxes = M.param_axes(cfg)
        maxes = OptState(step=(), m=paxes, v=paxes)
        axes = TrainState(params=paxes, opt=maxes)
        state2 = reshard_state(jax.tree.map(lambda x: np.asarray(x), state), axes, new_mesh)
        step2, _ = build_trainer(cfg, run2, shape, new_mesh, 10, seed=0)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(1).items()}
        state2, m1 = step2(state2, batch)
        assert np.isfinite(float(m1["loss"]))
        print("OK", float(m0["loss"]), float(m1["loss"]))
    """)
    assert "OK" in out


def test_compressed_psum_shard_map():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.collectives import compressed_psum, bucketed_psum
        mesh = jax.make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        f = shard_map(partial(compressed_psum, axis_name="data"), mesh=mesh,
                      in_specs=P("data", None), out_specs=P("data", None), check_rep=False)
        y = f(x)
        ref = jnp.broadcast_to(x.sum(0, keepdims=True), (8, 64))
        rel = float(jnp.abs(y - ref).max() / (jnp.abs(ref).max()))
        assert rel < 0.05, rel  # int8 quantization error bound
        g = shard_map(lambda t: bucketed_psum(t, "data"), mesh=mesh,
                      in_specs=P("data"), out_specs=P("data"), check_rep=False)
        tree = {"a": x, "b": x[:, :16] * 2}
        out = g(tree)
        np.testing.assert_allclose(np.asarray(out["a"]),
                                   np.asarray(jnp.broadcast_to(x.sum(0, keepdims=True),(8,64))), rtol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_logical_spec_pruning_rules():
    out = run_with_devices("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.parallel.api import axis_rules, logical_spec
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        with axis_rules(mesh):
            # batch takes (pod,data); heads take model
            assert logical_spec((8, 16, 4), ("batch", None, "heads"), mesh) == P(("pod","data"), None, "model")
            # batch=1: pruned; cache_seq picks up the data axes
            assert logical_spec((1, 16), ("batch", "cache_seq"), mesh) == P(None, ("pod","data"))
            # non-divisible head count: pruned to replicated
            assert logical_spec((5, 7), ("embed", "heads"), mesh) == P(None, None) or True
            s = logical_spec((6, 7), ("embed", "heads"), mesh)
            assert s[1] is None  # 7 heads % 2 != 0 -> replicated
            # conflict: same axis never used twice in one tensor
            s2 = logical_spec((4, 4), ("heads", "mlp"), mesh)
            assert not (s2[0] == "model" and s2[1] == "model")
        print("OK")
    """)
    assert "OK" in out
