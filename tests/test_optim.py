"""Optimizer: AdamW reference equivalence, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.optim import (
    adamw_update,
    compress_grads,
    decompress_grads,
    init_error_feedback,
    init_opt_state,
    warmup_cosine,
)

KEY = jax.random.PRNGKey(0)


def test_adamw_matches_reference():
    params = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([[0.5, -0.5]])}
    grads = {"w": jnp.array([0.1, 0.2, -0.3]), "b": jnp.array([[0.01, -0.02]])}
    state = init_opt_state(params)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    new_p, new_s, gnorm = adamw_update(grads, state, params, lr=lr, beta1=b1,
                                       beta2=b2, eps=eps, weight_decay=wd,
                                       grad_clip=0.0)
    # numpy reference
    for k in params:
        g = np.asarray(grads[k], np.float64)
        m = (1 - b1) * g
        v = (1 - b2) * g ** 2
        mh = m / (1 - b1)
        vh = v / (1 - b2)
        ref = np.asarray(params[k], np.float64) - lr * (
            mh / (np.sqrt(vh) + eps) + wd * np.asarray(params[k], np.float64))
        np.testing.assert_allclose(np.asarray(new_p[k]), ref, rtol=1e-5)
    assert int(new_s.step) == 1


def test_grad_clip_scales_update():
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    state = init_opt_state(params)
    _, _, gnorm = adamw_update(grads, state, params, lr=1e-3, grad_clip=1.0)
    assert float(gnorm) == 200.0  # ||g|| = 100*2


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 0.11
    assert lrs[99] < 0.2
    assert max(lrs) <= 1.0 + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), scheme=st.sampled_from(["int8", "topk"]))
def test_compression_error_feedback_unbiased(seed, scheme):
    """Accumulated (decompressed + error) must equal the true gradient sum —
    the error-feedback invariant that makes compressed SGD converge."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (64,))}
    err = init_error_feedback(g)
    total_sent = np.zeros(64)
    total_true = np.zeros(64)
    key = jax.random.PRNGKey(seed + 1)
    for i in range(5):
        gi = {"w": jax.random.normal(jax.random.PRNGKey(seed + 10 + i), (64,))}
        total_true += np.asarray(gi["w"])
        key, sub = jax.random.split(key)
        comp, err = compress_grads(gi, err, scheme=scheme, key=sub, topk_frac=0.1)
        dec = decompress_grads(comp, scheme=scheme)
        total_sent += np.asarray(dec["w"])
    # residual bounded by the error buffer (exact identity):
    np.testing.assert_allclose(total_sent + np.asarray(err["w"]), total_true,
                               rtol=1e-4, atol=1e-4)


def test_compressed_sgd_converges_on_quadratic():
    """min ||x - c||^2 with int8-compressed gradients + error feedback."""
    c = jnp.linspace(-1, 1, 32)
    x = {"x": jnp.zeros(32)}
    err = init_error_feedback(x)
    key = KEY
    for i in range(200):
        g = {"x": 2 * (x["x"] - c)}
        key, sub = jax.random.split(key)
        comp, err = compress_grads(g, err, scheme="int8", key=sub)
        dec = decompress_grads(comp, scheme="int8")
        x = {"x": x["x"] - 0.05 * dec["x"]}
    assert float(jnp.max(jnp.abs(x["x"] - c))) < 0.02
