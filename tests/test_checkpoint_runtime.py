"""Fault tolerance: checkpoint atomicity, restart bit-exactness, stragglers,
elastic resharding, data-pipeline determinism."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import DEFAULT_RUN, ShapeConfig, get_config
from repro.data import make_pipeline
from repro.launch.steps import init_train_state, make_train_step
from repro.runtime import FailureInjector, StragglerMonitor, Supervisor

KEY = jax.random.PRNGKey(0)


def _tiny_setup(tmp, steps=10, fail_at=()):
    cfg = get_config("qwen3-0.6b", reduced=True)
    run = DEFAULT_RUN.replace(remat="none")
    shape = ShapeConfig("t", 32, 2, "train")
    step_fn = jax.jit(make_train_step(cfg, run, steps))
    state = init_train_state(cfg, run, KEY)
    pipeline = make_pipeline(cfg, shape, seed=7)
    ckpt = CheckpointManager(tmp, keep=2)
    sup = Supervisor(train_step=step_fn, pipeline=pipeline, ckpt=ckpt,
                     checkpoint_every=3,
                     injector=FailureInjector(fail_at=fail_at) if fail_at else None)
    return sup, state, ckpt


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(5, dtype=jnp.float32), "n": {"b": jnp.ones((2, 3))}}
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree, extra={"step": s}, block=True)
    assert ckpt.all_steps() == [3, 4]  # keep-k retention
    restored, meta = ckpt.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))
    assert meta["step"] == 4


def test_restart_is_bit_exact(tmp_path):
    """Uninterrupted run == run with an injected crash + restore."""
    sup1, state1, _ = _tiny_setup(tmp_path / "a", steps=10)
    _, hist1 = sup1.run(state1, 10)

    sup2, state2, _ = _tiny_setup(tmp_path / "b", steps=10, fail_at=(7,))
    _, hist2 = sup2.run(state2, 10)

    # the crashed run restores step 6's checkpoint and replays 6..9; final
    # losses must agree exactly (stateless pipeline + deterministic step)
    l1 = {h["step"]: h["loss"] for h in hist1}
    l2 = {h["step"]: h["loss"] for h in hist2}
    for s in range(10):
        assert abs(l1[s] - l2[s]) < 1e-6, (s, l1[s], l2[s])


def test_atomic_commit_no_partial_checkpoint(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=3)
    tree = {"w": jnp.ones(4)}
    ckpt.save(5, tree, block=True)
    # a leftover tmp dir (simulated crash mid-write) is never listed
    (tmp_path / "tmp.9").mkdir()
    (tmp_path / "step_00000009").mkdir()  # no arrays.npz -> incomplete
    assert ckpt.all_steps() == [5]


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(z_thresh=3.0, warmup_steps=3)
    for s in range(20):
        mon.observe(s, 0.10 + 0.001 * (s % 3))
    assert not mon.flagged
    mon.observe(20, 0.9)  # a 9x step
    assert len(mon.flagged) == 1 and mon.flagged[0][0] == 20


def test_pipeline_determinism_and_host_sharding():
    cfg = get_config("qwen3-0.6b", reduced=True)
    shape = ShapeConfig("t", 16, 8, "train")
    p1 = make_pipeline(cfg, shape, seed=3)
    p2 = make_pipeline(cfg, shape, seed=3)
    np.testing.assert_array_equal(p1.batch_at(11)["tokens"], p2.batch_at(11)["tokens"])
    assert not np.array_equal(p1.batch_at(11)["tokens"], p1.batch_at(12)["tokens"])
    # host sharding: two hosts produce different shards of the right size
    h0 = make_pipeline(cfg, shape, seed=3, n_hosts=2, host_id=0)
    h1 = make_pipeline(cfg, shape, seed=3, n_hosts=2, host_id=1)
    b0, b1 = h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"]
    assert b0.shape == (4, 16) and b1.shape == (4, 16)
    assert not np.array_equal(b0, b1)
    # labels are next-token shifted
    b = p1.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetch_iterator_resumes():
    cfg = get_config("qwen3-0.6b", reduced=True)
    shape = ShapeConfig("t", 16, 4, "train")
    p = make_pipeline(cfg, shape, seed=1)
    it = p.iterate(start_step=5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], p.batch_at(5)["tokens"])
