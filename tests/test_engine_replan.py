"""Engine re-plan hysteresis under SCRIPTED occupancy: EMA convergence at the
configured ema_alpha, cooldown suppression after a swap, atomicity of the
async background swap (in-flight batches keep the old plan's exact logits),
failed re-plans counting without killing serving, and the hot-swap
generation bump dropping stale in-flight re-plan results.

These drive `_observe` / `_launch_replan` / `_adopt_pending_plan` directly
(or gate the module-level `plan_network` on an event) so every interleaving
is deterministic — no sleeps, no races."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg19_sparse import CNNConfig
from repro.models.cnn import init_cnn
from repro.pipeline import plan_network, run_plan
from repro.serving import Engine, SimClock, plan_key, synth_image
from repro.serving import engine as engine_mod

TINY = CNNConfig(name="vgg-serve-tiny", in_channels=16, img_size=12,
                 plan=((8, 1), (16, 1)), n_classes=4)
SHAPE = (16, TINY.img_size, TINY.img_size)


@pytest.fixture(scope="module")
def params():
    return init_cnn(jax.random.PRNGKey(0), TINY)


def _engine(params, **kw):
    kw.setdefault("calib", jnp.stack([synth_image(SHAPE, 900, i, 0.5)
                                      for i in range(2)]))
    kw.setdefault("occ_threshold", 0.9)
    kw.setdefault("block_c", 8)
    kw.setdefault("max_batch", 4)
    kw.setdefault("deadline_s", 0.005)
    kw.setdefault("clock", SimClock())
    kw.setdefault("sim_service_s", 0.002)
    return Engine(params, TINY, **kw)


def _dense(seed):
    """A fully-dense request image: entry occupancy 1.0, far from the 0.5
    regime the engine planned at — the drift driver."""
    return synth_image(SHAPE, seed, 0, 0.0)


def test_ema_convergence_matches_alpha(params):
    """k scripted observations of a constant target converge the EMA exactly
    as target + (start - target) * (1 - alpha)^k — the published semantics of
    ema_alpha, pinned against silent re-weightings."""
    a = 0.3
    eng = _engine(params, ema_alpha=a, replan_band=10.0)  # band: never trigger
    start = eng._occ_ema.copy()
    target = np.full_like(start, 0.95)
    for k in range(1, 6):
        eng._observe(target.copy())
        expect = target + (start - target) * (1.0 - a) ** k
        np.testing.assert_allclose(eng._occ_ema, expect, rtol=1e-12)
    assert eng.n_replans == 0  # wide band: scripted drift never triggered
    # the telemetry timeline recorded one row per observation
    assert len(eng.metrics.occ_timeline) == 5


def test_replan_cooldown_suppresses_triggers(params):
    """After a swap the detector must hold fire for replan_cooldown
    observations even when the EMA sits far outside the band — the hysteresis
    that stops plan thrash on the tail of a regime change."""
    eng = _engine(params, ema_alpha=1.0, replan_band=0.05, replan_cooldown=3)
    launches = []
    eng._launch_replan = lambda: launches.append(eng.clock())
    # simulate an adopted re-plan: same schedule (changed=False), arms cooldown
    eng._pending_plan = eng.plan
    eng._adopt_pending_plan()
    assert eng.n_replans == 0 and eng._cooldown == 3
    far = np.zeros_like(eng._occ_ema)  # delta 0.5+: far outside the band
    for _ in range(3):
        eng._observe(far)
        assert launches == []  # cooldown ticks down, no launch
    eng._observe(far)
    assert len(launches) == 1  # first post-cooldown observation fires
    assert eng.metrics.replan_triggers == 1


def test_async_replan_swap_is_atomic_between_batches(params):
    """While a background re-plan is in flight, every executed batch keeps the
    OLD plan's bit-exact logits; the new plan only takes effect at the next
    poll() adoption point — never mid-stream."""
    eng = _engine(params, ema_alpha=1.0, replan_band=0.1, replan_cooldown=0,
                  replan_async=True)
    plan_old = eng.plan
    release = threading.Event()
    real_plan_network = engine_mod.plan_network

    def gated(*args, **kw):
        release.wait(30)
        return real_plan_network(*args, **kw)

    engine_mod.plan_network = gated
    try:
        batch1 = [_dense(i) for i in range(4)]
        out1 = eng.serve(batch1)  # dense batch: EMA jumps, trigger fires
        assert eng._replanning and eng.n_replans == 0
        np.testing.assert_array_equal(
            out1, np.asarray(run_plan(plan_old, params, jnp.stack(batch1))))
        batch2 = [_dense(10 + i) for i in range(4)]
        out2 = eng.serve(batch2)  # re-plan still in flight: old plan serves
        assert eng.plan is plan_old
        np.testing.assert_array_equal(
            out2, np.asarray(run_plan(plan_old, params, jnp.stack(batch2))))
        release.set()
        eng.join_replan()
    finally:
        engine_mod.plan_network = real_plan_network
    assert eng.poll() == []  # adoption point: swaps the finished plan in
    assert eng.n_replans == 1
    assert plan_key(0, eng.plan) != plan_key(0, plan_old)
    batch3 = [_dense(20 + i) for i in range(4)]
    np.testing.assert_array_equal(
        eng.serve(batch3),
        np.asarray(run_plan(eng.plan, params, jnp.stack(batch3))))
    swaps = [e for e in eng.metrics.replan_events if e["kind"] == "swap"]
    assert len(swaps) == 1 and swaps[0]["changed"]


def test_replan_error_counts_without_killing_serving(params):
    """A failing plan_network must not wedge the drift detector or drop the
    batch that triggered it: the error is counted, the old plan keeps
    serving, and the NEXT drift trigger (with planning healthy again)
    re-plans normally."""
    eng = _engine(params, ema_alpha=1.0, replan_band=0.1, replan_cooldown=0)
    plan_old = eng.plan
    real_plan_network = engine_mod.plan_network

    def boom(*args, **kw):
        raise RuntimeError("planner outage")

    engine_mod.plan_network = boom
    try:
        for round_ in range(2):
            batch = [_dense(round_ * 10 + i) for i in range(4)]
            out = eng.serve(batch)  # trigger -> work() raises -> batch survives
            np.testing.assert_array_equal(
                out, np.asarray(run_plan(plan_old, params, jnp.stack(batch))))
        assert eng.replan_errors == 2 and eng.n_replans == 0
        assert eng.plan is plan_old and not eng._replanning
    finally:
        engine_mod.plan_network = real_plan_network
    out = eng.serve([_dense(30 + i) for i in range(4)])  # healthy again
    assert out.shape == (4, 4)
    assert eng.n_replans == 1  # the retried trigger re-planned for real
    assert eng.stats()["replan_errors"] == 2
    kinds = [e["kind"] for e in eng.metrics.replan_events]
    assert kinds.count("error") == 2 and kinds.count("swap") == 1


def test_hot_swap_drops_stale_inflight_replan(params):
    """A hot_swap that lands while a background re-plan is in flight bumps
    the plan generation: the stale result (planned against the swapped-OUT
    params) must be dropped on arrival, never adopted over the new model."""
    eng = _engine(params, ema_alpha=1.0, replan_band=0.1, replan_cooldown=0,
                  replan_async=True)
    swap_plan = plan_network(params, jnp.stack([_dense(50), _dense(51)]),
                             eng.graph, occ_threshold=eng.plan.occ_threshold,
                             block_c=eng.plan.block_c,
                             use_pallas=eng.use_pallas)
    release = threading.Event()
    real_plan_network = engine_mod.plan_network

    def gated(*args, **kw):
        release.wait(30)
        return real_plan_network(*args, **kw)

    engine_mod.plan_network = gated
    try:
        eng.serve([_dense(i) for i in range(4)])  # drift: background re-plan
        assert eng._replanning
        eng.hot_swap(params, plan=swap_plan)  # lands mid-flight: bumps gen
        release.set()
        eng.join_replan()
    finally:
        engine_mod.plan_network = real_plan_network
    assert eng.poll() == []  # adoption point: nothing pending to adopt
    assert eng.plan is swap_plan  # the stale result did NOT clobber the swap
    assert eng._pending_plan is None and not eng._replanning
    assert eng.n_replans == 0 and eng.n_hot_swaps == 1
    # and the engine still serves, detector unwedged
    out = eng.serve([_dense(60 + i) for i in range(4)])
    assert out.shape == (4, 4)


def test_hot_swap_recenters_ema_and_arms_cooldown(params):
    eng = _engine(params, replan_cooldown=2)
    eng.serve([synth_image(SHAPE, 7, i, 0.5) for i in range(4)])
    eng.hot_swap(eng.params)  # re-plans on the most recent real batch
    np.testing.assert_array_equal(
        eng._occ_ema, np.array([lp.occupancy for lp in eng.plan.layers]))
    assert eng._cooldown == 2 and eng.n_hot_swaps == 1
