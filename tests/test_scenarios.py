"""Telemetry + scenario library: MetricsTracker/LatencyReservoir semantics,
seeded-scenario determinism, and the per-regime serving contracts —
diurnal drift re-plans within K batches to the plan `plan_network` would
pick at the drifted occupancy, bursts never strand a request, multi-tenant
streams over one shared PlanCache never cross-contaminate, hot swap is
atomic under load, and identical seeded replays are bit-identical
including metric snapshots (the BENCH-diff regression contract)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg19_sparse import CNNConfig
from repro.models.cnn import init_cnn
from repro.pipeline import plan_network, run_plan
from repro.serving import (
    DiurnalDriftScenario,
    Engine,
    HotSwapScenario,
    LatencyReservoir,
    ListScenario,
    MetricsTracker,
    MultiTenantScenario,
    PlanCache,
    PoissonBurstScenario,
    SimClock,
    TenantSpec,
    plan_key,
    replay_scenario,
    replay_stream,
    synth_image,
)

TINY = CNNConfig(name="vgg-serve-tiny", in_channels=16, img_size=12,
                 plan=((8, 1), (16, 1)), n_classes=4)
SHAPE = (16, TINY.img_size, TINY.img_size)
SERVICE_S = 0.002  # deterministic service-time model for every sim replay


@pytest.fixture(scope="module")
def params():
    return init_cnn(jax.random.PRNGKey(0), TINY)


def _engine(params, *, dead_frac=0.5, seed=900, **kw):
    """Scenario engine planned at the `dead_frac` regime, on a SimClock with
    the deterministic service model (so whole replays — logits AND metric
    snapshots — are pure functions of the seeds)."""
    kw.setdefault("calib", jnp.stack([synth_image(SHAPE, seed, i, dead_frac)
                                      for i in range(2)]))
    kw.setdefault("occ_threshold", 0.9)
    kw.setdefault("block_c", 8)
    kw.setdefault("max_batch", 4)
    kw.setdefault("deadline_s", 0.005)
    kw.setdefault("clock", SimClock())
    kw.setdefault("sim_service_s", SERVICE_S)
    kw.setdefault("ema_alpha", 0.5)
    kw.setdefault("replan_band", 0.15)
    kw.setdefault("replan_cooldown", 0)
    return Engine(params, TINY, **kw)


# ---------------------------------------------------------------------------
# MetricsTracker / LatencyReservoir
# ---------------------------------------------------------------------------


def test_latency_reservoir_percentiles_exact_when_unsaturated():
    """count <= size: every latency is in the sample, so the percentiles are
    numpy's linear-interpolated values exactly."""
    r = LatencyReservoir(size=256)
    vals = [i / 1e3 for i in range(1, 101)]  # 1..100 ms, in seconds
    for v in vals:
        r.add(v)
    p = r.percentiles_ms()
    ref = np.array(vals) * 1e3
    assert p["count"] == 100
    assert p["mean_ms"] == pytest.approx(float(ref.mean()))
    assert p["max_ms"] == pytest.approx(100.0)
    for q in (50, 95, 99):
        assert p[f"p{q}_ms"] == pytest.approx(float(np.percentile(ref, q)))
    empty = LatencyReservoir().percentiles_ms()
    assert empty == {"count": 0, "mean_ms": 0.0, "max_ms": 0.0,
                     "p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}


def test_latency_reservoir_bounded_and_seed_deterministic():
    """Beyond `size` the sample stays bounded (algorithm R) while count/mean/
    max stay exact, and the seeded PRNG makes two identical streams sample
    identically — the snapshot-determinism contract."""
    a, b = LatencyReservoir(size=8, seed=3), LatencyReservoir(size=8, seed=3)
    for i in range(1000):
        a.add(i * 1e-3)
        b.add(i * 1e-3)
    assert len(a.values) == 8 and a.count == 1000
    assert a.values == b.values
    assert a.percentiles_ms() == b.percentiles_ms()
    assert a.percentiles_ms()["max_ms"] == pytest.approx(999.0)
    with pytest.raises(ValueError):
        LatencyReservoir(size=0)


def test_metrics_tracker_snapshot_counts_and_json():
    t = MetricsTracker()
    t.on_submit(0.0)
    t.on_submit(0.001)
    t.on_batch(0.01, bucket=4, n_real=3, service_s=SERVICE_S)
    t.on_result(0.010)
    t.on_result(0.009)
    t.on_occupancy(0.01, np.array([0.5, 1.0]))
    t.on_replan_trigger(0.02, delta=0.3)
    t.on_replan_swap(0.03, changed=True)
    t.on_replan_error(0.04)
    t.on_hot_swap(0.05)
    s = t.snapshot()
    assert s["submitted"] == 2 and s["completed"] == 2 and s["batches"] == 1
    assert s["pad_samples"] == 1 and s["mean_fill"] == pytest.approx(0.75)
    assert s["bucket_counts"] == {"4": 1}
    assert s["service_s_total"] == pytest.approx(SERVICE_S)
    assert s["occ_timeline"] == [[0.01, [0.5, 1.0]]]
    assert [e["kind"] for e in s["replan_events"]] == [
        "trigger", "swap", "error", "hot_swap"]
    assert s["replans"] == {"triggers": 1, "swaps": 1, "errors": 1,
                            "hot_swaps": 1, "verify_rejects": 0}
    json.dumps(s)  # the whole snapshot must be JSON-serializable verbatim


def test_metrics_tracker_timelines_are_bounded():
    t = MetricsTracker(timeline_max=4)
    for i in range(10):
        t.on_occupancy(float(i), [0.5])
        t.on_replan_trigger(float(i), 0.2)
    s = t.snapshot()
    assert [row[0] for row in s["occ_timeline"]] == [6.0, 7.0, 8.0, 9.0]
    assert len(s["replan_events"]) == 4  # most recent kept, count stays exact
    assert s["replans"]["triggers"] == 10


def test_engine_stats_latency_covers_flush_tail(params):
    """A lone request completed only by drain() (never poll()) must reach the
    percentile accounting — the flush tail used to escape it entirely."""
    eng = _engine(params)
    eng.submit(synth_image(SHAPE, 1, 0))
    eng.clock.advance(0.001)
    assert eng.poll() == []  # not due: nothing completed through poll
    results = eng.drain()
    assert len(results) == 1
    st = eng.stats()
    assert st["lat_count"] == 1
    expect_ms = results[0].latency_s * 1e3
    assert st["p50_ms"] == pytest.approx(expect_ms)
    assert st["p99_ms"] == pytest.approx(expect_ms)
    assert st["mean_ms"] == pytest.approx(expect_ms)
    tel = st["telemetry"]
    assert tel["completed"] == 1 and tel["submitted"] == 1
    assert tel["bucket_counts"] == {"2": 1}  # min_bucket pad, not a 1-bucket


# ---------------------------------------------------------------------------
# scenario definitions: seeded determinism + regime shapes
# ---------------------------------------------------------------------------


def test_scenario_requests_deterministic_per_seed():
    def arrivals(seed):
        return [r.t for r in PoissonBurstScenario(
            in_shape=SHAPE, n_requests=12, seed=seed).requests()]

    assert arrivals(5) == arrivals(5)
    assert arrivals(5) != arrivals(6)
    ts = arrivals(5)
    assert all(b > a for a, b in zip(ts, ts[1:]))  # strictly increasing
    a = PoissonBurstScenario(in_shape=SHAPE, n_requests=3, seed=5).requests()
    b = PoissonBurstScenario(in_shape=SHAPE, n_requests=3, seed=5).requests()
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.img, rb.img)


def test_burst_rate_modulation():
    s = PoissonBurstScenario(base_rps=50.0, burst_rps=800.0,
                             burst_every_s=0.1, burst_len_s=0.03)
    assert s.rate_at(0.01) == 800.0  # inside the burst window
    assert s.rate_at(0.05) == 50.0  # between bursts
    assert s.rate_at(0.11) == 800.0  # the cycle repeats


def test_diurnal_dead_frac_profiles():
    step = DiurnalDriftScenario(dead_lo=0.5, dead_hi=0.0, drift="step",
                                t_drift=0.05)
    assert step.dead_frac_at(0.049) == 0.5
    assert step.dead_frac_at(0.05) == 0.0
    sine = DiurnalDriftScenario(dead_lo=0.1, dead_hi=0.7, drift="sine",
                                period_s=0.2)
    assert sine.dead_frac_at(0.0) == pytest.approx(0.1)
    assert sine.dead_frac_at(0.1) == pytest.approx(0.7)  # half period: peak
    assert sine.dead_frac_at(0.2) == pytest.approx(0.1)  # full cycle returns
    with pytest.raises(ValueError, match="drift"):
        DiurnalDriftScenario(drift="linear").dead_frac_at(0.0)


def test_scenario_constructor_validation():
    with pytest.raises(ValueError, match="one arrival per image"):
        ListScenario(imgs=(1, 2), arrivals=(0.0,))
    with pytest.raises(ValueError, match="swap_fn"):
        HotSwapScenario(in_shape=SHAPE)
    # ListScenario orders by arrival regardless of construction order
    s = ListScenario(imgs=("b", "a"), arrivals=(2.0, 1.0))
    assert [r.img for r in s.requests()] == ["a", "b"]
    assert s.streams() == ("",)


def test_replay_scenario_validates_clock_and_streams(params):
    eng = _engine(params)
    other = _engine(params)  # its own SimClock: not shared
    with pytest.raises(ValueError, match="ONE shared"):
        replay_scenario({"a": eng, "b": other},
                        ListScenario(imgs=(), arrivals=()))
    with pytest.raises(ValueError, match="SimClock"):
        replay_scenario({"a": _Fake()},  # wall clock: not replayable
                        ListScenario(imgs=(), arrivals=()))
    scn = ListScenario(imgs=(synth_image(SHAPE, 1, 0),), arrivals=(0.0,),
                       stream="ghost")
    with pytest.raises(ValueError, match="ghost"):
        replay_scenario(eng, scn)


class _Fake:
    """Engine stand-in whose clock is the (non-Sim) wall clock."""

    def __init__(self):
        import time

        self.clock = time.monotonic


# ---------------------------------------------------------------------------
# replay driver: wrapper equivalence + bit-identical determinism
# ---------------------------------------------------------------------------


def test_replay_stream_is_thin_wrapper_over_replay_scenario(params):
    """The steady-rate stream is the degenerate ListScenario: both drivers
    must produce identical results AND identical telemetry."""
    imgs = [synth_image(SHAPE, 3, i) for i in range(8)]
    rate = 300.0
    e1, e2 = _engine(params), _engine(params)
    r1 = replay_stream(e1, imgs, rate_rps=rate)
    arrivals = tuple(i / rate for i in range(len(imgs)))
    r2 = replay_scenario(e2, ListScenario(imgs=tuple(imgs),
                                          arrivals=arrivals))[""]
    assert [r.id for r in r1] == [r.id for r in r2]
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.logits, b.logits)
        assert (a.t_arrival, a.t_formed, a.t_done) == \
            (b.t_arrival, b.t_formed, b.t_done)
    assert e1.stats()["telemetry"] == e2.stats()["telemetry"]


def test_seeded_replay_is_bit_identical_including_snapshot(params):
    """Two identical seeded replays on the deterministic service model are
    indistinguishable: logits bit-identical AND `snapshot()` == — what makes
    a BENCH_scenarios.json diff a regression signal instead of noise."""
    def run():
        eng = _engine(params)
        scn = DiurnalDriftScenario(in_shape=SHAPE, n_requests=16,
                                   rate_rps=200.0, dead_lo=0.5, dead_hi=0.0,
                                   drift="step", t_drift=0.04, seed=7)
        results = replay_scenario(eng, scn)[""]
        return results, eng.stats()

    r1, s1 = run()
    r2, s2 = run()
    assert [r.id for r in r1] == [r.id for r in r2]
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.logits, b.logits)
        assert a.latency_s == b.latency_s
    assert s1["telemetry"] == s2["telemetry"]
    assert s1["occ_ema"] == s2["occ_ema"]


# ---------------------------------------------------------------------------
# the regime contracts
# ---------------------------------------------------------------------------


def test_diurnal_drift_replans_within_k_batches_to_reference_plan(params):
    """The tentpole contract: an engine planned at the dead_lo regime whose
    traffic steps to dead_hi must (a) trigger a re-plan within K executed
    batches of the drift onset and (b) land on the SAME schedule
    `plan_network` picks when calibrated at the drifted occupancy."""
    eng = _engine(params)
    key_before = plan_key(0, eng.plan)
    scn = DiurnalDriftScenario(in_shape=SHAPE, n_requests=24, rate_rps=200.0,
                               dead_lo=0.5, dead_hi=0.0, drift="step",
                               t_drift=0.03, seed=5)
    replay_scenario(eng, scn)
    st = eng.stats()
    assert st["replans"] >= 1
    tel = st["telemetry"]
    triggers = [e for e in tel["replan_events"] if e["kind"] == "trigger"]
    swaps = [e for e in tel["replan_events"]
             if e["kind"] == "swap" and e["changed"]]
    assert triggers and swaps
    assert triggers[0]["delta"] > eng.replan_band
    # (a) within K batches: occ_timeline has one row per executed batch
    k = sum(1 for t, _ in tel["occ_timeline"]
            if scn.t_drift <= t <= triggers[0]["t"])
    assert 1 <= k <= 4, f"re-plan took {k} post-drift batches"
    # (b) the adopted schedule is the one planning at the drifted occupancy
    # would pick (and it really is a different schedule than dead_lo's)
    drifted_calib = jnp.stack([
        synth_image(SHAPE, scn.seed, i, scn.dead_hi) for i in range(20, 24)])
    ref = plan_network(params, drifted_calib, eng.graph,
                       occ_threshold=eng.plan.occ_threshold,
                       block_c=eng.plan.block_c, use_pallas=eng.use_pallas)
    assert plan_key(0, eng.plan) == plan_key(0, ref)
    assert plan_key(0, eng.plan) != key_before


def test_burst_never_strands_requests(params):
    """A burst queues several full buckets at once; every request must still
    be served exactly once, and its formation wait is bounded by the deadline
    plus the service time of the buckets executed between its arrival and its
    formation (the backlog it legitimately queued behind) — never by the next
    arrival (the stranding failure the drain-every-due-bucket loop prevents)."""
    eng = _engine(params)
    scn = PoissonBurstScenario(in_shape=SHAPE, n_requests=24, base_rps=50.0,
                               burst_rps=2000.0, burst_every_s=0.08,
                               burst_len_s=0.03, seed=11)
    results = replay_scenario(eng, scn)[""]
    assert sorted(r.id for r in results) == list(range(24))  # none lost/dup
    batch_times = sorted({r.t_formed for r in results})
    for r in results:
        backlog = sum(1 for t in batch_times if r.t_arrival < t < r.t_formed)
        bound = eng.batcher.deadline_s + backlog * SERVICE_S + 1e-9
        assert r.t_formed - r.t_arrival <= bound, (
            f"request {r.id} waited {r.t_formed - r.t_arrival:.4f}s "
            f"(bound {bound:.4f}s, backlog {backlog})")
    # the burst actually coalesced: at least one full bucket formed
    assert max(eng.metrics.bucket_counts) == eng.batcher.max_batch


def test_multi_tenant_shared_cache_never_cross_contaminates(params):
    """Two models interleaved over ONE PlanCache: compiles bounded by the
    distinct PlanKeys (warmup only — steady streams add none), and every
    tenant's logits are bit-identical to ITS OWN model's run_plan reference."""
    from repro.configs.lenet import LENET_REDUCED
    from repro.graph import init_graph

    clock = SimClock()
    cache = PlanCache(max_entries=32)
    eng_vgg = _engine(params, clock=clock, cache=cache)
    lenet_graph = LENET_REDUCED
    lenet_params = init_graph(jax.random.PRNGKey(1), lenet_graph)
    lenet_calib = jnp.stack([synth_image(lenet_graph.in_shape, 901, i, 0.5)
                             for i in range(2)])
    eng_lenet = Engine(lenet_params, graph=lenet_graph, calib=lenet_calib,
                       occ_threshold=0.9, block_c=8, max_batch=4,
                       deadline_s=0.005, clock=clock, cache=cache,
                       sim_service_s=SERVICE_S, ema_alpha=0.5,
                       replan_band=0.15, replan_cooldown=0)
    engines = {"vgg": eng_vgg, "lenet": eng_lenet}
    warm = sum(e.warmup() for e in engines.values())
    assert warm == cache.compiles == len(cache)  # all keys distinct: no alias
    scn = MultiTenantScenario(tenants=(
        ("vgg", TenantSpec(in_shape=SHAPE, n_requests=6, rate_rps=100.0,
                           dead_frac=0.5)),
        ("lenet", TenantSpec(in_shape=lenet_graph.in_shape, n_requests=6,
                             rate_rps=100.0, dead_frac=0.5))), seed=13)
    results = replay_scenario(engines, scn)
    assert cache.compiles == warm  # shared cache: zero stream compiles
    for stream, eng in engines.items():
        assert eng.stats()["replans"] == 0  # steady regime: no drift
        tenant_reqs = [r for r in scn.requests() if r.stream == stream]
        ref = np.asarray(run_plan(eng.plan, eng.params,
                                  jnp.stack([r.img for r in tenant_reqs])))
        got = {r.id: r.logits for r in results[stream]}
        assert sorted(got) == list(range(len(tenant_reqs)))
        for i in range(len(tenant_reqs)):  # ids are per-engine submission order
            np.testing.assert_array_equal(got[i], ref[i])


def test_hot_swap_under_load_is_atomic(params):
    """Mid-stream swap to a BSR-pruned variant: every request completed
    before the swap carries the OLD model's exact logits, every one after
    carries the NEW model's, no bucket mixes the two, and both variants'
    programs end up resident in one cache."""
    from repro.sparse_weights import prune_graph_params

    eng = _engine(params)
    plan_old, params_old = eng.plan, eng.params
    pruned, report = prune_graph_params(params, 0.3, eng.graph)
    assert report.density <= 0.5  # the swap is a genuinely different model
    plan_new = plan_network(pruned, jnp.stack(
        [synth_image(SHAPE, 900, i, 0.5) for i in range(2)]), eng.graph,
        occ_threshold=eng.plan.occ_threshold, block_c=eng.plan.block_c,
        use_pallas=eng.use_pallas)

    def swap(engines):
        engines[""].hot_swap(pruned, plan=plan_new)

    n = 16
    scn = HotSwapScenario(in_shape=SHAPE, n_requests=n, rate_rps=200.0,
                          t_swap=0.04, swap_fn=swap, seed=17)
    results = replay_scenario(eng, scn)[""]
    assert sorted(r.id for r in results) == list(range(n))
    st = eng.stats()
    assert st["hot_swaps"] == 1 and st["plan_bsr"] >= 1
    swap_t = [e for e in st["telemetry"]["replan_events"]
              if e["kind"] == "hot_swap"][0]["t"]
    imgs = jnp.stack([r.img for r in scn.requests()])  # id == arrival order
    ref_old = np.asarray(run_plan(plan_old, params_old, imgs))
    ref_new = np.asarray(run_plan(plan_new, pruned, imgs))
    pre = [r for r in results if r.t_done <= swap_t]
    post = [r for r in results if r.t_done > swap_t]
    assert pre and post, "t_swap must land mid-stream to test atomicity"
    for r in pre:
        np.testing.assert_array_equal(r.logits, ref_old[r.id])
    for r in post:
        np.testing.assert_array_equal(r.logits, ref_new[r.id])
    # both variants' programs coexist: the pruned plan's keys are new entries
    assert plan_key(0, plan_new) != plan_key(0, plan_old)


def test_hot_swap_before_first_batch_requires_calib(params):
    eng = _engine(params)
    with pytest.raises(ValueError, match="hot_swap"):
        eng.hot_swap(params)  # no executed batch yet: no recent calib
    calib = jnp.stack([synth_image(SHAPE, 900, i, 0.5) for i in range(2)])
    eng.hot_swap(params, calib=calib)
    assert eng.n_hot_swaps == 1
    assert eng.stats()["telemetry"]["replans"]["hot_swaps"] == 1
