"""Per-arch smoke tests + the decode-vs-teacher-forcing equivalence checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as M

KEY = jax.random.PRNGKey(0)
ARCHS = [a for a in list_archs() if a != "vgg19-sparse"]


def _batch(cfg, b, s, with_labels=True):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size, jnp.int32)}
    if with_labels:
        batch["labels"] = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                             cfg.vocab_size, jnp.int32)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(KEY, (b, cfg.n_image_tokens, cfg.d_model)) * 0.02
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(KEY, (b, s, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shape + no-NaN asserts."""
    cfg = get_config(arch, reduced=True)
    params, _ = M.init_params(cfg, KEY)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    logits, _, aux = M.forward(cfg, params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss, grads = jax.value_and_grad(lambda p: M.lm_loss(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "deepseek-v2-236b", "jamba-v0.1-52b",
                                  "xlstm-125m", "whisper-tiny"])
def test_decode_matches_teacher_forcing(arch):
    """prefill+decode token-by-token must reproduce the full forward logits —
    the strongest correctness check of every cache path (KV, MLA latent,
    mamba/xlstm recurrent state)."""
    import dataclasses

    cfg = get_config(arch, reduced=True)
    if cfg.n_experts:
        # capacity DROPS are batch-composition-dependent (GShard semantics), so
        # exact decode==teacher-forcing equivalence requires no-drop capacity.
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params, _ = M.init_params(cfg, KEY)
    b, s = 2, 12
    batch = _batch(cfg, b, s, with_labels=False)
    full_logits, _, _ = M.forward(cfg, params, batch)

    caches, _ = M.init_cache(cfg, b, s + 4, jnp.float32)
    pre_len = 5
    pre = {k: (v[:, :pre_len] if k == "tokens" else v) for k, v in batch.items()}
    logits_p, caches = M.prefill(cfg, params, caches, pre)
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(full_logits[:, :pre_len], np.float32),
                               rtol=2e-3, atol=2e-3)
    # token-by-token decode for the rest
    if cfg.is_encoder_decoder:
        # reproduce encoder output once (frames path)
        from repro.models.layers import rms_norm, sinusoid_positions
        from repro.models.model import AUDIO_ENC_LAYOUT
        from repro.models.transformer import stack_apply
        fr = batch["frames"]
        pe = sinusoid_positions(fr.shape[1], cfg.d_model, fr.dtype)
        enc_pos = jnp.broadcast_to(jnp.arange(fr.shape[1])[None], fr.shape[:2])
        enc_out, _, _ = stack_apply(params["enc_groups"], fr + pe[None], cfg=cfg,
                                    positions=enc_pos, causal=False, layout=AUDIO_ENC_LAYOUT)
        enc_out = rms_norm(enc_out, params["enc_norm"], cfg.norm_eps)
    for t in range(pre_len, s):
        dec = {"tokens": batch["tokens"][:, t : t + 1]}
        if cfg.family == "vlm":
            dec["img_embeds"] = batch["img_embeds"]
        if cfg.is_encoder_decoder:
            dec["enc_out"] = enc_out
        lg, caches = M.decode_step(cfg, params, caches, dec, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=3e-3, atol=3e-3,
        )


@pytest.mark.parametrize("arch", ARCHS)
def test_param_counts_match_spec(arch):
    """Full configs land near the advertised sizes (sanity of the model math)."""
    spec_sizes = {
        "stablelm-12b": 12e9, "mistral-large-123b": 123e9, "minitron-8b": 8e9,
        "qwen3-0.6b": 0.6e9, "xlstm-125m": 0.125e9, "arctic-480b": 480e9,
        "deepseek-v2-236b": 236e9, "jamba-v0.1-52b": 52e9,
        "llama-3.2-vision-90b": 90e9, "whisper-tiny": 0.039e9,
    }
    cfg = get_config(arch)
    n = M.count_params_analytic(cfg)
    target = spec_sizes[arch]
    assert 0.55 * target <= n <= 1.45 * target, (arch, n, target)


def test_long_context_flags():
    assert get_config("xlstm-125m").supports_long_context
    assert get_config("jamba-v0.1-52b").supports_long_context
    assert not get_config("mistral-large-123b").supports_long_context
