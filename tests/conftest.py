import os
import sys

# tests must see ONE device (the dry-run sets 512 in its own process only)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make tests/_hypothesis_compat.py importable regardless of pytest import mode
sys.path.insert(0, os.path.dirname(__file__))
# repo root: the benchmark harness (`import benchmarks`) is under test too
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

jax.config.update("jax_enable_x64", False)
