import os
import subprocess
import sys
import textwrap

import pytest

# tests must see ONE device (the dry-run sets 512 in its own process only)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, SRC)
# make tests/_hypothesis_compat.py importable regardless of pytest import mode
sys.path.insert(0, os.path.dirname(__file__))
# repo root: the benchmark harness (`import benchmarks`) is under test too
sys.path.insert(0, ROOT)

import jax

jax.config.update("jax_enable_x64", False)


# the `sharding` marker is registered once, in pyproject.toml
# [tool.pytest.ini_options] markers


@pytest.fixture
def virtual_devices():
    """Runner executing python code under N virtual CPU devices
    (`XLA_FLAGS=--xla_force_host_platform_device_count=N`). The flag only
    takes effect before jax initializes, so the code runs in a fresh
    subprocess with PYTHONPATH covering src/ and the repo root; stdout is
    returned for assertions. Shared by the sharded-serving tests
    (tests/test_serving_sharded.py) and anything else marked `sharding`."""

    def run(code: str, n: int = 4, timeout: int = 420) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={n}").strip()
        env["PYTHONPATH"] = os.pathsep.join([os.path.abspath(SRC),
                                             os.path.abspath(ROOT)])
        r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                           capture_output=True, text=True, timeout=timeout,
                           env=env)
        assert r.returncode == 0, \
            f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
        return r.stdout

    return run
