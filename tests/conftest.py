import os
import sys

# tests must see ONE device (the dry-run sets 512 in its own process only)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
