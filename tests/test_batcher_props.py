"""Property-based MicroBatcher invariants (via tests/_hypothesis_compat.py —
real hypothesis when installed, the deterministic seeded stand-in otherwise).

Each property draws a seed and derives a batcher config plus an arbitrary
interleaving of submit / clock-advance / ready / flush operations from one
`random.Random(seed)` — the invariants must hold on EVERY interleaving, not
just the arrival patterns the example-based tests in test_serving.py script:

- conservation: no request is ever lost or duplicated across any interleaving;
- every formed batch respects the bucket discipline (n_real <= bucket <=
  max_batch, bucket in exec_buckets(), align-multiple, per-device slice >=
  the post-clamp min_bucket floor);
- ready() fires exactly when due (full bucket or oldest past deadline) and
  never otherwise;
- a driver that polls by next_deadline() never lets a request wait in the
  queue longer than deadline_s (the engine/replay contract).
"""
import random

from _hypothesis_compat import given, settings, st

from repro.serving import MicroBatcher, SimClock


def _config(rng):
    """A random VALID batcher config (invalid combos raise — pinned by
    test_batcher_align_device_slices — so the properties only draw configs
    that construct)."""
    align = rng.choice([1, 2, 4])
    max_batch = align * rng.randint(1, 8)
    min_bucket = rng.randint(1, 3)
    if align > 1 and max_batch // align < min_bucket:
        min_bucket = max_batch // align  # keep the floor satisfiable
    return dict(max_batch=max_batch, align=align, min_bucket=min_bucket,
                deadline_s=rng.choice([0.001, 0.005, 0.02]))


def _check_bucket(b, batch):
    assert 1 <= batch.n_real <= batch.bucket <= b.max_batch
    assert batch.bucket in b.exec_buckets()
    assert batch.bucket % b.align == 0
    # b.min_bucket is the POST-clamp floor (construction clamps max_batch=1
    # style configs); the per-device slice never goes below it
    assert batch.bucket // b.align >= b.min_bucket


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_no_request_lost_or_duplicated(seed):
    """Conservation across an arbitrary submit/advance/ready/flush
    interleaving: every submitted id comes back in exactly one batch."""
    rng = random.Random(seed)
    clock = SimClock()
    b = MicroBatcher(clock=clock, **_config(rng))
    submitted, formed = [], []
    for _ in range(rng.randint(1, 80)):
        op = rng.random()
        if op < 0.55:
            submitted.append(b.submit(object()))
        elif op < 0.75:
            clock.advance(rng.uniform(0.0, 0.01))
            batch = b.ready()
            if batch is not None:
                _check_bucket(b, batch)
                formed.append(batch)
        elif op < 0.9:
            batch = b.flush()
            if batch is not None:
                _check_bucket(b, batch)
                formed.append(batch)
        else:
            clock.advance(rng.uniform(0.0, 0.03))
    while b.pending():
        batch = b.flush()
        _check_bucket(b, batch)
        formed.append(batch)
    served = [r.id for batch in formed for r in batch.requests]
    assert sorted(served) == submitted  # ids are submission-ordered + unique
    assert len(served) == len(set(served))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_ready_fires_exactly_when_due(seed):
    """ready() forms a batch iff a full max_batch bucket is queued or the
    OLDEST request's deadline has passed — and never on a quiet queue."""
    rng = random.Random(seed)
    clock = SimClock()
    cfg = _config(rng)
    b = MicroBatcher(clock=clock, **cfg)
    oldest = []  # shadow arrival queue, in order
    for _ in range(rng.randint(1, 80)):
        if rng.random() < 0.5:
            b.submit(object())
            oldest.append(clock())
        else:
            clock.advance(rng.uniform(0.0, 0.012))
        queued = len(oldest)
        due = queued >= cfg["max_batch"] or (
            queued > 0 and clock() >= oldest[0] + cfg["deadline_s"])
        batch = b.ready()
        if due:
            assert batch is not None
            _check_bucket(b, batch)
            del oldest[:batch.n_real]
        else:
            assert batch is None
    assert b.pending() == len(oldest)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_deadline_contract_under_driver_polling(seed):
    """A driver that polls ready() no later than next_deadline() (the
    engine/replay_stream discipline) bounds EVERY request's queue wait by
    deadline_s, for arbitrary seeded arrival patterns."""
    rng = random.Random(seed)
    clock = SimClock()
    cfg = _config(rng)
    b = MicroBatcher(clock=clock, **cfg)
    arrivals = []
    t = 0.0
    for _ in range(rng.randint(1, 60)):
        t += rng.uniform(0.0, cfg["deadline_s"] * 2)
        arrivals.append(t)
    formed = []
    i = 0
    while i < len(arrivals) or b.pending():
        cands = [c for c in (b.next_deadline(),
                             arrivals[i] if i < len(arrivals) else None)
                 if c is not None]
        clock.set(min(cands))
        while i < len(arrivals) and arrivals[i] <= clock():
            b.submit(object(), now=arrivals[i])
            i += 1
        batch = b.ready()
        while batch is not None:  # a burst can leave several due buckets
            formed.append(batch)
            batch = b.ready()
    served = 0
    for batch in formed:
        _check_bucket(b, batch)
        for r in batch.requests:
            served += 1
            assert batch.t_formed - r.t_arrival <= cfg["deadline_s"] + 1e-9, (
                f"request waited {batch.t_formed - r.t_arrival:.5f}s with "
                f"deadline {cfg['deadline_s']}s (seed {seed})")
    assert served == len(arrivals)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_flush_drains_everything_in_bounded_batches(seed):
    """flush() repeated to exhaustion drains the whole queue in batches of at
    most max_batch, preserving submission order across batches."""
    rng = random.Random(seed)
    b = MicroBatcher(clock=SimClock(), **_config(rng))
    n = rng.randint(0, 40)
    ids = [b.submit(object()) for _ in range(n)]
    out = []
    while b.pending():
        batch = b.flush()
        _check_bucket(b, batch)
        out.extend(r.id for r in batch.requests)
    assert out == ids  # FIFO order survives arbitrary batch boundaries
    assert b.flush() is None  # empty queue: no phantom batch
