"""int8 quantized kernel family (DESIGN.md §10): absmax quantization bounds,
kernel-vs-ref agreement (tight — int32 accumulation is exact, so the Pallas
kernel and the plain-JAX quantized oracle compute the SAME math), ref-vs-fp32
accuracy (the error the budget governs), and the planner's probe-gated int8
placement with demotion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg19_sparse import CNNConfig, vgg19_graph
from repro.core import dead_channel_band, synth_feature_map
from repro.graph import init_graph
from repro.graph.registry import get_op
from repro.kernels.ecr_conv.ops import ecr_conv
from repro.models.cnn import shift_dead_channels
from repro.pipeline import plan_network, run_plan
from repro.quant import (
    absmax_scale,
    conv2d_bsr_int8,
    conv2d_bsr_int8_ref,
    dequantize_int8,
    ecr_conv_int8,
    ecr_conv_int8_ref,
    quantize_int8,
    quantize_weights,
)
from repro.sparse_weights import prune_graph_params


def _fm(shape, sparsity, seed=0):
    return synth_feature_map(jax.random.PRNGKey(seed), shape, sparsity)


# ---------------------------------------------------------------------------
# quantization primitives
# ---------------------------------------------------------------------------


def test_absmax_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 3.0
    s = absmax_scale(x)
    xq = quantize_int8(x, s)
    assert xq.dtype == jnp.int8
    # symmetric absmax: |x - dq(q(x))| <= scale/2, and the max hits +-127
    err = jnp.abs(dequantize_int8(xq, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-7
    assert int(jnp.abs(xq).max()) == 127


def test_zero_maps_to_zero_exactly():
    # load-bearing for sparsity: a dead channel must quantize to exact zeros
    # so the (ids, cnt) schedules still skip it
    x = jnp.zeros((4, 6, 6)).at[0].set(1.0)
    s = absmax_scale(x)
    xq = quantize_int8(x, s)
    assert int(jnp.abs(xq[1:]).sum()) == 0
    assert float(jnp.abs(dequantize_int8(xq, s)[1:]).sum()) == 0.0


def test_quantize_weights_per_output_channel():
    w = jax.random.normal(jax.random.PRNGKey(1), (6, 4, 3, 3))
    w = w.at[3].multiply(100.0)  # one huge channel must not crush the others
    wq, sw = quantize_weights(w)
    assert sw.shape == (6,)
    for i in range(6):
        np.testing.assert_allclose(
            np.asarray(dequantize_int8(wq[i], sw[i])), np.asarray(w[i]),
            atol=float(sw[i]) / 2 + 1e-7)


# ---------------------------------------------------------------------------
# kernel vs quantized oracle: tight; oracle vs fp32: the accuracy budget
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", [0.0, 0.5, 1.0])
def test_ecr_int8_kernel_matches_ref(sparsity):
    x = _fm((16, 12, 12), sparsity)
    k = jax.random.normal(jax.random.PRNGKey(2), (24, 16, 3, 3))
    out = ecr_conv_int8(x, k)
    ref = ecr_conv_int8_ref(x, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_ecr_int8_batched_matches_ref():
    x = jnp.stack([_fm((16, 12, 12), 0.5, seed=s) for s in range(3)])
    k = jax.random.normal(jax.random.PRNGKey(3), (24, 16, 3, 3))
    out = ecr_conv_int8(x, k)
    ref = ecr_conv_int8_ref(x, k)
    assert out.shape == (3, 24, 10, 10)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_ecr_int8_tile_override_matches_ref():
    x = _fm((16, 12, 12), 0.5, seed=4)
    k = jax.random.normal(jax.random.PRNGKey(5), (24, 16, 3, 3))
    out = ecr_conv_int8(x, k, block_c=12, block_o=8)
    ref = ecr_conv_int8_ref(x, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_ecr_int8_vs_fp32_tolerance():
    x = _fm((16, 12, 12), 0.5, seed=6)
    k = jax.random.normal(jax.random.PRNGKey(7), (24, 16, 3, 3))
    q = ecr_conv_int8(x, k)
    f = ecr_conv(x, k)
    # ~1% of the output scale: 8-bit operands, per-channel weight scales
    scale = float(jnp.abs(f).max())
    assert float(jnp.abs(q - f).max()) <= 0.05 * scale


def test_bsr_int8_kernel_matches_ref_and_fp32():
    w = jax.random.normal(jax.random.PRNGKey(8), (24, 16, 3, 3))
    x = _fm((16, 12, 12), 0.3, seed=9)
    out = conv2d_bsr_int8(x, w)
    ref = conv2d_bsr_int8_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    from repro.sparse_weights import conv2d_bsr_ref

    f = conv2d_bsr_ref(x, w)
    assert float(jnp.abs(out - f).max()) <= 0.05 * float(jnp.abs(f).max())


def test_bsr_int8_batched():
    w = jax.random.normal(jax.random.PRNGKey(10), (24, 16, 3, 3))
    x = jnp.stack([_fm((16, 12, 12), 0.3, seed=s) for s in range(2)])
    out = conv2d_bsr_int8(x, w)
    ref = conv2d_bsr_int8_ref(x, w)
    assert out.shape == (2, 24, 10, 10)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# registry + planner: the precision axis
# ---------------------------------------------------------------------------

TINY2 = CNNConfig(name="vgg-quant-tiny", in_channels=16, img_size=12,
                  plan=((8, 2),), n_classes=4)


@pytest.fixture(scope="module")
def graph():
    return vgg19_graph(TINY2)


@pytest.fixture(scope="module")
def params(graph):
    return shift_dead_channels(init_graph(jax.random.PRNGKey(0), graph))


@pytest.fixture(scope="module")
def calib(graph):
    c, h, w = graph.in_shape
    return dead_channel_band(
        jax.random.uniform(jax.random.PRNGKey(1), (2, c, h, w)), 0.5)


def test_int8_impls_registered_quantized():
    assert get_op("conv", "ecr_int8").quantized
    assert get_op("conv", "ecr_int8").sparse
    assert get_op("conv", "bsr_int8").quantized
    assert get_op("conv", "bsr_int8").weight_sparse
    assert not get_op("conv", "ecr_pallas").quantized


def test_plan_network_int8_off_is_unchanged(graph, params, calib):
    base = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8)
    assert base.int8_report is None
    assert all(not get_op(lp.kind, lp.impl).quantized for lp in base.layers)


def test_plan_network_int8_upgrade_and_probe(graph, params, calib):
    base = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8)
    assert base.layers[0].impl == "ecr_pallas"  # in-stage conv: unfusable
    p8 = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8,
                      int8=True)
    rep = p8.int8_report
    assert rep is not None
    assert 0 in rep.layers and rep.demoted == ()
    assert p8.layers[0].impl == "ecr_int8"
    assert p8.counts()["int8"] == len(rep.layers)
    assert rep.top1_agreement >= 0.98  # the default budget held
    # the probe's recorded drift is real: re-check against the fp32 plan
    lb = run_plan(base, params, calib)
    l8 = run_plan(p8, params, calib)
    drift = float(jnp.abs(lb - l8).max())
    assert 0 < drift <= rep.max_logit_drift + 1e-6


def test_plan_network_int8_demotes_to_meet_budget(graph, params, calib):
    # budget > 1.0 is unreachable with ANY drift -> every upgrade demotes
    # and the plan is fp32-exact again
    p = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8,
                     int8=True, int8_budget=1.1)
    rep = p.int8_report
    assert rep.layers == () and len(rep.demoted) >= 1
    assert all(not get_op(lp.kind, lp.impl).quantized for lp in p.layers)
    base = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8)
    assert jnp.array_equal(run_plan(p, params, calib),
                           run_plan(base, params, calib))


def test_plan_network_bsr_int8_on_pruned(graph, params, calib):
    pruned, _ = prune_graph_params(params, 0.3, graph)
    pb = plan_network(pruned, calib, graph, occ_threshold=0.75, block_c=8)
    assert any(lp.impl == "bsr" for lp in pb.layers)
    pq = plan_network(pruned, calib, graph, occ_threshold=0.75, block_c=8,
                      int8=True)
    assert any(lp.impl == "bsr_int8" for lp in pq.layers)
    # int8 counts in its own bucket AND the bsr family's
    c = pq.counts()
    assert c["int8"] >= 1 and c["bsr"] >= c["int8"]
    lb = run_plan(pb, pruned, calib)
    lq = run_plan(pq, pruned, calib)
    assert float(jnp.abs(lb - lq).max()) <= \
        pq.int8_report.max_logit_drift + 1e-6


def test_int8_cost_hooks_price_below_fp32():
    from repro.graph.registry import unit_model_us

    g = vgg19_graph(TINY2)
    u = list(g.units())[0]
    for fp, q in [(("conv", "ecr_pallas"), ("conv", "ecr_int8")),
                  (("conv", "bsr"), ("conv", "bsr_int8"))]:
        f = unit_model_us(*fp, u, occupancy=0.5, weight_density=0.5, batch=2)
        i8 = unit_model_us(*q, u, occupancy=0.5, weight_density=0.5, batch=2)
        assert i8 < f
