"""Observability subsystem (DESIGN.md §9): tracer span nesting/ordering and
bit-identical SimClock replays, Chrome trace_event schema validity, the
NullTracer zero-overhead contract, the shared timing harness's outlier
rejection, CalibrationDB fit/lookup/persistence, and the planner-facing
calibration contract — an empty DB plans bit-identically to no calibration,
a populated one can flip a layer's impl choice."""
import json
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs.vgg19_sparse import CNNConfig, vgg19_graph
from repro.core import dead_channel_band
from repro.graph import init_graph
from repro.models.cnn import shift_dead_channels
from repro.obs import (
    DEFAULT_ROOFLINE,
    NULL_TRACER,
    CalibEntry,
    CalibrationDB,
    LayerTiming,
    ProfileReport,
    Tracer,
    profile_plan,
    time_callable,
)
from repro.obs.calibrate import device_kind
from repro.pipeline import plan_network
from repro.serving import Engine, SimClock, plan_key, replay_stream

TINY = CNNConfig(name="vgg-obs-tiny", in_channels=16, img_size=12,
                 plan=((8, 1), (16, 1)), n_classes=4)


@pytest.fixture(scope="module")
def graph():
    return vgg19_graph(TINY)


@pytest.fixture(scope="module")
def params(graph):
    return shift_dead_channels(init_graph(jax.random.PRNGKey(0), graph))


@pytest.fixture(scope="module")
def calib(graph):
    c, h, w = graph.in_shape
    return dead_channel_band(
        jax.random.uniform(jax.random.PRNGKey(1), (2, c, h, w)), 0.5)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_exit_order():
    clock = SimClock()
    tr = Tracer(clock=clock)
    with tr.span("outer", a=1):
        clock.advance(0.001)
        with tr.span("inner"):
            clock.advance(0.002)
        clock.advance(0.003)
    # events land in span-EXIT order: inner closes first
    assert [e["name"] for e in tr.events] == ["inner", "outer"]
    inner, outer = tr.events
    assert inner["args"]["depth"] == 1 and outer["args"]["depth"] == 0
    assert outer["args"]["a"] == 1
    # the inner interval is contained in the outer one (ts/dur in us)
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-9
    assert inner["dur"] == pytest.approx(2000.0)
    assert outer["dur"] == pytest.approx(6000.0)


def test_span_annotate_and_error_visibility():
    tr = Tracer(clock=SimClock())
    with pytest.raises(RuntimeError):
        with tr.span("batch") as sp:
            sp.annotate(fill=0.75)
            raise RuntimeError("boom")
    (e,) = tr.events
    assert e["args"]["fill"] == 0.75
    assert e["args"]["error"] == "RuntimeError"  # crashed span stays visible


def test_instants_and_counters_record():
    clock = SimClock()
    tr = Tracer(clock=clock)
    tr.instant("hot_swap", variant="pruned")
    tr.counter("occ_ema", 0.625)
    phs = [e["ph"] for e in tr.events]
    assert phs == ["i", "C"]
    assert tr.events[0]["args"]["variant"] == "pruned"
    assert tr.events[1]["args"]["occ_ema"] == 0.625


def _scripted_trace() -> bytes:
    clock = SimClock()
    tr = Tracer(clock=clock)
    with tr.span("plan", graph="g"):
        clock.advance(0.004)
    for b in (2, 4):
        with tr.span("execute_batch", bucket=b):
            clock.advance(0.001 * b)
    tr.instant("swap")
    return json.dumps(tr.chrome_trace(), sort_keys=True).encode()


def test_simclock_replay_bit_identical():
    assert _scripted_trace() == _scripted_trace()


def test_chrome_trace_schema():
    clock = SimClock()
    tr = Tracer(clock=clock)
    with tr.span("a"):
        clock.advance(0.001)
        tr.instant("mark")
    payload = tr.chrome_trace()
    assert payload["displayTimeUnit"] == "ms"
    assert json.loads(json.dumps(payload)) == payload  # JSON-serializable
    for e in payload["traceEvents"]:
        assert set(e) >= {"name", "ph", "ts", "pid", "tid", "args"}
        assert e["ph"] in ("X", "i", "C")
        assert e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        if e["ph"] == "i":
            assert e["s"] == "t"


def test_logical_tids_not_os_idents():
    import threading

    tr = Tracer(clock=SimClock())
    with tr.span("main"):
        pass

    def worker():
        with tr.span("bg"):
            pass

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    by_name = {e["name"]: e for e in tr.events}
    assert by_name["main"]["tid"] == 0  # first-span order, not get_ident()
    assert by_name["bg"]["tid"] == 1


def test_null_tracer_zero_overhead():
    s1 = NULL_TRACER.span("a", x=1)
    s2 = NULL_TRACER.span("b")
    assert s1 is s2  # one shared no-op object, no per-span allocation
    with s1:
        pass
    NULL_TRACER.instant("i")
    assert NULL_TRACER.events == ()
    assert NULL_TRACER.chrome_trace() == {"traceEvents": [],
                                          "displayTimeUnit": "ms"}
    with pytest.raises(ValueError):
        NULL_TRACER.save("/tmp/never.json")


# ---------------------------------------------------------------------------
# timing harness
# ---------------------------------------------------------------------------

def test_time_callable_outlier_rejection():
    sleeps = iter([0.0, 0.002, 0.002, 0.080, 0.002, 0.002])  # warmup + 5

    def f():
        time.sleep(next(sleeps))
        return 0

    t = time_callable(f, iters=5, warmup=1, outlier_tol=2.0)
    assert t.rejected >= 1  # the 80ms spike is dropped ...
    assert t.median_us < 40_000.0  # ... and cannot drag the median
    assert len(t.samples_us) == 5  # raw samples are all kept for inspection


def test_time_callable_no_rejection_by_default():
    t = time_callable(lambda: 0, iters=3, warmup=0)
    assert t.rejected == 0 and len(t.samples_us) == 3


# ---------------------------------------------------------------------------
# calibration DB
# ---------------------------------------------------------------------------

def _timing(index, kind, impl, measured, predicted, block_c=8):
    return LayerTiming(index=index, kind=kind, impl=impl, occupancy=0.5,
                       weight_density=1.0, batch=2, block_c=block_c,
                       measured_us=measured, spread=0.0,
                       predicted_us=predicted, flops=1e6, bytes=1e4)


def test_calibration_fit_and_lookup():
    report = ProfileReport(
        graph_name="g", device_kind="testdev", batch=2, block_c=8,
        timings=(
            _timing(0, "conv", "dense", measured=100.0, predicted=10.0),
            _timing(1, "conv", "dense", measured=200.0, predicted=20.0),
            _timing(0, "conv", "ecr_pallas", measured=1000.0, predicted=10.0),
        ))
    db = CalibrationDB.from_report(report)
    # dense: ratio 0.1 on both layers -> scale 0.1
    c = db.lookup("conv", "dense", 8, device="testdev")
    assert c.peak_flops == pytest.approx(DEFAULT_ROOFLINE.peak_flops * 0.1)
    assert c.hbm_bw == pytest.approx(DEFAULT_ROOFLINE.hbm_bw * 0.1)
    # scaled constants predict the measured time for the fitted rows
    t = report.timings[0]
    assert c.time_us(t.flops, t.bytes) == pytest.approx(
        DEFAULT_ROOFLINE.time_us(t.flops, t.bytes) / 0.1)
    assert db.covers("conv", "ecr_pallas", 8, device="testdev")
    assert not db.covers("conv", "bsr", 8, device="testdev")
    # block_c fallback: an explicit geometry falls back to the bc=0 entry
    db.put("conv", "bsr", 0, CalibEntry(1e12, 1e9, 0.5, 1, 0.0),
           device="testdev")
    assert db.covers("conv", "bsr", 16, device="testdev")
    # device isolation: another device's fit is never consulted
    assert not db.covers("conv", "dense", 8, device="elsewhere")


def test_calibration_save_load_roundtrip(tmp_path):
    db = CalibrationDB(device="testdev")
    db.put("conv", "dense", 8, CalibEntry(1e12, 2e9, 0.25, 3, 0.1),
           device="testdev")
    path = db.save(str(tmp_path / "calib.json"))
    back = CalibrationDB.load(path)
    assert back.device == "testdev"
    assert back.entries == db.entries
    with pytest.raises(ValueError):  # schema guard
        (tmp_path / "bad.json").write_text('{"schema": "other"}')
        CalibrationDB.load(str(tmp_path / "bad.json"))


def test_empty_db_is_falsy_and_defaults():
    db = CalibrationDB(device="testdev")
    assert not db and len(db) == 0
    assert db.constants_for("conv", "dense", 8) is DEFAULT_ROOFLINE


def test_report_agreement_and_recalibration():
    # model says ecr is faster; the clock says dense is: top1 = 0 before
    # calibration, 1 after (the fitted per-impl scales reorder the pair)
    report = ProfileReport(
        graph_name="g", device_kind="testdev", batch=2, block_c=8,
        timings=(
            _timing(0, "conv", "dense", measured=100.0, predicted=20.0),
            _timing(0, "conv", "ecr_pallas", measured=400.0, predicted=10.0),
        ))
    assert report.agreement()["top1"] == 0.0
    db = CalibrationDB.from_report(report)
    # recalibrated() needs the units to re-predict -> exercise the scales
    # directly: predicted/scale reproduces the measured ordering
    dense, ecr = report.timings
    s_dense = db.entries[("testdev", "conv", "dense", (8, 0, 0, 0, 0))].scale
    s_ecr = db.entries[("testdev", "conv", "ecr_pallas", (8, 0, 0, 0, 0))].scale
    assert dense.predicted_us / s_dense < ecr.predicted_us / s_ecr


# ---------------------------------------------------------------------------
# planner contract
# ---------------------------------------------------------------------------

def test_empty_db_plans_bit_identically(graph, params, calib):
    base = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8)
    empty = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8,
                         calibration=CalibrationDB())
    assert plan_key(2, empty) == plan_key(2, base)


def test_calibration_shift_flips_impl_choice(graph, params, calib):
    base = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8)
    n_sparse = base.counts()["sparse"]
    assert n_sparse >= 1  # the premise: default constants pick sparse layers
    # a DB fitted on THIS device saying the sparse kernels run at 1e-6 of
    # the roofline while dense runs at it: the occupancy-rule re-check must
    # flip those layers to dense
    dev = device_kind()
    db = CalibrationDB(device=dev)
    slow = CalibEntry(DEFAULT_ROOFLINE.peak_flops * 1e-6,
                      DEFAULT_ROOFLINE.hbm_bw * 1e-6, 1e-6, 2, 0.0)
    fast = CalibEntry(DEFAULT_ROOFLINE.peak_flops,
                      DEFAULT_ROOFLINE.hbm_bw, 1.0, 2, 0.0)
    for kind, impl in (("conv", "ecr_pallas"), ("conv_pool", "pecr_pallas"),
                       ("conv_pool", "ecr_pallas")):
        db.put(kind, impl, 8, slow, device=dev)
    db.put("conv", "dense", 8, fast, device=dev)
    flipped = plan_network(params, calib, graph, occ_threshold=0.75,
                           block_c=8, calibration=db)
    assert flipped.counts()["sparse"] < n_sparse
    assert plan_key(2, flipped) != plan_key(2, base)


# ---------------------------------------------------------------------------
# profile_plan + engine integration (one real end-to-end pass)
# ---------------------------------------------------------------------------

def test_profile_plan_rows_and_fit(graph, params, calib):
    plan = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8)
    tr = Tracer(clock=SimClock())
    report = profile_plan(plan, params, calib, iters=1, warmup=1, tracer=tr)
    impls = {t.impl for t in report.timings}
    assert {"dense", "ecr_pallas"} <= impls  # sparse families resolved
    assert all(t.measured_us > 0 and t.predicted_us > 0
               for t in report.timings)
    assert report.batch == 2 and report.block_c == 8
    # trace: one profile span wrapping one profile_layer span per row
    names = [e["name"] for e in tr.events]
    assert names.count("profile_layer") == len(report.timings)
    assert names[-1] == "profile"  # the wrapper exits last
    db = CalibrationDB.from_report(report)
    assert db  # every profiled (kind, impl) fitted
    recal = report.recalibrated(db)
    assert recal.agreement()["top1"] >= report.agreement()["top1"]


def test_engine_traces_and_telemetry(graph, params, calib):
    clock = SimClock()
    tr = Tracer(clock=clock)
    engine = Engine(params, graph=graph, calib=calib, occ_threshold=0.75,
                    block_c=8, max_batch=4, deadline_s=0.005, clock=clock,
                    mesh=None, sim_service_s=0.003, tracer=tr)
    imgs = [calib[i % 2] for i in range(6)]
    replay_stream(engine, imgs, rate_rps=200.0)
    names = [e["name"] for e in tr.events]
    assert "plan" in names and "compile" in names
    n_exec = names.count("execute_batch")
    assert n_exec == engine.n_batches >= 1
    # sim_service_s model: the execute span's duration IS the charged time
    execs = [e for e in tr.events if e["name"] == "execute_batch"]
    assert all(e["dur"] == pytest.approx(3000.0) for e in execs)
    # telemetry carries the profile digest once profile() has run
    assert engine.stats()["telemetry"]["profile"] is None
    report = engine.profile(iters=1, warmup=1)  # uses the last real batch
    digest = engine.stats()["telemetry"]["profile"]
    assert digest["graph"] == graph.name
    assert digest["agreement"]["layers"] == len(report.layers())
    assert len(digest["rows"]) == len(report.timings)


def test_engine_default_tracer_is_null(graph, params, calib):
    engine = Engine(params, graph=graph, calib=calib, occ_threshold=0.0,
                    block_c=8, mesh=None)
    assert engine.tracer is NULL_TRACER
    assert jnp.asarray(engine.serve([calib[0]])).shape == (1, TINY.n_classes)
