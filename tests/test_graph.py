"""LayerGraph IR + op registry: shape inference, pool modes, fusion rule,
single-site dispatch, LeNet/AlexNet end-to-end through plan -> run -> serve,
and the occupancy_stat edge cases the serving engine relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.alexnet import ALEXNET, ALEXNET_REDUCED
from repro.configs.lenet import LENET, LENET_REDUCED
from repro.configs.vgg19_sparse import CNNConfig, vgg19_graph
from repro.graph import (
    ConvSpec,
    DenseSpec,
    Flatten,
    LayerGraph,
    PoolSpec,
    ReLU,
    as_graph,
    fusion_eligible,
    get_op,
    init_graph,
    maxpool2d,
    run_graph,
    unit_impl,
    weight_shapes,
)
from repro.pipeline import occupancy_stat, plan_network, run_plan
from repro.serving import Engine, SimClock, plan_key

# ---------------------------------------------------------------------------
# IR: shape inference on the canonical networks
# ---------------------------------------------------------------------------


def test_canonical_shapes():
    assert LENET.feature_shape() == (16, 5, 5) and LENET.flat_dim() == 400
    assert ALEXNET.feature_shape() == (256, 6, 6) and ALEXNET.flat_dim() == 9216
    vgg = vgg19_graph(CNNConfig())
    assert vgg.feature_shape() == (512, 7, 7) and vgg.flat_dim() == 25088
    assert len(vgg.units()) == 16 and vgg.n_classes() == 1000
    # AlexNet's overlapping pools: 55 -> 27 -> 13 -> 6
    outs = [u.out_shape for u in ALEXNET.units()]
    assert outs[0] == (64, 27, 27) and outs[1] == (192, 13, 13)
    assert outs[-1] == (256, 6, 6)


def test_units_group_conv_relu_pool():
    units = LENET.units()
    assert len(units) == 2
    assert all(u.relu and u.pool is not None for u in units)
    assert units[0].conv == ConvSpec(6, k=5, stride=1, pad=0)
    assert units[1].stage == 1 and units[1].slot == 0
    assert ALEXNET.units()[3].pool is None  # conv4 is in-stage


def test_graph_rejects_bad_topology():
    with pytest.raises(ValueError, match="ReLU must follow a conv"):
        LayerGraph("bad", (1, 8, 8), (ReLU(), Flatten(), DenseSpec(2))).units()
    with pytest.raises(ValueError, match="pool must follow"):
        LayerGraph("bad", (1, 8, 8), (PoolSpec(2), Flatten(), DenseSpec(2))).units()
    with pytest.raises(ValueError, match="dense head"):
        LayerGraph("bad", (1, 8, 8), (ConvSpec(4),)).units()
    with pytest.raises(ValueError, match="only DenseSpec may follow Flatten"):
        LayerGraph("bad", (1, 8, 8), (Flatten(), ConvSpec(4))).units()


def test_signature_is_structural():
    a = vgg19_graph(CNNConfig(name="a", img_size=32, plan=((8, 1),), n_classes=4))
    b = vgg19_graph(CNNConfig(name="b", img_size=32, plan=((8, 1),), n_classes=4))
    c = vgg19_graph(CNNConfig(name="c", img_size=32, plan=((16, 1),), n_classes=4))
    assert a.signature() == b.signature()  # names don't split compiled programs
    assert a.signature() != c.signature()
    assert as_graph(None).signature() == vgg19_graph(CNNConfig()).signature()


def test_weight_shapes_and_init_graph():
    conv_shapes, dense_shapes = weight_shapes(LENET)
    assert conv_shapes == ((6, 1, 5, 5), (16, 6, 5, 5))
    assert dense_shapes == ((400, 120), (120, 84), (84, 10))
    params = init_graph(jax.random.PRNGKey(0), LENET_REDUCED)
    out = run_graph(LENET_REDUCED, params,
                    jnp.ones((2,) + LENET_REDUCED.in_shape))
    assert out.shape == (2, LENET_REDUCED.n_classes())


# ---------------------------------------------------------------------------
# pool modes: the explicit-truncation satellite
# ---------------------------------------------------------------------------


def test_maxpool_valid_raises_on_truncation():
    x = jnp.arange(25.0).reshape(1, 5, 5)
    with pytest.raises(ValueError, match="silently drop"):
        maxpool2d(x, PoolSpec(2))  # 5 % 2 != 0: the old code dropped a row
    out = maxpool2d(x, PoolSpec(2, mode="floor"))
    assert out.shape == (1, 2, 2)
    np.testing.assert_array_equal(np.asarray(out), [[[6.0, 8.0], [16.0, 18.0]]])


def test_maxpool_ceil_last_window_starts_inside_input():
    """ceil_mode must never emit a window lying entirely in the -inf padding
    (the cuDNN/PyTorch rule) — stride > p with naive ceil arithmetic would
    leak -inf into the feature map."""
    x = jnp.arange(16.0).reshape(1, 4, 4)
    out = maxpool2d(x, PoolSpec(1, stride=2, mode="ceil"))
    assert out.shape == (1, 2, 2)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_array_equal(np.asarray(out), [[[0.0, 2.0], [8.0, 10.0]]])


def test_maxpool_ceil_keeps_partial_tail():
    x = jnp.arange(36.0).reshape(1, 6, 6)
    spec = PoolSpec(3, stride=2, mode="ceil")
    out = maxpool2d(x, spec)
    assert out.shape == (1, 3, 3)
    # the tail window covers rows/cols 4..5 only; max of the map is 35
    assert float(out[0, -1, -1]) == 35.0
    with pytest.raises(ValueError, match="silently drop"):
        maxpool2d(x, PoolSpec(3, stride=2))  # (6-3) % 2 != 0
    # overlapping valid pool on a tiling map works (AlexNet's 13 -> 6)
    y = jnp.zeros((2, 4, 13, 13))
    assert maxpool2d(y, PoolSpec(3, stride=2)).shape == (2, 4, 6, 6)


def test_models_maxpool_compat_modes():
    from repro.models.cnn import _maxpool

    x = jnp.arange(16.0).reshape(1, 4, 4)
    np.testing.assert_array_equal(np.asarray(_maxpool(x, 2)),
                                  [[[5.0, 7.0], [13.0, 15.0]]])
    with pytest.raises(ValueError, match="silently drop"):
        _maxpool(jnp.zeros((1, 5, 5)), 2)
    assert _maxpool(jnp.zeros((1, 5, 5)), 2, mode="floor").shape == (1, 2, 2)


# ---------------------------------------------------------------------------
# registry: one dispatch site, fusion rule
# ---------------------------------------------------------------------------


def test_registry_unknown_impl_raises():
    with pytest.raises(ValueError, match="unknown conv impl"):
        get_op("conv", "nope")
    from repro.core import conv2d
    from repro.core.pecr import conv_pool

    with pytest.raises(ValueError, match="unknown conv impl"):
        conv2d(jnp.ones((1, 4, 4)), jnp.ones((1, 1, 3, 3)), 1, "nope")
    with pytest.raises(ValueError, match="unknown conv_pool impl"):
        conv_pool(jnp.ones((1, 6, 6)), jnp.ones((1, 1, 3, 3)), impl="nope")


def test_fusion_rule():
    lenet_units = LENET.units()
    assert all(fusion_eligible(u) for u in lenet_units)  # 28->14, 10->5 tile
    alex_units = ALEXNET.units()
    assert not any(fusion_eligible(u) for u in alex_units)  # overlapping pools
    # a fused request resolves per-unit: fused where eligible, family conv else
    assert unit_impl(lenet_units[0], "pecr_pallas") == ("conv_pool", "pecr_pallas")
    assert unit_impl(alex_units[0], "pecr_pallas") == ("conv", "ecr_pallas")
    assert unit_impl(alex_units[0], "dense") == ("conv", "dense")


def test_registry_cost_hooks_present_for_planned_impls():
    for kind, impl in (("conv", "dense"), ("conv", "ecr"), ("conv", "ecr_pallas"),
                       ("conv_pool", "unfused"), ("conv_pool", "pecr"),
                       ("conv_pool", "pecr_pallas")):
        op = get_op(kind, impl)
        kw = {"pool": 2} if kind == "conv_pool" else {}
        cost = op.cost(8, 10, 10, 16, 3, 3, stride=1, occupancy=0.5, **kw)
        assert cost["flops"] > 0 and cost["bytes"] > 0
    # the unfused baseline pays the intermediate round trip fusion deletes
    unfused = get_op("conv_pool", "unfused").cost(8, 10, 10, 16, 3, 3,
                                                  stride=1, pool=2)
    fused = get_op("conv_pool", "pecr").cost(8, 10, 10, 16, 3, 3,
                                             stride=1, pool=2)
    assert unfused["bytes"] > fused["bytes"]


def test_serving_graphs_all_build():
    """Every CLI-reachable graph must pass shape inference — the full VGG
    serving resolution regressed once on a stage-5 pool that only worked via
    the silent-truncation bug PoolSpec now rejects."""
    from repro.launch.serve_cnn import MODELS, serving_graph

    for model in MODELS:
        for full in (False, True):
            g = serving_graph(model, full)
            assert g.units() and g.flat_dim() > 0


# ---------------------------------------------------------------------------
# LeNet / AlexNet end-to-end: plan -> run -> serve (acceptance)
# ---------------------------------------------------------------------------


def _graph_calib(graph, n=3, seed=1):
    return jax.random.uniform(jax.random.PRNGKey(seed), (n,) + graph.in_shape)


@pytest.mark.parametrize("graph", [LENET_REDUCED, ALEXNET_REDUCED],
                         ids=["lenet", "alexnet"])
def test_sparse_plan_matches_dense_reference(graph):
    """occ_threshold=1.0 forces every layer sparse; the executed plan must
    reproduce the all-dense logits within tolerance on the real topology
    (5x5 pad-0 fused LeNet stacks / strided + ceil-pool AlexNet stacks)."""
    params = init_graph(jax.random.PRNGKey(0), graph)
    imgs = _graph_calib(graph)
    plan = plan_network(params, imgs, graph, occ_threshold=1.0, block_c=8)
    assert all(get_op(lp.kind, lp.impl).sparse for lp in plan.layers)
    out = run_plan(plan, params, imgs)
    ref = run_graph(graph, params, imgs, "dense")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_lenet_plan_fuses_alexnet_plan_does_not():
    lp = plan_network(init_graph(jax.random.PRNGKey(0), LENET_REDUCED),
                      _graph_calib(LENET_REDUCED), LENET_REDUCED,
                      occ_threshold=1.0, block_c=8)
    assert [l.impl for l in lp.layers] == ["pecr_pallas", "pecr_pallas"]
    ap = plan_network(init_graph(jax.random.PRNGKey(0), ALEXNET_REDUCED),
                      _graph_calib(ALEXNET_REDUCED), ALEXNET_REDUCED,
                      occ_threshold=1.0, block_c=8)
    assert all(l.impl == "ecr_pallas" and l.kind == "conv" for l in ap.layers)
    assert ap.counts()["fused"] == 0 and lp.counts()["fused"] == 2


@pytest.mark.parametrize("graph", [LENET_REDUCED, ALEXNET_REDUCED],
                         ids=["lenet", "alexnet"])
def test_engine_serves_graph_network_exactly(graph):
    """N single-image requests through the engine == run_plan on the same
    images (the serving acceptance, on non-VGG topologies). Tolerance note:
    deep ReLU stacks kill channels sample-dependently, so a bucket of 4 and
    the whole batch of 5 can have different live-channel UNIONS — the
    shared-union compaction permutation (and with it the fp32 contraction
    order) then differs in low-order bits; bit-exactness is only contracted
    when co-batched samples share a union (DESIGN.md §4, pinned for VGG in
    test_serving)."""
    params = init_graph(jax.random.PRNGKey(0), graph)
    calib = _graph_calib(graph, n=2, seed=9)
    eng = Engine(params, graph=graph, calib=calib, occ_threshold=1.0,
                 block_c=8, max_batch=4, deadline_s=0.005, clock=SimClock())
    imgs = [_graph_calib(graph, n=1, seed=100 + i)[0] for i in range(5)]
    served = eng.serve(imgs)
    ref = np.asarray(run_plan(eng.plan, params, jnp.stack(imgs)))
    np.testing.assert_allclose(served, ref, rtol=1e-5, atol=1e-6)
    assert eng.stats()["compiles"] > 0


def test_plan_key_carries_graph_signature():
    lenet_params = init_graph(jax.random.PRNGKey(0), LENET_REDUCED)
    alex_params = init_graph(jax.random.PRNGKey(0), ALEXNET_REDUCED)
    lp = plan_network(lenet_params, _graph_calib(LENET_REDUCED), LENET_REDUCED,
                      occ_threshold=0.0, block_c=8)
    ap = plan_network(alex_params, _graph_calib(ALEXNET_REDUCED), ALEXNET_REDUCED,
                      occ_threshold=0.0, block_c=8)
    kl, ka = plan_key(4, lp), plan_key(4, ap)
    assert kl.graph_sig == LENET_REDUCED.signature()
    assert kl != ka  # two all-dense plans must not share a compiled program
    # same graph, different name: programs ARE shared
    other = plan_network(lenet_params, _graph_calib(LENET_REDUCED),
                         LENET_REDUCED, occ_threshold=0.0, block_c=8)
    assert plan_key(4, other) == kl


def test_run_plan_validates_dense_head():
    graph = LENET_REDUCED
    params = init_graph(jax.random.PRNGKey(0), graph)
    plan = plan_network(params, _graph_calib(graph), graph, block_c=8)
    bad = {"conv": params["conv"], "dense": params["dense"][:1]}
    with pytest.raises(ValueError, match="dense weights"):
        run_plan(plan, bad, _graph_calib(graph))


def test_layerplan_is_the_structural_truth():
    """run_plan executes from each LayerPlan's own specs — a plan whose
    layers predate the IR (sentinel ConvSpec) is rejected, and a plan/graph
    unit-count mismatch is caught by validation, not zip-truncated."""
    from repro.pipeline.planner import LayerPlan

    graph = LENET_REDUCED
    params = init_graph(jax.random.PRNGKey(0), graph)
    plan = plan_network(params, _graph_calib(graph), graph, block_c=8)
    legacy = LayerPlan(index=0, stage=0, slot=0, kind="conv", impl="dense",
                       occupancy=1.0, in_shape=(1, 16, 16), out_shape=(4, 6, 6))
    with pytest.raises(ValueError, match="predates the LayerGraph IR"):
        legacy.to_unit()
    mismatched = plan.__class__(layers=plan.layers[:1],
                                occ_threshold=plan.occ_threshold,
                                block_c=plan.block_c, graph=plan.graph)
    bad_params = {"conv": params["conv"][:1], "dense": params["dense"]}
    with pytest.raises(ValueError, match="plan/graph mismatch"):
        run_plan(mismatched, bad_params, _graph_calib(graph))


# ---------------------------------------------------------------------------
# occupancy_stat edge cases (serving drift-detector inputs)
# ---------------------------------------------------------------------------


def _band_batch(n=4, c=16, dead=8, hw=6):
    x = np.array(jax.random.uniform(jax.random.PRNGKey(0), (n, c, hw, hw)),
                 np.float32)
    if dead:
        x[:, c - dead:] = 0.0
    return jnp.asarray(x)


def test_occupancy_stat_n_valid_zero_is_zero():
    assert float(occupancy_stat(_band_batch(), 8, n_valid=0)) == 0.0


def test_occupancy_stat_n_valid_clamped_to_batch():
    x = _band_batch(n=4)
    full = float(occupancy_stat(x, 8, n_valid=4))
    over = float(occupancy_stat(x, 8, n_valid=9))  # beyond N must not deflate
    assert over == pytest.approx(full)
    assert full == pytest.approx(float(occupancy_stat(x, 8)))


def test_occupancy_stat_c_not_divisible_by_block():
    x = _band_batch(c=12, dead=6)  # 6 live channels, block_c=8 -> blocks 8+4
    occ = float(occupancy_stat(x, 8))
    # packed live prefix spans ceil(6/8)=1 of ceil(12/8)=2 blocks
    assert occ == pytest.approx(0.5)


def test_occupancy_stat_all_zero_batch():
    z = jnp.zeros((3, 16, 5, 5))
    assert float(occupancy_stat(z, 8)) == 0.0
    assert float(occupancy_stat(z, 8, n_valid=3)) == 0.0
    # all-zero pads appended to real samples don't change the masked stat
    x = _band_batch(n=2)
    padded = jnp.concatenate([x, jnp.zeros_like(x)])
    masked = float(occupancy_stat(padded, 8, n_valid=2))
    assert masked == pytest.approx(float(occupancy_stat(x, 8)))
