"""Loop-aware HLO cost analysis vs XLA's own on loop-free programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import collective_stats
from repro.launch.hlo_cost import analyze


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_matches_xla_on_unrolled():
    def f(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    c = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 256), jnp.float32))
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):  # older jax returns [dict]
        xla = xla[0]
    mine = analyze(c.as_text())
    assert abs(mine["flops"] - xla["flops"]) / xla["flops"] < 0.05
    assert abs(mine["bytes"] - xla["bytes accessed"]) / xla["bytes accessed"] < 0.3


def test_scan_multiplied_by_trip_count():
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def f_one(x, w):
        return jnp.tanh(x @ w)

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    scan_flops = analyze(_compile(f_scan, a, w).as_text())["flops"]
    one_flops = analyze(_compile(f_one, a, w).as_text())["flops"]
    ratio = scan_flops / one_flops
    assert 9.0 < ratio < 11.5


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    flops = analyze(_compile(f, a, w).as_text())["flops"]
    expect = 2 * 64 * 64 * 64 * 12
    assert abs(flops - expect) / expect < 0.1


def test_dot_flops_exact():
    def f(a, b):
        return a @ b

    c = _compile(f, jax.ShapeDtypeStruct((32, 100), jnp.float32),
                 jax.ShapeDtypeStruct((100, 48), jnp.float32))
    flops = analyze(c.as_text())["flops"]
    assert abs(flops - 2 * 32 * 100 * 48) <= 2 * 32 * 48  # +- elementwise noise


def test_collective_stats_parse():
    hlo = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ag = f32[32,16]{1,0} all-gather(%p), dimensions={0}
  %ar = f32[16,16]{1,0} all-reduce(%p), to_apply=%sum
  ROOT %r = f32[16,16]{1,0} add(%ar, %ar)
}
"""
    s = collective_stats(hlo)
    assert s["bytes_by_kind"]["all-gather"] == 32 * 16 * 4
    assert s["bytes_by_kind"]["all-reduce"] == 16 * 16 * 4
    assert s["counts"]["all-gather"] == 1
