"""End-to-end behaviour: training learns, CNN paths agree at network scale,
the lifted sparse-FFN is numerically exact, serve loop generates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DEFAULT_RUN, ShapeConfig, get_config
from repro.configs.vgg19_sparse import CNN_REDUCED
from repro.core import synth_feature_map
from repro.core.sparse_ffn import sparse_ffn_apply, sparse_ffn_stats
from repro.launch.steps import init_train_state, make_train_step
from repro.models.cnn import cnn_forward, init_cnn

KEY = jax.random.PRNGKey(0)


def test_training_learns_copy_task():
    """Tiny model on a repetitive stream: loss must drop substantially."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    run = DEFAULT_RUN.replace(remat="none", learning_rate=3e-3, warmup_steps=5)
    step_fn = jax.jit(make_train_step(cfg, run, 60))
    state = init_train_state(cfg, run, KEY)
    # highly learnable data: period-4 token pattern
    toks = jnp.tile(jnp.array([5, 9, 2, 7], jnp.int32), (4, 16))[:, :33]
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    first = None
    for s in range(40):
        state, m = step_fn(state, batch)
        if first is None:
            first = float(m["loss"])
    last = float(m["loss"])
    assert last < first * 0.5, (first, last)


def test_cnn_all_paths_agree_at_network_scale():
    p = init_cnn(KEY, CNN_REDUCED)
    img = synth_feature_map(jax.random.PRNGKey(1), (3, 32, 32), 0.6)
    base = cnn_forward(p, img, "dense", CNN_REDUCED)
    for impl in ("im2col", "ecr", "pecr", "ecr_pallas", "pecr_pallas"):
        out = cnn_forward(p, img, impl, CNN_REDUCED)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-3, atol=1e-3)


def test_cnn_batch_vmap():
    p = init_cnn(KEY, CNN_REDUCED)
    imgs = synth_feature_map(jax.random.PRNGKey(2), (4, 3, 32, 32), 0.5)
    out = jax.vmap(lambda im: cnn_forward(p, im, "dense", CNN_REDUCED))(imgs)
    assert out.shape == (4, CNN_REDUCED.n_classes)


def test_sparse_ffn_exactness_and_stats():
    """Block-ECR FFN == dense FFN exactly (zeros contribute nothing)."""
    x = jax.random.normal(KEY, (32, 64))
    w1 = jax.random.normal(jax.random.PRNGKey(1), (64, 256)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(2), (256, 64)) * 0.1
    y, occ = sparse_ffn_apply(x, w1, w2, "relu2", block=(8, 128))
    h = jnp.square(jax.nn.relu(x @ w1))
    np.testing.assert_allclose(np.asarray(y), np.asarray(h @ w2), rtol=1e-5, atol=1e-5)
    st = sparse_ffn_stats(x, w1, "relu2")
    assert 0.0 < st["element_sparsity"] < 1.0
    assert 0.0 <= st["skippable_flop_frac"] <= 1.0


def test_serve_generates_tokens():
    from repro.launch.serve import serve

    gen = serve("qwen3-0.6b", reduced=True, batch=2, prompt_len=8, gen_len=4)
    assert gen.shape == (2, 4)
    assert (np.asarray(gen) >= 0).all()
