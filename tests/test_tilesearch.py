"""Tile-geometry search (DESIGN.md §10): candidate grids conform to the
layer, the winner rule's by-construction floor (modeled AND measured time
<= the default geometry's), winner persistence + erasure in the
CalibrationDB tiles table (with v1 schema compat), and the closed loop —
`plan_network(tiles=db)` stamps the stored winner and the plan cache keys
on it."""
import jax
import jax.numpy as jnp
import json
import pytest

from repro.configs.vgg19_sparse import CNNConfig, vgg19_graph
from repro.core import dead_channel_band
from repro.graph import init_graph
from repro.graph.ir import graph_weights
from repro.kernels.tiles import DEFAULT_TILE, TileConfig
from repro.models.cnn import shift_dead_channels
from repro.obs import (
    CalibrationDB,
    layer_tile_candidates,
    search_layer,
    tile_search,
    unit_shape_key,
)
from repro.pipeline import plan_network, run_plan
from repro.serving import plan_key

TINY = CNNConfig(name="vgg-tilesearch-tiny", in_channels=16, img_size=12,
                 plan=((8, 1), (16, 1)), n_classes=4)


@pytest.fixture(scope="module")
def graph():
    return vgg19_graph(TINY)


@pytest.fixture(scope="module")
def params(graph):
    return shift_dead_channels(init_graph(jax.random.PRNGKey(0), graph))


@pytest.fixture(scope="module")
def calib(graph):
    c, h, w = graph.in_shape
    return dead_channel_band(
        jax.random.uniform(jax.random.PRNGKey(1), (2, c, h, w)), 0.5)


@pytest.fixture(scope="module")
def plan(graph, params, calib):
    return plan_network(params, calib, graph, occ_threshold=0.75, block_c=8)


@pytest.fixture(scope="module")
def searched(plan, params, calib):
    return tile_search(plan, params, calib, iters=2, warmup=1, max_timed=2)


def test_candidates_conform_and_default_first(graph):
    units = list(graph.units())
    cands = layer_tile_candidates(units[0], "conv", "ecr_pallas", batch=2)
    assert cands[0] == DEFAULT_TILE
    c, o = units[0].in_shape[0], units[0].conv.c_out
    for t in cands[1:]:
        assert 0 < t.block_c <= max(8, c) and 0 < t.block_o <= max(8, o)
        assert t.bt == t.bf == t.bd == 0
    bsr = layer_tile_candidates(units[0], "conv", "bsr", batch=2)
    assert bsr[0] == DEFAULT_TILE
    for t in bsr[1:]:
        assert t.block_c == t.block_o == 0
        assert t.bt > 0 and t.bf > 0 and t.bd > 0


def test_search_layer_floor_and_shape(graph, params, calib):
    unit = list(graph.units())[0]
    conv_ws, _ = graph_weights(params)
    r = search_layer(unit, conv_ws[0], calib, "conv", "ecr_pallas",
                     iters=2, warmup=1, max_timed=2)
    assert r.shape_key == unit_shape_key(unit)
    assert r.default.timed  # the default is ALWAYS wall-timed
    assert r.best.timed
    # the winner rule's floor: modeled AND measured <= the default's
    assert r.best.model_us <= r.default.model_us
    assert r.best.measured_us <= r.default.measured_us
    keys = [c.key for c in r.candidates]
    assert DEFAULT_TILE.key() in keys and len(keys) == len(set(keys))
    row = r.row()
    assert row["n_timed"] >= 1 and row["n_candidates"] == len(r.candidates)


def test_search_layer_non_pallas_is_trivial(graph, params, calib):
    unit = list(graph.units())[0]
    conv_ws, _ = graph_weights(params)
    r = search_layer(unit, conv_ws[0], calib, "conv", "dense")
    assert r.best == r.default and len(r.candidates) == 1
    assert not r.best.timed and not r.improved


def test_tile_search_report_and_floor(searched, plan):
    report, db = searched
    assert len(report.layers) == len(plan.layers)
    assert report.floor_holds()
    s = report.summary()
    assert s["layers"] == len(plan.layers) and s["floor_holds"]
    assert s["model_speedup"] >= 1.0  # winner modeled <= default everywhere
    # fit=True wrote measured-backed entries for every timed geometry
    assert any(k[3] == (0, 0, 0, 0, 0) for k in db.entries)


def test_tile_search_persists_only_pallas_winners(searched, plan):
    report, db = searched
    pallas_shapes = {r.shape_key for r in report.layers
                     if r.best.key != DEFAULT_TILE.key()}
    for (_dev, _kind, _impl, shape), tkey in db.tiles.items():
        assert shape in pallas_shapes and any(tkey)


def test_default_winner_erases_stale_entry(plan, params, calib, graph):
    db = CalibrationDB(device="cpu")
    lp = next(lp for lp in plan.layers if lp.impl != "dense")
    unit = list(graph.units())[lp.index]
    sk = unit_shape_key(unit)
    db.put_tile(lp.kind, lp.impl, sk, TileConfig(block_c=8, block_o=8))
    assert db.best_tile(lp.kind, lp.impl, sk) is not None
    db.put_tile(lp.kind, lp.impl, sk, DEFAULT_TILE)  # defaults won -> erase
    assert db.best_tile(lp.kind, lp.impl, sk) is None
    db.put_tile(lp.kind, lp.impl, sk, None)  # None behaves like all-zero
    assert not db.tiles


def test_db_roundtrip_with_tiles(tmp_path, searched):
    _, db = searched
    db.put_tile("conv", "ecr_pallas", (16, 12, 12, 8, 3, 1, 2),
                TileConfig(block_c=12, block_o=8))
    p = db.save(str(tmp_path / "cal.json"))
    db2 = CalibrationDB.load(p)
    assert db2.entries == db.entries
    assert db2.tiles == db.tiles
    t = db2.best_tile("conv", "ecr_pallas", (16, 12, 12, 8, 3, 1, 2))
    assert t == TileConfig(block_c=12, block_o=8)


def test_db_v1_schema_compat(tmp_path):
    v1 = {"schema": "calibration-v1", "device": "cpu",
          "entries": [{"device": "cpu", "kind": "conv", "impl": "dense",
                       "block_c": 8, "peak_flops": 1e12, "hbm_bw": 1e11,
                       "scale": 0.5, "n_samples": 3, "resid_spread": 0.1}]}
    p = tmp_path / "v1.json"
    p.write_text(json.dumps(v1))
    db = CalibrationDB.load(str(p))
    # v1's block_c key embeds as the 5-tuple (bc, 0, 0, 0, 0); no tiles table
    assert ("cpu", "conv", "dense", (8, 0, 0, 0, 0)) in db.entries
    assert db.tiles == {}
    assert db.lookup("conv", "dense", 8) is not None


def test_plan_network_stamps_stored_winner(graph, params, calib, plan):
    db = CalibrationDB(device="cpu")
    lp = next(lp for lp in plan.layers if lp.impl != "dense")
    unit = list(graph.units())[lp.index]
    win = TileConfig(block_c=8, block_o=8)
    db.put_tile(lp.kind, lp.impl, unit_shape_key(unit), win)
    tiled = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8,
                         tiles=db)
    assert tiled.layers[lp.index].tile == win
    assert all(t.tile is None for i, t in enumerate(tiled.layers)
               if i != lp.index)
    # the stamped geometry executes exactly (tile exactness is pinned in
    # test_tiles.py; here: the planned path end to end)
    ref = run_plan(plan, params, calib)
    out = run_plan(tiled, params, calib)
    assert float(jnp.abs(out - ref).max()) <= 1e-4
    # compiled programs are cached PER GEOMETRY: the key must differ
    assert plan_key(2, tiled) != plan_key(2, plan)
    assert plan_key(2, tiled).tile_sig == ((lp.index, win.key()),)


def test_tile_search_then_plan_closes_loop(searched, graph, params, calib,
                                           plan):
    """The full loop: search -> persist -> plan consults the winners table.
    Every stamped tile must be exactly the stored winner for that layer."""
    report, db = searched
    tiled = plan_network(params, calib, graph, occ_threshold=0.75, block_c=8,
                         tiles=db)
    for lp in tiled.layers:
        stored = db.best_tile(lp.kind, lp.impl,
                              unit_shape_key(list(graph.units())[lp.index]))
        assert lp.tile == stored
    out = run_plan(tiled, params, calib)
    ref = run_plan(plan, params, calib)
    assert float(jnp.abs(out - ref).max()) <= 1e-4
