"""Perf-history subsystem (repro.obs.history, DESIGN.md §13).

Pins the acceptance contract of the `repro-bench` CI gate: over two
ingested runs of the benchmark harness's payloads, `check` exits 0 on a
bit-identical rerun and nonzero on a seeded synthetic regression — plus
the store's append-only/dedup discipline, the noise-aware classification
(rolling median + MAD, min-sample guards, per-class thresholds), the
`write_bench_json`/`parse_csv_rows` round trip with the device stamp, and
the telemetry/profile/calibration exporters into the same record schema.
"""
import json
import os
import subprocess
import sys

import pytest

from benchmarks._util import parse_csv_rows, write_bench_json
from repro.obs.history import (
    BenchDB,
    Thresholds,
    calibration_rows,
    check_db,
    classify,
    diff_db,
    html_report,
    make_payload,
    metric_direction,
    metric_noise_class,
    payload_records,
    telemetry_rows,
    trend_table,
)
from repro.obs.history.cli import main as cli_main

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def bench_payload(sha="aaa1111", ts="2026-01-01T00:00:00Z", us=1000.0,
                  p50=2.0, name="model_zoo", device="cpu"):
    """A payload in exactly the `write_bench_json` shape `benchmarks/run.py
    --json` emits (git SHA + timestamp + versions + device stamp + rows)."""
    return {"name": name, "schema": "name,us_per_call,derived",
            "git_sha": sha, "timestamp": ts,
            "versions": {"jax": "0.9", "jaxlib": "0.9"},
            "device_kind": device, "platform": device,
            "rows": [
                {"name": "zoo/lenet/sparse", "us_per_call": us,
                 "p50_ms": p50, "throughput_rps": 100.0,
                 "derived": "batch=2"},
                {"name": "zoo/lenet/dense", "us_per_call": us * 2,
                 "derived": "batch=2"},
            ]}


# -- store -------------------------------------------------------------------


def test_ingest_and_series_typing(tmp_path):
    db = BenchDB(str(tmp_path / "db.jsonl"))
    n = db.ingest_payload(bench_payload())
    # us_per_call + p50_ms + throughput_rps on row 1, us_per_call on row 2;
    # "derived"/"name" (strings) never become series
    assert n == 4
    keys = set(db.series())
    assert ("model_zoo", "zoo/lenet/sparse", "p50_ms", "cpu") in keys
    assert all(k[3] == "cpu" for k in keys)


def test_device_kind_separates_baselines(tmp_path):
    """CPU-interpret and TPU points must form disjoint series — a TPU run
    never lands on (or gates against) the CPU baseline."""
    db = BenchDB(str(tmp_path / "db.jsonl"))
    db.ingest_payload(bench_payload(device="cpu"))
    db.ingest_payload(bench_payload(sha="bbb2222", ts="2026-01-02T00:00:00Z",
                                    us=99999.0, device="TPU v5e"))
    series = db.series()
    key_cpu = ("model_zoo", "zoo/lenet/sparse", "us_per_call", "cpu")
    key_tpu = ("model_zoo", "zoo/lenet/sparse", "us_per_call", "TPU v5e")
    assert len(series[key_cpu]) == 1 and len(series[key_tpu]) == 1
    # and the fresh TPU point has no CPU baseline: no-baseline, not regressed
    verdicts = {v.metric: v for v in check_db(db)}
    assert verdicts["us_per_call"].status == "no-baseline"


def test_dedupe_and_reload(tmp_path):
    path = str(tmp_path / "db.jsonl")
    db = BenchDB(path)
    assert db.ingest_payload(bench_payload()) == 4
    assert db.ingest_payload(bench_payload()) == 0  # identical: all dups
    db2 = BenchDB(path)  # JSONL round trip preserves everything
    assert len(db2) == 4
    assert db2.ingest_payload(bench_payload()) == 0
    assert db2.records[0].identity() == db.records[0].identity()
    # append-only: the file starts with the schema header line
    first = open(path).readline()
    assert json.loads(first)["schema"] == "benchdb-v1"


def test_payload_records_skips_labels_and_nonscalars():
    payload = bench_payload()
    payload["rows"][0].update({"layer": 3, "seed": 0, "flag": True,
                               "nested": {"a": 1}, "note": "text"})
    recs = payload_records(payload)
    metrics = {r.metric for r in recs}
    assert "layer" not in metrics and "seed" not in metrics
    assert "flag" not in metrics and "nested" not in metrics
    assert "us_per_call" in metrics


# -- classification ----------------------------------------------------------


def test_metric_direction_and_noise_class():
    assert metric_direction("us_per_call") == -1
    assert metric_direction("p99_ms") == -1
    assert metric_direction("service_s_total") == -1
    assert metric_direction("throughput_rps") == 1
    assert metric_direction("speedup") == 1
    assert metric_direction("top1_agreement") == 1
    assert metric_direction("batches") == 0  # tracked, never gated
    assert metric_noise_class("p50_ms") == "noisy"
    assert metric_noise_class("top1_agreement") == "exact"
    assert metric_noise_class("stream_compiles") == "exact"


def test_classify_flat_on_identical_and_min_samples_guard():
    th = Thresholds()
    assert classify([100.0], 100.0, "us_per_call", th).status == "flat"
    guard = Thresholds(min_samples=3)
    v = classify([100.0, 100.0], 100.0, "us_per_call", guard)
    assert v.status == "no-baseline"  # guarded: too little history to judge


def test_classify_regressed_improved_directions():
    th = Thresholds(rel_noisy=0.5)
    assert classify([100.0], 200.0, "us_per_call", th).status == "regressed"
    assert classify([100.0], 40.0, "us_per_call", th).status == "improved"
    # higher-is-better flips the sign
    assert classify([100.0], 40.0, "throughput_rps", th).status == "regressed"
    assert classify([100.0], 200.0, "throughput_rps", th).status == "improved"


def test_mad_widens_band_on_noisy_history():
    """A series whose history is noisy earns a wider band: the same +36%
    excursion that trips a tight relative threshold on quiet history is
    absorbed by the MAD term on jittery history."""
    th = Thresholds(rel_noisy=0.10, mad_k=4.0)
    quiet = [100.0, 101.0, 99.0, 100.0]
    noisy = [100.0, 140.0, 80.0, 120.0]
    assert classify(quiet, 136.0, "us_per_call", th).status == "regressed"
    assert classify(noisy, 136.0, "us_per_call", th).status == "flat"


def test_mad_needs_minimum_samples():
    """The MAD of two points is just half their gap, so one noisy early
    pair must not widen the band enough to swallow a real cliff: with only
    two priors the relative term alone gates (a 3x jump over a 509/299
    pair regresses), while the same spread across >= mad_min_samples
    priors legitimately earns the wide MAD band."""
    th = Thresholds()  # rel_noisy=0.5, mad_k=4.0, mad_min_samples=3
    assert classify([509.9, 299.0], 897.0, "us_per_call", th).status \
        == "regressed"
    # four priors with the same spread: the MAD term engages and absorbs it
    assert classify([509.9, 299.0, 510.0, 300.0], 897.0, "us_per_call",
                    th).status == "flat"
    # the guard is configurable: demanding 5 priors re-tightens the band
    tight = Thresholds(mad_min_samples=5)
    assert classify([509.9, 299.0, 510.0, 300.0], 897.0, "us_per_call",
                    tight).status == "regressed"


def test_exact_metrics_gate_tight():
    """Deterministic metrics (agreement scores) regress on small moves the
    noisy class would absorb."""
    v = classify([1.0, 1.0, 1.0], 0.9, "top1_agreement", Thresholds())
    assert v.status == "regressed"


# -- the acceptance contract: two runs, flat vs seeded regression ------------


def test_identical_rerun_is_flat_exit0(tmp_path):
    db_path = str(tmp_path / "db.jsonl")
    f1 = tmp_path / "BENCH_a.json"
    f2 = tmp_path / "BENCH_b.json"
    f1.write_text(json.dumps(bench_payload()))
    f2.write_text(json.dumps(bench_payload(ts="2026-01-02T00:00:00Z")))
    assert cli_main(["ingest", "--db", db_path, str(f1)]) == 0
    assert cli_main(["check", "--db", db_path, str(f2)]) == 0
    verdicts = check_db(BenchDB(db_path))
    gated = [v for v in verdicts if v.status not in ("ungated",)]
    assert gated and all(v.status == "flat" for v in gated)


def test_seeded_regression_exits_nonzero(tmp_path):
    """The mutation test: perturb ONE metric beyond threshold and the gate
    must trip — and name the right series."""
    db_path = str(tmp_path / "db.jsonl")
    base = bench_payload()
    bad = bench_payload(ts="2026-01-02T00:00:00Z")
    bad["rows"][0]["p50_ms"] *= 3.0  # >> rel_noisy=0.5
    f1 = tmp_path / "BENCH_a.json"
    f2 = tmp_path / "BENCH_b.json"
    f1.write_text(json.dumps(base))
    f2.write_text(json.dumps(bad))
    assert cli_main(["ingest", "--db", db_path, str(f1)]) == 0
    assert cli_main(["check", "--db", db_path, str(f2)]) == 1
    verdicts = check_db(BenchDB(db_path))
    regressed = [v for v in verdicts if v.status == "regressed"]
    assert [(v.row, v.metric) for v in regressed] == \
        [("zoo/lenet/sparse", "p50_ms")]


def test_check_cli_process_level(tmp_path):
    """The literal CI invocation: `python -m repro.obs.history.cli check`
    exit codes observed at the process boundary."""
    db_path = str(tmp_path / "db.jsonl")
    f1 = tmp_path / "BENCH_a.json"
    f1.write_text(json.dumps(bench_payload()))
    bad = bench_payload(ts="2026-01-02T00:00:00Z")
    bad["rows"][1]["us_per_call"] *= 10.0
    f2 = tmp_path / "BENCH_b.json"
    f2.write_text(json.dumps(bad))
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join([os.path.abspath(SRC),
                                           os.path.abspath(ROOT)]))

    def run(*args):
        return subprocess.run(
            [sys.executable, "-m", "repro.obs.history.cli", *args],
            capture_output=True, text=True, env=env, timeout=120)

    r = run("check", "--db", db_path, str(f1))
    assert r.returncode == 0, r.stderr  # first run: no baseline yet
    r = run("check", "--db", db_path, str(f2), "--json")
    assert r.returncode == 1, r.stderr
    report = json.loads(r.stdout)
    assert report["regressed"] == 1
    assert any(v["status"] == "regressed" and v["metric"] == "us_per_call"
               for v in report["verdicts"])


def test_check_threshold_flags(tmp_path):
    """--rel-noisy reshapes the gate: a +30% move regresses at 0.1 and
    passes at 0.5."""
    db_path = str(tmp_path / "db.jsonl")
    f1 = tmp_path / "BENCH_a.json"
    f2 = tmp_path / "BENCH_b.json"
    bad = bench_payload(ts="2026-01-02T00:00:00Z")
    bad["rows"][0]["us_per_call"] *= 1.3
    f1.write_text(json.dumps(bench_payload()))
    f2.write_text(json.dumps(bad))
    assert cli_main(["ingest", "--db", db_path, str(f1), str(f2)]) == 0
    assert cli_main(["check", "--db", db_path, "--rel-noisy", "0.5"]) == 0
    assert cli_main(["check", "--db", db_path, "--rel-noisy", "0.1"]) == 1


def test_check_skips_stale_series(tmp_path):
    """A bench that did NOT re-run this time has no fresh evidence: its
    series must not be judged against the candidate SHA."""
    db = BenchDB(str(tmp_path / "db.jsonl"))
    db.ingest_payload(bench_payload(name="old_bench"))
    db.ingest_payload(bench_payload(sha="bbb2222",
                                    ts="2026-01-02T00:00:00Z",
                                    name="fresh_bench", us=5000.0))
    verdicts = check_db(db, sha="bbb2222")
    assert verdicts and all(v.bench == "fresh_bench" for v in verdicts)


# -- diff --------------------------------------------------------------------


def test_diff_between_shas(tmp_path):
    db = BenchDB(str(tmp_path / "db.jsonl"))
    db.ingest_payload(bench_payload(sha="aaa1111"))
    db.ingest_payload(bench_payload(sha="bbb2222",
                                    ts="2026-01-02T00:00:00Z", us=2000.0))
    rows = diff_db(db, "aaa1111", "bbb2222")
    by = {(r["row"], r["metric"]): r for r in rows}
    r = by[("zoo/lenet/sparse", "us_per_call")]
    assert r["a"] == 1000.0 and r["b"] == 2000.0
    assert r["rel_delta"] == pytest.approx(1.0)
    assert r["better"] is False  # lower-is-better metric got worse
    same = by[("zoo/lenet/sparse", "throughput_rps")]
    assert same["better"] is None  # unchanged


# -- write_bench_json / parse_csv_rows round trip + device stamp -------------


def test_write_bench_json_roundtrip_and_device_stamp(tmp_path):
    csv = ("name,us_per_call,derived\n"
           "fig9/conv_1,123.4,speedup=2.0\n"
           "_meta/fig9_wall_s,1.5,module wall time (seconds)\n"
           "bogus-line\n")
    rows = parse_csv_rows(csv)
    assert rows == [{"name": "fig9/conv_1", "us_per_call": 123.4,
                     "derived": "speedup=2.0"}]
    path = write_bench_json("roundtrip", rows, str(tmp_path))
    payload = json.load(open(path))
    # the run stamp: SHA + timestamp + versions + device (satellite: the
    # device stamp keeps CPU and TPU baselines apart in the history DB)
    for key in ("git_sha", "timestamp", "versions", "device_kind",
                "platform"):
        assert key in payload, key
    assert payload["platform"] != "unknown"
    db = BenchDB(str(tmp_path / "db.jsonl"))
    assert db.ingest_file(path) == 1
    ((key, recs),) = db.series().items()
    assert key[:3] == ("roundtrip", "fig9/conv_1", "us_per_call")
    assert recs[0].value == 123.4
    assert recs[0].device_kind == payload["device_kind"]


def test_ingest_rejects_non_bench_json(tmp_path):
    p = tmp_path / "BENCH_bad.json"
    p.write_text(json.dumps({"not": "a payload"}))
    with pytest.raises(ValueError):
        BenchDB(str(tmp_path / "db.jsonl")).ingest_file(str(p))
    assert cli_main(["ingest", "--db", str(tmp_path / "db.jsonl"),
                     str(p)]) == 2


# -- exporters: telemetry / profile / calibration ----------------------------


def test_telemetry_rows_schema():
    snapshot = {"submitted": 10, "completed": 10, "batches": 4,
                "pad_samples": 2, "mean_fill": 0.75, "service_s_total": 0.1,
                "latency": {"count": 10, "mean_ms": 2.0, "max_ms": 5.0,
                            "p50_ms": 1.5, "p95_ms": 4.0, "p99_ms": 5.0},
                "replans": {"triggers": 1, "swaps": 1, "errors": 0,
                            "hot_swaps": 0, "verify_rejects": 0},
                "occ_timeline": [[0.0, [0.5]]], "replan_events": []}
    (row,) = telemetry_rows(snapshot, prefix="telemetry/vgg/steady")
    assert row["name"] == "telemetry/vgg/steady"
    assert row["p95_ms"] == 4.0 and row["replan_swaps"] == 1
    # only scalars — the timelines stay out of the trajectory
    assert all(not isinstance(v, (list, dict)) for v in row.values())
    recs = payload_records(make_payload("serving", [row]))
    assert {r.metric for r in recs} >= {"p50_ms", "p99_ms", "mean_fill",
                                        "replan_triggers"}


def test_profile_and_calibration_rows():
    from repro.obs.calibrate import CalibEntry, CalibrationDB
    from repro.obs.profile import LayerTiming, ProfileReport

    timings = tuple(
        LayerTiming(index=i, kind="conv", impl=impl, occupancy=0.5,
                    weight_density=1.0, batch=2, block_c=8,
                    measured_us=100.0 * (i + 1), spread=0.1,
                    predicted_us=50.0 * (i + 1), flops=1e6, bytes=1e4)
        for i, impl in ((0, "dense"), (0, "ecr_pallas"), (1, "dense")))
    report = ProfileReport(graph_name="lenet", device_kind="cpu", batch=2,
                           block_c=8, timings=timings)
    rows = report.history_rows()
    names = [r["name"] for r in rows]
    assert "profile/lenet/conv/dense" in names
    assert "profile/lenet/agreement" in names
    agr = rows[-1]
    assert 0.0 <= agr["top1_agreement"] <= 1.0
    db = CalibrationDB(device="cpu")
    db.put("conv", "dense", 8, CalibEntry(peak_flops=1e12, hbm_bw=1e11,
                                          scale=0.5, n_samples=3,
                                          resid_spread=0.2))
    (crow,) = calibration_rows(db)
    assert crow["name"] == "calib/cpu/conv/dense/bc8"
    assert crow["scale"] == 0.5 and crow["resid_spread"] == 0.2
    # both exporters land in the same record schema
    recs = payload_records(make_payload("obs", rows + [crow]))
    assert {r.metric for r in recs} >= {"ratio_median", "top1_agreement",
                                        "scale", "resid_spread"}


# -- rendering ---------------------------------------------------------------


def test_trend_table_and_html_report(tmp_path):
    db = BenchDB(str(tmp_path / "db.jsonl"))
    db.ingest_payload(bench_payload())
    db.ingest_payload(bench_payload(sha="bbb2222",
                                    ts="2026-01-02T00:00:00Z", us=3000.0))
    table = trend_table(db)
    assert "zoo/lenet/sparse/us_per_call" in table
    assert "regressed" in table
    md = trend_table(db, markdown=True)
    assert md.startswith("| series |")
    html = html_report(db)
    assert html.startswith("<!doctype html>")
    assert "<svg" in html and "regressed" in html
    assert "src=" not in html  # self-contained: no external assets
    out = tmp_path / "report.html"
    assert cli_main(["report", "--db", str(db.path), "--html",
                     str(out)]) == 0
    assert out.read_text().startswith("<!doctype html>")


def test_benchdb_gitignored():
    """The DB is a CI artifact, not a tracked file — a stray local
    benchdb.jsonl must not show up in git status."""
    gitignore = open(os.path.join(ROOT, ".gitignore")).read()
    assert "benchdb" in gitignore or "*.jsonl" in gitignore
