"""Pallas flash attention kernel: fwd/bwd sweeps vs the fp32 oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_fwd_pallas
from repro.kernels.flash_attention.ops import flash_attention_p, flash_mha
from repro.kernels.flash_attention.ref import attention_ref

KEY = jax.random.PRNGKey(0)


def _inputs(bkv, g, sq, sk, d, dtype=jnp.float32, scale=0.5):
    q = jax.random.normal(KEY, (bkv, g, sq, d), dtype) * scale
    k = jax.random.normal(jax.random.PRNGKey(1), (bkv, sk, d), dtype) * scale
    v = jax.random.normal(jax.random.PRNGKey(2), (bkv, sk, d), dtype) * scale
    return q, k, v


@pytest.mark.parametrize("bkv,g,sq,sk,d", [(1, 1, 32, 32, 16), (2, 4, 64, 128, 32),
                                           (3, 2, 48, 96, 64)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_sweep(bkv, g, sq, sk, d, causal, dtype):
    q, k, v = _inputs(bkv, g, sq, sk, d, dtype)
    out, m, l = flash_fwd_pallas(q, k, v, scale=d ** -0.5, causal=causal, qc=16, kc=32)
    ref = attention_ref(q, k, v, scale=d ** -0.5, causal=causal)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_fwd_decode_mode():
    """Sq=1 with q_offset/kv_len — the serve_step configuration."""
    q, k, v = _inputs(2, 4, 1, 128, 32)
    out, _, _ = flash_fwd_pallas(q, k, v, scale=32 ** -0.5, causal=True,
                                 q_offset=99, kv_len=100, qc=1, kc=32)
    ref = attention_ref(q, k, v, scale=32 ** -0.5, causal=True, q_offset=99, kv_len=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_grads_match_reference(causal):
    q, k, v = _inputs(2, 3, 64, 128, 32)

    def loss_k(q, k, v):
        return jnp.sum(flash_attention_p(q, k, v, 32 ** -0.5, causal, 0, None, 32, 64) ** 2)

    def loss_r(q, k, v):
        return jnp.sum(attention_ref(q, k, v, scale=32 ** -0.5, causal=causal) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_model_wrapper_matches_jnp_flash():
    from repro.models.attention import flash_attention as jnp_flash

    b, s, kv, g, d = 2, 48, 2, 4, 16
    q = jax.random.normal(KEY, (b, s, kv, g, d)) * 0.4
    k = jax.random.normal(jax.random.PRNGKey(3), (b, s, kv, d)) * 0.4
    v = jax.random.normal(jax.random.PRNGKey(4), (b, s, kv, d)) * 0.4
    om = flash_mha(q, k, v, causal=True, qc=16, kc=16)
    ref = jnp_flash(q, k, v, causal=True, scale=d ** -0.5, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(om), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_gqa_groups_share_kv():
    """All groups of one kv head see the same k/v (GQA semantics)."""
    q, k, v = _inputs(1, 4, 16, 16, 8)
    q_same = jnp.broadcast_to(q[:, :1], q.shape)  # identical queries per group
    out, _, _ = flash_fwd_pallas(q_same, k, v, scale=8 ** -0.5, causal=True, qc=8, kc=8)
    for g in range(1, 4):
        np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(out[:, g]),
                                   rtol=1e-6, atol=1e-6)
