"""Batched sparse-CNN pipeline: batched ECR/PECR equivalence, ragged batches,
batch=1 consistency with the single-image API, and the per-layer planner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg19_sparse import CNNConfig
from repro.core import conv2d, conv_pool, synth_feature_map
from repro.kernels.conv_pool.ops import fused_conv_pool
from repro.kernels.conv_pool.ref import conv_pool_ref
from repro.kernels.ecr_conv.ops import ecr_conv
from repro.kernels.ecr_conv.ref import ecr_conv_ref
from repro.models.cnn import cnn_forward, cnn_forward_batch, init_cnn
from repro.pipeline import measure_occupancy, plan_network, run_plan

KEY = jax.random.PRNGKey(0)


def _batch(n, shape, sparsities, seed=0):
    """A batch with per-sample (ragged) sparsity."""
    return jnp.stack(
        [synth_feature_map(jax.random.PRNGKey(seed + i), shape, s)
         for i, s in zip(range(n), sparsities)]
    )


# ---------------------------------------------------------------------------
# batched oracles vs dense, all strides the paper evaluates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2, 3])
@pytest.mark.parametrize("impl", ["ecr", "im2col"])
def test_batched_conv_equivalence(stride, impl):
    x = _batch(3, (4, 11, 11), [0.0, 0.6, 0.95])
    k = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 3, 3))
    ref = conv2d(x, k, stride, "dense")
    assert ref.shape[0] == 3
    out = conv2d(x, k, stride, impl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_batched_conv_pool_equivalence():
    x = _batch(2, (4, 10, 10), [0.3, 0.9])
    k = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 3, 3))
    ref = conv_pool(x, k, 1, 2, None, "unfused")
    out = conv_pool(x, k, 1, 2, None, "pecr")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# batched Pallas kernels: ragged per-sample sparsity in one batch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2, 3])
def test_batched_ecr_pallas_ragged(stride):
    # sample 0: a dead channel block; sample 1: dense; sample 2: all zero
    x = np.zeros((3, 16, 10, 10), np.float32)
    x[0] = np.asarray(synth_feature_map(jax.random.PRNGKey(0), (16, 10, 10), 0.5))
    x[0, 4:12] = 0
    x[1] = np.asarray(synth_feature_map(jax.random.PRNGKey(1), (16, 10, 10), 0.1))
    x = jnp.asarray(x)
    k = jax.random.normal(jax.random.PRNGKey(2), (8, 16, 3, 3))
    y = ecr_conv(x, k, stride=stride, block_c=8, block_o=8)
    ref = ecr_conv_ref(x, k, stride)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4, atol=2e-4)
    # all-zero sample must come out exactly zero (every block skipped)
    assert np.abs(np.asarray(y[2])).max() == 0.0


@pytest.mark.parametrize("pool", [2, 3])
def test_batched_conv_pool_pallas_ragged(pool):
    x = np.zeros((2, 16, 11, 11), np.float32)
    x[0] = np.asarray(synth_feature_map(jax.random.PRNGKey(3), (16, 11, 11), 0.7))
    x[0, 8:16] = 0
    x[1] = np.asarray(synth_feature_map(jax.random.PRNGKey(4), (16, 11, 11), 0.2))
    x = jnp.asarray(x)
    k = jax.random.normal(jax.random.PRNGKey(5), (8, 16, 3, 3))
    y = fused_conv_pool(x, k, stride=1, pool=pool, block_c=8, block_o=8)
    ref = conv_pool_ref(x, k, 1, pool)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# batch=1 equivalence with the single-image API
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fn_pair", ["ecr", "conv_pool"])
def test_batch_one_matches_single_image(fn_pair):
    x = synth_feature_map(jax.random.PRNGKey(6), (16, 9, 9), 0.6)
    k = jax.random.normal(jax.random.PRNGKey(7), (8, 16, 3, 3))
    if fn_pair == "ecr":
        single = ecr_conv(x, k, block_c=8, block_o=8)
        batched = ecr_conv(x[None], k, block_c=8, block_o=8)
    else:
        single = fused_conv_pool(x, k, block_c=8, block_o=8)
        batched = fused_conv_pool(x[None], k, block_c=8, block_o=8)
    assert batched.shape == (1,) + single.shape
    np.testing.assert_allclose(np.asarray(batched[0]), np.asarray(single),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# whole-network batch: all impls match per-image results (acceptance)
# ---------------------------------------------------------------------------


_TINY = CNNConfig(name="vgg-tiny", img_size=16, plan=((8, 2), (16, 1)), n_classes=8)


@pytest.mark.parametrize("impl", ["dense", "ecr", "pecr", "ecr_pallas", "pecr_pallas"])
def test_cnn_forward_batch_matches_per_image(impl):
    params = init_cnn(jax.random.PRNGKey(0), _TINY)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (3, 3, 16, 16))
    out = cnn_forward_batch(params, imgs, impl, _TINY)
    per = jnp.stack([cnn_forward(params, imgs[i], impl, _TINY) for i in range(3)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(per), rtol=1e-4, atol=1e-4)
    ref = cnn_forward_batch(params, imgs, "dense", _TINY)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# pipeline planner
# ---------------------------------------------------------------------------


def test_measure_occupancy_counts_dead_channels():
    x = np.array(synth_feature_map(jax.random.PRNGKey(8), (16, 8, 8), 0.2))
    x[8:16] = 0.0
    assert measure_occupancy(jnp.asarray(x), block_c=8) == pytest.approx(0.5)
    assert measure_occupancy(jnp.zeros((2, 16, 8, 8)), block_c=8) == 0.0


def test_measure_occupancy_matches_shared_union_schedule():
    """Disjoint per-sample live sets: the union pack keeps every channel, so
    the batched kernel skips nothing and the measured occupancy must be 1.0
    (a per-sample measure would wrongly report 0.5 and mis-plan the layer)."""
    x = np.zeros((2, 16, 6, 6), np.float32)
    x[0, 0::2] = 1.0
    x[1, 1::2] = 1.0
    assert measure_occupancy(jnp.asarray(x), block_c=8) == 1.0


def test_plan_dense_when_occupancy_high():
    params = init_cnn(jax.random.PRNGKey(0), _TINY)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (2, 3, 16, 16))
    plan = plan_network(params, imgs, _TINY, occ_threshold=0.5)
    assert all(lp.impl == "dense" for lp in plan.layers)  # dense input, live net
    out = run_plan(plan, params, imgs, _TINY)
    ref = cnn_forward_batch(params, imgs, "dense", _TINY)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_plan_sparse_layers_still_match_dense():
    """Force the sparse decision (threshold=1.0 admits every layer) and check
    the executed mixed plan still reproduces the dense forward."""
    params = init_cnn(jax.random.PRNGKey(0), _TINY)
    imgs = jax.random.uniform(jax.random.PRNGKey(1), (2, 3, 16, 16))
    plan = plan_network(params, imgs, _TINY, occ_threshold=1.0)
    assert any(lp.impl != "dense" for lp in plan.layers)
    assert plan.layers[-1].kind == "conv_pool" and plan.layers[-1].impl == "pecr_pallas"
    out = run_plan(plan, params, imgs, _TINY)
    ref = cnn_forward_batch(params, imgs, "dense", _TINY)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-3)
    counts = plan.counts()
    assert counts["sparse"] == len(plan.layers) and counts["fused"] == 2
