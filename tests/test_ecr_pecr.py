"""Core ECR/PECR correctness: paper semantics, strides, property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    conv2d,
    conv_pool,
    ecr_compress,
    ecr_spmv,
    synth_feature_map,
    window_stats,
)
from repro.core.pecr import fused_traffic_bytes

KEY = jax.random.PRNGKey(0)


def _fm(shape, sparsity, seed=0):
    return synth_feature_map(jax.random.PRNGKey(seed), shape, sparsity)


# ---------------------------------------------------------------------------
# equivalence: every impl == dense, all strides the paper evaluates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2, 3])
@pytest.mark.parametrize("impl", ["ecr", "im2col"])
@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.95, 1.0])
def test_conv_equivalence(stride, impl, sparsity):
    x = _fm((4, 11, 11), sparsity)
    k = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 3, 3))
    ref = conv2d(x, k, stride, "dense")
    out = conv2d(x, k, stride, impl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("sparsity", [0.0, 0.7, 1.0])
def test_conv_pool_equivalence(sparsity):
    x = _fm((4, 10, 10), sparsity)
    k = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 3, 3))
    ref = conv_pool(x, k, 1, 2, None, "unfused")
    out = conv_pool(x, k, 1, 2, None, "pecr")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_pooling_stride_one_matches_paper_fig7():
    """Paper Fig. 7 uses conv stride 1 AND pooling stride 1."""
    x = _fm((1, 5, 5), 0.5)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 3, 3))
    out = conv_pool(x, k, 1, 2, 1, "pecr")  # pooling stride 1
    ref = conv_pool(x, k, 1, 2, 1, "unfused")
    assert out.shape == (1, 2, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# format invariants (Algorithm 1 semantics)
# ---------------------------------------------------------------------------


def test_ecr_format_invariants():
    x = _fm((2, 7, 7), 0.8)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 3))
    ecr = ecr_compress(x, k, 3, 3, 1)
    f, ptr = np.asarray(ecr.f_data), np.asarray(ecr.ptr)
    # Ptr == nonzero count, -1 sentinel for empty windows (Algorithm 1 L12-16)
    from repro.core.sparsity import extract_windows

    wins = np.asarray(extract_windows(x, 3, 3, 1)).reshape(len(ptr), -1)
    nnz = (wins != 0).sum(1)
    np.testing.assert_array_equal(ptr, np.where(nnz > 0, nnz, -1))
    # nonzeros packed to the front; padding tail is exactly zero
    for i, n in enumerate(nnz):
        assert (f[i, :n] != 0).all()
        assert (f[i, n:] == 0).all()
    # SpMV reproduces the dense conv
    ref = conv2d(x, k[None], 1, "dense")[0]
    np.testing.assert_allclose(np.asarray(ecr_spmv(ecr)), np.asarray(ref), atol=1e-4)


def test_paper_worked_example_mac_reduction():
    """§IV-D: ~0.7 sparsity feature maps reduce muls/adds by >= 60%/70%-ish;
    exact claim in the paper's example: -63% muls, -71% adds for its Fig.4 map."""
    x = np.asarray(_fm((1, 5, 5), 0.72, seed=3))
    st_ = window_stats(x, 3, 3, 1)
    assert st_.dense_muls == 9 * 9  # 9 windows x 9 taps
    assert st_.sparse_muls == sum(
        (np.asarray(x)[0, i : i + 3, j : j + 3] != 0).sum()
        for i in range(3) for j in range(3))
    assert st_.mul_reduction > 0.4
    assert st_.add_reduction >= st_.mul_reduction  # adds always reduce >= muls


# ---------------------------------------------------------------------------
# hypothesis: equivalence holds for arbitrary sparsity patterns
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    data=st.data(),
    c=st.integers(1, 3),
    hw=st.integers(5, 9),
    stride=st.integers(1, 2),
)
def test_hypothesis_ecr_equals_dense(data, c, hw, stride):
    mask_bits = data.draw(st.lists(st.booleans(), min_size=c * hw * hw,
                                   max_size=c * hw * hw))
    vals = np.arange(1, c * hw * hw + 1, dtype=np.float32).reshape(c, hw, hw)
    x = jnp.asarray(vals * np.array(mask_bits, np.float32).reshape(c, hw, hw))
    k = jax.random.normal(jax.random.PRNGKey(7), (2, c, 3, 3))
    ref = conv2d(x, k, stride, "dense")
    out = conv2d(x, k, stride, "ecr")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), sparsity=st.floats(0.0, 1.0))
def test_hypothesis_pecr_index_corrected(seed, sparsity):
    """Paper Algorithm 3 line 11 types `i*j+i`; our corrected `i*k_w+j` must
    reproduce dense conv+pool for every sparsity pattern."""
    x = _fm((2, 8, 8), sparsity, seed=seed)
    k = jax.random.normal(jax.random.PRNGKey(seed), (1, 2, 3, 3))
    out = conv_pool(x, k, 1, 2, None, "pecr")
    ref = conv_pool(x, k, 1, 2, None, "unfused")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# traffic model (paper Fig. 3 / §V motivation)
# ---------------------------------------------------------------------------


def test_fused_traffic_strictly_less():
    t = fused_traffic_bytes((64, 56, 56), o=64, kh=3, kw=3)
    assert t["fused_bytes"] < t["unfused_bytes"]
    assert 0.3 < t["saved_frac"] < 1.0
