"""Weight-sparsity subsystem: pruning format, conv2d_bsr correctness, the
planner's joint occupancy x density impl selection, plan-cache pruned-variant
keys, and pruned LeNet/AlexNet/VGG end-to-end through the serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dead_channel_band
from repro.graph import init_graph
from repro.graph.registry import get_op, unit_model_us
from repro.models.cnn import shift_dead_channels
from repro.pipeline import plan_network, run_plan
from repro.serving import plan_key
from repro.sparse_weights import (
    conv2d_bsr,
    conv2d_bsr_ref,
    conv_weight_matrix,
    prune_graph_params,
    prune_matrix,
    weight_block,
    weight_block_density,
)


def _graph(model: str):
    from repro.launch.serve_cnn import serving_graph

    return serving_graph(model)


def _calib(graph, n=4, seed=0, dead_frac=0.5):
    c, h, w = graph.in_shape
    return dead_channel_band(
        jax.random.uniform(jax.random.PRNGKey(seed), (n, c, h, w)), dead_frac)


@pytest.fixture(scope="module")
def vgg():
    graph = _graph("vgg19")
    params = shift_dead_channels(init_graph(jax.random.PRNGKey(0), graph))
    return graph, params


# ---------------------------------------------------------------------------
# pruning format
# ---------------------------------------------------------------------------


def test_prune_matrix_zeros_whole_blocks_lowest_norm_first():
    bt, bf = 8, 16
    m = np.ones((2 * bt, 4 * bf), np.float32)
    m[:bt, :bf] = 0.01  # weakest block
    m[:bt, bf : 2 * bf] = 0.1  # second weakest
    pruned, kept, total = prune_matrix(m, 0.75, (bt, bf))
    assert (kept, total) == (6, 8)
    assert np.abs(pruned[:bt, :2 * bf]).max() == 0.0  # both weak blocks gone
    assert np.array_equal(pruned[bt:], m[bt:])  # strong blocks untouched


def test_prune_matrix_ragged_edges_and_identity():
    m = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (13, 50)))
    same, kept, total = prune_matrix(m, 1.0, (8, 16))
    assert np.array_equal(same, m) and kept == total
    pruned, kept, total = prune_matrix(m, 0.5, (8, 16))
    assert pruned.shape == m.shape
    assert kept == int(np.ceil(0.5 * total))


def test_prune_matrix_never_counts_dead_blocks_as_kept():
    """Re-pruning already-pruned weight must report the LIVE density (what
    weight_block_density will measure), not the nominal top-k size."""
    bt, bf = 8, 16
    m = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (2 * bt, 4 * bf)))
    half, _, _ = prune_matrix(m, 0.5, (bt, bf))  # 4 of 8 blocks dead
    same, kept, total = prune_matrix(half, 1.0, (bt, bf))
    assert np.array_equal(same, half)
    assert (kept, total) == (4, 8)
    again, kept, total = prune_matrix(half, 0.75, (bt, bf))  # top-6 incl dead
    assert kept == 4  # only the 4 live blocks count
    assert np.array_equal(again, half)


def test_weight_block_density_measures_pruned_conv():
    w = jax.random.normal(jax.random.PRNGKey(2), (16, 16, 3, 3))
    assert weight_block_density(w) == 1.0
    mat = np.asarray(conv_weight_matrix(w))
    block = weight_block(*mat.shape)
    pruned, kept, total = prune_matrix(mat, 0.3, block)
    d = weight_block_density(jnp.asarray(pruned.reshape(w.shape)))
    assert abs(d - kept / total) < 1e-6


def test_prune_graph_params_report_and_per_layer_override(vgg):
    graph, params = vgg
    probe = _calib(graph)
    pruned, rep = prune_graph_params(params, 0.3, graph,
                                     per_layer={0: 1.0}, probe=probe)
    by = rep.by_name()
    assert by["conv_1"].achieved_density == 1.0  # override honored
    assert by["conv_2"].achieved_density <= 0.5
    assert 0.0 < rep.density < 1.0
    assert rep.max_logit_drift is not None and rep.top1_agreement is not None
    # pruned params keep shapes and really carry the reported density
    for w, lp in zip(pruned["conv"], ("conv_1", "conv_2", "conv_3")):
        assert abs(weight_block_density(w) - by[lp].achieved_density) < 1e-6


def test_prune_graph_params_accepts_legacy_layout():
    from repro.configs.vgg19_sparse import CNNConfig
    from repro.models.cnn import init_cnn

    ccfg = CNNConfig(name="legacy-tiny", in_channels=8, img_size=8,
                     plan=((8, 1),), n_classes=4)
    params = init_cnn(jax.random.PRNGKey(0), ccfg)
    pruned, rep = prune_graph_params(params, 0.5)
    assert set(pruned) == {"conv", "dense"}  # normalized to graph-native
    assert len(pruned["conv"]) == 1 and len(pruned["dense"]) == 2


# ---------------------------------------------------------------------------
# conv2d_bsr vs the dense-on-pruned reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("c,o,hw,k,stride", [(16, 16, 12, 3, 1), (3, 24, 20, 5, 2),
                                             (8, 8, 9, 3, 1)])
@pytest.mark.parametrize("density", [1.0, 0.3])
def test_conv2d_bsr_matches_dense_on_pruned(c, o, hw, k, stride, density):
    w = jax.random.normal(jax.random.PRNGKey(c * o), (o, c, k, k)) * 0.1
    mat = np.asarray(conv_weight_matrix(w))
    pruned, _, _ = prune_matrix(mat, density, weight_block(*mat.shape))
    w = jnp.asarray(pruned.reshape(w.shape))
    x = jax.random.normal(jax.random.PRNGKey(hw), (2, c, hw, hw))
    y = conv2d_bsr(x, w, stride=stride)
    ref = conv2d_bsr_ref(x, w, stride=stride)
    assert y.shape == ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    # single-image path agrees with its batched row
    y0 = conv2d_bsr(x[0], w, stride=stride)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y[0]),
                               rtol=2e-4, atol=2e-4)


def test_conv2d_bsr_fully_pruned_weights_give_zero():
    w = jnp.zeros((8, 8, 3, 3))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 10, 10))
    assert np.abs(np.asarray(conv2d_bsr(x, w))).max() == 0.0


# ---------------------------------------------------------------------------
# registry + cost model
# ---------------------------------------------------------------------------


def test_registry_bsr_op_flags():
    op = get_op("conv", "bsr")
    assert op.weight_sparse and op.pallas and not op.sparse
    assert op.fused_with is None  # no fused-pool variant


def test_bsr_cost_scales_with_weight_density_not_occupancy(vgg):
    graph, _ = vgg
    unit = graph.units()[0]
    us = [unit_model_us("conv", "bsr", unit, weight_density=d)
          for d in (1.0, 0.5, 0.1)]
    assert us[0] > us[1] > us[2]  # pruning buys modeled time
    a = unit_model_us("conv", "bsr", unit, occupancy=0.1, weight_density=0.5)
    b = unit_model_us("conv", "bsr", unit, occupancy=1.0, weight_density=0.5)
    assert a == b  # activation occupancy buys BSR nothing
    # density -> 0: BSR undercuts ECR even on a LOW-occupancy layer
    ecr = unit_model_us("conv", "ecr_pallas", unit, occupancy=0.3)
    assert unit_model_us("conv", "bsr", unit, weight_density=0.05) < ecr


# ---------------------------------------------------------------------------
# planner impl selection (the joint occupancy x density decision)
# ---------------------------------------------------------------------------


def test_density_one_never_selects_bsr(vgg):
    graph, params = vgg
    for th in (0.0, 0.75, 1.0):
        plan = plan_network(params, _calib(graph), graph, occ_threshold=th,
                            block_c=8)
        assert plan.counts()["bsr"] == 0
        assert all(lp.weight_density == 1.0 for lp in plan.layers)


def test_low_density_prefers_bsr_on_low_occupancy_layers(vgg):
    graph, params = vgg
    pruned, _ = prune_graph_params(params, 0.3, graph)
    plan = plan_network(pruned, _calib(graph), graph, block_c=8)
    bsr = [lp for lp in plan.layers if lp.impl == "bsr"]
    assert bsr, "density 0.3 must hand at least one layer to BSR"
    # at least one BSR placement displaced a layer the occupancy rule had
    # already marked sparse — weight sparsity out-modeled activation sparsity
    assert any(lp.occupancy <= plan.occ_threshold for lp in bsr)
    assert all(lp.weight_density <= 0.5 for lp in bsr)


def test_bsr_threshold_gates_selection(vgg):
    graph, params = vgg
    pruned, _ = prune_graph_params(params, 0.3, graph)
    plan = plan_network(pruned, _calib(graph), graph, block_c=8,
                        bsr_threshold=0.0)
    assert plan.counts()["bsr"] == 0  # gate closed: densities are all > 0


def test_validate_plan_rejects_density_mismatch(vgg):
    graph, params = vgg
    pruned, _ = prune_graph_params(params, 0.3, graph)
    plan = plan_network(pruned, _calib(graph), graph, block_c=8)
    assert plan.counts()["bsr"] > 0
    calib = _calib(graph, seed=7)
    run_plan(plan, pruned, calib)  # planned-over params: fine
    with pytest.raises(ValueError, match="weight block density"):
        run_plan(plan, params, calib)  # unpruned params under a BSR plan


def test_plan_key_distinguishes_pruned_variants(vgg):
    graph, params = vgg
    calib = _calib(graph)
    p03, _ = prune_graph_params(params, 0.3, graph)
    p01, _ = prune_graph_params(params, 0.1, graph)
    plan03 = plan_network(p03, calib, graph, block_c=8)
    plan01 = plan_network(p01, calib, graph, block_c=8)
    dense_plan = plan_network(params, calib, graph, block_c=8)
    assert plan_key(4, dense_plan).weight_sig == ()  # pre-BSR keys unchanged
    k03, k01 = plan_key(4, plan03), plan_key(4, plan01)
    assert k03.weight_sig and k03 != k01  # two pruned variants never collide


# ---------------------------------------------------------------------------
# end-to-end: pruned model zoo through plan_network -> run_plan -> Engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["lenet", "alexnet", "vgg19"])
def test_pruned_model_end_to_end(model):
    from repro.graph.executor import run_graph

    graph = _graph(model)
    params = shift_dead_channels(init_graph(jax.random.PRNGKey(0), graph))
    calib = _calib(graph)
    pruned, rep = prune_graph_params(params, 0.3, graph)
    assert rep.density <= 0.55  # coarse block grids quantize, but must prune
    plan = plan_network(pruned, calib, graph, block_c=8)
    assert plan.counts()["bsr"] >= 1
    logits = run_plan(plan, pruned, calib)
    ref = run_graph(graph, pruned, calib, impl="dense")
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_pruned_engine_serve_matches_run_plan(vgg):
    from repro.serving import Engine

    graph, params = vgg
    pruned, _ = prune_graph_params(params, 0.3, graph)
    calib = _calib(graph)
    eng = Engine(pruned, graph=graph, calib=calib, block_c=8, mesh=None,
                 max_batch=4)
    assert eng.plan.counts()["bsr"] >= 1
    imgs = [np.asarray(calib[i]) for i in range(3)]
    served = eng.serve(imgs)
    ref = np.asarray(run_plan(eng.plan, pruned, jnp.stack(
        [jnp.asarray(i) for i in imgs])))
    np.testing.assert_allclose(served, ref, rtol=1e-5, atol=1e-5)
    assert eng.stats()["plan_bsr"] >= 1
