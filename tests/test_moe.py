"""MoE routing invariants (property-based) + brute-force equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models.moe import _capacity, init_moe, moe_ffn
from repro.models.layers import unzip_params

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = get_config("arctic-480b", reduced=True)
    kw.setdefault("dense_residual_ff", False)
    kw.setdefault("n_shared_experts", 0)
    return dataclasses.replace(base, **kw)


def _params(cfg, key=KEY):
    px = init_moe(key, cfg)
    vals, _ = unzip_params(px)
    return vals


def test_brute_force_equivalence_no_drops():
    """With no-drop capacity, MoE == explicit per-token top-k expert sum."""
    cfg = _cfg(n_experts=4, top_k=2, capacity_factor=8.0)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, cfg.d_model)) * 0.5
    y, aux = moe_ffn(p, x, cfg)

    # brute force
    xt = np.asarray(x.reshape(-1, cfg.d_model))
    logits = xt @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    w1, w3, w2 = (np.asarray(p[k], np.float32) for k in ("w1", "w3", "w2"))
    ref = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[: cfg.top_k]
        g = probs[t][top]
        g = g / g.sum()
        for gi, e in zip(g, top):
            h = xt[t] @ w1[e]
            h = h / (1 + np.exp(-h)) * (xt[t] @ w3[e])  # silu gate
            ref[t] += gi * (h @ w2[e])
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model), ref,
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), t=st.integers(2, 16))
def test_hypothesis_routing_invariants(seed, t):
    cfg = _cfg(n_experts=8, top_k=2, capacity_factor=1.0)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, t, cfg.d_model))
    y, aux = moe_ffn(p, x, cfg)
    # output finite, aux >= 0 and bounded (aux = coef * E * sum(me*ce) <= coef*E)
    assert np.isfinite(np.asarray(y)).all()
    a = float(aux)
    assert 0.0 <= a <= cfg.router_aux_loss * cfg.n_experts
    # capacity respected: each expert receives at most `cap` tokens
    cap = _capacity(t, cfg)
    logits = x.reshape(t, -1) @ p["router"].astype(jnp.float32)
    _, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    counts = np.bincount(np.asarray(eidx).reshape(-1), minlength=cfg.n_experts)
    # (over-capacity is allowed in the *assignments*; the buffer drops them —
    # verified by construction since slots >= cap scatter out of bounds)
    assert cap >= 8


def test_dropped_tokens_get_zero_routed_output():
    """capacity_factor tiny -> most tokens dropped -> routed output ~ 0."""
    cfg = _cfg(n_experts=8, top_k=1, capacity_factor=0.01)
    p = _params(cfg)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model))
    y, _ = moe_ffn(p, x, cfg)
    # at most E * cap = 8 * 8 rows can be nonzero
    nonzero_rows = int((jnp.abs(y.reshape(64, -1)).max(-1) > 1e-6).sum())
    assert nonzero_rows <= 8 * 8


def test_shared_expert_and_dense_residual():
    cfg = _cfg(n_experts=4, top_k=2, n_shared_experts=1)
    p = _params(cfg)
    x = jax.random.normal(KEY, (2, 4, cfg.d_model))
    y, _ = moe_ffn(p, x, cfg)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()


def test_grad_flows_through_router():
    cfg = _cfg(n_experts=4, top_k=2)
    p = _params(cfg)
    x = jax.random.normal(KEY, (1, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_ffn(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0.0
