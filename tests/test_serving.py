"""Serving subsystem: batcher deadline contract, plan-cache compile counts,
engine-vs-run_plan exactness, occupancy-drift re-planning, autotune selection,
and the planner edge cases serving relies on (validation, occ_threshold=0,
block_c override, batch=1 occupancy)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vgg19_sparse import CNNConfig
from repro.kernels.ecr_conv.ops import channel_block_occupancy
from repro.models.cnn import init_cnn
from repro.pipeline import measure_occupancy, plan_network, run_plan
from repro.serving import (
    Engine,
    MicroBatcher,
    SimClock,
    autotune,
    bucket_sizes,
    plan_key,
    replay_stream,
)

TINY = CNNConfig(name="vgg-serve-tiny", in_channels=16, img_size=12,
                 plan=((8, 1), (16, 1)), n_classes=4)


@pytest.fixture(scope="module")
def params():
    return init_cnn(jax.random.PRNGKey(0), TINY)


def _img(seed, dead=8):
    """Single request image; `dead` trailing channels are zero. All test
    requests share one dead-channel band, so the shared-union compaction
    permutation is identical for ANY subset of them — the condition under
    which engine batching is bit-exact against the whole-batch reference."""
    x = np.array(jax.random.uniform(jax.random.PRNGKey(seed),
                                    (16, TINY.img_size, TINY.img_size)), np.float32)
    if dead:
        x[16 - dead:] = 0.0
    return jnp.asarray(x)


def _engine(params, **kw):
    kw.setdefault("calib", jnp.stack([_img(900), _img(901)]))
    kw.setdefault("occ_threshold", 0.9)
    kw.setdefault("block_c", 8)
    kw.setdefault("max_batch", 4)
    kw.setdefault("deadline_s", 0.005)
    kw.setdefault("clock", SimClock())
    return Engine(params, TINY, **kw)


# ---------------------------------------------------------------------------
# batcher: buckets and the deadline contract (simulated clock)
# ---------------------------------------------------------------------------


def test_bucket_sizes():
    assert bucket_sizes(8) == (1, 2, 4, 8)
    assert bucket_sizes(6) == (1, 2, 4, 6)  # non-pow2 cap HONORED, not clamped
    assert bucket_sizes(1) == (1,)
    with pytest.raises(ValueError):
        bucket_sizes(0)


def test_batcher_honors_non_power_of_two_max_batch():
    """max_batch=6 used to be silently clamped to 4; the requested cap must
    now be a real bucket (a full 6-queue forms one 6-batch, not 4 + leftovers)."""
    clock = SimClock()
    b = MicroBatcher(max_batch=6, deadline_s=1.0, clock=clock)
    assert b.max_batch == 6 and b.buckets == (1, 2, 4, 6)
    for i in range(6):
        b.submit(i)
    batch = b.ready()  # full bucket dispatches immediately at the true cap
    assert batch is not None and batch.n_real == 6 and batch.bucket == 6
    assert b.pending() == 0
    b.submit(99)
    clock.advance(1.1)
    assert b.ready().bucket == 2  # pow2 buckets below the cap still serve


def test_batcher_align_device_slices():
    """align=N (sharded serving): executed buckets are N-multiples whose
    per-device slice keeps the min_bucket bit-exactness floor."""
    b = MicroBatcher(max_batch=8, deadline_s=1.0, clock=SimClock(), align=4)
    assert b.exec_buckets() == (8,)  # 8/4 = 2 >= min_bucket; 4/4 = 1 < floor
    assert b.bucket_for(1) == 8 and b.bucket_for(8) == 8
    b2 = MicroBatcher(max_batch=8, deadline_s=1.0, clock=SimClock(), align=2)
    assert b2.exec_buckets() == (4, 8)
    assert b2.bucket_for(3) == 4
    with pytest.raises(ValueError, match="multiple of"):
        MicroBatcher(max_batch=6, deadline_s=1.0, clock=SimClock(), align=4)
    # a full bucket that would leave shards below the min_bucket floor must
    # REFUSE, not silently clamp away the bit-exactness contract
    with pytest.raises(ValueError, match="floor"):
        MicroBatcher(max_batch=8, deadline_s=1.0, clock=SimClock(), align=8)
    b3 = MicroBatcher(max_batch=8, deadline_s=1.0, clock=SimClock(), align=8,
                      min_bucket=1)  # explicit opt-in to M=1 shards
    assert b3.exec_buckets() == (8,)


def test_batcher_full_bucket_dispatches_immediately():
    clock = SimClock()
    b = MicroBatcher(max_batch=4, deadline_s=1.0, clock=clock)
    for i in range(4):
        b.submit(i)
    batch = b.ready()  # no time has passed: full bucket, not the deadline
    assert batch is not None and batch.n_real == 4 and batch.bucket == 4
    assert b.pending() == 0


def test_batcher_never_exceeds_deadline_simulated_clock():
    """Drive a jittery arrival pattern; every request must be FORMED into a
    batch within deadline_s of its arrival, provided the driver polls by
    next_deadline() — the engine/replay_stream contract."""
    clock = SimClock()
    deadline = 0.010
    b = MicroBatcher(max_batch=4, deadline_s=deadline, clock=clock)
    arrivals = [0.0, 0.001, 0.002, 0.015, 0.0151, 0.04, 0.08, 0.0805, 0.081,
                0.0815, 0.0816, 0.3]
    formed = {}  # id -> (t_arrival, t_formed)
    i = 0
    while len(formed) < len(arrivals):
        t_arr = arrivals[i] if i < len(arrivals) else None
        t_dl = b.next_deadline()
        if t_arr is not None and (t_dl is None or t_arr <= t_dl):
            clock.set(t_arr)
            b.submit(i, now=t_arr)
            i += 1
        else:
            clock.set(t_dl)
        while True:
            batch = b.ready()
            if batch is None:
                break
            for r in batch.requests:
                formed[r.id] = (r.t_arrival, batch.t_formed)
    waits = [tf - ta for ta, tf in formed.values()]
    assert max(waits) <= deadline + 1e-12
    assert len(formed) == len(arrivals)


def test_batcher_pads_to_power_of_two_buckets():
    clock = SimClock()
    b = MicroBatcher(max_batch=8, deadline_s=0.01, clock=clock, min_bucket=1)
    for i in range(3):
        b.submit(i)
    clock.advance(0.011)
    batch = b.ready()
    assert batch.n_real == 3 and batch.bucket == 4  # ragged tail pads 3 -> 4
    b.submit(99)
    clock.advance(0.02)
    assert b.ready().bucket == 1  # min_bucket=1 admits the single bucket
    b2 = MicroBatcher(max_batch=8, deadline_s=0.01, clock=clock)  # default floor
    b2.submit(1)
    clock.advance(0.02)
    assert b2.ready().bucket == 2  # lone request pads to the 2-bucket


# ---------------------------------------------------------------------------
# engine: exactness against run_plan + compile counting
# ---------------------------------------------------------------------------


def test_engine_matches_run_plan_fp32_exact(params):
    """Acceptance: N single-image requests through the engine == run_plan on
    the same images, bit-for-bit, across ragged buckets (5 -> [4, 2-padded])."""
    eng = _engine(params)
    imgs = [_img(i) for i in range(5)]
    served = eng.serve(imgs)
    ref = np.asarray(run_plan(eng.plan, params, jnp.stack(imgs), TINY))
    assert served.dtype == np.float32
    assert np.array_equal(served, ref)
    assert eng.stats()["pad_samples"] > 0  # the ragged tail really was padded


def test_engine_poll_drains_burst_of_full_buckets(params):
    """A burst of 3x max_batch requests leaves three full buckets due AT
    ONCE; one poll() must drain them all (the old one-batch-per-poll loop
    stranded the rest until the next deadline poll, so a queued request
    could wait arbitrarily longer than deadline_s under load)."""
    eng = _engine(params)  # max_batch=4, SimClock
    imgs = [_img(7000 + i) for i in range(12)]
    for img in imgs:
        eng.submit(img)
    results = eng.poll()
    assert len(results) == 12  # every due full bucket served in this poll
    assert eng.batcher.pending() == 0
    assert sorted(r.id for r in results) == list(range(12))
    assert eng.stats()["batches"] == 3
    # and the burst's logits are still the whole-batch reference, per bucket
    ref = np.asarray(run_plan(eng.plan, params, jnp.stack(imgs), TINY))
    by_id = {r.id: r.logits for r in results}
    assert np.array_equal(np.stack([by_id[i] for i in range(12)]), ref)
    assert eng.poll() == []  # nothing left due


def test_engine_serve_empty_request_list(params):
    """serve([]) used to crash in np.stack on the empty result list; it must
    return an empty (0, n_classes) float32 array instead."""
    eng = _engine(params)
    out = eng.serve([])
    assert out.shape == (0, TINY.n_classes) and out.dtype == np.float32
    assert eng.stats()["batches"] == 0 and eng.stats()["requests"] == 0
    # and the engine still serves normally afterwards
    assert eng.serve([_img(0)]).shape == (1, TINY.n_classes)


def test_engine_non_power_of_two_max_batch_exact(params):
    """max_batch=6 end-to-end: the cap bucket compiles and stays bit-exact
    against the whole-batch reference."""
    eng = _engine(params, max_batch=6)
    imgs = [_img(7100 + i) for i in range(6)]
    served = eng.serve(imgs)
    ref = np.asarray(run_plan(eng.plan, params, jnp.stack(imgs), TINY))
    assert np.array_equal(served, ref)
    assert eng.stats()["batches"] == 1  # one full 6-bucket, no 4+2 split


def test_engine_exact_on_fully_dense_requests(params):
    """No dead channels at all: compaction is the identity for every batch
    composition, so exactness must hold here too (and the plan goes dense)."""
    eng = _engine(params, occ_threshold=0.5,
                  calib=jnp.stack([_img(900, dead=0), _img(901, dead=0)]))
    assert all(lp.impl == "dense" for lp in eng.plan.layers)
    imgs = [_img(i, dead=0) for i in range(3)]
    served = eng.serve(imgs)
    ref = np.asarray(run_plan(eng.plan, params, jnp.stack(imgs), TINY))
    assert np.array_equal(served, ref)


def test_plan_cache_compiles_each_key_exactly_once(params):
    eng = _engine(params)
    # one program per executable bucket (bucket 1 is floored away, see batcher)
    assert eng.warmup() == len(eng.batcher.exec_buckets())
    compiles = eng.cache.stats()["compiles"]
    for wave in range(3):  # repeat traffic over every bucket shape
        for n in (1, 2, 3, 4, 7):
            eng.serve([_img(1000 + wave * 10 + i) for i in range(n)])
    stats = eng.stats()
    assert stats["compiles"] == compiles  # the stream NEVER compiled
    assert stats["hits"] > 0 and stats["replans"] == 0


def test_plan_cache_lru_eviction_and_counters():
    """Boundedness regression: the cache must evict in LRU order (a hit
    refreshes recency), count every hit/miss/eviction, and recompile an
    evicted key on its next use — graphs x meshes x pruned densities
    multiply keys, so an unbounded cache is a serving memory leak."""
    from repro.serving import PlanCache, PlanKey

    def key(b):
        return PlanKey(bucket=b, block_c=8, occ_sig=(("conv", "dense"),))

    cache = PlanCache(max_entries=2)
    assert cache.get_or_compile(key(1), None, lambda: "exe1") == "exe1"
    assert cache.get_or_compile(key(2), None, lambda: "exe2") == "exe2"
    # hit on key(1) refreshes it: key(2) is now least-recently-used
    assert cache.get_or_compile(key(1), None, lambda: "BUG") == "exe1"
    assert cache.get_or_compile(key(3), None, lambda: "exe3") == "exe3"
    assert key(2) not in cache and key(1) in cache and key(3) in cache
    assert len(cache) == 2
    assert cache.stats() == {"entries": 2, "compiles": 3, "hits": 1,
                             "misses": 3, "evictions": 1}
    # the evicted key is a real miss again: build runs a second time
    assert cache.get_or_compile(key(2), None, lambda: "exe2b") == "exe2b"
    assert cache.stats()["compiles"] == 4 and cache.stats()["evictions"] == 2


def test_plan_key_distinguishes_schedule_not_occupancy(params):
    sparse = plan_network(params, jnp.stack([_img(0)]), TINY,
                          occ_threshold=0.9, block_c=8)
    sparse2 = plan_network(params, jnp.stack([_img(1)]), TINY,
                           occ_threshold=0.9, block_c=8)
    dense = plan_network(params, jnp.stack([_img(0, dead=0)]), TINY,
                         occ_threshold=0.9, block_c=8)
    assert plan_key(4, sparse) == plan_key(4, sparse2)  # same schedule: one program
    assert plan_key(4, sparse) != plan_key(4, dense)
    assert plan_key(4, sparse) != plan_key(2, sparse)


def test_plan_key_one_device_mesh_is_the_unsharded_key(params):
    """A 1-device mesh compiles the same program as no mesh at all, so the
    keys must collide (mesh_shape only appears at >= 2 devices; the sharded
    subprocess tests cover the distinct 2-/4-device keys)."""
    from repro.parallel import data_mesh

    plan = plan_network(params, jnp.stack([_img(0)]), TINY,
                        occ_threshold=0.9, block_c=8)
    assert plan_key(4, plan).mesh_shape == ()
    assert plan_key(4, plan, data_mesh(1)) == plan_key(4, plan)


# ---------------------------------------------------------------------------
# occupancy drift -> re-plan (hysteresis, atomic swap)
# ---------------------------------------------------------------------------


def test_engine_replans_on_occupancy_drift(params):
    """Plan on sparse calibration, then serve dense traffic: the observed
    occupancy EMA leaves the band and the engine re-plans to dense."""
    eng = _engine(params, ema_alpha=0.5, replan_band=0.2, replan_cooldown=0)
    assert any(lp.impl != "dense" for lp in eng.plan.layers)
    old_key = plan_key(0, eng.plan)
    for wave in range(3):
        eng.serve([_img(2000 + wave * 10 + i, dead=0) for i in range(4)])
    assert eng.n_replans >= 1
    assert plan_key(0, eng.plan) != old_key
    assert all(lp.impl == "dense" for lp in eng.plan.layers)


def test_engine_stable_traffic_never_replans(params):
    """Hysteresis: traffic matching the calibration stays inside the band."""
    eng = _engine(params, replan_band=0.2)
    for wave in range(3):
        eng.serve([_img(3000 + wave * 10 + i) for i in range(4)])
    assert eng.n_replans == 0


def test_engine_background_replan_swaps_atomically(params):
    eng = _engine(params, ema_alpha=0.5, replan_band=0.2, replan_cooldown=0,
                  replan_async=True)
    eng.serve([_img(4000 + i, dead=0) for i in range(4)])
    eng.join_replan()  # wait for the worker, then adopt at the swap point
    eng.serve([_img(4100 + i, dead=0) for i in range(4)])
    assert eng.n_replans >= 1
    assert all(lp.impl == "dense" for lp in eng.plan.layers)


def test_replay_stream_latency_accounting(params):
    eng = _engine(params, deadline_s=0.004)
    imgs = [_img(5000 + i) for i in range(6)]
    results = replay_stream(eng, imgs, rate_rps=500.0)
    assert len(results) == len(imgs)
    assert sorted(r.id for r in results) == list(range(6))
    for r in results:
        assert r.t_done >= r.t_arrival  # service time is charged to the clock
        assert np.isfinite(r.latency_s)


# ---------------------------------------------------------------------------
# autotune
# ---------------------------------------------------------------------------


def test_autotune_timing_and_model_modes(params):
    calib = jnp.stack([_img(0), _img(1)])
    res = autotune(params, calib, TINY, thresholds=(0.0, 0.9), block_cs=(8,),
                   iters=2, mode="time")
    assert not res.used_model
    assert len(res.candidates) == 2
    assert res.best.wall_us == min(c.wall_us for c in res.candidates)
    # model mode: deterministic fallback ranking; the sparse plan must model
    # faster than all-dense at 50% dead channels (skipped DMA + MACs)
    res_m = autotune(params, calib, TINY, thresholds=(0.0, 0.9), block_cs=(8,),
                     iters=1, mode="model")
    assert res_m.used_model
    by_th = {c.occ_threshold: c for c in res_m.candidates}
    assert by_th[0.9].model_us < by_th[0.0].model_us
    assert res_m.best.occ_threshold == 0.9
    # the tuned plan still executes correctly
    out = run_plan(res_m.plan, params, calib, TINY)
    ref = run_plan(plan_network(params, calib, TINY, occ_threshold=0.0), params,
                   calib, TINY)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-3)


def test_autotune_dedupes_identical_schedules(params):
    calib = jnp.stack([_img(0, dead=0)])  # dense input: every threshold agrees
    res = autotune(params, calib, TINY, thresholds=(0.0, 0.5, 0.75), block_cs=(8,),
                   iters=1, mode="time")
    walls = {c.wall_us for c in res.candidates}
    assert len(walls) == 1  # one timing shared across the deduped grid points


# ---------------------------------------------------------------------------
# planner edge cases serving relies on (satellites)
# ---------------------------------------------------------------------------


def test_run_plan_rejects_wrong_input_shape(params):
    plan = plan_network(params, jnp.stack([_img(0)]), TINY)
    bad = jnp.zeros((2, 16, 10, 10), jnp.float32)  # wrong H, W
    with pytest.raises(ValueError, match="calibrated for input shape"):
        run_plan(plan, params, bad, TINY)
    with pytest.raises(ValueError, match=r"\(C,H,W\)"):
        run_plan(plan, params, jnp.zeros((16, 12), jnp.float32), TINY)


def test_run_plan_rejects_mismatched_params(params):
    plan = plan_network(params, jnp.stack([_img(0)]), TINY)
    shallow = {"stages": [params["stages"][0]], "fc1": params["fc1"],
               "fc2": params["fc2"]}
    with pytest.raises(ValueError, match="silently truncate"):
        run_plan(plan, shallow, jnp.stack([_img(1)]), TINY)


def test_run_plan_rejects_negative_block_c(params):
    plan = plan_network(params, jnp.stack([_img(0)]), TINY)
    bad = plan.__class__(layers=plan.layers, occ_threshold=plan.occ_threshold,
                         block_c=-8)
    with pytest.raises(ValueError, match="block_c"):
        run_plan(bad, params, jnp.stack([_img(1)]), TINY)


def test_occ_threshold_zero_yields_all_dense_plan(params):
    """occ_threshold=0: only an exactly-zero-occupancy layer may go sparse, so
    any nonzero traffic plans fully dense — the serving escape hatch."""
    calib = jnp.stack([_img(0), _img(1)])  # sparse but nonzero
    plan = plan_network(params, calib, TINY, occ_threshold=0.0)
    assert all(lp.impl == "dense" for lp in plan.layers)
    assert plan.counts() == {"dense": len(plan.layers), "sparse": 0, "fused": 0,
                             "bsr": 0, "int8": 0}


def test_explicit_block_c_override_honored_end_to_end(params, monkeypatch):
    """block_c=8 at plan time must reach every Pallas call in run_plan."""
    import repro.kernels.conv_pool.ops as cp_ops
    import repro.kernels.ecr_conv.ops as ecr_ops

    plan = plan_network(params, jnp.stack([_img(0), _img(1)]), TINY,
                        occ_threshold=1.0, block_c=8)
    assert plan.block_c == 8
    assert all(lp.impl.endswith("_pallas") for lp in plan.layers)
    seen = []
    real_ecr, real_fused = ecr_ops.ecr_conv, cp_ops.fused_conv_pool

    def spy_ecr(x, w, stride=1, interpret=True, block_c=0, **kw):
        seen.append(("ecr", block_c))
        return real_ecr(x, w, stride=stride, interpret=interpret,
                        block_c=block_c, **kw)

    def spy_fused(x, w, stride=1, pool=2, p_s=None, interpret=True, block_c=0, **kw):
        seen.append(("pecr", block_c))
        return real_fused(x, w, stride=stride, pool=pool, p_s=p_s,
                          interpret=interpret, block_c=block_c, **kw)

    monkeypatch.setattr(ecr_ops, "ecr_conv", spy_ecr)
    monkeypatch.setattr(cp_ops, "fused_conv_pool", spy_fused)
    run_plan(plan, params, jnp.stack([_img(2), _img(3)]), TINY)
    assert len(seen) == len(plan.layers)
    assert all(bc == 8 for _, bc in seen)


def test_measure_occupancy_batch1_equals_single_image_compacted():
    """measure_occupancy at batch=1 == the single-image post-compaction
    occupancy of DESIGN.md §2.2 (ceil(n_live/bc)/n_cb)."""
    for seed, sparsity_dead in ((0, 5), (1, 11), (2, 0)):
        x = np.array(jax.random.uniform(jax.random.PRNGKey(seed), (16, 9, 9)),
                     np.float32)
        if sparsity_dead:
            x[16 - sparsity_dead:] = 0.0
        x = jnp.asarray(x)
        batched = measure_occupancy(x[None], block_c=8)
        single = channel_block_occupancy(x, 8, compact=True)
        assert batched == pytest.approx(single)


# ---------------------------------------------------------------------------
# benchmark JSON emission (satellite)
# ---------------------------------------------------------------------------


def test_write_bench_json_roundtrip(tmp_path):
    from benchmarks._util import parse_csv_rows, write_bench_json

    rows = parse_csv_rows("name,us_per_call,derived\n"
                          "fig9/conv_1/s1,12.5,dense_us=40 occ=0.50\n"
                          "not a row\n"
                          "serve/rate20,100.0,throughput_rps=19.9 p50_ms=4.0\n")
    assert [r["name"] for r in rows] == ["fig9/conv_1/s1", "serve/rate20"]
    path = write_bench_json("unit", rows, str(tmp_path), extra={"points": [1]})
    data = json.loads(open(path).read())
    assert data["name"] == "unit" and data["points"] == [1]
    assert data["rows"][0]["us_per_call"] == 12.5


def test_serve_benchmark_emits_json(tmp_path):
    """End-to-end smoke of benchmarks/serve_vgg19.py at test scale: the JSON
    artifact must carry throughput/latency per rate point."""
    from benchmarks import serve_vgg19

    path = serve_vgg19.main(reduced=True, json_dir=str(tmp_path),
                            rates=(100.0,), n_requests=4)
    data = json.loads(open(path).read())
    assert data["name"] == "serve_vgg19"
    (point,) = data["points"]
    assert point["rate_rps"] == 100.0
    assert point["throughput_rps"] > 0
    assert point["p95_ms"] >= point["p50_ms"] > 0
    assert point["stream_compiles"] == 0  # steady-state serving never compiles
