"""Pallas kernel sweeps (interpret mode) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import synth_feature_map
from repro.kernels.bsr_matmul.ops import block_schedule, sparse_matmul
from repro.kernels.bsr_matmul.ref import bsr_matmul_ref, bsr_matmul_schedule_ref
from repro.kernels.bsr_matmul.kernel import bsr_matmul_pallas
from repro.kernels.conv_pool.ops import fused_conv_pool
from repro.kernels.conv_pool.ref import conv_pool_ref
from repro.kernels.ecr_conv.ops import channel_block_occupancy, ecr_conv
from repro.kernels.ecr_conv.ref import ecr_conv_ref

KEY = jax.random.PRNGKey(0)


def _sparse(shape, sparsity, seed=0, dtype=jnp.float32):
    return synth_feature_map(jax.random.PRNGKey(seed), shape, sparsity, dtype)


# ---------------------------------------------------------------------------
# bsr_matmul: shape x dtype x sparsity sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,f,d", [(8, 128, 128), (16, 256, 128), (40, 512, 384),
                                   (7, 100, 50), (64, 384, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sparsity", [0.0, 0.6, 0.97])
def test_bsr_matmul_sweep(t, f, d, dtype, sparsity):
    h = _sparse((t, f), sparsity, seed=t + d, dtype=dtype).reshape(t, f)
    w = jax.random.normal(jax.random.PRNGKey(1), (f, d), dtype)
    y = sparse_matmul(h, w)
    ref = bsr_matmul_ref(h, w)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_bsr_schedule_matches_oracle_schedule():
    """Separates schedule bugs from kernel bugs (ECR compaction semantics)."""
    h = np.array(jax.random.normal(KEY, (16, 512)))
    h[0:8, 128:256] = 0
    h[8:16, 0:384] = 0
    h = jnp.asarray(h)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 128))
    ids, cnt = block_schedule(h, 8, 128)
    assert int(cnt[0]) == 3 and int(cnt[1]) == 1
    ref = bsr_matmul_schedule_ref(h, w, np.asarray(ids), np.asarray(cnt), (8, 128, 128))
    y = bsr_matmul_pallas(h, w, ids, cnt, block=(8, 128, 128))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(bsr_matmul_ref(h, w)), atol=1e-4)


def test_bsr_all_zero_rows():
    h = jnp.zeros((16, 256))
    w = jax.random.normal(KEY, (256, 128))
    y = sparse_matmul(h, w)
    assert np.asarray(jnp.abs(y)).max() == 0.0


@pytest.mark.parametrize("block", [(8, 128, 128), (8, 32, 64), (16, 64, 128),
                                   (8, 8, 8)])
@pytest.mark.parametrize("t,f,d", [(24, 192, 96), (7, 100, 50)])
def test_bsr_block_shape_sweep(block, t, f, d):
    """Ref-vs-Pallas agreement across non-default block shapes, including
    ragged (padded) edges — the geometries `conv2d_bsr` actually runs
    (small-layer weight matrices shrink bf below the 128-lane default)."""
    h = _sparse((t, f), 0.7, seed=t + f + block[1])
    w = jax.random.normal(jax.random.PRNGKey(4), (f, d))
    y = sparse_matmul(h, w, block=block)
    np.testing.assert_allclose(np.asarray(y), np.asarray(bsr_matmul_ref(h, w)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block", [(8, 128, 128), (8, 32, 128)])
def test_bsr_all_zero_block_rows(block):
    """A row-block whose every f-block is dead (cnt=0) must flush exact
    zeros — the `@pl.when` guard never fires and the accumulator init is the
    only write. Mixed with live row-blocks so the gather offsets are
    exercised around the dead one."""
    bt, bf, bd = block
    t, f, d = 4 * bt, 4 * bf, 2 * bd
    h = np.array(jax.random.normal(KEY, (t, f)))
    h[bt : 2 * bt] = 0.0  # row-block 1 fully dead
    h[2 * bt :, :2 * bf] = 0.0  # row-blocks 2-3 half dead
    h = jnp.asarray(h)
    w = jax.random.normal(jax.random.PRNGKey(5), (f, d))
    ids, cnt = block_schedule(h, bt, bf)
    assert int(cnt[1]) == 0 and int(cnt[2]) == 2
    y = bsr_matmul_pallas(h, w, ids, cnt, block=block)
    assert np.abs(np.asarray(y[bt : 2 * bt])).max() == 0.0
    sched_ref = bsr_matmul_schedule_ref(h, w, np.asarray(ids), np.asarray(cnt),
                                        block)
    np.testing.assert_allclose(np.asarray(y), np.asarray(sched_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(bsr_matmul_ref(h, w)),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# ecr_conv: channels x stride x dtype sweep, dead channel blocks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("c,o,hw", [(8, 8, 14), (16, 16, 10), (16, 8, 7), (3, 4, 9)])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ecr_conv_sweep(c, o, hw, stride, dtype):
    x = np.array(_sparse((c, hw, hw), 0.6, seed=c * hw, dtype=jnp.float32))
    if c >= 16:
        x[c // 2 : c // 2 + 8] = 0.0  # a dead channel block
    x = jnp.asarray(x, dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (o, c, 3, 3), dtype)
    y = ecr_conv(x, k, stride=stride, block_c=8, block_o=8)
    ref = ecr_conv_ref(x, k, stride)
    tol = 2e-4 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_ecr_conv_all_zero_input():
    x = jnp.zeros((8, 10, 10))
    k = jax.random.normal(KEY, (8, 8, 3, 3))
    y = ecr_conv(x, k, block_c=8, block_o=8)
    assert np.asarray(jnp.abs(y)).max() == 0.0


def test_channel_block_occupancy():
    x = np.array(_sparse((16, 8, 8), 0.3))
    x[0:8] = 0
    occ = channel_block_occupancy(jnp.asarray(x), block_c=8)
    assert occ == 0.5


# ---------------------------------------------------------------------------
# conv_pool fused kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("c,o,hw", [(8, 8, 11), (16, 8, 9)])
@pytest.mark.parametrize("pool", [2, 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv_pool_sweep(c, o, hw, pool, dtype):
    x = _sparse((c, hw, hw), 0.5, seed=hw, dtype=dtype)
    k = jax.random.normal(jax.random.PRNGKey(3), (o, c, 3, 3), dtype)
    y = fused_conv_pool(x, k, stride=1, pool=pool, block_c=8, block_o=8)
    ref = conv_pool_ref(x, k, 1, pool)
    tol = 2e-4 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_conv_pool_relu_applied():
    """PECR applies ReLU before pooling (paper §V-D): outputs must be >= 0."""
    x = _sparse((8, 10, 10), 0.2)
    k = -jnp.abs(jax.random.normal(KEY, (8, 8, 3, 3)))  # all-negative conv
    y = fused_conv_pool(x, k, block_c=8, block_o=8)
    assert float(y.min()) >= 0.0
