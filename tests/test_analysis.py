"""Static verifier (repro.analysis): every documented diagnostic code fires
under one targeted corruption, clean plans verify clean across the model zoo
(dense and pruned+int8), and the serving hook points reject erroring plans
without interrupting serving."""
import json
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    CODES,
    PlanVerificationError,
    check_launch_descriptor,
    check_schedule,
    schedule_ok,
    verify_plan,
)
from repro.analysis.diagnostics import DiagnosticSink, errors
from repro.graph import init_graph
from repro.graph.ir import ConvSpec
from repro.kernels.ecr_conv.ops import ecr_conv_launch
from repro.kernels.conv_pool.ops import conv_pool_launch
from repro.kernels.tiles import TileConfig
from repro.launch.serve_cnn import serving_graph, synth_requests
from repro.models.cnn import shift_dead_channels
from repro.pipeline.planner import plan_network, run_plan
from repro.quant.ops import ecr_conv_int8_launch
from repro.sparse_weights.conv import bsr_conv_launch


def _setup(model, prune=None, int8=False, seed=0):
    graph = serving_graph(model)
    params = shift_dead_channels(init_graph(jax.random.PRNGKey(seed), graph))
    calib = jnp.stack(synth_requests(graph, 2, seed=seed + 1))
    if prune is not None:
        from repro.sparse_weights import prune_graph_params

        params, _ = prune_graph_params(params, prune, graph, probe=calib)
    plan = plan_network(params, calib, graph, int8=int8)
    return plan, params, calib


@pytest.fixture(scope="module")
def lenet():
    return _setup("lenet")


def _codes(diags):
    return {d.code for d in diags}


# ---------------------------------------------------------------------------
# clean plans verify clean (zoo sweep, dense and pruned+int8)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["lenet", "alexnet", "vgg19"])
def test_clean_plan_verifies_clean(model):
    plan, params, calib = _setup(model)
    assert verify_plan(plan, params, batch=int(calib.shape[0])) == []


def test_clean_pruned_int8_plan_verifies_clean():
    plan, params, calib = _setup("lenet", prune=0.3, int8=True)
    assert verify_plan(plan, params, batch=int(calib.shape[0])) == []


def test_every_code_documented_and_tested():
    # the table is the contract: every code this file corrupts toward exists
    assert set(CODES) == {
        "RPA101", "RPA102", "RPA103", "RPA104", "RPA105",
        "RPA201", "RPA202", "RPA203", "RPA204", "RPA205", "RPA206",
        "RPA207", "RPA208", "RPA209", "RPA301", "RPA901",
    }


# ---------------------------------------------------------------------------
# launch geometry (RPA101-RPA105): corrupt a descriptor field, re-check
# ---------------------------------------------------------------------------


def _conv_launch(**kw):
    return ecr_conv_launch(16, 12, 12, 32, 3, 3, **kw)


def test_clean_launches_check_clean():
    assert check_launch_descriptor(_conv_launch(batch=4)) == []
    assert check_launch_descriptor(
        conv_pool_launch(16, 12, 12, 32, pool=2)) == []
    assert check_launch_descriptor(bsr_conv_launch(32, 144, 100)) == []
    assert check_launch_descriptor(ecr_conv_int8_launch(16, 12, 12, 32)) == []


def test_rpa101_grid_mismatch_conv():
    bad = replace(_conv_launch(), n_cb=3)  # 16 channels / block 8 needs 2
    assert "RPA101" in _codes(check_launch_descriptor(bad))
    bad = replace(_conv_launch(), o_pad=5)  # pad no longer minimal
    assert "RPA101" in _codes(check_launch_descriptor(bad))


def test_rpa101_grid_mismatch_bsr():
    good = bsr_conv_launch(32, 144, 100)
    bad = replace(good, nt=good.nt + 1)
    assert "RPA101" in _codes(check_launch_descriptor(bad))
    # the pre-fix sparse_matmul bug: schedule/padding at one geometry, the
    # kernel launched at another — representable as a corrupted block size
    bad = replace(good, bf=good.bf * 2)
    assert "RPA101" in _codes(check_launch_descriptor(bad))


def test_rpa102_out_of_bounds_gather():
    bad = replace(_conv_launch(), stride=0)
    assert "RPA102" in _codes(check_launch_descriptor(bad))
    bad = replace(_conv_launch(), kh=13)  # kernel taller than the input
    assert "RPA102" in _codes(check_launch_descriptor(bad))
    bad = replace(bsr_conv_launch(32, 144, 100), bd=0)
    assert "RPA102" in _codes(check_launch_descriptor(bad))


def test_rpa103_vmem_budget():
    # default resolution at the block_c floor: over budget is a WARN
    big = ecr_conv_launch(8, 2048, 2048, 8)
    diags = check_launch_descriptor(big)
    assert [d.code for d in diags] == ["RPA103"]
    assert diags[0].severity == "warn"
    # an explicitly requested oversized tile is an ERROR: the default
    # policy would have shrunk it, so only a request can get here
    big = ecr_conv_launch(128, 512, 512, 128,
                          tile=TileConfig(block_c=128))
    diags = check_launch_descriptor(big)
    assert [d.code for d in diags] == ["RPA103"]
    assert diags[0].severity == "error"


def test_rpa104_int8_contract():
    good = ecr_conv_int8_launch(16, 12, 12, 32)
    assert good.acc_dtype == "int32"
    assert "RPA104" in _codes(
        check_launch_descriptor(replace(good, acc_dtype="float32")))
    assert "RPA104" in _codes(
        check_launch_descriptor(replace(good, weight_scales="none")))


def test_rpa105_fused_pool_inexact():
    good = conv_pool_launch(16, 12, 12, 32, pool=2)  # oh=ow=10, 2 divides
    assert check_launch_descriptor(good) == []
    bad = replace(good, pool=3)  # 10 % 3 != 0: the kernel would floor
    assert "RPA105" in _codes(check_launch_descriptor(bad))


# ---------------------------------------------------------------------------
# plan invariants (RPA201-RPA209, RPA301): one targeted corruption per code
# ---------------------------------------------------------------------------


def test_rpa201_empty_plan(lenet):
    plan, params, _ = lenet
    diags = verify_plan(replace(plan, layers=()))
    assert _codes(diags) == {"RPA201"}
    assert "empty PipelinePlan" in diags[0].message


def test_rpa201_pre_ir_layer(lenet):
    plan, params, _ = lenet
    bad = replace(plan, layers=(
        replace(plan.layers[0], conv=ConvSpec(0)),) + plan.layers[1:])
    diags = verify_plan(bad)
    assert "RPA201" in _codes(diags)
    assert any("predates the LayerGraph IR" in d.message for d in diags)


def test_rpa201_plan_graph_mismatch(lenet):
    plan, params, _ = lenet
    other = serving_graph("alexnet")
    diags = verify_plan(replace(plan, graph=other))
    assert "RPA201" in _codes(diags)
    assert any("plan/graph mismatch" in d.message for d in diags)


def test_rpa202_graph_fails_shape_inference(lenet):
    plan, params, _ = lenet
    # conv + ReLU only: no Flatten + dense head, so _parse refuses
    bad_graph = replace(plan.graph, nodes=plan.graph.nodes[:2])
    assert "RPA202" in _codes(verify_plan(replace(plan, graph=bad_graph)))


def test_rpa203_illegal_fusion(lenet):
    plan, params, _ = lenet
    # claim fusion on a unit with no pool: the fusion rule must refuse
    bad = replace(plan, layers=(
        replace(plan.layers[0], kind="conv_pool", impl="pecr_pallas",
                pool=None),
    ) + plan.layers[1:], graph=None)  # graph=None isolates the fusion check
    assert "RPA203" in _codes(verify_plan(bad))


def test_rpa204_nonconforming_tile_is_warn(lenet):
    plan, params, _ = lenet
    bad = replace(plan, layers=(
        replace(plan.layers[0], impl="ecr_pallas",
                tile=TileConfig(block_c=1000)),
    ) + plan.layers[1:])
    diags = verify_plan(bad, params, batch=2)
    assert "RPA204" in _codes(diags)
    assert errors(diags) == []  # a fallback is advisory, the plan still runs


def test_rpa205_density_mismatch(lenet):
    plan, params, _ = lenet
    bad = replace(plan, layers=(
        replace(plan.layers[0], kind="conv", impl="bsr", weight_density=0.3),
    ) + plan.layers[1:])
    diags = verify_plan(bad, params, batch=2)  # params are UNPRUNED
    assert "RPA205" in _codes(diags)
    assert any("weight block density" in d.message for d in diags)


def test_rpa206_int8_without_report(lenet):
    plan, params, _ = lenet
    bad = replace(plan, layers=(
        replace(plan.layers[0], impl="ecr_int8"),) + plan.layers[1:],
        int8_report=None)
    diags = verify_plan(bad)
    rpa206 = [d for d in diags if d.code == "RPA206"]
    assert rpa206 and rpa206[0].severity == "warn"


def test_rpa208_unknown_impl(lenet):
    plan, params, _ = lenet
    bad = replace(plan, layers=(
        replace(plan.layers[0], impl="nope"),) + plan.layers[1:])
    assert "RPA208" in _codes(verify_plan(bad))


def test_rpa209_field_sanity(lenet):
    plan, params, _ = lenet
    assert "RPA209" in _codes(verify_plan(replace(plan, block_c=-1)))
    bad = replace(plan, layers=(
        replace(plan.layers[0], occupancy=1.5),) + plan.layers[1:])
    assert "RPA209" in _codes(verify_plan(bad))
    bad = replace(plan, layers=(
        replace(plan.layers[0], weight_density=-0.1),) + plan.layers[1:])
    assert "RPA209" in _codes(verify_plan(bad))


def test_rpa301_params_mismatch(lenet):
    plan, params, _ = lenet
    dropped = {"conv": params["conv"][:-1], "dense": params["dense"]}
    diags = verify_plan(plan, dropped)
    assert "RPA301" in _codes(diags)
    assert any("silently truncate" in d.message for d in diags)
    # wrong C_in on one weight
    w0 = params["conv"][0]
    widened = {"conv": [jnp.concatenate([w0, w0], axis=1)]
               + list(params["conv"][1:]), "dense": params["dense"]}
    diags = verify_plan(plan, widened)
    assert "RPA301" in _codes(diags)


# ---------------------------------------------------------------------------
# schedules (RPA207) + the run-time guard
# ---------------------------------------------------------------------------


def test_rpa207_schedule_invariants():
    ids = np.array([0, 1, 2, 0], np.int32)
    assert schedule_ok(ids, 3, 4)
    assert schedule_ok(ids, 3, 4) and schedule_ok(ids[:3], 3, 3)
    # cnt out of range
    assert not schedule_ok(ids, 5, 4)
    # id out of range
    assert not schedule_ok(np.array([0, 9, 2, 0]), 3, 4)
    # duplicate / unsorted live prefix
    assert not schedule_ok(np.array([0, 0, 2, 0]), 3, 4)
    assert not schedule_ok(np.array([2, 0, 1, 0]), 3, 4)
    # padding beyond cnt is unconstrained (both builders pad arbitrarily)
    assert schedule_ok(np.array([1, 3, 1, 1]), 2, 4)
    # batched form: per-row cnt
    ids2 = np.array([[0, 1, 0], [1, 2, 1]], np.int32)
    assert schedule_ok(ids2, np.array([2, 2]), 3)
    sink = DiagnosticSink()
    check_schedule(ids2, np.array([2, 4]), 3, sink, layer=1)
    assert [d.code for d in sink.items] == ["RPA207"]
    assert sink.items[0].layer == 1


def test_guard_schedule_off_by_default():
    from repro.kernels.schedule_guard import guard_schedule, schedules_checked

    assert not schedules_checked()
    ids = jnp.array([7, 0, 0], jnp.int32)
    out_ids, out_cnt = guard_schedule(ids, jnp.int32(9), 3)
    assert out_ids is ids  # identity: the hot path is untouched


def test_guard_schedule_clamps_when_enabled(monkeypatch):
    monkeypatch.setenv("REPRO_CHECK_SCHEDULES", "1")
    from repro.kernels.schedule_guard import guard_schedule, schedules_checked

    assert schedules_checked()
    ids, cnt = guard_schedule(jnp.array([-1, 7, 2], jnp.int32),
                              jnp.int32(9), 3)
    assert ids.tolist() == [0, 2, 2] and int(cnt) == 3
    # a valid schedule passes through unchanged (values, not identity)
    ids, cnt = guard_schedule(jnp.array([0, 2, 1], jnp.int32),
                              jnp.int32(2), 3)
    assert ids.tolist() == [0, 2, 1] and int(cnt) == 2


def test_guarded_ops_stay_exact(monkeypatch):
    monkeypatch.setenv("REPRO_CHECK_SCHEDULES", "1")
    from repro.core.ecr import conv2d_dense
    from repro.kernels.ecr_conv.ops import ecr_conv

    x = jax.random.uniform(jax.random.PRNGKey(0), (8, 10, 10))
    x = x.at[4:].set(0.0)
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 3, 3))
    np.testing.assert_allclose(ecr_conv(x, w), conv2d_dense(x, w, 1),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# dead imports (RPA901)
# ---------------------------------------------------------------------------


def test_rpa901_dead_imports():
    from pathlib import Path

    from repro.analysis.deadcode import check_dead_imports, dead_modules

    src = Path(__file__).resolve().parents[1] / "src"
    dead, _ = dead_modules(src)
    assert "repro.configs.arctic_480b" in dead  # seed leftover
    assert "repro.launch.train" in dead
    # the CNN spine is reachable
    for mod in ("repro.pipeline.planner", "repro.serving.engine",
                "repro.kernels.ecr_conv.ops", "repro.analysis.plan"):
        assert mod not in dead
    sink = DiagnosticSink()
    check_dead_imports(src, sink)
    assert sink.items and all(d.code == "RPA901" and d.severity == "info"
                              for d in sink.items)


# ---------------------------------------------------------------------------
# hook points: validate_plan wrapper, PlanCache, Engine.hot_swap
# ---------------------------------------------------------------------------


def test_validate_plan_raises_value_error(lenet):
    plan, params, calib = lenet
    bad = replace(plan, layers=(
        replace(plan.layers[0], impl="nope"),) + plan.layers[1:])
    with pytest.raises(ValueError, match="RPA208"):
        run_plan(bad, params, calib)


def test_plan_network_verifies_before_returning(lenet):
    # planning against params missing a conv layer must raise, not emit a
    # broken plan (the zip inside planning would silently truncate)
    plan, params, calib = lenet
    dropped = {"conv": params["conv"][:-1], "dense": params["dense"]}
    with pytest.raises(ValueError):
        plan_network(dropped, calib, plan.graph)


def test_plan_cache_refuses_erroring_plan(lenet):
    from repro.serving import PlanCache, plan_key

    plan, params, _ = lenet
    bad = replace(plan, layers=(
        replace(plan.layers[0], impl="nope"),) + plan.layers[1:])
    cache = PlanCache()
    built = []
    with pytest.raises(PlanVerificationError):
        cache.get_or_compile(plan_key(2, plan), bad,
                             lambda: built.append(1) or "exe")
    assert built == []  # the expensive AOT compile never ran
    # a good plan still compiles, and sentinel plans stay allowed
    assert cache.get_or_compile(plan_key(2, plan), plan, lambda: "exe") == "exe"
    assert cache.get_or_compile(plan_key(4, plan), None, lambda: "exe2") == "exe2"


def test_engine_hot_swap_rejects_corrupted_plan():
    from repro.serving import Engine, SimClock, replay_stream

    graph = serving_graph("lenet")
    params = shift_dead_channels(init_graph(jax.random.PRNGKey(0), graph))
    calib = jnp.stack(synth_requests(graph, 2, seed=1))
    eng = Engine(params, graph, calib=calib, max_batch=2,
                 deadline_s=0.005, clock=SimClock())
    good = eng.plan
    bad = replace(good, layers=(
        replace(good.layers[0], impl="nope"),) + good.layers[1:])
    assert eng.hot_swap(params, plan=bad) is False
    assert eng.plan is good  # rejected atomically, nothing mutated
    assert eng.verify_rejects == 1
    assert eng.stats()["verify_rejects"] == 1
    events = eng.stats()["telemetry"]["replan_events"]
    rejects = [e for e in events if e["kind"] == "verify_reject"]
    assert rejects and "RPA208" in rejects[0]["codes"]
    # serving continues on the old plan...
    results = replay_stream(eng, synth_requests(graph, 4, seed=2),
                            rate_rps=200.0)
    assert len(results) == 4
    # ...and a valid swap still lands
    assert eng.hot_swap(params, plan=good) is True
    assert eng.n_hot_swaps == 1


# ---------------------------------------------------------------------------
# repro-lint CLI
# ---------------------------------------------------------------------------


def test_cli_clean_zoo_json(capsys):
    from repro.analysis.cli import main

    rc = main(["--model", "lenet", "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert doc["n_errors"] == 0
    assert doc["reports"][0]["model"].startswith("lenet")
    assert doc["reports"][0]["plan"]["layers"]


def test_cli_dead_imports(capsys):
    from repro.analysis.cli import main

    rc = main(["--model", "lenet", "--dead-imports", "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0  # infos never fail the lint
    repo = [r for r in doc["reports"] if r["model"] == "<repo>"][0]
    assert any(d["code"] == "RPA901" and "arctic_480b" in d["message"]
               for d in repo["diagnostics"])


def test_cli_pruned_int8(capsys):
    from repro.analysis.cli import main

    rc = main(["--model", "lenet", "--prune-density", "0.3", "--int8",
               "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0 and doc["n_errors"] == 0
