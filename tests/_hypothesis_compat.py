"""`hypothesis` if installed, else a deterministic stand-in (same test API).

The property tests in this suite use a small slice of hypothesis's API:
``given``, ``settings``, and a handful of strategies. `hypothesis` is an
*optional* dependency (declared as the ``test`` extra in pyproject.toml); on a
clean interpreter the suite must still collect and run, so this module
substitutes a deterministic sampler: each strategy draws from a PRNG seeded
per example index, and ``@given`` replays ``max_examples`` fixed samples.
No shrinking, no example database — install hypothesis for the real search.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _DataObject:
        """Stand-in for `st.data()`'s interactive draw object."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=2**30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            hi = min_size + 10 if max_size is None else max_size

            def draw(rng):
                n = rng.randint(min_size, hi)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def data():
            return _Strategy(_DataObject)

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        # applied above @given, so it annotates given()'s wrapper
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            # NOT functools.wraps: the wrapper must hide fn's signature, or
            # pytest would resolve the drawn parameters as fixtures
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                for i in range(n):
                    rng = random.Random(0xEC8 + 7919 * i)
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
