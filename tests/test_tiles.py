"""Tile-geometry threading (DESIGN.md §10): the `TileConfig` resolution
fallback contract, exactness of every Pallas kernel across a geometry grid
(including non-dividing and oversized requests), and the stat-vs-schedule
regression — `channel_block_occupancy` / `occupancy_stat` must measure at
the block size the kernel ACTUALLY resolves, never a silently different one
(the block-size-1 degradation bug on non-dividing shapes)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import synth_feature_map
from repro.kernels.conv_pool.ops import fused_conv_pool
from repro.kernels.ecr_conv.ops import channel_block_occupancy, ecr_conv
from repro.kernels.tiles import (
    DEFAULT_TILE,
    TileConfig,
    as_tile,
    pick_block_c,
    resolve_block_c,
    resolve_bsr_tile,
    resolve_conv_tile,
)
from repro.pipeline.planner import occupancy_stat
from repro.sparse_weights import conv2d_bsr, conv2d_bsr_ref, prune_matrix, weight_block
from repro.sparse_weights.format import conv_weight_matrix


def _fm(shape, sparsity, seed=0):
    return synth_feature_map(jax.random.PRNGKey(seed), shape, sparsity)


# ---------------------------------------------------------------------------
# resolution contract
# ---------------------------------------------------------------------------


def test_tileconfig_falsy_and_key_roundtrip():
    assert not TileConfig()
    assert not DEFAULT_TILE
    t = TileConfig(block_c=12, bt=8)
    assert t
    assert TileConfig.from_key(t.key()) == t
    assert t.key() == (12, 0, 8, 0, 0)


def test_as_tile_precedence():
    # explicit tile wins outright; else legacy block_c lifts into one
    t = TileConfig(block_c=16, block_o=32)
    assert as_tile(t, 8) is t
    assert as_tile(None, 8) == TileConfig(block_c=8)
    assert as_tile(TileConfig(), 0) is DEFAULT_TILE


def test_resolve_block_c_honors_conforming_and_rejects_oversized():
    # conforming: 0 < bc <= max(8, c) honored EXACTLY, even non-dividing
    assert resolve_block_c(12, 12, 16, TileConfig(block_c=12)) == 12
    assert resolve_block_c(12, 12, 16, TileConfig(block_c=16)) == 16
    # oversized / non-positive -> the default policy, independently
    auto = resolve_block_c(12, 12, 16, None)
    assert resolve_block_c(12, 12, 16, TileConfig(block_c=256)) == auto
    assert resolve_block_c(12, 12, 16, TileConfig()) == auto
    # small c: bc request up to max(8, c) still honored
    assert resolve_block_c(4, 4, 3, TileConfig(block_c=8)) == 8


def test_resolve_block_c_dtype_bytes_widens_int8():
    # at a spatial size where fp32 halves the block, int8 fits 4x channels
    h = w = 512  # 512*512*128*4 = 128MB >> budget; shrinks fp32's pick
    bc_f32 = resolve_block_c(h, w, 256, None, dtype_bytes=4)
    bc_i8 = resolve_block_c(h, w, 256, None, dtype_bytes=1)
    assert bc_i8 == min(4 * bc_f32, 128)
    assert pick_block_c(h, w, 256, dtype_bytes=1) == 4 * pick_block_c(h, w, 256)


def test_resolve_conv_tile_bo_clamp():
    bc, bo = resolve_conv_tile(12, 12, 16, 24, TileConfig(block_c=8, block_o=8))
    assert (bc, bo) == (8, 8)
    # default bo = min(128, max(8, o)); an oversized request clamps the same
    assert resolve_conv_tile(12, 12, 16, 24, None)[1] == 24
    assert resolve_conv_tile(12, 12, 16, 24, TileConfig(block_o=999))[1] == 24


def test_resolve_bsr_tile_per_dim_independent_fallback():
    o, k_taps, p = 24, 144, 100
    dbt, dbf = weight_block(o, k_taps)
    # a good bf request survives a silly bd request (and vice versa)
    bt, bf, bd = resolve_bsr_tile(o, k_taps, p, TileConfig(bt=8, bf=16, bd=10 ** 6))
    assert (bt, bf) == (8, 16)
    assert bd == resolve_bsr_tile(o, k_taps, p, None)[2]
    bt, bf, bd = resolve_bsr_tile(o, k_taps, p, TileConfig(bt=10 ** 6, bf=16, bd=32))
    assert bt == dbt and (bf, bd) == (16, 32)
    assert resolve_bsr_tile(o, k_taps, p, TileConfig()) == (dbt, dbf,
                                                           resolve_bsr_tile(o, k_taps, p)[2])


# ---------------------------------------------------------------------------
# exactness across the geometry grid (ECR / PECR / BSR)
# ---------------------------------------------------------------------------

# includes the non-dividing 12-on-16 fallback shape and a small bo
_CONV_GRID = [(8, 8), (8, 32), (12, 8), (16, 128)]


@pytest.mark.parametrize("bc,bo", _CONV_GRID)
def test_ecr_conv_tile_grid_matches_default(bc, bo):
    x = _fm((16, 12, 12), 0.6)
    k = jax.random.normal(jax.random.PRNGKey(1), (24, 16, 3, 3))
    ref = ecr_conv(x, k)
    out = ecr_conv(x, k, block_c=bc, block_o=bo)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("bc,bo", _CONV_GRID)
def test_pecr_fused_tile_grid_matches_default(bc, bo):
    x = _fm((16, 12, 12), 0.6, seed=2)
    k = jax.random.normal(jax.random.PRNGKey(3), (24, 16, 3, 3))
    ref = fused_conv_pool(x, k, 1, 2)
    out = fused_conv_pool(x, k, 1, 2, block_c=bc, block_o=bo)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_ecr_conv_batched_tile_grid_matches_default():
    x = jnp.stack([_fm((16, 12, 12), 0.5, seed=s) for s in range(3)])
    k = jax.random.normal(jax.random.PRNGKey(4), (24, 16, 3, 3))
    ref = ecr_conv(x, k)
    out = ecr_conv(x, k, block_c=12, block_o=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_oversized_request_is_bit_identical_to_default():
    """A non-conforming request FALLS BACK (same resolved geometry), so the
    output must be bit-identical to the default path, not merely close."""
    x = _fm((16, 12, 12), 0.5, seed=5)
    k = jax.random.normal(jax.random.PRNGKey(6), (24, 16, 3, 3))
    ref = ecr_conv(x, k)
    out = ecr_conv(x, k, block_c=4096, block_o=4096)
    assert jnp.array_equal(out, ref)
    pref = fused_conv_pool(x, k, 1, 2)
    pout = fused_conv_pool(x, k, 1, 2, block_c=4096)
    assert jnp.array_equal(pout, pref)


@pytest.mark.parametrize("tile", [TileConfig(bt=8, bf=16, bd=32),
                                  TileConfig(bt=16, bf=32, bd=64),
                                  TileConfig(bt=8, bf=10 ** 6, bd=64),
                                  TileConfig()])
def test_bsr_tile_grid_matches_ref(tile):
    w = jax.random.normal(jax.random.PRNGKey(7), (24, 16, 3, 3))
    wm, _, _ = prune_matrix(np.asarray(conv_weight_matrix(w)), 0.4,
                            weight_block(24, 16 * 9))
    w = jnp.asarray(wm.reshape(w.shape))
    x = _fm((16, 12, 12), 0.3, seed=8)
    ref = conv2d_bsr_ref(x, w)
    out = conv2d_bsr(x, w, tile=tile if tile else None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# stat == executed schedule (the block-size-1 degradation regression)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_c", [8, 12, 16, 128])
def test_channel_block_occupancy_matches_executed_schedule(block_c):
    """The statistic must be measured at the kernel's RESOLVED geometry: for
    a non-dividing block_c the kernel pads the tail up to a block multiple
    (resolve_conv_tile), so the stat equals ceil(n_live/bc)/ceil(c/bc) at
    that same bc — never the silent block-size-1 reading."""
    c, h, w = 16, 10, 10
    x = _fm((c, h, w), 0.0, seed=9)
    x = x.at[5:].set(0.0)  # 5 live channels
    bc = resolve_conv_tile(h, w, c, c, TileConfig(block_c=block_c))[0]
    n_cb = math.ceil(c / bc)
    expect = math.ceil(5 / bc) / n_cb
    got = channel_block_occupancy(x, block_c=block_c, compact=True)
    assert got == pytest.approx(expect)
    # the planner's traced statistic resolves through the SAME rule
    stat = float(occupancy_stat(x[None], block_c))
    assert stat == pytest.approx(expect)
    # and at block_c=12 on c=16 specifically, the resolved size IS 12 (two
    # blocks, one of them padding-tailed) — the old stat degraded to bc=1
    if block_c == 12:
        assert bc == 12 and n_cb == 2 and expect == 0.5


def test_occupancy_stat_tile_beats_legacy_block_c():
    x = _fm((16, 10, 10), 0.0, seed=10).at[5:].set(0.0)
    # an explicit tile takes precedence over the scalar argument
    via_tile = float(occupancy_stat(x[None], 128, tile=TileConfig(block_c=8)))
    via_scalar = float(occupancy_stat(x[None], 8))
    assert via_tile == via_scalar == pytest.approx(1 / 2)


def test_occupancy_stat_int8_geometry():
    # dtype_bytes=1 resolves the auto pick 4x wider only when VMEM binds;
    # with an explicit conforming block the two widths agree exactly
    x = _fm((16, 10, 10), 0.0, seed=11).at[5:].set(0.0)
    a = float(occupancy_stat(x[None], 8, dtype_bytes=4))
    b = float(occupancy_stat(x[None], 8, dtype_bytes=1))
    assert a == b
