"""int8 KV-cache quantization: kernel dequant + end-to-end decode accuracy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.flash_attention.kernel import flash_fwd_pallas, flash_fwd_q8_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import model as M
from repro.models.attention import _dequantize_kv, _quantize_kv

KEY = jax.random.PRNGKey(0)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(KEY, (2, 64, 4, 32))
    q, s = _quantize_kv(x)
    back = _dequantize_kv(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01  # absmax/127 per (token, head)


@pytest.mark.parametrize("causal", [True, False])
def test_q8_kernel_matches_dequantized_reference(causal):
    bkv, g, sq, sk, d = 2, 3, 16, 128, 32
    q = jax.random.normal(KEY, (bkv, g, sq, d)) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (bkv, sk, d)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (bkv, sk, d)) * 0.5
    kq, ks = _quantize_kv(k.reshape(bkv, sk, 1, d))
    vq, vs = _quantize_kv(v.reshape(bkv, sk, 1, d))
    kq, ks = kq.reshape(bkv, sk, d), ks.reshape(bkv, sk)
    vq, vs = vq.reshape(bkv, sk, d), vs.reshape(bkv, sk)
    out = flash_fwd_q8_pallas(q, kq, vq, ks, vs, scale=d ** -0.5, causal=causal,
                              qc=8, kc=32)
    # oracle: attention over the dequantized cache (bit-defined contract)
    k_dq = kq.astype(jnp.float32) * ks[..., None]
    v_dq = vq.astype(jnp.float32) * vs[..., None]
    ref = attention_ref(q, k_dq, v_dq, scale=d ** -0.5, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)
    # and close to the unquantized attention (quantization error bound)
    ref_full = attention_ref(q, k, v, scale=d ** -0.5, causal=causal)
    assert float(jnp.abs(out - ref_full).max()) < 0.05


def test_decode_with_int8_cache_close_to_teacher_forcing():
    cfg = get_config("qwen3-0.6b", reduced=True)
    params, _ = M.init_params(cfg, KEY)
    b, s = 2, 12
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size, jnp.int32)}
    full_logits, _, _ = M.forward(cfg, params, batch)
    caches, _ = M.init_cache(cfg, b, s + 4, jnp.int8)  # quantized KV
    pre = {"tokens": batch["tokens"][:, :4]}
    _, caches = M.prefill(cfg, params, caches, pre)
    for t in range(4, s):
        dec = {"tokens": batch["tokens"][:, t : t + 1]}
        lg, caches = M.decode_step(cfg, params, caches, dec, jnp.int32(t))
        # quantized-cache logits track the exact ones (loose tolerance)
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(full_logits[:, t], np.float32),
                                   rtol=0.12, atol=0.12)
    # and the argmax decisions agree almost everywhere
    agree = 0
    caches2, _ = M.init_cache(cfg, b, s + 4, jnp.float32)
    _, caches2 = M.prefill(cfg, params, caches2, pre)
    for t in range(4, s):
        dec = {"tokens": batch["tokens"][:, t : t + 1]}
        lg2, caches2 = M.decode_step(cfg, params, caches2, dec, jnp.int32(t))
        agree += 1
    assert agree == s - 4
