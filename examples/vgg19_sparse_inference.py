"""The paper's own evaluation scenario: VGG-19 inference with the conv stack
running through dense / ECR / fused-PECR paths, reporting per-layer sparsity,
skipped MACs, and the fused-traffic saving (paper Figs 2, 9, 12) — then the
batched serving view: a whole batch through each path as one set of per-layer
whole-batch calls, and the pipeline planner's per-layer dense/sparse schedule.

Run: PYTHONPATH=src python examples/vgg19_sparse_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vgg19_sparse import CNNConfig
from repro.core import window_stats
from repro.core.pecr import fused_traffic_bytes
from repro.models.cnn import (
    cnn_feature_maps,
    cnn_forward,
    cnn_forward_batch,
    init_cnn,
    shift_dead_channels,
)
from repro.pipeline import plan_network, run_plan

ccfg = CNNConfig(img_size=64)  # full VGG-19 depth/channels, reduced resolution
params = init_cnn(jax.random.PRNGKey(0), ccfg)
img = jax.random.uniform(jax.random.PRNGKey(1), (3, 64, 64))

print("running VGG-19 through the three conv paths...")
logits = {impl: cnn_forward(params, img, impl, ccfg) for impl in ("dense", "ecr", "pecr")}
for impl in ("ecr", "pecr"):
    err = float(jnp.abs(logits[impl] - logits["dense"]).max())
    print(f"  {impl:5s} vs dense: max|delta logits| = {err:.2e}")

print("\nbatched inference (batch as ONE whole-batch call per layer):")
for n in (2, 4):
    batch = jax.random.uniform(jax.random.PRNGKey(2), (n, 3, 64, 64))
    ref = cnn_forward_batch(params, batch, "dense", ccfg)
    for impl in ("ecr", "pecr"):
        out = cnn_forward_batch(params, batch, impl, ccfg)
        err = float(jnp.abs(out - ref).max())
        print(f"  batch={n} {impl:5s} vs dense: max|delta logits| = {err:.2e}")
    # batch == stacked per-image (the batched formats are per-sample exact)
    per = jnp.stack([cnn_forward(params, batch[i], "dense", ccfg) for i in range(n)])
    print(f"  batch={n} dense batch-vs-per-image max delta = "
          f"{float(jnp.abs(ref - per).max()):.2e}")

print("\npipeline planner (per-layer dense/ECR/PECR schedule from measured occupancy):")
# plan on a trained-like net: whole filters die with depth (paper Fig. 2),
# which is the structured sparsity the block schedule can actually skip
trained_like = shift_dead_channels(params)
calib = jax.random.uniform(jax.random.PRNGKey(3), (2, 3, 64, 64))
plan = plan_network(trained_like, calib, ccfg, occ_threshold=0.9, use_pallas=False)
for lp in plan.layers:
    print(f"  conv_{lp.index + 1:2d} stage={lp.stage} occ={lp.occupancy:.2f} "
          f"-> {lp.impl}{' (fused pool)' if lp.impl.startswith('pecr') else ''}")
print(f"  plan counts: {plan.counts()}")
planned = run_plan(plan, trained_like, calib, ccfg)
ref = cnn_forward_batch(trained_like, calib, "dense", ccfg)
print(f"  planned-vs-dense max|delta logits| = {float(jnp.abs(planned - ref).max()):.2e}")

print("\nper-conv-layer sparsity of the feature maps entering each layer:")
maps = cnn_feature_maps(params, img, ccfg)
for i, m in enumerate(maps):
    m = np.asarray(m)
    st = window_stats(m, 3, 3, 1)
    print(f"  conv_{i+1:2d} {str(m.shape):>15s} sparsity={float((m==0).mean()):.2f} "
          f"MACs skipped={st.mul_reduction:.0%}")

print("\nfused conv+pool HBM-traffic saving per stage (PECR, paper Fig. 12):")
c, res = 3, 64
for stage, (cout, n) in enumerate(((64, 2), (128, 2), (256, 4), (512, 4), (512, 4))):
    t = fused_traffic_bytes((cout, res, res), cout, 3, 3, dtype_bytes=2)
    print(f"  stage {stage+1}: saved {t['saved_frac']:.0%} of bytes")
    res //= 2
