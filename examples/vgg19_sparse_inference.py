"""The paper's own evaluation scenario: VGG-19 inference with the conv stack
running through dense / ECR / fused-PECR paths, reporting per-layer sparsity,
skipped MACs, and the fused-traffic saving (paper Figs 2, 9, 12).

Run: PYTHONPATH=src python examples/vgg19_sparse_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vgg19_sparse import CNNConfig
from repro.core import window_stats
from repro.core.pecr import fused_traffic_bytes
from repro.models.cnn import cnn_feature_maps, cnn_forward, init_cnn

ccfg = CNNConfig(img_size=64)  # full VGG-19 depth/channels, reduced resolution
params = init_cnn(jax.random.PRNGKey(0), ccfg)
img = jax.random.uniform(jax.random.PRNGKey(1), (3, 64, 64))

print("running VGG-19 through the three conv paths...")
logits = {impl: cnn_forward(params, img, impl, ccfg) for impl in ("dense", "ecr", "pecr")}
for impl in ("ecr", "pecr"):
    err = float(jnp.abs(logits[impl] - logits["dense"]).max())
    print(f"  {impl:5s} vs dense: max|delta logits| = {err:.2e}")

print("\nper-conv-layer sparsity of the feature maps entering each layer:")
maps = cnn_feature_maps(params, img, ccfg)
total_saved = 0
for i, m in enumerate(maps):
    m = np.asarray(m)
    st = window_stats(m, 3, 3, 1)
    print(f"  conv_{i+1:2d} {str(m.shape):>15s} sparsity={float((m==0).mean()):.2f} "
          f"MACs skipped={st.mul_reduction:.0%}")

print("\nfused conv+pool HBM-traffic saving per stage (PECR, paper Fig. 12):")
c, res = 3, 64
for stage, (cout, n) in enumerate(((64, 2), (128, 2), (256, 4), (512, 4), (512, 4))):
    t = fused_traffic_bytes((cout, res, res), cout, 3, 3, dtype_bytes=2)
    print(f"  stage {stage+1}: saved {t['saved_frac']:.0%} of bytes")
    res //= 2
