"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps on CPU, with the full production stack — sharded state (host
mesh), grad accumulation, async checkpointing, fault-tolerant supervisor
(a failure is injected mid-run to demonstrate restore), straggler monitor.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import logging

import jax

from repro.configs import get_config
from repro.configs.base import ModelConfig, _REGISTRY, _REDUCED
from repro.launch.train import train

logging.basicConfig(level=logging.INFO, format="%(message)s")

# ~100M-parameter member of the qwen3 family (DESIGN.md: reduced configs keep
# the family's structure — GQA + qk_norm + tied embeddings)
QWEN3_100M = dataclasses.replace(
    get_config("qwen3-0.6b"),
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32768, attn_chunk=256,
)
_REGISTRY["qwen3-100m"] = QWEN3_100M
_REDUCED["qwen3-100m"] = QWEN3_100M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()
    n = sum(p.size for p in jax.tree_util.tree_leaves(
        __import__("repro.models.model", fromlist=["model"]).init_params(
            QWEN3_100M, jax.random.PRNGKey(0))[0]))
    print(f"model: qwen3-100m ({n/1e6:.0f}M params)")
    state, history = train(
        "qwen3-100m", steps=args.steps, reduced=True,
        global_batch=args.global_batch, seq_len=args.seq_len, grad_accum=2,
        ckpt_dir="/tmp/repro_train_lm", checkpoint_every=50,
        fail_at=(125,),  # injected node failure -> restore from step-100 ckpt
        resume=False,
    )
    print(f"first loss {history[0]['loss']:.3f} -> final loss {history[-1]['loss']:.3f}")
    assert history[-1]["loss"] < history[0]["loss"]


if __name__ == "__main__":
    main()
