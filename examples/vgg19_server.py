"""Serving walkthrough: single-image VGG requests through the sparsity-aware
engine — dynamic micro-batching, one compile per bucket, exactness against
the offline `run_plan` reference, occupancy-drift re-planning, and the
offline autotuner.

Run: PYTHONPATH=src python examples/vgg19_server.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import init_graph
from repro.launch.serve_cnn import serving_graph, synth_requests
from repro.models.cnn import shift_dead_channels
from repro.pipeline import run_plan
from repro.serving import Engine, SimClock, autotune, replay_stream

# the CLI's reduced net: full VGG-19 is overkill for a walkthrough; trained-
# like nets arrive with whole dead channels (paper Fig. 2), which is the
# structure the engine's plan skips — synth_requests bakes that band in
graph = serving_graph("vgg19", full=False)
params = shift_dead_channels(init_graph(jax.random.PRNGKey(0), graph))

print("1) offline autotune: search (occ_threshold, block_c) on a calibration batch")
calib = jnp.stack(synth_requests(graph, 2, seed=1))
tuned = autotune(params, calib, graph, thresholds=(0.5, 0.9), block_cs=(8,), iters=2)
for c in tuned.candidates:
    print(f"   th={c.occ_threshold:.2f} bc={c.block_c} wall={c.wall_us:8.1f}us "
          f"model={c.model_us:8.3f}us counts={c.plan.counts()}")
print(f"   picked th={tuned.best.occ_threshold} bc={tuned.best.block_c} "
      f"(timing too noisy -> model fallback: {tuned.used_model})")

print("\n2) engine: deadline-bounded micro-batching on a simulated clock")
clock = SimClock()
engine = Engine(params, graph=graph, plan=tuned.plan, max_batch=4, deadline_s=0.005,
                clock=clock)
print(f"   plan: {[f'conv{lp.index+1}:{lp.impl}' for lp in engine.plan.layers]}")
print(f"   buckets={engine.batcher.exec_buckets()}, warmup compiled "
      f"{engine.warmup()} programs")

imgs = synth_requests(graph, 7, seed=100)
results = replay_stream(engine, imgs, rate_rps=400.0)
lat = sorted(r.latency_s * 1e3 for r in results)
stats = engine.stats()
print(f"   served {len(results)} requests in {stats['batches']} batches "
      f"(mean fill {stats['mean_fill']:.2f}), p50 latency {lat[len(lat)//2]:.1f}ms")
print(f"   cache: {stats['compiles']} compiles, {stats['hits']} hits "
      f"(the stream itself never compiles)")

print("\n3) exactness: engine logits == offline run_plan, bit-for-bit")
by_id = {r.id: r.logits for r in results}
served = np.stack([by_id[i] for i in sorted(by_id)])
ref = np.asarray(run_plan(engine.plan, params, jnp.stack(imgs)))
print(f"   fp32-exact: {np.array_equal(served, ref)}")

print("\n4) occupancy drift: dense traffic arrives -> engine re-plans")
dense_imgs = synth_requests(graph, 12, seed=200, dead_frac=0.0)
engine.serve(dense_imgs)
stats = engine.stats()
print(f"   after dense traffic: replans={stats['replans']}, plan now "
      f"{[lp.impl for lp in engine.plan.layers]} "
      f"(occ EMA {stats['occ_ema']})")
