"""Serving example: batched prefill + greedy decode with KV caches, for a
dense GQA model and for two exotic cache families (MLA latent cache, xLSTM
recurrent state) to show the same serving loop drives all of them.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import logging

from repro.launch.serve import serve

logging.basicConfig(level=logging.INFO, format="%(message)s")

for arch in ("qwen3-0.6b", "deepseek-v2-236b", "xlstm-125m"):
    print(f"--- {arch} (reduced config) ---")
    gen = serve(arch, reduced=True, batch=4, prompt_len=32, gen_len=16)
    print(f"generated token matrix {gen.shape}:\n{gen[:2]}")
