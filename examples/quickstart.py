"""Quickstart: the paper's technique in five minutes on CPU.

1. Build a sparse feature map (deep-layer statistics: dead channels + ReLU).
2. Convolve it three ways: dense, ECR (paper §IV), fused PECR (paper §V) —
   all numerically identical.
3. Show the paper's metric (skipped MACs) and the TPU kernel's metric
   (skipped channel blocks after ECR compaction).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import conv2d, conv_pool, synth_feature_map, window_stats
from repro.kernels.ecr_conv.ops import channel_block_occupancy

key = jax.random.PRNGKey(0)

# a deep-layer-like feature map: 256 channels, 14x14, 80% zeros
x = synth_feature_map(key, (256, 14, 14), sparsity=0.8)
kernels = jax.random.normal(jax.random.PRNGKey(1), (128, 256, 3, 3)) * 0.05

dense = conv2d(x, kernels, stride=1, impl="dense")
ecr = conv2d(x, kernels, stride=1, impl="ecr")  # paper Algorithm 1+2
pallas = conv2d(x, kernels, stride=1, impl="ecr_pallas")  # TPU kernel (interpret)
print(f"ECR    vs dense max err: {float(jnp.abs(ecr - dense).max()):.2e}")
print(f"Pallas vs dense max err: {float(jnp.abs(pallas - dense).max()):.2e}")

fused = conv_pool(x, kernels, impl="pecr")  # conv+ReLU+maxpool in one pass
unfused = conv_pool(x, kernels, impl="unfused")
print(f"PECR   vs unfused max err: {float(jnp.abs(fused - unfused).max()):.2e}")

st = window_stats(jax.device_get(x), 3, 3, 1)
print(f"\npaper metric  — multiplications skipped: {st.mul_reduction:.0%} "
      f"(additions: {st.add_reduction:.0%})")
occ = channel_block_occupancy(x, 8, compact=True)
print(f"TPU kernel    — channel blocks skipped after compaction: {1-occ:.0%}")
print(f"                (MXU MACs and HBM->VMEM DMAs both drop by this factor)")
