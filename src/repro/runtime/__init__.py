from repro.runtime.supervisor import Supervisor, SimulatedFailure, FailureInjector
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.elastic import shrink_mesh, reshard_state

__all__ = [
    "Supervisor",
    "SimulatedFailure",
    "FailureInjector",
    "StragglerMonitor",
    "shrink_mesh",
    "reshard_state",
]
