"""Training supervisor: checkpoint/restart fault tolerance + straggler watch.

The supervisor owns the outer loop:

  while steps remain:
      batch  = pipeline.batch_at(step)          # stateless -> replay-exact
      state  = train_step(state, batch)         # may raise (node failure)
      monitor.observe(step_time)                # straggler detection
      every N steps: ckpt.save(step, state)     # async + atomic

On failure (real exception or injected `SimulatedFailure`): restore the latest
complete checkpoint, optionally shrink the mesh (elastic), and continue from
the restored step. The restart test kills a run mid-interval and checks the
resumed loss trajectory is identical to an uninterrupted run.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.supervisor")


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministic failure schedule: raise at the given global steps."""

    fail_at: tuple = ()
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclass
class Supervisor:
    train_step: Callable  # (state, batch) -> (state, metrics)
    pipeline: object  # batch_at(step) -> dict
    ckpt: CheckpointManager
    checkpoint_every: int = 50
    max_restarts: int = 10
    injector: Optional[FailureInjector] = None
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    on_restart: Optional[Callable[[int], None]] = None

    def run(self, state, total_steps: int, start_step: int = 0):
        """Returns (final_state, history). Restarts transparently on failure."""
        step = start_step
        restarts = 0
        history = []
        while step < total_steps:
            try:
                batch = {k: jax.numpy.asarray(v) for k, v in self.pipeline.batch_at(step).items()}
                if self.injector:
                    self.injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                self.monitor.observe(step, time.perf_counter() - t0)
                history.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
                step += 1
                if step % self.checkpoint_every == 0:
                    self.ckpt.save(step, state, extra={"step": step})
            except SimulatedFailure as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                log.warning("failure: %s — restoring latest checkpoint", e)
                self.ckpt.wait()
                restored, meta = self.ckpt.restore(state)
                if restored is None:  # no checkpoint yet: restart from scratch
                    step = start_step
                else:
                    state = restored
                    step = int(meta["step"])
                if self.on_restart:
                    self.on_restart(step)
        self.ckpt.save(total_steps, state, extra={"step": total_steps}, block=True)
        return state, history
