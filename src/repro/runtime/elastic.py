"""Elastic re-meshing: rebuild a smaller mesh after node loss and reshard.

The production flow on real hardware: coordinator notices missing hosts →
re-runs `jax.distributed.initialize` with the survivors → rebuilds the mesh
with a shrunken data axis → restores the latest checkpoint under the new
shardings (the checkpoint layer stores whole logical arrays, so any mesh works)
→ replays the data pipeline from the step counter (stateless pipeline).

Here the same code path is exercised on host-platform devices: `shrink_mesh`
drops a data-axis slice, `reshard_state` device_puts a state tree under the
new mesh's shardings. The batch size contract: global batch stays fixed, so
the per-replica batch grows (grad_accum absorbs it — `rebalance_grad_accum`).
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.parallel.api import axes_leaves, logical_spec


def shrink_mesh(mesh: Mesh, lost_data_slices: int = 1) -> Mesh:
    """Drop the last `lost_data_slices` rows of the data axis (failed hosts)."""
    devs = mesh.devices
    axes = mesh.axis_names
    di = axes.index("data")
    keep = devs.shape[di] - lost_data_slices
    if keep < 1:
        raise ValueError("cannot shrink data axis below 1")
    sl = [slice(None)] * devs.ndim
    sl[di] = slice(0, keep)
    return Mesh(devs[tuple(sl)], axes)


def reshard_state(state, axes_tree, new_mesh: Mesh):
    """device_put every leaf under the new mesh's resolved shardings."""
    flat_s, treedef = jax.tree_util.tree_flatten(state)
    flat_a = axes_leaves(axes_tree)
    assert len(flat_s) == len(flat_a)
    out = []
    for leaf, ax in zip(flat_s, flat_a):
        spec = logical_spec(np.shape(leaf), ax, new_mesh)
        out.append(jax.device_put(leaf, NamedSharding(new_mesh, spec)))
    return treedef.unflatten(out)


def rebalance_grad_accum(run, old_mesh: Mesh, new_mesh: Mesh):
    """Keep the global batch fixed: scale grad_accum by the dp shrink factor."""
    old_dp = math.prod(old_mesh.shape[a] for a in old_mesh.axis_names if a != "model")
    new_dp = math.prod(new_mesh.shape[a] for a in new_mesh.axis_names if a != "model")
    if old_dp == new_dp:
        return run
    scale = max(1, round(old_dp / new_dp))
    return run.replace(grad_accum=run.grad_accum * scale)
