"""Straggler detection: per-step wall-time EMA + z-score outlier flagging.

At pod scale the common failure shape is not a crash but a slow chip/host
(thermal throttle, flaky ICI link, noisy neighbor on the host NIC). The
monitor keeps an EMA/EMVar of step time; a step slower than
`mean + z_thresh * std` is flagged, and `on_straggler` fires with the stats so
the launcher can mark the slot for replacement (here: logged + counted; the
elastic path in `runtime/elastic.py` is the mitigation).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class StragglerMonitor:
    z_thresh: float = 3.0
    min_rel: float = 0.25  # never flag steps < (1+min_rel) x mean (var floor)
    decay: float = 0.95
    warmup_steps: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if `dt` is a straggler step."""
        self._n += 1
        if self._n <= self.warmup_steps:
            # seed statistics
            d = dt - self._mean
            self._mean += d / self._n
            self._var += d * (dt - self._mean)
            return False
        std = max((self._var / max(self._n - 1, 1)) ** 0.5, 1e-9)
        is_slow = dt > max(self._mean + self.z_thresh * std,
                           self._mean * (1 + self.min_rel))
        if is_slow:
            self.flagged.append((step, dt, self._mean))
            if self.on_straggler:
                self.on_straggler(step, dt, self._mean)
        else:
            # only fold non-outliers into the EMA (outliers would mask repeats)
            self._mean = self.decay * self._mean + (1 - self.decay) * dt
            self._var = self.decay * self._var + (1 - self.decay) * (dt - self._mean) ** 2
        return is_slow

    def timed(self, step: int):
        return _StepTimer(self, step)


class _StepTimer:
    def __init__(self, mon: StragglerMonitor, step: int):
        self.mon, self.step = mon, step

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.mon.observe(self.step, time.perf_counter() - self.t0)
        return False
