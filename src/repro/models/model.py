"""Public model API: init / forward / cache / decode for every assigned arch.

All families go through one `forward`:
  - LM (dense/moe/ssm/hybrid/vlm): token embed -> group stack -> logits
  - audio (whisper): frame embeddings (frontend STUB input) -> encoder stack;
    decoder stack with interleaved cross-attention; enc-dec caches for decode.

Step semantics used by launch/ and the dry-run:
  train:   forward(tokens) -> logits; loss vs labels
  prefill: forward(tokens, caches, write_pos=0) -> logits + filled caches
  decode:  forward(one token, caches, write_pos=pos) -> next-token logits
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import Px, embed_init, ones_init, rms_norm, sinusoid_positions, unzip_params
from repro.models.transformer import (
    Sub,
    group_layout,
    init_group_caches,
    init_groups,
    n_groups,
    stack_apply,
)
from repro.parallel.api import shard

AUDIO_DEC_LAYOUT = [Sub("attn", "none"), Sub("cross", "dense")]
AUDIO_ENC_LAYOUT = [Sub("attn", "dense")]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params_px(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 5)
    d, v = cfg.d_model, cfg.vocab_size
    p: dict[str, Any] = {
        "embed": embed_init(ks[0], (v, d), ("vocab", "embed")),
        "final_norm": ones_init((d,), (None,)),
    }
    if cfg.is_encoder_decoder:
        p["enc_groups"] = init_groups(ks[1], cfg, AUDIO_ENC_LAYOUT, cfg.n_encoder_layers)
        p["enc_norm"] = ones_init((d,), (None,))
        p["groups"] = init_groups(ks[2], cfg, AUDIO_DEC_LAYOUT, cfg.n_layers)
    else:
        p["groups"] = init_groups(ks[1], cfg)
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(ks[3], (d, v), ("embed", "vocab"))
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    """Returns (params values tree, logical-axes tree)."""
    px = init_params_px(cfg, key)
    vals, axes = unzip_params(px)
    vals = jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, vals)
    return vals, axes


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    """(ShapeDtypeStruct tree, axes tree) — no allocation (for dry-run/analysis)."""
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0), dtype)[0])
    return shapes, param_axes(cfg)


def param_axes(cfg: ModelConfig):
    """Logical-axes tree matching init_params' values tree (cheap, abstract)."""
    px = jax.eval_shape(lambda: init_params_px(cfg, jax.random.PRNGKey(0)))
    _, axes = unzip_params(px)
    return axes


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    from repro.parallel.api import axes_leaves

    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0))[0])
    axes = param_axes(cfg)
    total = 0
    for s, a in zip(jax.tree_util.tree_leaves(shapes), axes_leaves(axes)):
        n = math.prod(s.shape)
        if active_only and isinstance(a, tuple) and "experts" in a:
            n = int(n * cfg.top_k / max(cfg.n_experts, 1))
        total += n
    return total


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """(cache tree, axes tree) for the decode/prefill stack."""
    if cfg.is_encoder_decoder:
        return init_group_caches(cfg, batch, max_len, dtype, AUDIO_DEC_LAYOUT, cfg.n_layers)
    return init_group_caches(cfg, batch, max_len, dtype)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """(ShapeDtypeStruct cache tree, axes tree) without allocating."""
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype)[0])
    # axes are shape-independent: take them from a tiny concrete instance
    # (a decode_32k cache for a 480B arch is ~275GB — never allocate it here)
    axes = init_cache(cfg, 1, 8, dtype)[1]
    return cache, axes


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _logits(cfg, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ w.astype(x.dtype)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return shard(logits, "batch", None, "vocab")


def forward(cfg: ModelConfig, params, batch: dict, *, caches=None, write_pos=None,
            remat: str = "none", return_hidden: bool = False):
    """Returns (logits, new_caches, aux_loss); final-norm hidden states instead
    of logits when return_hidden (the chunked-xent loss path)."""
    wp = 0 if write_pos is None else write_pos
    if cfg.is_encoder_decoder:
        return _forward_encdec(cfg, params, batch, caches=caches, write_pos=wp,
                               remat=remat, return_hidden=return_hidden)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    x = shard(x, "batch", "seq_sp", None)
    positions = wp + jnp.arange(s)[None, :]
    positions = jnp.broadcast_to(positions, (b, s))
    kv_src = batch.get("img_embeds") if cfg.family == "vlm" else None
    x, new_caches, aux = stack_apply(
        params["groups"], x, cfg=cfg, positions=positions, caches=caches,
        write_pos=write_pos, causal=True, kv_src=kv_src, remat=remat)
    if return_hidden:
        return rms_norm(x, params["final_norm"], cfg.norm_eps), new_caches, aux
    return _logits(cfg, params, x), new_caches, aux


def _forward_encdec(cfg, params, batch, *, caches, write_pos, remat,
                    return_hidden: bool = False):
    d = cfg.d_model
    if "frames" in batch:  # frontend stub provides frame embeddings
        fr = batch["frames"]
        pe = sinusoid_positions(fr.shape[1], d, fr.dtype)
        enc_x = shard(fr + pe[None], "batch", "seq_sp", None)
        enc_pos = jnp.broadcast_to(jnp.arange(fr.shape[1])[None], fr.shape[:2])
        enc_out, _, _ = stack_apply(
            params["enc_groups"], enc_x, cfg=cfg, positions=enc_pos, causal=False,
            remat=remat, layout=AUDIO_ENC_LAYOUT)
        enc_out = rms_norm(enc_out, params["enc_norm"], cfg.norm_eps)
    else:
        enc_out = batch["enc_out"]

    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    pos = write_pos + jnp.arange(s)[None, :]
    x = x + _abs_pos(pos, d, x.dtype)
    x = shard(x, "batch", "seq_sp", None)
    positions = jnp.broadcast_to(pos, (b, s))
    x, new_caches, aux = stack_apply(
        params["groups"], x, cfg=cfg, positions=positions, caches=caches,
        write_pos=write_pos if caches is not None else None, causal=True,
        kv_src=enc_out, remat=remat, layout=AUDIO_DEC_LAYOUT)
    if return_hidden:
        return rms_norm(x, params["final_norm"], cfg.norm_eps), new_caches, aux
    return _logits(cfg, params, x), new_caches, aux


def _abs_pos(pos, d, dtype):
    """Sinusoidal absolute positions for arbitrary (possibly traced) offsets."""
    div = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32) / d * jnp.log(10_000.0))
    ang = pos.astype(jnp.float32)[..., None] * div  # (B?, S, d/2)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(dtype)


# ---------------------------------------------------------------------------
# step-level entry points (used by launch/, examples/, dry-run)
# ---------------------------------------------------------------------------


def _chunked_xent(x, w_t, labels, vocab_chunk: int = 16384):
    """Cross-entropy without materializing (B,S,V) logits (§Perf minitron C2:
    for a 256k vocab the logits + f32 logsumexp dominate the non-attention
    byte traffic). Scans vocab chunks with running (max, sumexp, gold);
    checkpointed so the backward recomputes per-chunk logits too."""
    b, s, d = x.shape
    v = w_t.shape[1]
    cs = min(vocab_chunk, v)
    n_chunks = -(-v // cs)
    vp = n_chunks * cs

    def body(carry, ci):
        m, acc, gold = carry
        wc = jax.lax.dynamic_slice_in_dim(w_t, ci * cs, cs, axis=1)  # padded-safe? no: clamp
        lg = (x @ wc).astype(jnp.float32)  # (B,S,cs)
        col = ci * cs + jnp.arange(cs)
        lg = jnp.where((col < v)[None, None, :], lg, -1e30)
        m_new = jnp.maximum(m, lg.max(-1))
        acc = acc * jnp.exp(m - m_new) + jnp.exp(lg - m_new[..., None]).sum(-1)
        idx = labels - ci * cs
        in_range = (idx >= 0) & (idx < cs)
        g = jnp.take_along_axis(lg, jnp.clip(idx, 0, cs - 1)[..., None], axis=-1)[..., 0]
        gold = gold + jnp.where(in_range, g, 0.0)
        return (m_new, acc, gold), None

    # keep W in-bounds: dynamic_slice clamps the start, so pad W to the grid
    if vp != v:
        w_t = jnp.pad(w_t, ((0, 0), (0, vp - v)))
    init = (jnp.full((b, s), -1e30, jnp.float32), jnp.zeros((b, s), jnp.float32),
            jnp.zeros((b, s), jnp.float32))
    (m, acc, gold), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), init, jnp.arange(n_chunks))
    lse = jnp.log(jnp.maximum(acc, 1e-30)) + m
    mask = (labels >= 0).astype(jnp.float32)
    return ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# §Perf minitron iteration C2 (REFUTED): chunked xent reduces peak logits
# memory but NOT HBM traffic (each vocab chunk still materializes once, plus
# per-chunk re-reads of x and the backward recompute) — measured +6% on the
# memory term. Kept for its capacity benefit, off by default.
LOSS_VOCAB_CHUNK_MIN = 1 << 30


def lm_loss(cfg: ModelConfig, params, batch, *, remat="none"):
    labels = batch["labels"]
    if cfg.vocab_size >= LOSS_VOCAB_CHUNK_MIN and not cfg.logit_softcap:
        x, _, aux = forward(cfg, params, batch, remat=remat, return_hidden=True)
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        nll = _chunked_xent(x, w.astype(x.dtype), labels)
        return nll + aux
    logits, _, aux = forward(cfg, params, batch, remat=remat)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll + aux


def prefill(cfg, params, caches, batch, *, remat="none"):
    logits, new_caches, _ = forward(cfg, params, batch, caches=caches, write_pos=0, remat=remat)
    return logits, new_caches


def decode_step(cfg, params, caches, batch, pos):
    """batch["tokens"]: (B,1); pos: scalar int32 — returns (logits, caches)."""
    logits, new_caches, _ = forward(cfg, params, batch, caches=caches, write_pos=pos)
    return logits, new_caches


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; the dry-run shards these)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        spec = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        if cfg.family == "vlm":
            spec["img_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model), dtype)
        if cfg.is_encoder_decoder:
            spec["frames"] = sds((b, s, cfg.d_model), dtype)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": sds((b, s), i32)}
        if cfg.family == "vlm":
            spec["img_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model), dtype)
        if cfg.is_encoder_decoder:
            spec["frames"] = sds((b, s, cfg.d_model), dtype)
        return spec
    # decode: one new token against a seq_len cache
    spec = {"tokens": sds((b, 1), i32)}
    if cfg.family == "vlm":
        spec["img_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model), dtype)
    if cfg.is_encoder_decoder:
        spec["enc_out"] = sds((b, s, cfg.d_model), dtype)
    return spec
