"""Mamba (selective SSM) block — jamba's attention-free sublayer.

Sequential-scan formulation (lax.scan over time): one HLO body regardless of
sequence length, O(1) decode state = (conv ring buffer, SSM state). Numerics in
fp32 for the recurrence.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Px, dense_init
from repro.parallel.api import shard


def _d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mamba(key, cfg: ModelConfig) -> dict:
    d, di, n, cw = cfg.d_model, _d_inner(cfg), cfg.ssm_state_dim, cfg.ssm_conv_width
    ks = jax.random.split(key, 7)
    dt_rank = max(1, d // 16)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), ("embed", "mlp")),
        "conv_w": dense_init(ks[1], (cw, di), (None, "mlp"), fan_in=cw),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * n), ("mlp", None)),
        "dt_proj": dense_init(ks[3], (dt_rank, di), (None, "mlp")),
        "a_log": Px(jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
                    ("mlp", None)),
        "d_skip": Px(jnp.ones((di,), jnp.float32), ("mlp",)),
        "out_proj": dense_init(ks[4], (di, d), ("mlp", "embed"), fan_in=di),
    }


class MambaState(NamedTuple):
    conv: jax.Array  # (B, cw-1, di) ring of last inputs
    ssm: jax.Array  # (B, di, N) fp32


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    di, n, cw = _d_inner(cfg), cfg.ssm_state_dim, cfg.ssm_conv_width
    return MambaState(conv=jnp.zeros((batch, cw - 1, di), dtype),
                      ssm=jnp.zeros((batch, di, n), jnp.float32))


MAMBA_STATE_AXES = MambaState(conv=("batch", None, "mlp"), ssm=("batch", "mlp", None))


def mamba_block(p, x, cfg: ModelConfig, state: Optional[MambaState] = None):
    """x: (B,S,D) -> (y, new_state). state carries decode recurrence."""
    b, s, d = x.shape
    di, n, cw = _d_inner(cfg), cfg.ssm_state_dim, cfg.ssm_conv_width
    dt_rank = max(1, d // 16)
    xz = x @ p["in_proj"].astype(x.dtype)  # (B,S,2di)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard(xs, "batch", None, "mlp")

    # causal depthwise conv1d width cw (prepend state or zeros)
    prev = state.conv.astype(xs.dtype) if state is not None else jnp.zeros((b, cw - 1, di), xs.dtype)
    xpad = jnp.concatenate([prev, xs], axis=1)  # (B, S+cw-1, di)
    conv_w = p["conv_w"].astype(xs.dtype)
    xc = sum(xpad[:, i : i + s, :] * conv_w[i] for i in range(cw))
    xc = jax.nn.silu(xc)
    new_conv = jax.lax.dynamic_slice_in_dim(xpad, s, cw - 1, axis=1)

    proj = xc @ p["x_proj"].astype(xs.dtype)  # (B,S,dt_rank+2n)
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"].astype(xs.dtype))  # (B,S,di)
    bmat = proj[..., dt_rank : dt_rank + n].astype(jnp.float32)  # (B,S,n)
    cmat = proj[..., dt_rank + n :].astype(jnp.float32)  # (B,S,n)
    a = -jnp.exp(p["a_log"])  # (di, n) fp32

    h0 = state.ssm if state is not None else jnp.zeros((b, di, n), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,di) (B,di) (B,n) (B,n)
        da = jnp.exp(dtt.astype(jnp.float32)[..., None] * a)  # (B,di,n)
        h = da * h + (dtt * xt).astype(jnp.float32)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    # §Perf: the selective scan is inherently sequential (per-channel decay
    # couples (d, n, t) — the mamba2/SSD chunk trick needs scalar decay), but
    # unrolling U steps per scan iteration keeps the (B,di,n) state out of HBM
    # for U-1 of every U steps (it only crosses the loop boundary).
    unroll = 16 if (s % 16 == 0 and s > 16) else (8 if (s % 8 == 0 and s > 8) else 1)

    def step_u(h, inps):
        ys = []
        for u in range(unroll):
            h, y = step(h, jax.tree_util.tree_map(lambda t: t[u], inps))
            ys.append(y)
        return h, jnp.stack(ys)

    xs_t = jnp.moveaxis(xc, 1, 0)  # (S,B,di)
    dt_t = jnp.moveaxis(dt, 1, 0)
    b_t = jnp.moveaxis(bmat, 1, 0)
    c_t = jnp.moveaxis(cmat, 1, 0)
    if unroll > 1:
        seq = jax.tree_util.tree_map(
            lambda t: t.reshape(s // unroll, unroll, *t.shape[1:]),
            (xs_t, dt_t, b_t, c_t))
        h_last, ys = jax.lax.scan(step_u, h0, seq)
        ys = ys.reshape(s, b, di)
    else:
        h_last, ys = jax.lax.scan(step, h0, (xs_t, dt_t, b_t, c_t))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,di)
    y = y + xc * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return shard(out, "batch", "seq_sp", None), MambaState(conv=new_conv, ssm=h_last)
