"""Unified decoder stack: every LM family as a scan over repeating layer groups.

A *group* is the smallest repeating pattern of sublayers:
  dense/moe LM    -> [attn]                        x n_layers groups
  jamba hybrid    -> [mamba x4, attn, mamba x3]    x (n_layers/8) groups
                      (attn at index 4; MoE FFN on odd indices)
  llama-vision    -> [self x4, cross]              x (n_layers/5) groups
  xlstm           -> [mlstm, slstm]                x (n_layers/2) groups

Group params are stacked on a leading (n_groups,) axis and the stack runs as a
single `lax.scan` — one HLO body per family regardless of depth (compile time
and remat policy both depend on the body, not the depth). Caches ride the scan
as per-group xs/ys.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_ffn import activation_fn
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import Px, dense_init, ones_init, rms_norm, unzip_params
from repro.models.moe import init_moe, moe_ffn
from repro.parallel.api import shard


class Sub(NamedTuple):
    kind: str  # attn | mla | cross | mamba | mlstm | slstm
    ffn: str  # dense | moe | moe+dense | none


def group_layout(cfg: ModelConfig) -> list[Sub]:
    fam = cfg.family
    if fam in ("dense", "vlm", "audio") or (fam == "moe" and cfg.attn_type == "gqa"):
        base_ffn = "moe+dense" if (cfg.n_experts and cfg.dense_residual_ff) else (
            "moe" if cfg.n_experts else "dense")
        if fam == "vlm" and cfg.cross_attn_every:
            n = cfg.cross_attn_every
            return [Sub("attn", base_ffn)] * (n - 1) + [Sub("cross", base_ffn)]
        return [Sub("attn", base_ffn)]
    if fam == "moe":  # mla
        return [Sub("mla", "moe")]
    if fam == "hybrid":
        n = cfg.attn_every
        attn_pos = n // 2
        out = []
        for i in range(n):
            kind = "attn" if i == attn_pos else "mamba"
            ffn = "moe" if (cfg.moe_every and i % cfg.moe_every == 1) else "dense"
            out.append(Sub(kind, ffn))
        return out
    if fam == "ssm":
        return [Sub("mlstm", "none"), Sub("slstm", "none")]
    raise ValueError(fam)


def n_groups(cfg: ModelConfig) -> int:
    lay = group_layout(cfg)
    assert cfg.n_layers % len(lay) == 0, (cfg.name, cfg.n_layers, len(lay))
    return cfg.n_layers // len(lay)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig, d_ff: int) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.mlp_activation in ("relu", "relu2"):  # non-gated: the ECR-sparse form
        return {
            "w1": dense_init(ks[0], (d, d_ff), ("embed", "mlp")),
            "w2": dense_init(ks[1], (d_ff, d), ("mlp", "embed"), fan_in=d_ff),
        }
    return {
        "w1": dense_init(ks[0], (d, d_ff), ("embed", "mlp")),
        "w3": dense_init(ks[1], (d, d_ff), ("embed", "mlp")),
        "w2": dense_init(ks[2], (d_ff, d), ("mlp", "embed"), fan_in=d_ff),
    }


def ffn_apply(p, x, cfg: ModelConfig):
    act = activation_fn(cfg.mlp_activation)
    if "w3" in p:
        h = act(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    else:
        h = act(x @ p["w1"].astype(x.dtype))
        if cfg.ffn_sparsity == "block_ecr":
            # dense-equivalent of the block-ECR skip (DESIGN.md §4): exact zeros
            # after ReLU-family activations; the Pallas bsr_matmul realizes the
            # skip on hardware, XLA sees the numerically-identical masked form.
            h = shard(h, "batch", None, "mlp")
    h = shard(h, "batch", None, "mlp")
    return shard(h @ p["w2"].astype(x.dtype), "batch", "seq_sp", None)


def init_sublayer(key, sub: Sub, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"ln1": ones_init((cfg.d_model,), (None,))}
    if sub.kind == "attn":
        p["mix"] = attn_mod.init_gqa(k1, cfg)
    elif sub.kind == "cross":
        p["mix"] = attn_mod.init_gqa(k1, cfg, cross=True)
    elif sub.kind == "mla":
        p["mix"] = attn_mod.init_mla(k1, cfg)
    elif sub.kind == "mamba":
        p["mix"] = ssm_mod.init_mamba(k1, cfg)
    elif sub.kind == "mlstm":
        p["mix"] = xlstm_mod.init_mlstm(k1, cfg)
    elif sub.kind == "slstm":
        p["mix"] = xlstm_mod.init_slstm(k1, cfg)
    else:
        raise ValueError(sub.kind)
    if sub.ffn != "none":
        p["ln2"] = ones_init((cfg.d_model,), (None,))
        if "moe" in sub.ffn:
            p["moe"] = init_moe(k2, cfg)
        if sub.ffn in ("dense", "moe+dense"):
            p["ffn"] = init_ffn(k3, cfg, cfg.d_ff)
    return p


def _stack_px(trees: list):
    """Stack a list of Px-trees along a new leading 'layers' axis."""
    def is_px(x):
        return isinstance(x, Px)

    def stack(*leaves):
        return Px(jnp.stack([l.value for l in leaves]), ("layers",) + tuple(leaves[0].axes))

    return jax.tree_util.tree_map(stack, *trees, is_leaf=is_px)


def init_groups(key, cfg: ModelConfig, layout=None, groups=None):
    lay = layout or group_layout(cfg)
    g = groups or n_groups(cfg)

    def one_group(k):
        ks = jax.random.split(k, len(lay))
        return {f"sub{i}": init_sublayer(ks[i], s, cfg) for i, s in enumerate(lay)}

    return _stack_px([one_group(k) for k in jax.random.split(key, g)])


# ---------------------------------------------------------------------------
# caches (decode / prefill state), aligned with the group layout
# ---------------------------------------------------------------------------


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def _prefix_layers(axes_tree):
    return jax.tree_util.tree_map(lambda t: ("layers",) + t, axes_tree, is_leaf=_is_axes_leaf)


def init_group_caches(cfg: ModelConfig, batch: int, max_len: int, dtype, layout=None, groups=None):
    """Returns (cache_tree, axes_tree): per sublayer position, stacked (G, ...)."""
    lay = layout or group_layout(cfg)
    g = groups or n_groups(cfg)

    def stack_leading(tree):
        return jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (g,) + x.shape).copy(), tree)

    # int8 requests quantized KV caches; recurrent/latent states stay bf16
    base_dt = jnp.bfloat16 if dtype == jnp.int8 else dtype
    caches, axes = [], []
    for s in lay:
        if s.kind == "attn":
            c = attn_mod.init_gqa_cache(cfg, batch, max_len, dtype)
            a = _prefix_layers(attn_mod.cache_axes(dtype == jnp.int8))
        elif s.kind == "mla":
            c = attn_mod.init_mla_cache(cfg, batch, max_len, base_dt)
            a = _prefix_layers(attn_mod.MLA_CACHE_AXES)
        elif s.kind == "mamba":
            c = ssm_mod.init_mamba_state(cfg, batch, base_dt)
            a = _prefix_layers(ssm_mod.MAMBA_STATE_AXES)
        elif s.kind == "mlstm":
            c = xlstm_mod.init_mlstm_state(cfg, batch)
            a = _prefix_layers(xlstm_mod.MLSTM_STATE_AXES)
        elif s.kind == "slstm":
            c = xlstm_mod.init_slstm_state(cfg, batch)
            a = _prefix_layers(xlstm_mod.SLSTM_STATE_AXES)
        else:  # cross: kv recomputed from the (static) image/encoder tokens
            c, a = None, None
        caches.append(stack_leading(c) if c is not None else None)
        axes.append(a)
    return tuple(caches), tuple(axes)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def apply_sublayer(sub: Sub, p, x, *, cfg, positions, cache, write_pos, causal, kv_src):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = None
    if sub.kind in ("attn", "cross"):
        out, new_cache = attn_mod.gqa_attention(
            p["mix"], h, cfg=cfg, positions=positions,
            causal=(causal and sub.kind == "attn"),
            cache=cache, write_pos=write_pos,
            kv_src=kv_src if sub.kind == "cross" else None)
    elif sub.kind == "mla":
        out, new_cache = attn_mod.mla_attention(
            p["mix"], h, cfg=cfg, positions=positions, causal=causal,
            cache=cache, write_pos=write_pos)
    elif sub.kind == "mamba":
        out, new_cache = ssm_mod.mamba_block(p["mix"], h, cfg, state=cache)
    elif sub.kind == "mlstm":
        out, new_cache = xlstm_mod.mlstm_block(p["mix"], h, cfg, state=cache)
    elif sub.kind == "slstm":
        out, new_cache = xlstm_mod.slstm_block(p["mix"], h, cfg, state=cache)
    else:
        raise ValueError(sub.kind)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if sub.ffn != "none":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        delta = 0.0
        if "moe" in p:
            mo, aux = moe_ffn(p["moe"], h2, cfg)
            delta = delta + mo
        if "ffn" in p:
            delta = delta + ffn_apply(p["ffn"], h2, cfg)
        x = x + delta
    return x, new_cache, aux


def stack_apply(groups_params, x, *, cfg: ModelConfig, positions,
                caches=None, write_pos=None, causal=True, kv_src=None,
                remat: str = "none", layout=None):
    """Run the full group stack. Returns (x, new_caches, aux_loss)."""
    lay = layout or group_layout(cfg)
    use_cache = caches is not None

    def body(carry, xs):
        x, aux = carry
        gp, gcache = xs if use_cache else (xs, tuple(None for _ in lay))
        new_caches = []
        for i, sub in enumerate(lay):
            x, nc, a = apply_sublayer(
                sub, gp[f"sub{i}"], x, cfg=cfg, positions=positions,
                cache=gcache[i], write_pos=write_pos, causal=causal, kv_src=kv_src)
            new_caches.append(nc)
            aux = aux + a
        return (x, aux), (tuple(new_caches) if use_cache else None)

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = (groups_params, caches) if use_cache else groups_params
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux
