"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar memory).

Alternating mLSTM/sLSTM stack per the xlstm-125m config. Both use exponential
gating with the max-stabilizer; recurrences run as lax.scan over time in fp32.
Decode state is O(1): (C, n, m) for mLSTM, (c, n, h, m) for sLSTM — this is
why xlstm runs the long_500k cell that full-attention archs must skip.

d_ff = 0 in the config: the mLSTM block carries a pre-up-projection (expand=2)
and the sLSTM block a gated 4/3 FFN, per the paper's block diagrams.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, ones_init, rms_norm
from repro.parallel.api import shard


def _di(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> dict:
    d, di, h = cfg.d_model, _di(cfg), cfg.n_heads
    dh = di // h
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], (d, 2 * di), ("embed", "mlp")),
        "wq": dense_init(ks[1], (di, h, dh), ("mlp", "heads", "head_dim")),
        "wk": dense_init(ks[2], (di, h, dh), ("mlp", "heads", "head_dim")),
        "wv": dense_init(ks[3], (di, h, dh), ("mlp", "heads", "head_dim")),
        "wi": dense_init(ks[4], (di, h), ("mlp", "heads")),
        "wf": dense_init(ks[5], (di, h), ("mlp", "heads")),
        "down": dense_init(ks[6], (di, d), ("mlp", "embed"), fan_in=di),
    }


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dh, dh) matrix memory
    n: jax.Array  # (B, H, dh)
    m: jax.Array  # (B, H) stabilizer


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    h, dh = cfg.n_heads, _di(cfg) // cfg.n_heads
    return MLSTMState(
        c=jnp.zeros((batch, h, dh, dh), jnp.float32),
        n=jnp.zeros((batch, h, dh), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


MLSTM_STATE_AXES = MLSTMState(c=("batch", "heads", None, None),
                              n=("batch", "heads", None), m=("batch", "heads"))


def mlstm_block(p, x, cfg: ModelConfig, state: Optional[MLSTMState] = None,
                chunk: int = 128):
    # chunk balances boundary state writes (∝ S/L · dh²) against intra-chunk
    # (L,L) tile materializations (∝ S·L·H): L=256 regressed 5x (intra-bound),
    # L=128 is the measured optimum (§Perf iterations 3-4).
    b, s, d = x.shape
    di, h = _di(cfg), cfg.n_heads
    dh = di // h
    up = x @ p["up"].astype(x.dtype)
    xi, z = jnp.split(up, 2, axis=-1)  # (B,S,di) each
    xi = shard(xi, "batch", None, "mlp")
    q = jnp.einsum("bsd,dhk->bshk", xi, p["wq"].astype(x.dtype)) * dh ** -0.5
    k = jnp.einsum("bsd,dhk->bshk", xi, p["wk"].astype(x.dtype)) * dh ** -0.5
    v = jnp.einsum("bsd,dhk->bshk", xi, p["wv"].astype(x.dtype))
    ig = jnp.einsum("bsd,dh->bsh", xi, p["wi"].astype(x.dtype)).astype(jnp.float32)
    fg = jnp.einsum("bsd,dh->bsh", xi, p["wf"].astype(x.dtype)).astype(jnp.float32)

    st = state if state is not None else init_mlstm_state(cfg, b)
    if s > 1 and s % chunk == 0:
        (c, n, m), y = _mlstm_chunkwise(q, k, v, ig, fg, st, chunk)
    else:
        (c, n, m), y = _mlstm_sequential(q, k, v, ig, fg, st)
    y = y.astype(x.dtype).reshape(b, s, di)
    y = y * jax.nn.silu(z)
    out = y @ p["down"].astype(x.dtype)
    return shard(out, "batch", "seq_sp", None), MLSTMState(c=c, n=n, m=m)


def _mlstm_sequential(q, k, v, ig, fg, st: MLSTMState):
    """Step-by-step oracle (and the decode path: one state update per token)."""

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, it, ft = inp  # (B,H,dh) x3, (B,H) x2
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(logf + m - m_new)
        c = f_[..., None, None] * c + i_[..., None, None] * (
            vt.astype(jnp.float32)[..., :, None] * kt.astype(jnp.float32)[..., None, :]
        )
        n = f_[..., None] * n + i_[..., None] * kt.astype(jnp.float32)
        hn = jnp.einsum("bhvk,bhk->bhv", c, qt.astype(jnp.float32))
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt.astype(jnp.float32))),
                            jnp.exp(-m_new))
        y = hn / denom[..., None]
        return (c, n, m_new), y

    seq = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
           jnp.moveaxis(ig, 1, 0), jnp.moveaxis(fg, 1, 0))
    (c, n, m), ys = jax.lax.scan(step, (st.c, st.n, st.m), seq)
    return (c, n, m), jnp.moveaxis(ys, 0, 1)  # (B,S,H,dh)


def _mlstm_chunkwise(q, k, v, ig, fg, st: MLSTMState, L: int):
    """Stabilized chunkwise-parallel mLSTM (§Perf hillclimb: the sequential
    scan materializes the (B,H,dh,dh) matrix memory EVERY step — 590MB/step
    for xlstm-125m train_4k, the worst memory term of the whole grid).

    Within a chunk of L steps everything is (L,L)/(L,dh) matmuls; the matrix
    state C/n/m is materialized only at chunk boundaries (L x fewer HBM
    round-trips). Derivation: with b_j = cumsum(log sig f), M_j = max(m_prev,
    cummax_l<=j(i_l - b_l)) and the stored-state invariant C_stored = e^{-m} C,
      intra_jl = e^{(i_l - b_l) - M_j} (l<=j),   inter_j = e^{m_prev - M_j}
      y_j = [ (S (.) intra) V + inter_j (q C_prev) ] / max(|.|_n, e^{-m_j})
    Validated against `_mlstm_sequential` (tests/test_xlstm_chunkwise.py)."""
    b, s, h, dh = q.shape
    nc = s // L
    qf = jnp.moveaxis(q.reshape(b, nc, L, h, dh), 1, 0).astype(jnp.float32)
    kf = jnp.moveaxis(k.reshape(b, nc, L, h, dh), 1, 0).astype(jnp.float32)
    vf = jnp.moveaxis(v.reshape(b, nc, L, h, dh), 1, 0).astype(jnp.float32)
    igf = jnp.moveaxis(ig.reshape(b, nc, L, h), 1, 0)
    fgf = jnp.moveaxis(fg.reshape(b, nc, L, h), 1, 0)

    def chunk_step(carry, inp):
        c, n, m_prev = carry  # (B,H,dh,dh) (B,H,dh) (B,H)
        qc_, kc_, vc_, ic_, fc_ = inp  # (B,L,H,dh)x3 (B,L,H)x2
        logf = jax.nn.log_sigmoid(fc_)  # (B,L,H)
        bj = jnp.cumsum(logf, axis=1)  # (B,L,H) cumulative decay
        a = ic_ - bj  # i_l - b_l
        mj_run = jnp.maximum(jax.lax.cummax(a, axis=1), m_prev[:, None, :])  # M_j
        m_j = bj + mj_run  # per-position stabilizer
        # intra-chunk decay weights w_jl = exp((i_l - b_l) - M_j), causal l <= j
        w = jnp.exp(a[:, :, None, :] - mj_run[:, None, :, :])  # (B,l,j,H)
        mask = jnp.tril(jnp.ones((L, L), bool))  # (j,l): l <= j
        w = jnp.where(mask.T[None, :, :, None], w, 0.0)
        qk = jnp.einsum("bjhd,blhd->bljh", qc_, kc_)  # q_j . k_l
        # H(=4) cannot shard the 16-way model axis — without this constraint
        # the (B,L,L,H) intra tensors replicate over it (§Perf iter 6: shard j)
        qk = shard(qk, "batch", None, "seq_sp", None)
        sw = qk * w
        sw = shard(sw, "batch", None, "seq_sp", None)
        intra = jnp.einsum("bljh,blhd->bjhd", sw, vc_)  # (B,j,H,dh)
        inter_f = jnp.exp(m_prev[:, None, :] - mj_run)  # (B,j,H)
        # C is (v-dim, k-dim); q contracts the k-dim (matches sequential bhvk,bhk->bhv)
        inter = jnp.einsum("bjhe,bhde->bjhd", qc_, c) * inter_f[..., None]
        num = intra + inter
        # normalizer: q_j . n_j with the same weights (n accumulates k's)
        qn = jnp.einsum("bljh->bjh", sw)
        qn = qn + jnp.einsum("bjhd,bhd->bjh", qc_, n) * inter_f
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_j))
        y = num / denom[..., None]
        # chunk-end state update (weights at row j = L-1)
        wL = jnp.exp(a - mj_run[:, -1:, :])  # (B,l,H)
        decay_end = jnp.exp(m_prev - mj_run[:, -1, :])
        c_new = decay_end[..., None, None] * c + jnp.einsum(
            "blh,blhd,blhe->bhde", wL, vc_, kc_)
        n_new = decay_end[..., None] * n + jnp.einsum("blh,blhd->bhd", wL, kc_)
        return (c_new, n_new, m_j[:, -1, :]), y

    (c, n, m), ys = jax.lax.scan(chunk_step, (st.c, st.n, st.m),
                                 (qf, kf, vf, igf, fgf))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dh)
    return (c, n, m), y


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    f = int(d * 4 / 3) // 8 * 8  # gated 4/3 FFN, 8-aligned
    ks = jax.random.split(key, 7)
    return {
        "wz": dense_init(ks[0], (d, d), ("embed", "mlp")),
        "wi": dense_init(ks[1], (d, d), ("embed", "mlp")),
        "wf": dense_init(ks[2], (d, d), ("embed", "mlp")),
        "wo": dense_init(ks[3], (d, d), ("embed", "mlp")),
        # recurrent matrix (z,i,f,o). NOTE §Perf iterations 3-4: a block-
        # diagonal per-head form (xLSTM paper's design, H x fewer weights)
        # REGRESSED the memory term 5x — the batched (B,H,dh)x(H,dh,4dh)
        # einsum inside the unrolled scan lowers to per-step reshape/copy
        # chains that outweigh the weight-bytes saved. Kept dense.
        "r": dense_init(ks[4], (d, 4 * d), ("embed", "mlp")),
        "ffn_up": dense_init(ks[5], (d, 2 * f), ("embed", "mlp")),
        "ffn_down": dense_init(ks[6], (f, d), ("mlp", "embed"), fan_in=f),
        "norm": ones_init((d,), (None,)),
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, D)
    n: jax.Array
    h: jax.Array
    m: jax.Array


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))


SLSTM_STATE_AXES = SLSTMState(c=("batch", "mlp"), n=("batch", "mlp"),
                              h=("batch", "mlp"), m=("batch", "mlp"))


def slstm_block(p, x, cfg: ModelConfig, state: Optional[SLSTMState] = None):
    b, s, d = x.shape
    h_heads = cfg.n_heads
    dh = d // h_heads
    z_in = x @ p["wz"].astype(x.dtype)
    i_in = x @ p["wi"].astype(x.dtype)
    f_in = x @ p["wf"].astype(x.dtype)
    o_in = x @ p["wo"].astype(x.dtype)
    st = state if state is not None else init_slstm_state(cfg, b)
    r = p["r"].astype(jnp.float32)  # (H, dh, 4dh) block-diagonal recurrence

    def step(carry, inp):
        c, n, hprev, m = carry
        zt, it, ft, ot = (t.astype(jnp.float32) for t in inp)  # (B,D)
        rec = hprev @ r  # (B, 4D)
        rz, ri, rf, ro = jnp.split(rec, 4, axis=-1)
        zt, it, ft, ot = zt + rz, it + ri, ft + rf, ot + ro
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(logf + m - m_new)
        c = f_ * c + i_ * jnp.tanh(zt)
        n = f_ * n + i_
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (z_in, i_in, f_in, o_in))
    # §Perf: sLSTM is truly sequential (recurrent R h_{t-1}); unroll U steps
    # per scan iteration so the (B,D) states cross the HBM loop boundary U x
    # less often (same trick as the mamba scan).
    unroll = 16 if (s % 16 == 0 and s > 16) else (8 if (s % 8 == 0 and s > 8) else 1)
    if unroll > 1:
        def step_u(carry, inps):
            ys = []
            for u in range(unroll):
                carry, y = step(carry, jax.tree_util.tree_map(lambda t: t[u], inps))
                ys.append(y)
            return carry, jnp.stack(ys)

        sequ = jax.tree_util.tree_map(
            lambda t: t.reshape(s // unroll, unroll, *t.shape[1:]), seq)
        (c, n, hl, m), ys = jax.lax.scan(step_u, (st.c, st.n, st.h, st.m), sequ)
        ys = ys.reshape(s, b, d)
    else:
        (c, n, hl, m), ys = jax.lax.scan(step, (st.c, st.n, st.h, st.m), seq)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,D)
    # post-norm gated FFN (4/3)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    u = y @ p["ffn_up"].astype(x.dtype)
    u1, u2 = jnp.split(u, 2, axis=-1)
    y = (jax.nn.gelu(u1) * u2) @ p["ffn_down"].astype(x.dtype)
    return shard(y, "batch", "seq_sp", None), SLSTMState(c=c, n=n, h=hl, m=m)
