"""Mixture-of-Experts FFN with capacity-based top-k routing (GShard-style drops).

Dispatch is sort-based (ECR again, at token granularity: tokens are "nonzeros"
of the (token, expert) routing matrix; we compact them into per-expert
capacity buffers and run dense MXU matmuls per expert — sparse scheduling,
dense arithmetic, same as the conv kernels):

  1. top-k gating -> (token, expert) pairs
  2. stable argsort by expert id -> slot-within-expert via segment ranking
  3. scatter rows into the (E, C, D) buffer (over-capacity tokens drop)
  4. per-expert matmuls (E-sharded: expert parallelism over the "model" axis)
  5. gather back + gate-weighted combine

The buffer is sharded ("experts" -> model axis, "expert_cap" -> data axes) so
each chip holds E/ep x C/dp rows.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_ffn import activation_fn
from repro.models.layers import dense_init
from repro.parallel.api import shard


def init_moe(key, cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), ("embed", None)),
        "w1": dense_init(ks[1], (e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "w3": dense_init(ks[2], (e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "w2": dense_init(ks[3], (e, f, d), ("experts", "mlp", "embed"), fan_in=f),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": dense_init(kk[0], (d, fs), ("embed", "mlp")),
            "w3": dense_init(kk[1], (d, fs), ("embed", "mlp")),
            "w2": dense_init(kk[2], (fs, d), ("mlp", "embed"), fan_in=fs),
        }
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    cap = _capacity(t, cfg)
    xt = x.reshape(t, d)

    logits = xt @ p["router"].astype(jnp.float32)  # (T, E) fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch/GShard form)
    me = probs.mean(0)  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (t * k)
    aux = cfg.router_aux_loss * e * jnp.sum(me * ce)

    # --- ECR-style compaction: sort (token,expert) pairs by expert ------------
    fe = eidx.reshape(-1)  # (T*k,)
    order = jnp.argsort(fe, stable=True)
    se = fe[order]
    pos = jnp.arange(t * k, dtype=jnp.int32)
    seg_first = jnp.where(jnp.concatenate([jnp.array([True]), se[1:] != se[:-1]]), pos, 0)
    slot_sorted = pos - jax.lax.cummax(seg_first)
    slots = jnp.zeros((t * k,), jnp.int32).at[order].set(slot_sorted)
    keep = slots < cap
    token_of = jnp.arange(t * k, dtype=jnp.int32) // k
    flat = jnp.where(keep, fe * cap + slots, e * cap)  # OOB -> dropped

    buf = jnp.zeros((e * cap, d), x.dtype).at[flat].add(
        xt[token_of], mode="drop"
    ).reshape(e, cap, d)
    buf = shard(buf, "experts", "expert_cap", None)

    act = activation_fn(cfg.mlp_activation)
    # explicit bf16 FSDP gather: without the constraint XLA hoists the f32
    # convert above the implicit weight all-gather and moves 2x the bytes
    # (§Perf arctic iteration B1)
    w1 = shard(p["w1"].astype(x.dtype), "experts", None, None)
    w3 = shard(p["w3"].astype(x.dtype), "experts", None, None)
    w2 = shard(p["w2"].astype(x.dtype), "experts", None, None)
    h = act(jnp.einsum("ecd,edf->ecf", buf, w1))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w3)
    h = shard(h, "experts", "expert_cap", "mlp")
    out_buf = jnp.einsum("ecf,efd->ecd", h, w2)
    out_buf = shard(out_buf, "experts", "expert_cap", None).reshape(e * cap, d)

    rows = jnp.where(keep[:, None], out_buf[jnp.clip(flat, 0, e * cap - 1)], 0.0)  # (T*k, D)
    y = (rows.reshape(t, k, d) * gates[..., None].astype(x.dtype)).sum(1)

    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = act(xt @ sp["w1"].astype(x.dtype)) * (xt @ sp["w3"].astype(x.dtype))
        y = y + hs @ sp["w2"].astype(x.dtype)
    return y.reshape(b, s, d), aux
