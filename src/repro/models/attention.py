"""Attention: chunked (flash-style) softmax attention, GQA, MLA, cross-attn.

The chunked implementation scans over query and key blocks with running
(max, denominator, accumulator) statistics so no (Sq, Sk) score matrix is ever
materialized — required for the prefill_32k / train_4k shapes and remat-friendly
(pure jnp, no kernel; the HLO stays small because both loops are lax.scan).

MLA (deepseek-v2) uses the *absorbed* formulation: queries are projected into
the kv-lora latent space, so the cache holds only (c_kv, k_rope) and attention
runs as GQA with a single shared "kv head" of width kv_lora(+rope). The O(S)
per-head key/value expansion of the naive form never happens.
"""
from __future__ import annotations

import os
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Px, dense_init, ones_init, rms_norm, rope
from repro.parallel.api import shard

_NEG = -1e30


# ---------------------------------------------------------------------------
# Chunked flash attention (pure jnp)
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool, scale: float, q_offset=0,
                    q_chunk: int = 512, k_chunk: int = 1024, kv_len=None,
                    save_memory: bool = True):
    """q: (B,Sq,KV,G,Dk)  k: (B,Sk,KV,Dk)  v: (B,Sk,KV,Dv) -> (B,Sq,KV,G,Dv).

    `q_offset` is the absolute position of q[0] (decode: the cache write pos);
    `kv_len` masks keys at index >= kv_len (unwritten cache tail).

    `save_memory` wraps each q-block in jax.checkpoint: without it, autodiff of
    the kv scan stacks the (qc,kc) attention probabilities for EVERY chunk pair
    (f32+bf16+mask — the dominant HBM term found by the dry-run roofline);
    with it the backward recomputes per-chunk scores, which is the flash
    backward pass.
    """
    b, sq, nkv, g, dk = q.shape
    sk, dv = k.shape[1], v.shape[-1]
    if os.environ.get("REPRO_ATTN_STUB"):
        # dry-run instrumentation (§Perf): replace all S^2 attention work with
        # a shape-preserving O(S) stand-in, so compiling with/without the stub
        # measures the attention region's exact FLOP/byte share differentially
        # (HLO metadata tags lose some transpose-synthesized backward ops).
        out = jnp.broadcast_to(v.mean(axis=1)[:, None, :, None, :],
                               (b, sq, nkv, g, dv)).astype(v.dtype)
        return out
    qc = q_chunk if sq % q_chunk == 0 else sq
    kc = k_chunk if sk % k_chunk == 0 else sk
    nq, nk = sq // qc, sk // kc
    q = q * scale

    def q_block(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(state, ki):
            # named_scope INSIDE the body: remat/transpose paths keep inner
            # scopes, so the dry-run can re-account fwd AND bwd to the kernel
            with jax.named_scope("flash_attention"):
                m, l, acc = state
                k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kc, kc, axis=1)
                v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kc, kc, axis=1)
                s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                               preferred_element_type=jnp.float32)
                k_pos = ki * kc + jnp.arange(kc)
                mask = jnp.ones((qc, kc), bool)
                if causal:
                    mask &= q_pos[:, None] >= k_pos[None, :]
                if kv_len is not None:
                    mask &= (k_pos < kv_len)[None, :]
                s = jnp.where(mask, s, _NEG)
                m_new = jnp.maximum(m, s.max(-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, nkv, g, qc), _NEG, jnp.float32),
            jnp.zeros((b, nkv, g, qc), jnp.float32),
            jnp.zeros((b, nkv, g, qc, dv), jnp.float32),
        )
        kv = jax.checkpoint(kv_step, prevent_cse=False) if save_memory else kv_step
        (m, l, acc), _ = jax.lax.scan(kv, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (b,kv,g,qc,dv)
        return None, out.astype(v.dtype)

    qb = jax.checkpoint(q_block, prevent_cse=False) if save_memory else q_block
    # the named_scope tags this region in HLO metadata: the dry-run roofline
    # re-accounts its HBM bytes to the Pallas flash kernel's streaming model
    # (kernels/flash_attention — same math, score tiles stay in VMEM).
    with jax.named_scope("flash_attention"):
        _, outs = jax.lax.scan(qb, None, jnp.arange(nq))  # (nq,b,kv,g,qc,dv)
    out = jnp.moveaxis(outs, 0, 3)  # (b,kv,g,nq,qc,dv)
    return out.reshape(b, nkv, g, sq, dv).transpose(0, 3, 1, 2, 4)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), ("embed", "heads", "head_dim")),
        "wk": dense_init(ks[1], (d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": dense_init(ks[2], (d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": dense_init(ks[3], (h, hd, d), ("heads", "head_dim", "embed"), fan_in=h * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), (None,))
        p["k_norm"] = ones_init((hd,), (None,))
    if cross:
        p["gate"] = Px(jnp.zeros((), jnp.float32), ())  # tanh-gated cross-attn
    return p


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, KV, hd) — bf16/f32, or int8 when quantized
    v: jax.Array
    k_scale: Optional[jax.Array] = None  # (B, S_max, KV) per-token-head absmax
    v_scale: Optional[jax.Array] = None


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    """dtype jnp.int8 -> quantized cache (§Perf decode lever: halves the
    dominant cache-streaming term; dequant fuses into the attention region /
    the Pallas flash kernel dequants per block in VMEM)."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (batch, max_len, kv, hd)
    if dtype == jnp.int8:
        return KVCache(k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
                       k_scale=jnp.zeros(shape[:3], jnp.float32),
                       v_scale=jnp.zeros(shape[:3], jnp.float32))
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def cache_axes(quantized: bool) -> KVCache:
    """Axes tree matching the cache instance (None scale fields drop out of
    both pytrees consistently for the unquantized cache)."""
    sc = ("batch", "cache_seq", "cache_kv") if quantized else None
    return KVCache(k=("batch", "cache_seq", "cache_kv", "cache_hd"),
                   v=("batch", "cache_seq", "cache_kv", "cache_hd"),
                   k_scale=sc, v_scale=sc)


CACHE_AXES = cache_axes(False)


def _quantize_kv(x):
    """(B,S,KV,hd) -> int8 values + (B,S,KV) scales (symmetric absmax)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def gqa_attention(p, x, *, cfg: ModelConfig, positions, causal=True,
                  cache: Optional[KVCache] = None, write_pos=None,
                  kv_src: Optional[jax.Array] = None):
    """x: (B,S,D). kv_src: encoder/image states for cross-attention.

    cache + write_pos: write k/v at write_pos, attend over the whole cache.
    Returns (out, new_cache).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = h // kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    src = x if kv_src is None else kv_src
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(src.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(src.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta and kv_src is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    # §Perf (arctic iterations B1/B3): when kv_heads cannot shard the model
    # axis (8 kv vs 16-way TP), pad the query heads to the next TP multiple
    # (56 -> 64: zero q-heads contribute nothing and are sliced off) and
    # replicate KV heads (Megatron GQA-under-TP). Attention then runs fully
    # head-sharded instead of all-gathering full-seq q/k/v over the model
    # axis every layer. Train/prefill only — decode would materialize the
    # repeated KV cache.
    from repro.parallel.api import current_mesh

    mesh = current_mesh()
    msz = mesh.shape.get("model", 1) if mesh else 1
    pad_g = 0
    q = q.reshape(b, s, kv, g, hd)
    if mesh is not None and s > 1 and cache is None and msz > 1 and kv % msz != 0:
        h_pad = -(-h // msz) * msz  # ceil to TP multiple
        if h_pad % kv == 0 and h_pad <= 2 * h:
            g_pad = h_pad // kv
            pad_g = g_pad - g
            if pad_g:
                q = jnp.concatenate(
                    [q, jnp.zeros((b, s, kv, pad_g, hd), q.dtype)], axis=3)
            k = shard(jnp.repeat(k, g_pad, axis=2), "batch", None, "heads", None)
            v = shard(jnp.repeat(v, g_pad, axis=2), "batch", None, "heads", None)
            q = shard(q.reshape(b, s, h_pad, 1, hd), "batch", None, "heads", None, None)
            kv, g = h_pad, 1

    kv_len = None
    q_offset = 0
    new_cache = None
    if cache is not None and cache.k.dtype == jnp.int8:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        kc = jax.lax.dynamic_update_slice_in_dim(cache.k, kq, write_pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache.v, vq, write_pos, axis=1)
        ksc = jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ks, write_pos, axis=1)
        vsc = jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vs, write_pos, axis=1)
        new_cache = KVCache(k=kc, v=vc, k_scale=ksc, v_scale=vsc)
        with jax.named_scope("flash_attention"):  # dequant fuses into the kernel
            k = _dequantize_kv(kc, ksc, x.dtype)
            v = _dequantize_kv(vc, vsc, x.dtype)
        kv_len = write_pos + s
        q_offset = write_pos
    elif cache is not None:
        kc = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), write_pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), write_pos, axis=1)
        new_cache = KVCache(k=kc, v=vc)
        k, v = kc, vc
        kv_len = write_pos + s
        q_offset = write_pos
    out = flash_attention(
        q, k, v, causal=causal, scale=hd ** -0.5, q_offset=q_offset,
        q_chunk=min(cfg.attn_chunk // 2, 512) or s, k_chunk=cfg.attn_chunk,
        kv_len=kv_len,
    )
    if pad_g:  # drop the zero padding heads
        out = out.reshape(b, s, cfg.n_kv_heads, -1, hd)[:, :, :, : h // cfg.n_kv_heads]
    out = out.reshape(b, s, h, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    if "gate" in p:  # gated cross-attention (llama-vision style)
        out = jnp.tanh(p["gate"].astype(out.dtype)) * out
    return shard(out, "batch", "seq_sp", None), new_cache


# ---------------------------------------------------------------------------
# MLA block (deepseek-v2), absorbed formulation
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, qr), ("embed", "q_lora")),
        "w_uq": dense_init(ks[1], (qr, h, dn + dr), ("q_lora", "heads", "head_dim")),
        "w_dkv": dense_init(ks[2], (d, r), ("embed", "kv_lora")),
        "w_uk": dense_init(ks[3], (r, h, dn), ("kv_lora", "heads", "head_dim")),
        "w_uv": dense_init(ks[4], (r, h, dv), ("kv_lora", "heads", "head_dim")),
        "w_kr": dense_init(ks[5], (d, dr), ("embed", "head_dim")),
        "w_o": dense_init(ks[6], (h, dv, d), ("heads", "head_dim", "embed"), fan_in=h * dv),
        "q_norm": ones_init((qr,), (None,)),
        "kv_norm": ones_init((r,), (None,)),
    }


class MLACache(NamedTuple):
    c_kv: jax.Array  # (B, S, r) compressed latent — the MLA cache-size win
    k_rope: jax.Array  # (B, S, dr)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
    )


MLA_CACHE_AXES = MLACache(c_kv=("batch", "cache_seq", None),
                          k_rope=("batch", "cache_seq", None))


def mla_attention(p, x, *, cfg: ModelConfig, positions, causal=True,
                  cache: Optional[MLACache] = None, write_pos=None):
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    cq = rms_norm(x @ p["w_dq"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhk->bshk", cq, p["w_uq"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    # absorb W_uk: queries into latent space -> cache never expands per head
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"].astype(x.dtype))
    c_kv = rms_norm(x @ p["w_dkv"].astype(x.dtype), p["kv_norm"], cfg.norm_eps)
    k_rope = rope(x @ p["w_kr"].astype(x.dtype), positions, cfg.rope_theta)

    kv_len, q_offset, new_cache = None, 0, None
    if cache is not None:
        ckv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_kv.astype(cache.c_kv.dtype), write_pos, axis=1)
        krc = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, k_rope.astype(cache.k_rope.dtype), write_pos, axis=1)
        new_cache = MLACache(c_kv=ckv, k_rope=krc)
        c_kv, k_rope = ckv, krc
        kv_len = write_pos + s
        q_offset = write_pos
    # single shared "kv head": keys = [c_kv ; k_rope], queries = [q_lat ; q_rope]
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)[:, :, None]  # (B,S,1,H,r+dr)
    k_eff = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None]  # (B,Sk,1,r+dr)
    v_eff = c_kv[:, :, None]  # (B,Sk,1,r)
    q_eff = q_eff.reshape(b, s, 1, h, r + dr)
    # §Perf (deepseek): the 128 query heads shard the model axis (the shared
    # latent kv head is tiny and replicates); without this constraint GSPMD
    # replicated the whole absorbed attention over the model axis.
    q_eff = shard(q_eff, "batch", None, None, "heads", None)
    out_lat = flash_attention(
        q_eff, k_eff, v_eff, causal=causal, scale=(dn + dr) ** -0.5,
        q_offset=q_offset, q_chunk=min(cfg.attn_chunk // 2, 512) or s,
        k_chunk=cfg.attn_chunk, kv_len=kv_len,
    )  # (B,S,1,H,r)
    out_lat = out_lat.reshape(b, s, h, r)
    out = jnp.einsum("bshr,rhv->bshv", out_lat, p["w_uv"].astype(x.dtype))
    out = jnp.einsum("bshv,hvd->bsd", out, p["w_o"].astype(x.dtype))
    return shard(out, "batch", "seq_sp", None), new_cache
