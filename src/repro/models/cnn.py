"""CNNs for the paper's own evaluation: VGG-19 (+ reduced variants), with the
conv stack runnable through every implementation the paper compares:

  impl = "dense"       lax.conv + separate ReLU + separate maxpool (cuDNN stand-in)
  impl = "im2col"      materialized extension + GEMM (paper §VII baseline)
  impl = "ecr"         ECR sparse conv (paper §IV), unfused pooling
  impl = "pecr"        ECR conv for in-stage layers + PECR fused conv+ReLU+pool
                       for the stage-final layer (paper §V)
  impl = "ecr_pallas" / "pecr_pallas"  same, through the Pallas TPU kernels

All convs are 3x3 stride 1 with explicit 1-pixel padding (== SAME), pooling is
2x2/2 max — the VGG-19 configuration the paper benchmarks (Figs 9, 12).

Also holds the whisper conv frontend (a STUB for the assigned shapes; the
dry-run feeds precomputed frame embeddings — this exists so the ECR conv has a
real consumer in the audio arch and is exercised by unit tests).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.vgg19_sparse import CNNConfig
from repro.core.ecr import conv2d
from repro.core.pecr import conv_pool


def init_cnn(key, ccfg: CNNConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 64)
    ki = iter(keys)
    stages = []
    c_in = ccfg.in_channels
    k = ccfg.kernel_size
    for c_out, n_convs in ccfg.plan:
        convs = []
        for _ in range(n_convs):
            w = jax.random.normal(next(ki), (c_out, c_in, k, k), dtype) * (c_in * k * k) ** -0.5
            convs.append(w)
            c_in = c_out
        stages.append(convs)
    # classifier dims from a shape-only trace
    feat = jax.eval_shape(partial(_features, impl="dense", ccfg=ccfg),
                          {"stages": stages},
                          jax.ShapeDtypeStruct((ccfg.in_channels, ccfg.img_size, ccfg.img_size), dtype))
    flat = feat.shape[0] * feat.shape[1] * feat.shape[2]
    fc1 = jax.random.normal(next(ki), (flat, 512), dtype) * flat ** -0.5
    fc2 = jax.random.normal(next(ki), (512, ccfg.n_classes), dtype) * 512 ** -0.5
    return {"stages": stages, "fc1": fc1, "fc2": fc2}


def _pad1(x):
    """1-pixel spatial padding, single image (C,H,W) or batch (N,C,H,W)."""
    return jnp.pad(x, ((0, 0),) * (x.ndim - 2) + ((1, 1), (1, 1)))


def _maxpool(x, p):
    """Unfused p x p / p max-pool over the trailing two (spatial) dims."""
    oh, ow = x.shape[-2:]
    lead = x.shape[:-2]
    x = x[..., : oh // p * p, : ow // p * p]
    return x.reshape(*lead, oh // p, p, ow // p, p).max(axis=(-3, -1))


def _features(params, img, *, impl: str, ccfg: CNNConfig):
    """(C,H,W) -> (C_out, h, w) after all conv stages; batched (N,C,H,W) ->
    (N, C_out, h, w). Every conv/conv_pool call carries the whole batch, so
    each layer is ONE jitted op (batched Pallas grid for the *_pallas impls,
    native lax / vmapped oracle batching otherwise)."""
    x = img
    p = ccfg.pool_size
    for convs in params["stages"]:
        for i, w in enumerate(convs):
            last = i == len(convs) - 1
            xp = _pad1(x)
            if last and impl in ("pecr", "pecr_pallas"):
                fused_impl = "pecr" if impl == "pecr" else "pecr_pallas"
                x = conv_pool(xp, w, 1, p, None, fused_impl)  # conv+ReLU+pool fused
            else:
                conv_impl = {"pecr": "ecr", "pecr_pallas": "ecr_pallas"}.get(impl, impl)
                x = jnp.maximum(conv2d(xp, w, 1, conv_impl), 0.0)
                if last:
                    x = _maxpool(x, p)
    return x


def cnn_forward(params, img, impl: str = "dense", ccfg: CNNConfig = CNNConfig()):
    """(C,H,W) -> class logits, or a batch (N,C,H,W) -> (N, n_classes).

    The batch flows through the conv stack as whole-batch layer calls (not a
    python loop over samples); see `cnn_forward_batch` for the explicit API.
    """
    x = _features(params, img, impl=impl, ccfg=ccfg)
    x = x.reshape(x.shape[0], -1) if img.ndim == 4 else x.reshape(-1)
    x = jnp.maximum(x @ params["fc1"], 0.0)
    return x @ params["fc2"]


def cnn_forward_batch(params, imgs, impl: str = "dense", ccfg: CNNConfig = CNNConfig()):
    """Batched inference entry point: (N,C,H,W) -> (N, n_classes) logits.

    Each conv layer runs once over the whole batch: the dense path uses lax's
    native NCHW batching, the ECR/PECR oracles carry the batch dim through the
    compressed formats, and the Pallas paths use the (n_ob, N, n_cb) batched
    grid with per-sample channel-block schedules (DESIGN.md §2.4).
    """
    assert imgs.ndim == 4, f"expected (N,C,H,W), got {imgs.shape}"
    return cnn_forward(params, imgs, impl=impl, ccfg=ccfg)


def shift_dead_channels(params, rate: float = 0.04, shift: float = 0.12):
    """Emulate trained-net activation statistics on random-init params.

    Trained VGG nets lose whole filters to ReLU + BN shift, growing with depth
    (paper Fig. 2); random init does not. Shift a depth-growing fraction of
    each conv's output filters negative so ReLU kills those channels — used by
    `benchmarks/fig2_sparsity.py` and the planner demo to produce realistic
    channel-block occupancy without trained weights.
    """
    shifted = {"stages": [], "fc1": params["fc1"], "fc2": params["fc2"]}
    depth = 0
    for convs in params["stages"]:
        row = []
        for w in convs:
            key = jax.random.PRNGKey(depth)
            bias_mask = (jax.random.uniform(key, (w.shape[0], 1, 1, 1)) <
                         rate * depth).astype(w.dtype)
            row.append(w * (1.0 - bias_mask) - shift * bias_mask * jnp.abs(w))
            depth += 1
        shifted["stages"].append(row)
    return shifted


def cnn_feature_maps(params, img, ccfg: CNNConfig = CNNConfig()):
    """The paper's data set (§VI-A): every feature map ENTERING a conv layer."""
    maps = []
    x = img
    p = ccfg.pool_size
    for convs in params["stages"]:
        for i, w in enumerate(convs):
            maps.append(x)
            x = jnp.maximum(conv2d(_pad1(x), w, 1, "dense"), 0.0)
            if i == len(convs) - 1:
                o, oh, ow = x.shape
                x = x[:, : oh // p * p, : ow // p * p]
                x = x.reshape(o, oh // p, p, ow // p, p).max(axis=(2, 4))
    return maps


# ---------------------------------------------------------------------------
# whisper conv frontend (STUB consumer of the ECR conv; not in the dry-run path)
# ---------------------------------------------------------------------------


def init_whisper_frontend(key, n_mels: int, d_model: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "conv1": jax.random.normal(k1, (d_model, n_mels, 3), dtype) * (n_mels * 3) ** -0.5,
        "conv2": jax.random.normal(k2, (d_model, d_model, 3), dtype) * (d_model * 3) ** -0.5,
    }


def whisper_frontend(params, mel, stride2: bool = True):
    """mel: (n_mels, T) -> (T//2, d_model) frame embeddings (gelu conv x2)."""
    x = mel[None]  # (1, n_mels, T)
    x = jax.lax.conv_general_dilated(
        x, params["conv1"], window_strides=(1,), padding=((1, 1),),
        dimension_numbers=("NCH", "OIH", "NCH"))
    x = jax.nn.gelu(x)
    x = jax.lax.conv_general_dilated(
        x, params["conv2"], window_strides=((2,) if stride2 else (1,)), padding=((1, 1),),
        dimension_numbers=("NCH", "OIH", "NCH"))
    x = jax.nn.gelu(x)
    return x[0].T  # (T', d_model)
