"""CNNs for the paper's own evaluation: VGG-19 (+ reduced variants), with the
conv stack runnable through every implementation the paper compares:

  impl = "dense"       lax.conv + separate ReLU + separate maxpool (cuDNN stand-in)
  impl = "im2col"      materialized extension + GEMM (paper §VII baseline)
  impl = "ecr"         ECR sparse conv (paper §IV), unfused pooling
  impl = "pecr"        ECR conv for in-stage layers + PECR fused conv+ReLU+pool
                       for the stage-final layer (paper §V)
  impl = "ecr_pallas" / "pecr_pallas"  same, through the Pallas TPU kernels

Since the LayerGraph refactor this module holds no dispatch of its own: a
`CNNConfig` lowers onto the IR via `repro.configs.vgg19_sparse.vgg19_graph`
and executes through `repro.graph.executor` (the registry resolves every
(kind, impl) pair, including which stage-final layers fuse into PECR). Other
networks (`repro.configs.lenet` / `.alexnet`) use `repro.graph.run_graph` /
`init_graph` directly — VGG-19 is one graph constructor among several.

Also holds the whisper conv frontend (a STUB for the assigned shapes; the
dry-run feeds precomputed frame embeddings — this exists so the ECR conv has a
real consumer in the audio arch and is exercised by unit tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.vgg19_sparse import CNNConfig, vgg19_graph
from repro.graph.executor import maxpool2d, pad2d, run_head, run_units, uniform_impls
from repro.graph.ir import PoolSpec, graph_weights


def init_cnn(key, ccfg: CNNConfig, dtype=jnp.float32) -> dict:
    """Random VGG-style params in the legacy {"stages", "fc1", "fc2"} layout
    (graph-native callers use `repro.graph.init_graph` instead). Classifier
    dims come from the graph's static shape inference — no trace needed."""
    graph = vgg19_graph(ccfg)
    keys = jax.random.split(key, 64)
    ki = iter(keys)
    stages = []
    c_in = ccfg.in_channels
    k = ccfg.kernel_size
    for c_out, n_convs in ccfg.plan:
        convs = []
        for _ in range(n_convs):
            w = jax.random.normal(next(ki), (c_out, c_in, k, k), dtype) * (c_in * k * k) ** -0.5
            convs.append(w)
            c_in = c_out
        stages.append(convs)
    flat = graph.flat_dim()
    fc1 = jax.random.normal(next(ki), (flat, 512), dtype) * flat ** -0.5
    fc2 = jax.random.normal(next(ki), (512, ccfg.n_classes), dtype) * 512 ** -0.5
    return {"stages": stages, "fc1": fc1, "fc2": fc2}


def _pad1(x):
    """1-pixel spatial padding, single image (C,H,W) or batch (N,C,H,W)."""
    return pad2d(x, 1)


def _maxpool(x, p, stride: int = 0, mode: str = "valid"):
    """p x p max-pool over the trailing two (spatial) dims.

    mode="valid" (default) RAISES when the windows do not tile the map — the
    old behaviour silently truncated the tail (`x[..., :oh//p*p, :ow//p*p]`),
    which AlexNet/LeNet shapes actually hit; pass mode="floor" to truncate
    deliberately or mode="ceil" to keep a -inf-padded partial window."""
    return maxpool2d(x, PoolSpec(p, stride=stride, mode=mode))


def _features(params, img, *, impl: str, ccfg: CNNConfig):
    """(C,H,W) -> (C_out, h, w) after all conv stages; batched (N,C,H,W) ->
    (N, C_out, h, w). Every conv/conv_pool call carries the whole batch, so
    each layer is ONE jitted op (batched Pallas grid for the *_pallas impls,
    native lax / vmapped oracle batching otherwise). Impl resolution — which
    units fuse, which conv family backs a fused request — is the registry's
    `unit_impl` rule, not local string matching."""
    graph = vgg19_graph(ccfg)
    conv_ws, _ = graph_weights(params)
    return run_units(img, conv_ws, graph.units(), uniform_impls(graph, impl))


def cnn_forward(params, img, impl: str = "dense", ccfg: CNNConfig = CNNConfig()):
    """(C,H,W) -> class logits, or a batch (N,C,H,W) -> (N, n_classes).

    The batch flows through the conv stack as whole-batch layer calls (not a
    python loop over samples); see `cnn_forward_batch` for the explicit API.
    """
    graph = vgg19_graph(ccfg)
    x = _features(params, img, impl=impl, ccfg=ccfg)
    _, dense_ws = graph_weights(params)
    return run_head(x, dense_ws, graph.head())


def cnn_forward_batch(params, imgs, impl: str = "dense", ccfg: CNNConfig = CNNConfig()):
    """Batched inference entry point: (N,C,H,W) -> (N, n_classes) logits.

    Each conv layer runs once over the whole batch: the dense path uses lax's
    native NCHW batching, the ECR/PECR oracles carry the batch dim through the
    compressed formats, and the Pallas paths use the (n_ob, N, n_cb) batched
    grid with per-sample channel-block schedules (DESIGN.md §2.4).
    """
    assert imgs.ndim == 4, f"expected (N,C,H,W), got {imgs.shape}"
    return cnn_forward(params, imgs, impl=impl, ccfg=ccfg)


def shift_dead_channels(params, rate: float = 0.04, shift: float = 0.12):
    """Emulate trained-net activation statistics on random-init params.

    Trained VGG nets lose whole filters to ReLU + BN shift, growing with depth
    (paper Fig. 2); random init does not. Shift a depth-growing fraction of
    each conv's output filters negative so ReLU kills those channels — used by
    `benchmarks/fig2_sparsity.py` and the planner demo to produce realistic
    channel-block occupancy without trained weights. Works on both the legacy
    {"stages"} layout and the graph-native {"conv", "dense"} layout.
    """
    conv_ws, _ = graph_weights(params)
    shifted_ws = []
    for depth, w in enumerate(conv_ws):
        key = jax.random.PRNGKey(depth)
        bias_mask = (jax.random.uniform(key, (w.shape[0], 1, 1, 1)) <
                     rate * depth).astype(w.dtype)
        shifted_ws.append(w * (1.0 - bias_mask) - shift * bias_mask * jnp.abs(w))
    if "stages" in params:
        out = {"stages": [], "fc1": params["fc1"], "fc2": params["fc2"]}
        it = iter(shifted_ws)
        for convs in params["stages"]:
            out["stages"].append([next(it) for _ in convs])
        return out
    return {"conv": shifted_ws, "dense": list(params["dense"])}


def cnn_feature_maps(params, img, ccfg: CNNConfig = CNNConfig()):
    """The paper's data set (§VI-A): every feature map ENTERING a conv layer."""
    from repro.graph.executor import run_unit

    graph = vgg19_graph(ccfg)
    conv_ws, _ = graph_weights(params)
    maps = []
    x = img
    for unit, w in zip(graph.units(), conv_ws):
        maps.append(x)
        x = run_unit(x, w, unit, "conv", "dense")
    return maps


# ---------------------------------------------------------------------------
# whisper conv frontend (STUB consumer of the ECR conv; not in the dry-run path)
# ---------------------------------------------------------------------------


def init_whisper_frontend(key, n_mels: int, d_model: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "conv1": jax.random.normal(k1, (d_model, n_mels, 3), dtype) * (n_mels * 3) ** -0.5,
        "conv2": jax.random.normal(k2, (d_model, d_model, 3), dtype) * (d_model * 3) ** -0.5,
    }


def whisper_frontend(params, mel, stride2: bool = True):
    """mel: (n_mels, T) -> (T//2, d_model) frame embeddings (gelu conv x2)."""
    x = mel[None]  # (1, n_mels, T)
    x = jax.lax.conv_general_dilated(
        x, params["conv1"], window_strides=(1,), padding=((1, 1),),
        dimension_numbers=("NCH", "OIH", "NCH"))
    x = jax.nn.gelu(x)
    x = jax.lax.conv_general_dilated(
        x, params["conv2"], window_strides=((2,) if stride2 else (1,)), padding=((1, 1),),
        dimension_numbers=("NCH", "OIH", "NCH"))
    x = jax.nn.gelu(x)
    return x[0].T  # (T', d_model)
