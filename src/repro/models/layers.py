"""Shared layer primitives: params-with-logical-axes, norms, rope, inits."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Param leaves carry logical axis names; unzip before handing to the model.
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("value",),
    meta_fields=("axes",),
)
@dataclass
class Px:
    value: Any
    axes: tuple

    @property
    def shape(self):
        return self.value.shape


def _is_px(x):
    return isinstance(x, Px)


def unzip_params(tree):
    """tree-of-Px -> (values tree, logical-axes tree)."""
    vals = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=_is_px)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=_is_px)
    return vals, axes


def dense_init(key, shape, axes, dtype=jnp.float32, fan_in: Optional[int] = None) -> Px:
    fi = fan_in or (shape[-2] if len(shape) >= 2 else shape[-1])
    w = jax.random.normal(key, shape, dtype) * (fi ** -0.5)
    return Px(w.astype(dtype), axes)


def embed_init(key, shape, axes, dtype=jnp.float32) -> Px:
    return Px(jax.random.normal(key, shape, dtype) * 0.02, axes)


def ones_init(shape, axes, dtype=jnp.float32) -> Px:
    return Px(jnp.ones(shape, dtype), axes)


def zeros_init(shape, axes, dtype=jnp.float32) -> Px:
    return Px(jnp.zeros(shape, dtype), axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, ..., d) rotated over last dim; positions: (B, S) or (S,)."""
    d = x.shape[-1]
    assert d % 2 == 0
    freq = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)  # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (B, S, d/2)
    # broadcast ang to x's head dims: x (B, S, *H, d)
    while ang.ndim < x.ndim:
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(n: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32) / d * jnp.log(10_000.0))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)
