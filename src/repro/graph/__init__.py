"""LayerGraph IR + op registry: the model-agnostic spine (DESIGN.md §5).

`repro.graph` holds the three pieces every stage of the pipeline shares:

- `ir`: the typed network description (`ConvSpec`/`ReLU`/`PoolSpec`/`Flatten`/
  `DenseSpec` nodes in a `LayerGraph`), static shape inference, and the
  weight-layout plumbing (`graph_weights`, `init_graph`).
- `registry`: the ONE impl-dispatch site — (kind, impl) -> forward + cost hook
  + fusion metadata — and the PECR fusion rule (`fusion_eligible`).
- `executor`: graph walking (`run_units`/`run_head`/`run_graph`) plus the
  structural primitives (`pad2d`, mode-aware `maxpool2d`).

Network builders live with their configs (`repro.configs.vgg19_sparse.
vgg19_graph`, `repro.configs.lenet`, `repro.configs.alexnet`); `as_graph`
bridges the legacy `CNNConfig`-shaped call sites onto the IR.
"""
from repro.graph.executor import (
    maxpool2d,
    pad2d,
    run_graph,
    run_head,
    run_unit,
    run_units,
    uniform_impls,
)
from repro.graph.ir import (
    ConvSpec,
    ConvUnit,
    DenseSpec,
    Flatten,
    LayerGraph,
    PoolSpec,
    ReLU,
    graph_weights,
    init_graph,
    weight_shapes,
)
from repro.graph.registry import (
    OpImpl,
    conv_impl,
    fused_impl,
    fusion_eligible,
    get_op,
    list_ops,
    register_op,
    unit_impl,
)


def as_graph(graph_or_cfg) -> LayerGraph:
    """Normalize a `LayerGraph` | `CNNConfig` | None to a `LayerGraph` —
    the bridge that keeps every pre-IR call site (planner, engine, autotune,
    examples) working unchanged."""
    if isinstance(graph_or_cfg, LayerGraph):
        return graph_or_cfg
    from repro.configs.vgg19_sparse import CNNConfig, vgg19_graph

    if graph_or_cfg is None:
        graph_or_cfg = CNNConfig()
    if isinstance(graph_or_cfg, CNNConfig):
        return vgg19_graph(graph_or_cfg)
    raise TypeError(
        f"expected a LayerGraph or CNNConfig, got {type(graph_or_cfg).__name__}")


__all__ = [
    "ConvSpec",
    "ConvUnit",
    "DenseSpec",
    "Flatten",
    "LayerGraph",
    "OpImpl",
    "PoolSpec",
    "ReLU",
    "as_graph",
    "conv_impl",
    "fused_impl",
    "fusion_eligible",
    "get_op",
    "graph_weights",
    "init_graph",
    "list_ops",
    "maxpool2d",
    "pad2d",
    "register_op",
    "run_graph",
    "run_head",
    "run_unit",
    "run_units",
    "uniform_impls",
    "unit_impl",
    "weight_shapes",
]
