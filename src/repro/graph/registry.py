"""Op registry: THE one impl-dispatch site from planner to serving.

Every (node kind, impl) pair maps to one `OpImpl` carrying its forward
callable, its op-level cost hook (the autotuner's roofline fallback), and its
fusion metadata. The string-keyed if/elif chains that used to be duplicated
across `pipeline/planner.py`, `models/cnn.py` and the serving cost hooks all
collapse into `get_op` lookups; adding an impl (or a new fused epilogue) is
one `register_op` call, and planner/executor/serving pick it up unchanged.

Kinds:
  "conv"       plain convolution; ReLU / unfused pooling applied structurally
               by the executor around it.
  "conv_pool"  fused conv+ReLU+maxpool (the PECR family) — consumes the whole
               conv unit in one op, the conv result never leaves VMEM/registers.

The registry is also THE cost-dispatch site: `unit_cost` / `unit_model_us`
evaluate one conv unit's modeled FLOPs/bytes/roofline-time as any (kind,
impl) — the planner's joint dense/ECR/PECR/BSR decision and the autotuner's
noisy-clock fallback (`serving.autotune.plan_model_us`) both rank layers
through it, so an impl's cost hook is consulted identically everywhere.

The fusion rule lives here too: `fusion_eligible(unit)` says whether a conv
unit's structure admits the fused epilogue (adjacent ReLU + pool,
pooling stride == pool size, conv output tiled exactly by the pool — the
Pallas epilogue floors, so a remainder would silently change semantics), and
`fused_impl`/`conv_impl` map between a fused impl and the unfused conv impl of
the same family ("pecr_pallas" <-> "ecr_pallas").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.graph.ir import ConvUnit

# ---------------------------------------------------------------------------
# Registry core
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OpImpl:
    """One registered (kind, impl) implementation.

    forward: kind "conv"      -> f(x_padded, w, *, stride, block_c, tile) -> y
             kind "conv_pool" -> f(x_padded, w, *, stride, pool, block_c,
             tile) -> y  (`tile` is a `repro.kernels.tiles.TileConfig` — the
             searched kernel geometry; None/all-zero = the impl's defaults,
             and non-Pallas impls ignore it entirely)
    cost:    f(c, h, w, o, kh, kw, *, stride, occupancy, batch, [pool]) -> dict
             with "flops"/"bytes"/"out_elems" (None = no model; autotune then
             treats the layer as dense roofline).
    sparse:  occupancy-dependent (skips dead channel blocks) — the planner may
             only place these below occ_threshold, and the cost hook honours
             the measured occupancy.
    weight_sparse: depends on STATIC weight block density (skips pruned-away
             weight blocks; activation occupancy buys it nothing) — the
             planner only places these below its density gate, the cost hook
             honours `weight_density`, and `validate_plan` re-checks the
             params' measured density against the plan's at run time.
    pallas:  realized as a Pallas kernel (vs a jnp oracle / XLA path).
    quantized: int8 compute path (fp32 in/out, int8 operands inside) — the
             planner only places these under an explicit accuracy budget
             (`plan_network(int8=..., int8_budget=...)`), mirroring how
             weight_sparse impls sit behind the density gate.
    fused_with: for kind "conv_pool", the kind-"conv" impl of the same family
             (used when a unit's pool is NOT fusion-eligible); for kind
             "conv", the kind-"conv_pool" impl it upgrades to when fusion IS
             eligible (None = never fuses).
    launch:  f(unit, *, tile, block_c, batch) -> a resolved launch descriptor
             (`repro.kernels.tiles.ConvLaunch` / `BsrLaunch`) describing the
             Pallas grid this impl would run on `unit` — the geometry seam
             the static checker (`repro.analysis.launch`) verifies WITHOUT
             compiling. None for impls with no Pallas grid (XLA/jnp paths).
    """

    kind: str
    impl: str
    forward: Callable
    cost: Callable | None = None
    sparse: bool = False
    weight_sparse: bool = False
    pallas: bool = False
    quantized: bool = False
    fused_with: str | None = None
    launch: Callable | None = None


_OPS: dict = {}


def register_op(op: OpImpl) -> OpImpl:
    key = (op.kind, op.impl)
    if key in _OPS:
        raise ValueError(f"op {key} already registered")
    _OPS[key] = op
    return op


def get_op(kind: str, impl: str) -> OpImpl:
    try:
        return _OPS[(kind, impl)]
    except KeyError:
        known = sorted(i for k, i in _OPS if k == kind)
        raise ValueError(
            f"unknown {kind} impl {impl!r} (registered: {known})") from None


def list_ops(kind: str | None = None) -> tuple:
    return tuple(op for op in _OPS.values() if kind is None or op.kind == kind)


# ---------------------------------------------------------------------------
# Fusion rule
# ---------------------------------------------------------------------------


def fusion_eligible(unit: ConvUnit) -> bool:
    """conv+ReLU+pool -> PECR is legal iff the triple is adjacent AND the
    pool is the kernel-supported form: stride == p (non-overlapping) and the
    conv output tiles exactly (the fused epilogue floors; an inexact tiling
    would silently truncate, exactly what PoolSpec mode='valid' guards)."""
    pool = unit.pool
    if pool is None or not unit.relu:
        return False
    if pool.s != pool.p or pool.mode == "ceil":
        return False
    _, oh, ow = unit.conv_out_shape
    return oh % pool.p == 0 and ow % pool.p == 0


def fused_impl(conv_impl: str) -> str | None:
    """The kind-"conv_pool" impl of `conv_impl`'s family (None = no fusion)."""
    return get_op("conv", conv_impl).fused_with


def conv_impl(fused: str) -> str:
    """The kind-"conv" impl a fused impl falls back to on unfusable units."""
    op = get_op("conv_pool", fused)
    if op.fused_with is None:
        raise ValueError(f"fused impl {fused!r} declares no conv fallback")
    return op.fused_with


def unit_impl(unit: ConvUnit, impl: str) -> tuple:
    """Resolve a requested impl against one unit's structure -> (kind, impl).

    A fused-family request ("pecr", "pecr_pallas") becomes the fused op on
    fusion-eligible units and the family's plain conv elsewhere; a plain conv
    request passes through. This is the uniform-impl entry `models/cnn` uses;
    the planner makes the same call per layer with its own sparse decision.
    """
    if ("conv_pool", impl) in _OPS:
        if fusion_eligible(unit):
            return ("conv_pool", impl)
        return ("conv", conv_impl(impl))
    get_op("conv", impl)  # validate
    return ("conv", impl)


# ---------------------------------------------------------------------------
# Cost dispatch (the one place a unit is costed as a (kind, impl))
# ---------------------------------------------------------------------------

# THE roofline constants live in repro.obs.constants (one definition, which a
# measured CalibrationDB overrides per impl); these names stay re-exported so
# benchmarks/_util, the dry-run and autotune keep one import site.
from repro.obs.constants import (  # noqa: E402
    DEFAULT_HBM_BW as HBM_BW,  # noqa: F401
    DEFAULT_PEAK_FLOPS as PEAK_FLOPS,  # noqa: F401
    DEFAULT_ROOFLINE,
)


def _pool_round_trip(base: dict, pool: int, dtype_bytes: int = 4) -> dict:
    """Cost of running an UNFUSED pool after a conv whose cost is `base`: the
    intermediate write/read round trip and the pooled write that PECR fusion
    deletes (the comparison baseline of DESIGN.md §2.3), plus the pool max
    on the VPU."""
    conv_out = base["out_elems"] * dtype_bytes
    return {"flops": base["flops"] + base["out_elems"],
            "bytes": base["bytes"] + conv_out + conv_out / (pool * pool),
            "out_elems": base["out_elems"] // (pool * pool)}


def unit_cost(kind: str, impl: str, *, c, h, w, o, k, stride=1, pool=None,
              occupancy: float = 1.0, weight_density: float = 1.0,
              batch: int = 1) -> dict:
    """Modeled {"flops","bytes","out_elems"} of one conv unit executed as
    (kind, impl). h/w are the PADDED input dims; `pool` is the unit's pool
    window (None = no pool). A kind-"conv" impl with an adjacent pool is
    costed as its own hook + the unfused round trip; a kind-"conv_pool" impl
    consumes the pool in its hook. Occupancy/weight_density only reach hooks
    whose impl declares the corresponding sparsity (a dense impl is costed
    dense no matter what the input measured)."""
    op = get_op(kind, impl)
    kws = dict(stride=stride, batch=batch,
               occupancy=occupancy if op.sparse else 1.0)
    if op.weight_sparse:
        kws["weight_density"] = weight_density
    if pool is not None and kind != "conv_pool":
        return _pool_round_trip(op.cost(c, h, w, o, k, k, **kws), pool)
    if pool is not None:
        kws["pool"] = pool
    return op.cost(c, h, w, o, k, k, **kws)


def unit_model_us(kind: str, impl: str, unit: ConvUnit, *,
                  occupancy: float = 1.0, weight_density: float = 1.0,
                  batch: int = 1, block_c: int = 0, tile=None,
                  calibration=None) -> float:
    """Roofline-modeled time (us) of executing `unit` as (kind, impl) — the
    common currency of the planner's per-layer impl choice and the
    autotuner's whole-plan model (`plan_model_us` sums this per layer).

    `calibration` (a `repro.obs.calibrate.CalibrationDB`, or None) supplies
    MEASURED effective constants per (device kind, kind, impl, tile geometry);
    any key the DB does not cover — and calibration=None entirely — falls
    back to the datasheet defaults, bit-identically to the pre-calibration
    model. `block_c` is the plan's channel-block size (0 = auto) and `tile`
    the full searched `TileConfig` (None = defaults) — together the block
    geometry the calibration is keyed on."""
    conv = unit.conv
    c, h, w = unit.in_shape
    cost = unit_cost(kind, impl, c=c, h=h + 2 * conv.pad, w=w + 2 * conv.pad,
                     o=conv.c_out, k=conv.k, stride=conv.stride,
                     pool=unit.pool.p if unit.pool is not None else None,
                     occupancy=occupancy, weight_density=weight_density,
                     batch=batch)
    consts = DEFAULT_ROOFLINE if calibration is None else \
        calibration.constants_for(kind, impl, block_c, tile=tile)
    return consts.time_us(cost["flops"], cost["bytes"])


def unit_launch(kind: str, impl: str, unit: ConvUnit, *, tile=None,
                block_c: int = 0, batch: int = 1):
    """The resolved launch descriptor of executing `unit` as (kind, impl) —
    None when the impl has no Pallas grid to describe. This is the registry's
    geometry seam: the descriptor comes from the SAME builder the op's
    forward resolves through, so `repro.analysis` verifies the grid that
    would actually launch, never a re-derived approximation."""
    op = get_op(kind, impl)
    if op.launch is None:
        return None
    return op.launch(unit, tile=tile, block_c=block_c, batch=batch)


# ---------------------------------------------------------------------------
# Registrations — the entire impl surface, in one place
# ---------------------------------------------------------------------------


def _conv_dense(xp, w, *, stride, block_c=0, tile=None):
    from repro.core.ecr import conv2d_dense

    return conv2d_dense(xp, w, stride)


def _conv_im2col(xp, w, *, stride, block_c=0, tile=None):
    from repro.core.ecr import conv2d_im2col

    return conv2d_im2col(xp, w, stride)


def _conv_ecr(xp, w, *, stride, block_c=0, tile=None):
    from repro.core.ecr import conv2d_ecr

    return conv2d_ecr(xp, w, stride)


def _conv_ecr_pallas(xp, w, *, stride, block_c=0, tile=None):
    from repro.kernels.ecr_conv.ops import ecr_conv
    from repro.kernels.tiles import as_tile

    t = as_tile(tile, block_c)
    return ecr_conv(xp, w, stride, block_c=t.block_c, block_o=t.block_o)


def _conv_pool_unfused(xp, w, *, stride, pool, block_c=0, tile=None):
    from repro.core.pecr import conv_pool_unfused

    return conv_pool_unfused(xp, w, stride, pool.p, pool.s)


def _conv_pool_pecr(xp, w, *, stride, pool, block_c=0, tile=None):
    from repro.core.pecr import conv_pool_pecr

    return conv_pool_pecr(xp, w, stride, pool.p, pool.s)


def _conv_pool_pecr_pallas(xp, w, *, stride, pool, block_c=0, tile=None):
    from repro.kernels.conv_pool.ops import fused_conv_pool
    from repro.kernels.tiles import as_tile

    # p_s rides through so the kernel's stride==p assertion keeps guarding
    t = as_tile(tile, block_c)
    return fused_conv_pool(xp, w, stride, pool.p, p_s=pool.s,
                           block_c=t.block_c, block_o=t.block_o)


def _conv_cost(c, h, w, o, kh, kw, **kw_args):
    from repro.kernels.ecr_conv.ops import ecr_conv_cost

    return ecr_conv_cost(c, h, w, o, kh, kw, **kw_args)


def _conv_pool_cost(c, h, w, o, kh, kw, **kw_args):
    from repro.kernels.conv_pool.ops import conv_pool_cost

    return conv_pool_cost(c, h, w, o, kh, kw, **kw_args)


def _conv_pool_unfused_cost(c, h, w, o, kh, kw, *, pool=2, dtype_bytes=4, **kw_args):
    """Unfused conv -> ReLU -> pool: the conv cost plus the round trip PECR
    deletes (`_pool_round_trip` over the ECR/dense conv hook)."""
    from repro.kernels.ecr_conv.ops import ecr_conv_cost

    return _pool_round_trip(
        ecr_conv_cost(c, h, w, o, kh, kw, dtype_bytes=dtype_bytes, **kw_args),
        pool, dtype_bytes)


def _conv_bsr(xp, w, *, stride, block_c=0, tile=None):
    from repro.sparse_weights.conv import conv2d_bsr

    return conv2d_bsr(xp, w, stride, tile=tile if tile else None)


def _bsr_cost(c, h, w, o, kh, kw, **kw_args):
    from repro.sparse_weights.conv import bsr_conv_cost

    return bsr_conv_cost(c, h, w, o, kh, kw, **kw_args)


def _conv_ecr_int8(xp, w, *, stride, block_c=0, tile=None):
    from repro.kernels.tiles import as_tile
    from repro.quant.ops import ecr_conv_int8

    t = as_tile(tile, block_c)
    return ecr_conv_int8(xp, w, stride, block_c=t.block_c, block_o=t.block_o)


def _conv_bsr_int8(xp, w, *, stride, block_c=0, tile=None):
    from repro.quant.ops import conv2d_bsr_int8

    return conv2d_bsr_int8(xp, w, stride, tile=tile if tile else None)


def _ecr_int8_cost(c, h, w, o, kh, kw, **kw_args):
    from repro.quant.ops import ecr_conv_int8_cost

    return ecr_conv_int8_cost(c, h, w, o, kh, kw, **kw_args)


def _bsr_int8_cost(c, h, w, o, kh, kw, **kw_args):
    from repro.quant.ops import bsr_conv_int8_cost

    return bsr_conv_int8_cost(c, h, w, o, kh, kw, **kw_args)


# --- launch-descriptor adapters (OpImpl.launch): one per Pallas family ----


def _padded_unit_dims(unit):
    """(c, h, w, o, k, stride) of the kernel call `run_unit` makes for this
    unit — h/w carry the ConvSpec padding the executor applies first."""
    c, h, w = unit.in_shape
    conv = unit.conv
    return c, h + 2 * conv.pad, w + 2 * conv.pad, conv.c_out, conv.k, conv.stride


def _launch_ecr(unit, *, tile=None, block_c=0, batch=1):
    from repro.kernels.ecr_conv.ops import ecr_conv_launch
    from repro.kernels.tiles import as_tile

    c, h, w, o, k, stride = _padded_unit_dims(unit)
    return ecr_conv_launch(c, h, w, o, k, k, stride=stride,
                           tile=as_tile(tile, block_c), batch=batch)


def _launch_pecr(unit, *, tile=None, block_c=0, batch=1):
    from repro.kernels.conv_pool.ops import conv_pool_launch
    from repro.kernels.tiles import as_tile

    c, h, w, o, k, stride = _padded_unit_dims(unit)
    return conv_pool_launch(c, h, w, o, k, k, stride=stride,
                            pool=unit.pool.p if unit.pool is not None else 0,
                            tile=as_tile(tile, block_c), batch=batch)


def _bsr_unit_dims(unit, batch):
    c, _, _, o, k, _ = _padded_unit_dims(unit)
    _, oh, ow = unit.conv_out_shape
    return o, c * k * k, batch * oh * ow


def _launch_bsr(unit, *, tile=None, block_c=0, batch=1):
    from repro.kernels.tiles import as_tile
    from repro.sparse_weights.conv import bsr_conv_launch

    o, k_taps, p = _bsr_unit_dims(unit, batch)
    return bsr_conv_launch(o, k_taps, p, tile=as_tile(tile, block_c) or None)


def _launch_ecr_int8(unit, *, tile=None, block_c=0, batch=1):
    from repro.kernels.tiles import as_tile
    from repro.quant.ops import ecr_conv_int8_launch

    c, h, w, o, k, stride = _padded_unit_dims(unit)
    return ecr_conv_int8_launch(c, h, w, o, k, k, stride=stride,
                                tile=as_tile(tile, block_c), batch=batch)


def _launch_bsr_int8(unit, *, tile=None, block_c=0, batch=1):
    from repro.kernels.tiles import as_tile
    from repro.quant.ops import bsr_conv_int8_launch

    o, k_taps, p = _bsr_unit_dims(unit, batch)
    return bsr_conv_int8_launch(o, k_taps, p, tile=as_tile(tile, block_c) or None)


register_op(OpImpl("conv", "dense", _conv_dense, cost=_conv_cost))
register_op(OpImpl("conv", "im2col", _conv_im2col, cost=_conv_cost))
register_op(OpImpl("conv", "ecr", _conv_ecr, cost=_conv_cost, sparse=True,
                   fused_with="pecr"))
register_op(OpImpl("conv", "ecr_pallas", _conv_ecr_pallas, cost=_conv_cost,
                   sparse=True, pallas=True, fused_with="pecr_pallas",
                   launch=_launch_ecr))
register_op(OpImpl("conv", "bsr", _conv_bsr, cost=_bsr_cost,
                   weight_sparse=True, pallas=True, launch=_launch_bsr))
register_op(OpImpl("conv", "ecr_int8", _conv_ecr_int8, cost=_ecr_int8_cost,
                   sparse=True, pallas=True, quantized=True,
                   launch=_launch_ecr_int8))
register_op(OpImpl("conv", "bsr_int8", _conv_bsr_int8, cost=_bsr_int8_cost,
                   weight_sparse=True, pallas=True, quantized=True,
                   launch=_launch_bsr_int8))
register_op(OpImpl("conv_pool", "unfused", _conv_pool_unfused,
                   cost=_conv_pool_unfused_cost))
register_op(OpImpl("conv_pool", "pecr", _conv_pool_pecr, cost=_conv_pool_cost,
                   sparse=True, fused_with="ecr"))
register_op(OpImpl("conv_pool", "pecr_pallas", _conv_pool_pecr_pallas,
                   cost=_conv_pool_cost, sparse=True, pallas=True,
                   fused_with="ecr_pallas", launch=_launch_pecr))
