"""LayerGraph executor: walk the units, dispatch every op through the registry.

This is the ONE place forward execution happens — `models/cnn.cnn_forward`
(uniform impl), `pipeline/planner.run_plan` (per-layer planned impls) and the
serving engine's compiled runners are all thin wrappers over `run_units` +
`run_head` with different per-unit (kind, impl) assignments. Structural
concerns (padding, unfused ReLU/pool around a plain conv, flatten, the dense
head) live here; impl selection lives in `repro.graph.registry`; numerical
kernels live in core/ and kernels/.

The executor is deliberately mesh-OBLIVIOUS: every op is per-sample along
the batch dim, so under the sharded serving path (DESIGN.md §6) this exact
code runs unchanged inside a shard_map body on each device's batch slice —
the per-sample (ids, cnt) schedules it dispatches to are built shard-local,
and the only collective (the cross-shard occupancy aggregation) lives in
`repro.pipeline.planner.run_plan`, never here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph.ir import ConvUnit, LayerGraph, PoolSpec, graph_weights
from repro.graph.registry import get_op, unit_impl

# ---------------------------------------------------------------------------
# Structural primitives (impl-independent)
# ---------------------------------------------------------------------------


def pad2d(x, pad: int):
    """`pad`-pixel spatial zero padding, (C,H,W) / (N,C,H,W) (no-op pad=0)."""
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0),) * (x.ndim - 2) + ((pad, pad), (pad, pad)))


def maxpool2d(x, pool: PoolSpec):
    """Max-pool the trailing two dims per `pool` (p, stride, mode).

    mode="valid" raises on an inexact tiling (the explicit-truncation guard —
    shapes are static, so this is a plain python check even under jit);
    "floor" drops the tail; "ceil" pads with -inf to keep a partial window.
    """
    from repro.graph.ir import pool_out_len

    h, w = x.shape[-2:]
    oh, ow = pool_out_len(h, pool), pool_out_len(w, pool)  # validates mode
    pad_h = (oh - 1) * pool.s + pool.p - h if pool.mode == "ceil" else 0
    pad_w = (ow - 1) * pool.s + pool.p - w if pool.mode == "ceil" else 0
    lead = x.ndim - 2
    return jax.lax.reduce_window(
        x,
        -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating) else jnp.iinfo(x.dtype).min,
        jax.lax.max,
        window_dimensions=(1,) * lead + (pool.p, pool.p),
        window_strides=(1,) * lead + (pool.s, pool.s),
        padding=((0, 0),) * lead + ((0, max(pad_h, 0)), (0, max(pad_w, 0))),
    )


# ---------------------------------------------------------------------------
# Unit / graph execution
# ---------------------------------------------------------------------------


def run_unit(x, w, unit: ConvUnit, kind: str, impl: str, block_c: int = 0,
             tile=None):
    """Execute one conv unit as (kind, impl): the fused op consumes the whole
    conv+ReLU+pool triple; a plain conv gets the unit's ReLU / unfused pool
    applied structurally around it. `tile` is the layer's searched
    `TileConfig` (None = the impl's default geometry); non-Pallas impls
    ignore it."""
    op = get_op(kind, impl)
    xp = pad2d(x, unit.conv.pad)
    if kind == "conv_pool":
        return op.forward(xp, w, stride=unit.conv.stride, pool=unit.pool,
                          block_c=block_c, tile=tile)
    x = op.forward(xp, w, stride=unit.conv.stride, block_c=block_c, tile=tile)
    if unit.relu:
        x = jnp.maximum(x, 0.0)
    if unit.pool is not None:
        x = maxpool2d(x, unit.pool)
    return x


def run_units(x, conv_ws, units, impls, block_c: int = 0, tiles=None):
    """Run the conv body: `impls` is one (kind, impl) pair per unit; `tiles`
    (optional) one TileConfig-or-None per unit."""
    for i, (unit, (kind, impl), w) in enumerate(zip(units, impls, conv_ws)):
        tile = tiles[i] if tiles is not None else None
        x = run_unit(x, w, unit, kind, impl, block_c, tile=tile)
    return x


def run_head(x, dense_ws, head):
    """Flatten + the dense head ((N,C,H,W) -> (N,classes), or unbatched)."""
    x = x.reshape(x.shape[0], -1) if x.ndim == 4 else x.reshape(-1)
    for w, spec in zip(dense_ws, head):
        x = x @ w
        if spec.relu:
            x = jnp.maximum(x, 0.0)
    return x


def uniform_impls(graph: LayerGraph, impl: str) -> tuple:
    """One whole-network impl string -> per-unit (kind, impl) assignments
    (fused-family impls land on fusion-eligible units, their conv fallback
    elsewhere — the registry's `unit_impl` rule)."""
    return tuple(unit_impl(u, impl) for u in graph.units())


def run_graph(graph: LayerGraph, params, x, impl: str = "dense",
              block_c: int = 0):
    """(C,H,W) or (N,C,H,W) -> logits through the whole graph at one uniform
    impl. Per-layer planned execution is `repro.pipeline.run_plan`."""
    conv_ws, dense_ws = graph_weights(params)
    x = run_units(x, conv_ws, graph.units(), uniform_impls(graph, impl), block_c)
    return run_head(x, dense_ws, graph.head())
