"""LayerGraph IR: the typed, model-agnostic network description every stage of
the pipeline (planner -> executor -> serving -> autotune) consumes.

The paper's Table III point is that sparsity-aware convolution is not
VGG-specific — it extracts layers from LeNet, AlexNet and GoogLeNet — so the
spine must not be either. A `LayerGraph` is a linear sequence of typed nodes
(`ConvSpec`, `ReLU`, `PoolSpec`, `Flatten`, `DenseSpec`) plus an input shape;
everything else (which impl runs each conv, whether a conv+ReLU+pool triple
fuses into PECR) is decided downstream by the op registry and the planner,
never by the graph itself.

Shape inference is static python (shapes are compile-time facts for the Pallas
kernels anyway), so a graph knows every intermediate (C, H, W) without tracing,
and `units()` pre-groups the nodes into plannable conv units: one conv, its
trailing ReLU if adjacent, and its trailing pool if adjacent — the structural
precondition of the PECR fusion rule (`repro.graph.registry.fusion_eligible`).

Branching topologies (GoogLeNet inception) are out of scope for the linear IR;
`benchmarks/table3_single_layer.py` still covers their extracted single layers
synthetically.
"""
from __future__ import annotations

from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Node types
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvSpec:
    """2-D convolution node: `c_out` filters of k x k at `stride`, with
    `pad` pixels of explicit zero padding on each spatial edge."""

    c_out: int
    k: int = 3
    stride: int = 1
    pad: int = 1


@dataclass(frozen=True)
class ReLU:
    """Element-wise max(x, 0)."""


@dataclass(frozen=True)
class PoolSpec:
    """p x p max-pool at `stride` (0 = p, the non-overlapping default).

    `mode` governs what happens when the windows do not tile the map exactly
    (the (ih - p) % stride != 0 tail):
      - "valid" (default): REQUIRE exact coverage; shape inference raises.
        This is the guard against the silent `x[..., :oh//p*p, ...]`
        truncation the VGG-only code used to do.
      - "floor": drop the tail explicitly (the classic cuDNN default).
      - "ceil": pad with -inf so a partial tail window still contributes.
    """

    p: int = 2
    stride: int = 0  # 0 == p
    mode: str = "valid"  # valid | floor | ceil

    @property
    def s(self) -> int:
        return self.stride or self.p


@dataclass(frozen=True)
class Flatten:
    """(C, H, W) -> (C*H*W,) — the conv-stack / classifier seam."""


@dataclass(frozen=True)
class DenseSpec:
    """Fully-connected layer to `d_out` features, optional fused ReLU."""

    d_out: int
    relu: bool = False


# ---------------------------------------------------------------------------
# Shape inference
# ---------------------------------------------------------------------------


def conv_out_hw(h: int, w: int, conv: ConvSpec) -> tuple:
    oh = (h + 2 * conv.pad - conv.k) // conv.stride + 1
    ow = (w + 2 * conv.pad - conv.k) // conv.stride + 1
    if oh < 1 or ow < 1:
        raise ValueError(f"conv {conv} produces empty output from ({h}, {w})")
    return oh, ow


def pool_out_len(n: int, pool: PoolSpec) -> int:
    """Pooled length of one spatial dim; raises on an unintended tail
    (`mode="valid"` is the explicit-truncation guard of PoolSpec)."""
    if n < pool.p:
        raise ValueError(f"pool window p={pool.p} larger than input dim {n}")
    tail = (n - pool.p) % pool.s
    if pool.mode == "valid":
        if tail:
            raise ValueError(
                f"pool p={pool.p} stride={pool.s} would silently drop a "
                f"{tail}-wide tail of a {n}-wide map; use mode='floor' to "
                f"truncate or mode='ceil' to keep a partial window")
        return (n - pool.p) // pool.s + 1
    if pool.mode == "floor":
        return (n - pool.p) // pool.s + 1
    if pool.mode == "ceil":
        out = -(-(n - pool.p) // pool.s) + 1
        # standard ceil_mode rule (cuDNN/PyTorch): the last window must START
        # inside the input — a window lying entirely in the padding would
        # pool nothing but -inf and leak it into the feature map
        if (out - 1) * pool.s >= n:
            out -= 1
        return out
    raise ValueError(f"unknown pool mode {pool.mode!r}")


def pool_out_hw(h: int, w: int, pool: PoolSpec) -> tuple:
    return pool_out_len(h, pool), pool_out_len(w, pool)


# ---------------------------------------------------------------------------
# Conv units (the planner's granularity)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvUnit:
    """One plannable unit: a conv, its adjacent ReLU, its adjacent pool.

    `stage`/`slot` mirror the classic VGG indexing (stage = number of pools
    crossed so far, slot = conv index within the stage) so plans stay
    human-readable across architectures."""

    index: int
    stage: int
    slot: int
    conv: ConvSpec
    relu: bool
    pool: PoolSpec | None
    in_shape: tuple  # (C, H, W) entering the conv (pre-padding)
    out_shape: tuple  # (C, H, W) leaving the unit (post-pool if any)

    @property
    def conv_out_shape(self) -> tuple:
        """(C, H, W) after the conv itself (pre-pool)."""
        oh, ow = conv_out_hw(self.in_shape[1], self.in_shape[2], self.conv)
        return (self.conv.c_out, oh, ow)


@dataclass(frozen=True)
class LayerGraph:
    """A linear CNN: conv/ReLU/pool body, then Flatten, then dense head."""

    name: str
    in_shape: tuple  # (C, H, W)
    nodes: tuple  # tuple of ConvSpec | ReLU | PoolSpec | Flatten | DenseSpec

    def units(self) -> tuple:
        """Group body nodes into `ConvUnit`s (validates the topology)."""
        return self._parse()[0]

    def head(self) -> tuple:
        """The dense head: tuple[DenseSpec, ...] after the Flatten."""
        return self._parse()[1]

    def feature_shape(self) -> tuple:
        """(C, H, W) leaving the conv body (what Flatten sees)."""
        units = self.units()
        return units[-1].out_shape if units else self.in_shape

    def flat_dim(self) -> int:
        c, h, w = self.feature_shape()
        return c * h * w

    def n_classes(self) -> int:
        return self.head()[-1].d_out

    def signature(self) -> tuple:
        """Hashable structural identity (plan-cache key material): two graphs
        with the same shapes and node parameters share compiled programs."""
        return (tuple(self.in_shape), tuple(
            (type(n).__name__,) + tuple(vars(n).values()) for n in self.nodes))

    def _parse(self):
        units, head = [], []
        c, h, w = self.in_shape
        cur: dict | None = None  # open conv unit being grouped
        in_head = False
        stage = slot = 0

        def close():
            nonlocal cur
            if cur is not None:
                units.append(ConvUnit(**cur))
                cur = None

        for node in self.nodes:
            if in_head:
                if not isinstance(node, DenseSpec):
                    raise ValueError(
                        f"{self.name}: only DenseSpec may follow Flatten, got {node}")
                head.append(node)
                continue
            if isinstance(node, ConvSpec):
                close()
                oh, ow = conv_out_hw(h, w, node)
                cur = dict(index=len(units), stage=stage, slot=slot, conv=node,
                           relu=False, pool=None, in_shape=(c, h, w),
                           out_shape=(node.c_out, oh, ow))
                c, h, w = node.c_out, oh, ow
                slot += 1
            elif isinstance(node, ReLU):
                if cur is None or cur["pool"] is not None:
                    raise ValueError(f"{self.name}: ReLU must follow a conv")
                cur["relu"] = True
            elif isinstance(node, PoolSpec):
                if cur is None:
                    raise ValueError(f"{self.name}: pool must follow a conv unit")
                h, w = pool_out_hw(h, w, node)
                cur["pool"] = node
                cur["out_shape"] = (c, h, w)
                close()
                stage, slot = stage + 1, 0
            elif isinstance(node, Flatten):
                close()
                in_head = True
            else:
                raise ValueError(f"{self.name}: unknown node {node!r}")
        close()
        if not in_head or not head:
            raise ValueError(f"{self.name}: graph needs Flatten + a dense head")
        return tuple(units), tuple(head)


# ---------------------------------------------------------------------------
# Weight plumbing (the one flat_weights helper — shared by planner + executor)
# ---------------------------------------------------------------------------


def graph_weights(params) -> tuple:
    """Normalize a params dict to (conv_weights, dense_weights) flat lists.

    Accepts both the graph-native layout {"conv": [...], "dense": [...]} and
    the legacy VGG layout {"stages": [[w, ...], ...], "fc1": w, "fc2": w}.
    This is the single zip seam `validate_plan` and `run_plan` share — the
    length/shape checks live in `validate_plan`, the walk in the executor."""
    if "stages" in params:
        return ([w for convs in params["stages"] for w in convs],
                [params["fc1"], params["fc2"]])
    return list(params["conv"]), list(params["dense"])


def weight_shapes(graph: LayerGraph) -> tuple:
    """((conv weight shapes), (dense weight shapes)) implied by the graph."""
    conv_shapes = []
    for u in graph.units():
        conv_shapes.append((u.conv.c_out, u.in_shape[0], u.conv.k, u.conv.k))
    d_in = graph.flat_dim()
    dense_shapes = []
    for spec in graph.head():
        dense_shapes.append((d_in, spec.d_out))
        d_in = spec.d_out
    return tuple(conv_shapes), tuple(dense_shapes)


def init_graph(key, graph: LayerGraph, dtype=None):
    """Fan-in-scaled random params for a graph, in the graph-native layout."""
    import jax
    import jax.numpy as jnp

    dtype = dtype or jnp.float32
    conv_shapes, dense_shapes = weight_shapes(graph)
    keys = iter(jax.random.split(key, len(conv_shapes) + len(dense_shapes)))
    conv = [jax.random.normal(next(keys), s, dtype) * (s[1] * s[2] * s[3]) ** -0.5
            for s in conv_shapes]
    dense = [jax.random.normal(next(keys), s, dtype) * s[0] ** -0.5
             for s in dense_shapes]
    return {"conv": conv, "dense": dense}
