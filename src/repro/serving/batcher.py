"""Dynamic micro-batcher: single-image requests -> bucketed padded batches.

Serving traffic arrives one image at a time; the batched Pallas grids only pay
off when a whole batch flows through each layer as one op (DESIGN.md §2.4:
kernel-tensor reuse amortizes by 1/N). The batcher bridges the two: requests
queue until either a full bucket of `max_batch` is waiting or the OLDEST
request has been queued for `deadline_s` — then a batch is formed at the
smallest executable bucket that fits (powers of two plus the `max_batch` cap
itself, filtered by the device-alignment rule below), and the engine pads the ragged tail
with all-zero images (which the per-sample (ids, cnt) schedules skip entirely:
a pad sample costs 0 MACs in the sparse layers).

The deadline is a hard formation budget: provided the driver polls `ready()`
no later than `next_deadline()`, no request ever waits in the queue longer
than `deadline_s` (asserted by the simulated-clock test in
tests/test_serving.py). The clock is injectable — `SimClock` gives serving
tests and the queueing benchmark a deterministic timeline.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field


class SimClock:
    """Deterministic, manually-advanced clock (seconds). Duck-typed against
    `time.monotonic`: calling it reads the time; `advance`/`set` move it.
    The engine charges measured execution wall time into a SimClock so the
    simulated timeline carries real service times (see Engine._run_batch)."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t

    def set(self, t: float) -> float:
        self.t = max(self.t, float(t))  # monotonic: never move backwards
        return self.t


def bucket_sizes(max_batch: int) -> tuple:
    """Powers of two up to max_batch, plus max_batch itself when it is not a
    power of two (the requested cap is HONORED, never silently clamped —
    bucket_sizes(6) == (1, 2, 4, 6)): the bucket set every batch pads into.
    One jitted program per bucket keeps the compile count logarithmic in
    max_batch instead of linear in observed batch sizes; a non-power-of-two
    cap costs exactly one extra program."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    sizes = [1]
    while sizes[-1] * 2 <= max_batch:
        sizes.append(sizes[-1] * 2)
    if sizes[-1] != max_batch:
        sizes.append(max_batch)
    return tuple(sizes)


@dataclass(frozen=True)
class Request:
    """One queued single-image inference request."""

    id: int
    img: object  # (C,H,W) array
    t_arrival: float


@dataclass(frozen=True)
class MicroBatch:
    """A formed batch: `requests` are the real samples; `bucket` is the padded
    batch size the engine executes at (bucket - len(requests) pad samples)."""

    requests: tuple
    bucket: int
    t_formed: float

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def fill(self) -> float:
        return len(self.requests) / self.bucket


@dataclass
class MicroBatcher:
    """`min_bucket` floors the PER-DEVICE executed batch size (default 2):
    XLA's M=1 GEMV accumulates the classifier reduction in a different order
    than the GEMM used at M>=2, so padding lone requests up to a 2-bucket
    keeps every request's logits bit-identical to the whole-batch `run_plan`
    reference regardless of how the stream happened to be chopped into
    batches — and the pad sample is skipped by the sparse layers' per-sample
    schedules.

    `align` is the sharded-serving knob (DESIGN.md §6): with a data-parallel
    mesh of N devices the engine sets align=N, and every EXECUTED bucket is a
    multiple of align whose per-device slice is >= min_bucket — each shard
    gets an equal, >=2-sample slice (the bit-exactness floor applies on every
    device), and the extra pad samples stay free under the per-sample
    schedules. align=1 (the default) is exactly the unsharded behavior."""

    max_batch: int = 8
    deadline_s: float = 0.010
    clock: object = time.monotonic
    min_bucket: int = 2
    align: int = 1
    _q: deque = field(default_factory=deque, init=False, repr=False)
    _next_id: int = field(default=0, init=False, repr=False)

    def __post_init__(self):
        if self.align < 1:
            raise ValueError(f"align must be >= 1, got {self.align}")
        if self.max_batch % self.align:
            raise ValueError(
                f"max_batch={self.max_batch} must be a multiple of "
                f"align={self.align} (one equal slice per device)")
        self.buckets = bucket_sizes(self.max_batch)
        if self.align > 1 and self.max_batch // self.align < self.min_bucket:
            # silently clamping here would hand every shard an M=1 slice —
            # exactly the GEMV reduction-order case min_bucket exists to
            # prevent — and quietly void the bit-exactness contract
            raise ValueError(
                f"max_batch={self.max_batch} over align={self.align} devices "
                f"gives each shard {self.max_batch // self.align} sample(s), "
                f"below the min_bucket={self.min_bucket} bit-exactness floor; "
                "pass min_bucket=1 to accept M=1 shards or use fewer devices")
        # unsharded legacy clamp: max_batch=1 callers explicitly want singletons
        self.min_bucket = min(self.min_bucket, max(1, self.max_batch // self.align))

    def submit(self, img, now: float | None = None) -> int:
        """Queue one image; returns its request id (submission order)."""
        rid = self._next_id
        self._next_id += 1
        self._q.append(Request(id=rid, img=img, t_arrival=self.clock() if now is None else now))
        return rid

    def pending(self) -> int:
        return len(self._q)

    def next_deadline(self) -> float | None:
        """Absolute time by which `ready()` must next be polled (oldest
        arrival + deadline), or None when the queue is empty."""
        if not self._q:
            return None
        return self._q[0].t_arrival + self.deadline_s

    def exec_buckets(self) -> tuple:
        """The bucket sizes batches actually execute at — multiples of
        `align` whose per-device slice is >= min_bucket — the set the engine
        pre-compiles on warmup. Non-empty by construction (max_batch always
        qualifies)."""
        return tuple(b for b in self.buckets
                     if b % self.align == 0 and b // self.align >= self.min_bucket)

    def bucket_for(self, n: int) -> int:
        """Smallest executable bucket >= n (n is capped at max_batch by the
        callers)."""
        for b in self.exec_buckets():
            if b >= n:
                return b
        return self.max_batch

    def ready(self, now: float | None = None) -> MicroBatch | None:
        """Form a batch if one is due: a full max_batch bucket dispatches
        immediately; otherwise the oldest request's deadline forces a ragged
        flush. Returns None when nothing is due yet."""
        if not self._q:
            return None
        now = self.clock() if now is None else now
        if len(self._q) >= self.max_batch:
            return self._form(self.max_batch, now)
        if now >= self._q[0].t_arrival + self.deadline_s:
            return self._form(len(self._q), now)
        return None

    def flush(self, now: float | None = None) -> MicroBatch | None:
        """Unconditionally form a batch from up to max_batch queued requests
        (drain path: end of stream, shutdown)."""
        if not self._q:
            return None
        now = self.clock() if now is None else now
        return self._form(min(len(self._q), self.max_batch), now)

    def _form(self, n: int, now: float) -> MicroBatch:
        reqs = tuple(self._q.popleft() for _ in range(n))
        return MicroBatch(requests=reqs, bucket=self.bucket_for(n), t_formed=now)
