"""Offline plan autotuner: search (occ_threshold, block_c) on a calibration
batch, select by measured wall time, fall back to the cost model when timing
is too noisy.

The planner's two knobs interact: a bigger `block_c` amortizes schedule
overhead but rounds n_live up harder (fewer skippable blocks), and the
profitable `occ_threshold` shifts with both (paper Fig. 9/11: which layers
should run ECR/PECR is occupancy- and shape-dependent). The autotuner builds
one `PipelinePlan` per grid point (deduping points that collapse to the same
schedule), times the jitted whole-batch executor, and picks the fastest.

Timing on a shared machine is noisy; the fallback ranks by the modeled
roofline time instead: `hlo_cost.analyze` over the lowered executor for
all-dense plans (where the HLO is a faithful account of the math XLA will
run), and the kernel-level cost hooks (`ecr_conv_cost` / `conv_pool_cost`)
when the plan contains Pallas layers — interpret-mode Pallas lowers to an
emulation whose HLO counts the emulator, not the kernel, so sparse plans are
modeled at the granularity the kernels actually schedule (skipped blocks save
their MACs and their DMA).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax

from repro.graph import as_graph
from repro.graph.registry import HBM_BW, PEAK_FLOPS, get_op, unit_model_us
from repro.pipeline.planner import PipelinePlan, plan_network, run_plan, run_plan_sharded
from repro.serving.plan_cache import plan_key


@dataclass
class Candidate:
    occ_threshold: float
    block_c: int
    plan: PipelinePlan
    wall_us: float = float("inf")
    spread: float = 0.0  # (max-min)/median of the timing samples
    model_us: float = float("inf")
    timings_us: list = field(default_factory=list)

    def row(self) -> dict:
        return {"occ_threshold": self.occ_threshold, "block_c": self.block_c,
                "wall_us": round(self.wall_us, 1), "spread": round(self.spread, 3),
                "model_us": round(self.model_us, 3),
                "counts": self.plan.counts()}


@dataclass
class AutotuneResult:
    best: Candidate
    candidates: list
    used_model: bool  # True when the noisy-timing fallback decided the winner

    @property
    def plan(self) -> PipelinePlan:
        return self.best.plan


def plan_model_us(plan: PipelinePlan, params, batch: int = 1,
                  calibration=None) -> float:
    """Roofline-modeled execution time (us) of a plan at a given batch size:
    the registry's `unit_model_us` per layer (each LayerPlan's own IR specs —
    `to_unit` rejects pre-IR plans — so LeNet's 5x5 convs and AlexNet's
    strided/overlapping layers model at their real geometry; dense layers
    are the occupancy=1.0 point, BSR layers honour the plan's recorded
    weight density, unfused pools cost the round trip PECR deletes) plus the
    classifier GEMMs. Summing per-layer roofline maxima upper-bounds the
    whole-program roofline the pre-BSR version took over global totals —
    identical whenever one side of the roofline dominates every layer, which
    these conv stacks satisfy, and a consistent ranking either way.

    `calibration` (a `repro.obs.calibrate.CalibrationDB`) prices each layer
    at its impl's MEASURED effective constants (DESIGN.md §9); uncovered
    keys — and calibration=None — use the datasheet defaults. The head
    GEMMs always model at the defaults: they run as plain XLA dots, outside
    the per-impl kernel families the DB is keyed on."""
    from repro.graph.ir import graph_weights

    us = 0.0
    for lp in plan.layers:
        us += unit_model_us(lp.kind, lp.impl, lp.to_unit(),
                            occupancy=lp.occupancy,
                            weight_density=lp.weight_density, batch=batch,
                            block_c=plan.block_c,
                            tile=getattr(lp, "tile", None),
                            calibration=calibration)
    # classifier: flatten -> dense head GEMMs
    flops = 0.0
    nbytes = 0.0
    _, dense_ws = graph_weights(params)
    for w in dense_ws:
        d_in, d_out = w.shape
        flops += 2.0 * batch * d_in * d_out
        nbytes += 4.0 * (d_in * d_out + batch * (d_in + d_out))
    return us + max(flops / PEAK_FLOPS, nbytes / HBM_BW) * 1e6


def hlo_model_us(fn, *args) -> float:
    """Roofline time (us) from `hlo_cost.analyze` over the lowered program —
    the faithful model for plans with no Pallas (interpret-emulated) layers."""
    from repro.launch import hlo_cost

    hlo = jax.jit(fn).lower(*args).compile().as_text()
    a = hlo_cost.analyze(hlo)
    return max(a["flops"] / PEAK_FLOPS, a["bytes"] / HBM_BW) * 1e6


def _time_us(f, *args, iters: int = 3, warmup: int = 1) -> tuple:
    """(median_us, spread, samples) via the SHARED timing harness
    (`repro.obs.profile.time_callable` — jit warm-up, block_until_ready,
    median-of-k): autotune candidates and `obs.profile_plan` layer rows are
    measured by the same protocol, so their numbers are comparable.
    Outlier rejection stays off here — the spread feeds the noisy-clock
    fallback decision, which must see the raw clock quality."""
    from repro.obs.profile import time_callable

    t = time_callable(f, *args, iters=iters, warmup=warmup, outlier_tol=0.0)
    return t.median_us, t.spread, list(t.samples_us)


def _model_us(plan: PipelinePlan, params, calib, runner,
              calibration=None) -> float:
    if calibration is not None or \
            any(get_op(lp.kind, lp.impl).pallas for lp in plan.layers):
        return plan_model_us(plan, params, batch=calib.shape[0],
                             calibration=calibration)
    return hlo_model_us(runner, params, calib)


def autotune(params, calib, graph=None, *,
             thresholds=(0.0, 0.5, 0.75, 0.9), block_cs=(0, 8),
             iters: int = 3, warmup: int = 1, noise_tol: float = 0.25,
             use_pallas: bool = True, mode: str = "auto",
             mesh=None, calibration=None, tiles=None, int8: bool = False,
             int8_budget: float = 0.98) -> AutotuneResult:
    """Grid-search (occ_threshold, block_c); return the plan that serves the
    calibration batch fastest. `graph` is a LayerGraph or legacy CNNConfig
    (None = full VGG-19).

    mode="auto" selects by median wall time, unless the timing cannot
    separate the top two candidates — the winner's spread exceeds `noise_tol`,
    or the runner-up is within the larger of the two spreads — in which case
    the ranking falls back to the cost model (see module docstring).
    mode="time" / mode="model" force one criterion (used by tests and by
    callers that know their clock quality).

    `mesh` (a 1-D "data" mesh, DESIGN.md §6) times each candidate through the
    SHARDED executor the serving engine will actually run — the calibration
    batch must divide the device count. The cost-model fallback stays
    per-device (the roofline constants describe one chip, and the collective
    traffic is identical across candidates, so it cancels in the ranking).

    `calibration` (a `repro.obs.calibrate.CalibrationDB`) flows into both
    sides of the search: candidate plans are BUILT calibrated
    (`plan_network(calibration=)`) and the noisy-clock fallback ranks by the
    calibrated `plan_model_us` (a populated DB also retires the dense-plan
    HLO path — measured per-impl constants beat re-deriving the default
    roofline from lowered HLO). None keeps today's behavior exactly.

    `tiles` / `int8` / `int8_budget` pass straight through to `plan_network`:
    every candidate plan is built with the stored tile-search winners stamped
    and (when int8=True) the probe-gated quantized upgrades applied, so the
    search ranks the plans that would actually serve.
    """
    graph = as_graph(graph)
    if calib.ndim == 3:
        calib = calib[None]
    if mesh is not None and mesh.size == 1:
        mesh = None
    seen: dict = {}
    runners: dict = {}
    cands: list = []
    for th in thresholds:
        for bc in block_cs:
            plan = plan_network(params, calib, graph, occ_threshold=th,
                                block_c=bc, use_pallas=use_pallas,
                                calibration=calibration, tiles=tiles,
                                int8=int8, int8_budget=int8_budget)
            sig = plan_key(calib.shape[0], plan)
            if sig in seen:  # same schedule == same executable: reuse timing
                cands.append(Candidate(th, bc, plan, *seen[sig]))
                continue
            runners[sig] = _runner_for(plan)  # unsharded: the model fallback's HLO view
            if mode == "model":  # ranking by model only: skip the timing runs
                wall, spread, ts = float("inf"), 0.0, []
            else:
                wall, spread, ts = _time_us(jax.jit(_runner_for(plan, mesh)),
                                            params, calib,
                                            iters=iters, warmup=warmup)
            seen[sig] = (wall, spread, float("inf"), ts)
            cands.append(Candidate(th, bc, plan, wall, spread, float("inf"), ts))
    by_time = sorted(cands, key=lambda c: c.wall_us)
    # distinct schedules only: dedup aliases share one timing, and comparing
    # the winner against its own alias would read as margin 0 == "noisy"
    uniq: dict = {}
    for c in by_time:
        uniq.setdefault(plan_key(calib.shape[0], c.plan), c)
    distinct = list(uniq.values())
    used_model = mode == "model"
    if mode == "auto" and len(distinct) > 1:
        w0, w1 = distinct[0], distinct[1]
        margin = (w1.wall_us - w0.wall_us) / max(w0.wall_us, 1e-9)
        used_model = w0.spread > noise_tol or margin < max(w0.spread, w1.spread)
    elif mode == "auto":
        used_model = distinct[0].spread > noise_tol
    if used_model:
        # model cost is computed lazily, only when it actually decides the
        # ranking (hlo_model_us recompiles the dense programs to read HLO)
        model_by_sig: dict = {}
        for c in cands:
            sig = plan_key(calib.shape[0], c.plan)
            if sig not in model_by_sig:
                model_by_sig[sig] = _model_us(c.plan, params, calib,
                                              runners[sig], calibration)
            c.model_us = model_by_sig[sig]
    best = min(cands, key=lambda c: c.model_us) if used_model else by_time[0]
    return AutotuneResult(best=best, candidates=cands, used_model=used_model)


def _runner_for(plan: PipelinePlan, mesh=None):
    def run(params, imgs):
        if mesh is None:
            return run_plan(plan, params, imgs)
        return run_plan_sharded(plan, params, imgs, mesh)

    return run
