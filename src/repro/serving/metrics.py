"""Serving telemetry: the `MetricsTracker` the `Engine` feeds per event.

The re-planner and the plan cache exist for *shifting* traffic (the paper's
Fig. 3 diurnal-sparsity story), but counters alone cannot show whether a
re-plan fired at the right time or a cache key churned — that takes time
series. The tracker turns the engine's event stream into a deterministic,
JSON-serializable `snapshot()`:

- request/batch counters and per-bucket execute counts (which bucket shapes
  the traffic actually exercised — the fill story behind `mean_fill`);
- a bounded latency reservoir (Vitter's algorithm R on a seeded PRNG, so two
  identical replays sample identically) reporting p50/p95/p99/mean/max —
  fed per COMPLETED request, whether it completed through `poll()` or the
  `drain()`/flush tail, so `Engine.stats()` percentiles cover every request;
- the per-layer occupancy-EMA timeline (one row per executed batch) — the
  drift signal the re-planner consumes, recorded so a BENCH artifact can
  show occupancy moving and the re-plan answering;
- re-plan events (trigger with its out-of-band delta, swap with whether the
  schedule actually changed, error, hot-swap), timestamped on the engine's
  clock.

Determinism contract: on a `SimClock` with a fixed service-time model
(`Engine(sim_service_s=...)`), two identical replays produce bit-identical
snapshots — tests/test_scenarios.py pins this, which is what makes BENCH
JSON diffs meaningful rather than noise.

Cross-run trajectory: `repro.obs.history.telemetry_rows(snapshot)` renders
the scalar half of a snapshot as perf-history rows (DESIGN.md §13), so the
serving health of every run — p50/p95/p99, fill, re-plan counters — is a
first-class BenchDB series `repro-bench check` gates on
(`launch/serve_cnn.py --history` is the wired entry point).

All timestamps are whatever the engine's clock reads (simulated seconds for
SimClock replays, `time.monotonic` live). Timelines and event logs are
bounded deques: a long-lived engine keeps the most recent `timeline_max`
entries instead of growing without bound.
"""
from __future__ import annotations

import random
from collections import deque


def _percentile(sorted_vals, q: float) -> float:
    """Linear-interpolated percentile of an ascending list (numpy's default
    method, without materializing an array per snapshot). q in [0, 100]."""
    n = len(sorted_vals)
    if n == 0:
        return 0.0
    if n == 1:
        return float(sorted_vals[0])
    pos = (q / 100.0) * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac)


class LatencyReservoir:
    """Bounded uniform sample of request latencies (algorithm R).

    Exact while `count <= size` (every latency is in the sample — the test
    and CI-benchmark regime), an unbiased uniform subsample beyond. The PRNG
    is seeded so identical event streams produce identical reservoirs —
    the determinism contract of `MetricsTracker.snapshot()`.
    """

    def __init__(self, size: int = 4096, seed: int = 0):
        if size < 1:
            raise ValueError(f"reservoir size must be >= 1, got {size}")
        self.size = size
        self._rng = random.Random(seed)
        self.values: list = []
        self.count = 0
        self.total = 0.0
        self.max = 0.0

    def add(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v > self.max:
            self.max = v
        if len(self.values) < self.size:
            self.values.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self.size:
                self.values[j] = v

    def percentiles_ms(self) -> dict:
        """{"count", "mean_ms", "max_ms", "p50_ms", "p95_ms", "p99_ms"} over
        the reservoir (values stored in seconds, reported in ms)."""
        s = sorted(self.values)
        return {
            "count": self.count,
            "mean_ms": (self.total / self.count * 1e3) if self.count else 0.0,
            "max_ms": self.max * 1e3,
            "p50_ms": _percentile(s, 50) * 1e3,
            "p95_ms": _percentile(s, 95) * 1e3,
            "p99_ms": _percentile(s, 99) * 1e3,
        }


class MetricsTracker:
    """Event sink for one serving engine (or one shared stream of engines).

    The engine calls the `on_*` hooks; `snapshot()` renders the current state
    as a plain dict of JSON-serializable values (no numpy scalars, no
    tuples-vs-lists ambiguity) that `Engine.stats()` absorbs under
    ``"telemetry"`` and `benchmarks/_util.write_bench_json` can emit as a
    time series.
    """

    def __init__(self, reservoir_size: int = 4096, timeline_max: int = 4096,
                 seed: int = 0):
        self.latency = LatencyReservoir(reservoir_size, seed=seed)
        self.submitted = 0
        self.completed = 0
        self.batches = 0
        self.pad_samples = 0
        self._fill_sum = 0.0
        self.service_s_total = 0.0
        self.bucket_counts: dict = {}
        self.occ_timeline: deque = deque(maxlen=timeline_max)
        self.replan_events: deque = deque(maxlen=timeline_max)
        self.replan_triggers = 0
        self.replan_swaps = 0
        self.replan_errors = 0
        self.hot_swaps = 0
        self.verify_rejects = 0

    # -- engine hooks ------------------------------------------------------

    def on_submit(self, t: float) -> None:
        self.submitted += 1

    def on_batch(self, t: float, bucket: int, n_real: int,
                 service_s: float) -> None:
        """One executed bucket: `service_s` is the time CHARGED to the
        timeline (measured wall, or the engine's fixed `sim_service_s`
        model — the deterministic replays record the model, never the
        noisy wall)."""
        self.batches += 1
        self.pad_samples += bucket - n_real
        self._fill_sum += n_real / bucket
        self.service_s_total += float(service_s)
        self.bucket_counts[bucket] = self.bucket_counts.get(bucket, 0) + 1

    def on_result(self, latency_s: float) -> None:
        """One COMPLETED request — poll()-completed and drain()/flush-tail
        alike, so the percentiles never silently exclude the stragglers the
        deadline machinery exists to bound."""
        self.completed += 1
        self.latency.add(latency_s)

    def on_occupancy(self, t: float, ema) -> None:
        self.occ_timeline.append((float(t), [float(v) for v in ema]))

    def on_replan_trigger(self, t: float, delta: float) -> None:
        self.replan_triggers += 1
        self.replan_events.append(
            {"t": float(t), "kind": "trigger", "delta": float(delta)})

    def on_replan_swap(self, t: float, changed: bool) -> None:
        self.replan_swaps += 1
        self.replan_events.append(
            {"t": float(t), "kind": "swap", "changed": bool(changed)})

    def on_replan_error(self, t: float) -> None:
        self.replan_errors += 1
        self.replan_events.append({"t": float(t), "kind": "error"})

    def on_hot_swap(self, t: float) -> None:
        self.hot_swaps += 1
        self.replan_events.append({"t": float(t), "kind": "hot_swap"})

    def on_verify_reject(self, t: float, codes=()) -> None:
        """A candidate plan the static verifier refused (hot swap or re-plan
        adoption): `codes` are the error diagnostic codes that fired."""
        self.verify_rejects += 1
        self.replan_events.append({"t": float(t), "kind": "verify_reject",
                                   "codes": [str(c) for c in codes]})

    # -- rendering ---------------------------------------------------------

    def mean_fill(self) -> float:
        return self._fill_sum / self.batches if self.batches else 0.0

    def snapshot(self) -> dict:
        """The current telemetry as a deterministic, JSON-ready dict."""
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "batches": self.batches,
            "pad_samples": self.pad_samples,
            "mean_fill": self.mean_fill(),
            "service_s_total": self.service_s_total,
            "bucket_counts": {str(b): self.bucket_counts[b]
                              for b in sorted(self.bucket_counts)},
            "latency": self.latency.percentiles_ms(),
            "occ_timeline": [[t, list(e)] for t, e in self.occ_timeline],
            "replan_events": list(self.replan_events),
            "replans": {"triggers": self.replan_triggers,
                        "swaps": self.replan_swaps,
                        "errors": self.replan_errors,
                        "hot_swaps": self.hot_swaps,
                        "verify_rejects": self.verify_rejects},
        }
