"""Plan/compile cache: one AOT-compiled executable per (bucket, plan) key.

Serving cannot afford a recompile per request: the whole point of bucketed
batching is that the set of distinct programs is small and each compiles
exactly once. The cache key is

    (batch bucket, block_c, occupancy signature, graph signature, mesh shape,
     weight-sparsity signature)

where the mesh shape is the serving data mesh's ((axis, size), ...) — a
sharded executable bakes its device layout into the program, so one cache
serves the 1..N-device layouts of a schedule side by side (DESIGN.md §6) —
and the graph signature is the plan's `LayerGraph.signature()` — one engine
(or one shared cache) can serve several networks (VGG-19 / LeNet / AlexNet)
without two structurally different models ever colliding on a program — and
the occupancy signature is the tuple of per-layer impl decisions
("dense" / "ecr_pallas" / "pecr_pallas" / ...). This IS the occupancy bucket
that matters for compilation: the measured occupancies only reach the
compiled program through which side of `occ_threshold` each layer fell, so
quantizing occupancy to the decision boundary is the coarsest bucketing that
still maps every distinct executable to its own key — two re-plans whose
measured occupancies drifted but whose schedules agree share one compiled
program (cache hit, no recompile).

Compilation is ahead-of-time (`jax.jit(...).lower(...).compile()`), so a miss
pays its full cost at `get_or_compile` time and `compiles` counts real XLA
compilations — the serving tests assert compiles == number of distinct keys.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass


@dataclass(frozen=True)
class PlanKey:
    bucket: int  # padded batch size the executable was compiled for
    block_c: int  # the plan's channel-block size (0 = per-layer auto)
    occ_sig: tuple  # per-layer (kind, impl) decisions — the occupancy bucket
    graph_sig: tuple = ()  # LayerGraph.signature() — the network's structure
    mesh_shape: tuple = ()  # ((axis, size), ...) of the data mesh; () = 1 device
    weight_sig: tuple = ()  # (layer index, rounded density) per BSR layer
    tile_sig: tuple = ()  # (layer index, TileConfig.key()) per tiled layer


def plan_key(bucket: int, plan, mesh=None) -> PlanKey:
    """The cache key of executing `plan` at batch size `bucket` on `mesh`.

    `mesh` is the serving data mesh (None or a 1-device mesh key as `()`): a
    sharded executable bakes its device layout into the compiled program, so
    one shared cache can hold the 1..N-device variants of the same schedule
    side by side without collisions.

    The weight signature distinguishes PRUNED variants: two plans over the
    same graph whose BSR layers were pruned to different densities are
    different served models (same compiled program shape, different
    params/schedules), and one engine or shared cache must never hand one
    variant the other's entry. Only weight-sparse layers contribute (density
    rounded to 2 dp — the granularity pruning actually achieves), so every
    dense/ECR plan keeps the exact key it had before weight sparsity existed.

    The tile signature does the same for SEARCHED kernel geometry: a layer
    whose plan carries a non-default `TileConfig` compiles a different Pallas
    grid, so two plans differing only in tile geometry must not share an
    executable. Only layers with a non-default tile contribute, so every
    default-geometry plan keeps the exact key it had before tile search
    existed.
    """
    from repro.graph.registry import get_op

    graph = getattr(plan, "graph", None)
    mesh_shape = () if mesh is None or mesh.size == 1 else tuple(
        (str(a), int(s)) for a, s in mesh.shape.items())
    weight_sig = tuple(
        (lp.index, round(getattr(lp, "weight_density", 1.0), 2))
        for lp in plan.layers if get_op(lp.kind, lp.impl).weight_sparse)
    tile_sig = tuple(
        (lp.index, lp.tile.key()) for lp in plan.layers
        if getattr(lp, "tile", None) is not None and lp.tile)
    return PlanKey(bucket=int(bucket), block_c=int(plan.block_c),
                   occ_sig=tuple((lp.kind, lp.impl) for lp in plan.layers),
                   graph_sig=graph.signature() if graph is not None else (),
                   mesh_shape=mesh_shape, weight_sig=weight_sig,
                   tile_sig=tile_sig)


class PlanCache:
    """LRU cache of compiled executables, with hit/miss/compile counters."""

    def __init__(self, max_entries: int = 32):
        self.max_entries = max_entries
        self._entries: OrderedDict = OrderedDict()  # PlanKey -> (exe, plan)
        self.compiles = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._entries

    def get_or_compile(self, key: PlanKey, plan, build):
        """Return the executable for `key`, compiling via `build()` on a miss.

        `build` must return the AOT-compiled executable (it is only called on
        a miss, and exactly once per distinct key while the entry is resident).

        A miss statically verifies the plan FIRST (DESIGN.md §12): AOT
        compilation is the expensive step, and a plan with error-severity
        diagnostics must never reach it (the raise is a
        `PlanVerificationError`, before `build()` runs). Hits skip the check
        — whatever is cached already verified. Tests that exercise the cache
        mechanics with sentinel plans (None / no layers) are left alone.
        """
        if key in self._entries:
            self.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key][0]
        self.misses += 1
        if plan is not None and getattr(plan, "layers", None):
            from repro.analysis import assert_plan_ok

            assert_plan_ok(plan)
        exe = build()
        self.compiles += 1
        self._entries[key] = (exe, plan)
        if len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1
        return exe

    def stats(self) -> dict:
        return {"entries": len(self._entries), "compiles": self.compiles,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}
