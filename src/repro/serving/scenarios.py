"""Scenario library + replay driver: regime-diverse traffic for the engine.

Every serving benchmark before this module swept STEADY request rates, but
the engine's occupancy-EMA re-planner exists precisely for *shifting*
traffic (the paper's Fig. 3 diurnal-sparsity story; Shi & Chu show ReLU
sparsity moves per layer and per input). A `Scenario` is a deterministic,
seeded description of one traffic regime — arrival times plus a per-request
image source, all driven on the engine's `SimClock` — so re-plan quality,
cache behavior, and deadline handling become regression-testable per regime
instead of anecdotal.

Concrete regimes:

- `PoissonBurstScenario` — Poisson arrivals whose rate switches between a
  base and a burst level on a fixed cycle (the overload case the batcher's
  drain-every-due-bucket poll loop exists for);
- `DiurnalDriftScenario` — steady arrivals whose dead-channel band widens or
  narrows over simulated time (step or sinusoidal "hours"), the regime that
  must push the occupancy EMA out of the hysteresis band and re-plan;
- `MultiTenantScenario` — interleaved streams for several models sharing one
  `PlanCache` (the graph/weight signatures in `PlanKey` must keep tenants
  from ever cross-contaminating compiled programs);
- `HotSwapScenario` — a steady stream with a timed event that swaps the
  engine to a differently-pruned BSR variant under load
  (`Engine.hot_swap`, atomic between batches).

`replay_scenario` generalizes `replay_stream` (now a thin wrapper in
`engine.py`): it merges scenario arrivals, scenario events, and every
engine's batcher deadline into one deterministic event loop on a shared
`SimClock`. tests/test_scenarios.py pins per-regime behavior;
benchmarks/scenarios.py sweeps scenario x model into BENCH_scenarios.json.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field

import jax

from repro.core import dead_channel_band
from repro.serving.batcher import SimClock


def synth_image(in_shape, seed: int, i: int, dead_frac: float = 0.5):
    """The i-th request image of a seeded stream: uniform (C,H,W) with the
    TRAILING `dead_frac` channel band zeroed (the deterministic shared
    dead-channel band the serving stack's exactness contract rides on —
    DESIGN.md §2.2/§4). Pure function of (seed, i, dead_frac)."""
    img = jax.random.uniform(jax.random.PRNGKey(seed * 1000003 + i), in_shape)
    return dead_channel_band(img, dead_frac)


@dataclass(frozen=True)
class ScenarioRequest:
    """One scheduled request: arrival time, image, and the tenant stream it
    targets ("" = the scenario's only stream)."""

    t: float
    img: object
    stream: str = ""


class Scenario:
    """Protocol every scenario implements (duck-typed; subclassing is just
    documentation):

    - ``name`` — regime label (benchmark row / BENCH key);
    - ``requests()`` — the full request list, ordered by arrival time; must
      be a pure function of the scenario's constructor arguments (seeded
      PRNGs only) so identical scenarios replay bit-identically;
    - ``events`` — ((t, fn), ...) timed actions; ``fn(engines)`` runs once
      when the simulated clock first reaches ``t`` (between batches, never
      mid-execution — the driver only fires events at poll boundaries).
    """

    name: str = "scenario"
    events: tuple = ()

    def requests(self) -> list:
        raise NotImplementedError

    def streams(self) -> tuple:
        """The distinct stream keys, in first-appearance order."""
        seen: dict = {}
        for r in self.requests():
            seen.setdefault(r.stream, None)
        return tuple(seen)


@dataclass(frozen=True)
class ListScenario(Scenario):
    """Explicit (arrival, image) lists — the degenerate scenario
    `replay_stream` wraps, and the escape hatch for hand-built tests."""

    imgs: tuple = ()
    arrivals: tuple = ()
    name: str = "list"
    stream: str = ""

    def __post_init__(self):
        if len(self.imgs) != len(self.arrivals):
            raise ValueError(
                f"ListScenario needs one arrival per image, got "
                f"{len(self.imgs)} images / {len(self.arrivals)} arrivals")

    def requests(self) -> list:
        return [ScenarioRequest(t=float(t), img=img, stream=self.stream)
                for t, img in sorted(zip(self.arrivals, self.imgs),
                                     key=lambda p: p[0])]


@dataclass(frozen=True)
class PoissonBurstScenario(Scenario):
    """Markov-modulated Poisson arrivals: exponential interarrivals at
    `base_rps`, switching to `burst_rps` for the first `burst_len_s` of every
    `burst_every_s` cycle. The bursty regime overfills buckets (a burst
    queues several full max_batch buckets at once), so it pins the
    no-stranding property: every request is formed within its deadline plus
    the backlog of earlier due buckets."""

    in_shape: tuple = (16, 12, 12)
    n_requests: int = 32
    base_rps: float = 50.0
    burst_rps: float = 800.0
    burst_every_s: float = 0.25
    burst_len_s: float = 0.05
    dead_frac: float = 0.5
    seed: int = 0
    name: str = "burst"

    def rate_at(self, t: float) -> float:
        return self.burst_rps if (t % self.burst_every_s) < self.burst_len_s \
            else self.base_rps

    def requests(self) -> list:
        rng = random.Random(self.seed)
        t, out = 0.0, []
        for i in range(self.n_requests):
            t += rng.expovariate(self.rate_at(t))
            out.append(ScenarioRequest(
                t=t, img=synth_image(self.in_shape, self.seed, i,
                                     self.dead_frac)))
        return out


@dataclass(frozen=True)
class DiurnalDriftScenario(Scenario):
    """Steady arrivals whose OCCUPANCY drifts: the dead-channel band moves
    from `dead_lo` to `dead_hi` over simulated time. ``drift="step"`` flips
    at `t_drift` (the sharp regime change the re-plan-within-K-batches test
    pins); ``drift="sine"`` widens and narrows the band smoothly over
    `period_s` (set it to simulated hours for the paper's diurnal story).
    The engine planned at the `dead_lo` regime must re-plan to the schedule
    `plan_network` would pick at the drifted occupancy."""

    in_shape: tuple = (16, 12, 12)
    n_requests: int = 32
    rate_rps: float = 200.0
    dead_lo: float = 0.0
    dead_hi: float = 0.5
    drift: str = "step"  # "step" | "sine"
    t_drift: float = 0.05  # step: time of the flip
    period_s: float = 0.2  # sine: one widen+narrow cycle
    seed: int = 0
    name: str = "diurnal"

    def dead_frac_at(self, t: float) -> float:
        if self.drift == "step":
            return self.dead_hi if t >= self.t_drift else self.dead_lo
        if self.drift == "sine":
            import math

            phase = 0.5 - 0.5 * math.cos(2.0 * math.pi * t / self.period_s)
            return self.dead_lo + (self.dead_hi - self.dead_lo) * phase
        raise ValueError(f"unknown drift mode {self.drift!r} "
                         "(choose 'step' or 'sine')")

    def requests(self) -> list:
        out = []
        for i in range(self.n_requests):
            t = i / self.rate_rps
            out.append(ScenarioRequest(
                t=t, img=synth_image(self.in_shape, self.seed, i,
                                     self.dead_frac_at(t))))
        return out


@dataclass(frozen=True)
class TenantSpec:
    """One tenant stream of a multi-tenant scenario."""

    in_shape: tuple
    n_requests: int = 16
    rate_rps: float = 100.0
    dead_frac: float = 0.5


@dataclass(frozen=True)
class MultiTenantScenario(Scenario):
    """Interleaved per-tenant streams, each a steady seeded stream of its own
    shape/occupancy, merged by arrival time. The tenants' engines share one
    `PlanCache` (`Engine(cache=...)`): the graph signature in `PlanKey` must
    keep the compile count bounded by the number of DISTINCT keys, and no
    tenant may ever execute another tenant's program."""

    tenants: tuple = ()  # ((name, TenantSpec), ...)
    seed: int = 0
    name: str = "multi_tenant"

    def requests(self) -> list:
        out = []
        for k, (stream, spec) in enumerate(self.tenants):
            for i in range(spec.n_requests):
                out.append(ScenarioRequest(
                    t=i / spec.rate_rps,
                    img=synth_image(spec.in_shape, self.seed + 7919 * (k + 1),
                                    i, spec.dead_frac),
                    stream=stream))
        # stable sort: simultaneous arrivals keep tenant declaration order
        return sorted(out, key=lambda r: r.t)

    def streams(self) -> tuple:
        return tuple(stream for stream, _ in self.tenants)


@dataclass(frozen=True)
class HotSwapScenario(Scenario):
    """A steady stream that swaps the served model mid-flight: at `t_swap`
    the driver calls `swap_fn(engines)` — canonically
    ``engines[""].hot_swap(pruned_params)`` to install a differently-pruned
    BSR variant under load. The swap is atomic between batches: requests
    completed before it carry the old model's logits, requests after carry
    the new model's, and no in-flight bucket mixes the two."""

    in_shape: tuple = (16, 12, 12)
    n_requests: int = 32
    rate_rps: float = 200.0
    t_swap: float = 0.05
    swap_fn: object = None  # callable(engines: dict) -> None
    dead_frac: float = 0.5
    seed: int = 0
    name: str = "hot_swap"
    events: tuple = field(init=False, default=())

    def __post_init__(self):
        if self.swap_fn is None:
            raise ValueError("HotSwapScenario needs swap_fn= (the timed "
                             "model-swap action, e.g. a hot_swap closure)")
        object.__setattr__(self, "events",
                           ((float(self.t_swap), self.swap_fn),))

    def requests(self) -> list:
        return [ScenarioRequest(
            t=i / self.rate_rps,
            img=synth_image(self.in_shape, self.seed, i, self.dead_frac))
            for i in range(self.n_requests)]


# ---------------------------------------------------------------------------
# the replay driver
# ---------------------------------------------------------------------------


def replay_scenario(engines, scenario) -> dict:
    """Drive one scenario's event loop to completion on a shared `SimClock`.

    `engines` is one `Engine` or a ``{stream: Engine}`` mapping covering every
    stream the scenario emits; all engines must share ONE SimClock instance
    (the simulated timeline is global — one tenant's execution time delays
    every tenant's queue, exactly like a shared host).

    The loop is the deterministic generalization of the old `replay_stream`:
    enqueue every arrival at or before the current sim time (a backlog behind
    an executing batch must coalesce into full buckets, not dribble out as
    singletons), fire every due scenario event (between batches — never
    mid-execution), poll every engine until nothing is due (each executed
    batch may advance the clock past further deadlines, arrivals, or
    events), then jump the clock to the next event: the earliest of the next
    arrival, the next scenario event, and every engine's batcher deadline.

    Returns ``{stream: [ServedResult, ...]}`` in completion order per stream.
    """
    from repro.serving.engine import Engine

    if isinstance(engines, Engine):
        engines = {"": engines}
    clocks = {id(e.clock): e.clock for e in engines.values()}
    if len(clocks) != 1 or not isinstance(next(iter(clocks.values())), SimClock):
        raise ValueError("replay_scenario needs every engine on ONE shared "
                         "SimClock")
    clock = next(iter(clocks.values()))
    reqs = sorted(scenario.requests(), key=lambda r: r.t)
    missing = {r.stream for r in reqs} - set(engines)
    if missing:
        raise ValueError(f"scenario emits streams {sorted(missing)} with no "
                         f"engine (have {sorted(engines)})")
    events = sorted(((float(t), fn) for t, fn in scenario.events),
                    key=lambda e: e[0])
    results: dict = {k: [] for k in engines}
    served = 0
    i = 0

    def submit_due():
        nonlocal i
        while i < len(reqs) and reqs[i].t <= clock():
            engines[reqs[i].stream].submit(reqs[i].img, now=reqs[i].t)
            i += 1

    def fire_due_events():
        while events and events[0][0] <= clock():
            _, fn = events.pop(0)
            fn(engines)

    while served < len(reqs):
        submit_due()
        fire_due_events()
        progressed = True
        while progressed:
            progressed = False
            for stream, eng in engines.items():
                out = eng.poll()
                if out:
                    results[stream].extend(out)
                    served += len(out)
                    progressed = True
                    submit_due()  # execution moved the clock: new backlog
                    fire_due_events()
        if served >= len(reqs):
            break
        cands = [eng.next_deadline() for eng in engines.values()]
        if i < len(reqs):
            cands.append(reqs[i].t)
        if events:
            cands.append(events[0][0])
        cands = [c for c in cands if c is not None]
        if not cands:  # nothing queued, nothing scheduled: requests were lost
            break
        clock.set(min(cands))
    fire_due_events()  # an event scheduled at/after the final completion
    return results
