"""Occupancy-adaptive serving engine over the static `PipelinePlan`.

`Engine` turns the planner's plan-once artifact into a request-serving loop:

- requests enter through the `MicroBatcher` (deadline-bounded power-of-two
  buckets); the ragged tail is padded with all-zero images, which the
  per-sample (ids, cnt) schedules skip at zero MAC cost (DESIGN.md §2.4);
- each (bucket, plan) pair executes through ONE ahead-of-time compiled
  program from the `PlanCache` — steady-state serving never compiles;
- every executed batch also measures the per-layer observed channel-block
  occupancy of its REAL samples (the traced `occupancy_stat` with an
  `n_valid` mask) and folds it into an EMA; when the EMA drifts out of the
  hysteresis band around the occupancies the current plan was calibrated at,
  the engine re-plans on the most recent real batch — optionally in a
  background thread — and swaps the new plan in atomically between batches;
- with more than one local device (or an explicit `mesh=`), execution is
  data-parallel: the bucket's batch dim shards over a 1-D "data" mesh under
  shard_map, per-sample (ids, cnt) schedules stay device-local, and the
  occupancy statistic is aggregated across shards so the EMA/re-plan
  hysteresis reacts to global traffic (DESIGN.md §6).

Exactness contract: a request's logits are bit-identical to `run_plan` on the
same image(s) whenever the co-batched samples share a live-channel union (the
shared-union compaction permutation is then batch-composition-invariant); the
all-zero pad samples never perturb the union. tests/test_serving.py pins this.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import as_graph
from repro.obs.trace import NULL_TRACER
from repro.parallel.api import data_mesh, sharding_for
from repro.pipeline.planner import PipelinePlan, plan_network, run_plan, run_plan_sharded
from repro.serving.batcher import MicroBatch, MicroBatcher, SimClock
from repro.serving.metrics import MetricsTracker
from repro.serving.plan_cache import PlanCache, plan_key


@dataclass(frozen=True)
class ServedResult:
    """One completed request: logits plus the latency-accounting timestamps.
    `t_formed` is when the batcher formed the request's bucket — the deadline
    contract bounds (t_formed - t_arrival), and the burst scenario tests pin
    it; pre-existing constructors that omit it get 0.0."""

    id: int
    logits: np.ndarray  # (n_classes,)
    t_arrival: float
    t_done: float
    t_formed: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival


def auto_mesh(max_batch: int = 8, min_bucket: int = 2):
    """The engine's mesh="auto" policy: a 1-D "data" mesh over the LARGEST
    local-device prefix whose size divides `max_batch` AND leaves every
    shard at least `min_bucket` samples per full bucket — the two
    constraints the batcher's device-aligned buckets enforce (an M=1 shard
    slice would void the bit-exactness contract, see MicroBatcher). Never
    raises for lack of devices: an awkward host degrades to fewer devices
    (3 devices, max_batch=8 -> 2; 8 devices, max_batch=8 -> 4) instead of
    refusing to serve, and 1 device is always acceptable."""
    n_avail = len(jax.devices())
    fits = [d for d in range(1, n_avail + 1)
            if max_batch % d == 0 and max_batch // d >= min_bucket]
    return data_mesh(max(fits) if fits else 1)


def _make_runner(plan: PipelinePlan, mesh=None):
    """The whole-batch executor the cache compiles: logits + per-layer
    observed occupancy over the first n_valid (real) samples. The plan
    carries its own LayerGraph, so the runner is model-agnostic; with a
    mesh it runs under shard_map (batch sharded over "data", occupancy
    aggregated across shards — DESIGN.md §6)."""

    def run(params, imgs, n_valid):
        if mesh is None:
            return run_plan(plan, params, imgs, collect_occupancy=True,
                            n_valid=n_valid)
        return run_plan_sharded(plan, params, imgs, mesh,
                                collect_occupancy=True, n_valid=n_valid)

    return run


class Engine:
    """Sparsity-aware serving engine for any planned LayerGraph conv stack
    (VGG-19, LeNet, AlexNet, ... — pass `graph=` or a legacy `CNNConfig`).

    Drive it with `submit()` + `poll()` (event loop), `drain()` (end of
    stream), or the synchronous convenience `serve(imgs)`.

    `mesh` selects the data-parallel layout (DESIGN.md §6): "auto" (default)
    spans the largest local-device prefix whose size divides max_batch (all
    devices on a well-shaped host, fewer on an awkward one — never a
    construction failure), an explicit 1-D "data" mesh pins the device
    count (and raises when max_batch is not a multiple of it), and None
    forces single-device execution. On a 1-device host every
    choice degenerates to the exact pre-mesh behavior. With N > 1 devices the
    batcher's buckets are N-aligned (each shard takes an equal slice, local
    slices keep the min_bucket floor so logits stay bit-exact), the plan
    cache keys gain the mesh shape, and the occupancy EMA consumes the
    cross-shard aggregated statistic — the drift detector sees GLOBAL
    traffic, not one shard's slice of it.
    """

    def __init__(self, params, ccfg=None, *, graph=None,
                 plan: PipelinePlan | None = None, calib=None,
                 occ_threshold: float = 0.75, block_c: int = 0,
                 use_pallas: bool = True, max_batch: int = 8,
                 min_bucket: int = 2, deadline_s: float = 0.010,
                 clock=time.monotonic, mesh="auto",
                 ema_alpha: float = 0.25, replan_band: float = 0.15,
                 replan_cooldown: int = 2, replan_async: bool = False,
                 cache_entries: int = 32, cache: PlanCache | None = None,
                 metrics: MetricsTracker | None = None,
                 sim_service_s=None, tracer=None, calibration=None,
                 tiles=None, int8: bool = False, int8_budget: float = 0.98):
        # tracer: a repro.obs.trace.Tracer recording plan/compile/execute/
        # re-plan spans (DESIGN.md §9); the NULL_TRACER default is a shared
        # no-op object, so the untraced hot path allocates nothing.
        # calibration: a repro.obs.calibrate.CalibrationDB — every plan this
        # engine builds (initial, drift re-plans, hot-swap re-plans) prices
        # its impl choices at the measured effective constants; None (or an
        # empty DB) keeps the datasheet defaults bit-identically.
        # tiles: a CalibrationDB carrying tile-search winners — every plan
        # this engine builds stamps the stored measured-best geometry per
        # layer (plan_network(tiles=...)); often the same DB as calibration.
        # int8/int8_budget: let every plan upgrade layers to the quantized
        # impls under the probe-agreement budget (plan_network(int8=...)).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.calibration = calibration
        self.tiles = tiles
        self.int8 = bool(int8)
        self.int8_budget = float(int8_budget)
        graph = plan.graph if plan is not None and plan.graph is not None \
            else as_graph(graph if graph is not None else ccfg)
        if plan is None:
            if calib is None:
                raise ValueError("Engine needs either a prebuilt plan= or calib= images to plan on")
            with self.tracer.span("plan", graph=graph.name,
                                  occ_threshold=occ_threshold):
                plan = plan_network(params, calib, graph,
                                    occ_threshold=occ_threshold,
                                    block_c=block_c, use_pallas=use_pallas,
                                    calibration=calibration, tiles=tiles,
                                    int8=self.int8,
                                    int8_budget=self.int8_budget)
        # mesh="auto": 1-D data mesh over the largest local-device prefix
        # dividing max_batch (all devices when they divide; fewer on awkward
        # hosts rather than refusing to construct); a 1-device mesh (every
        # single-device host) normalizes to None, so the unsharded path —
        # and its cache keys — are bit-identical to pre-mesh engines. An
        # EXPLICIT mesh is never shrunk: a mismatch with max_batch raises.
        if mesh == "auto":
            mesh = auto_mesh(max_batch, min_bucket)
        if mesh is not None and mesh.size == 1:
            mesh = None
        if mesh is not None and "data" not in mesh.axis_names:
            raise ValueError(f"Engine needs a mesh with a 'data' axis, got "
                             f"{tuple(mesh.axis_names)}")
        self.mesh = mesh
        self.n_devices = int(mesh.shape["data"]) if mesh is not None else 1
        self.params = params
        self.graph = graph
        self.plan = plan
        self.use_pallas = use_pallas
        self.clock = clock
        self.batcher = MicroBatcher(max_batch=max_batch, deadline_s=deadline_s,
                                    clock=clock, min_bucket=min_bucket,
                                    align=self.n_devices)
        # cache= shares one PlanCache across engines (multi-tenant serving);
        # the graph/mesh/weight signatures in PlanKey keep tenants from ever
        # colliding on a compiled program
        self.cache = cache if cache is not None else PlanCache(max_entries=cache_entries)
        self.metrics = metrics if metrics is not None else MetricsTracker()
        # sim_service_s: deterministic service-time model for SimClock replays
        # (None = charge measured wall time; a float or callable(bucket,
        # n_real) -> seconds makes two identical replays — logits AND metric
        # snapshots — bit-identical, the regression-diff contract)
        self.sim_service_s = sim_service_s
        self.ema_alpha = ema_alpha
        self.replan_band = replan_band
        self.replan_cooldown = replan_cooldown
        self.replan_async = replan_async
        self._lock = threading.Lock()
        self._pending_plan: PipelinePlan | None = None
        self._replanning = False
        self._replan_thread: threading.Thread | None = None
        self._plan_gen = 0  # bumped by hot_swap: stale background re-plans
        self._cooldown = 0  # (planned against the swapped-out params) drop
        self._calib_recent = None  # last real (unpadded) executed batch
        self._occ_ema = np.array([lp.occupancy for lp in plan.layers])
        self.n_replans = 0
        self.replan_errors = 0
        self.n_hot_swaps = 0
        self.verify_rejects = 0  # plans the static verifier refused to adopt
        self.n_batches = 0
        self.n_requests = 0
        self.n_pad_samples = 0
        self._fill_sum = 0.0
        self._profile_summary = None  # last Engine.profile() digest

    # ------------------------------------------------------------------
    # request loop
    # ------------------------------------------------------------------

    def submit(self, img, now: float | None = None) -> int:
        """Queue one (C,H,W) image; returns the request id. `now` overrides
        the arrival stamp — replay_stream passes the TRUE scheduled arrival,
        which can precede the clock when execution of a previous batch
        advanced the simulated timeline past it (the queueing delay behind an
        executing batch must count against latency and the deadline)."""
        self.n_requests += 1
        rid = self.batcher.submit(jnp.asarray(img, jnp.float32), now=now)
        self.metrics.on_submit(self.clock() if now is None else now)
        return rid

    def next_deadline(self) -> float | None:
        """Absolute time the driver must poll by (batcher deadline contract)."""
        return self.batcher.next_deadline()

    def poll(self) -> list:
        """Adopt any finished re-plan, then run EVERY due batch — a burst of
        >= 2·max_batch requests leaves several full buckets queued, and
        serving only the first would strand the rest until the next deadline
        poll, breaking the batcher's wait bound under load. Each executed
        batch may advance a SimClock past further deadlines, so the drain
        loop re-checks readiness until nothing is due. Returns the completed
        `ServedResult`s ([] when nothing was due)."""
        out = []
        while True:
            self._adopt_pending_plan()
            batch = self.batcher.ready()
            if batch is None:
                return out
            out.extend(self._run_batch(batch))

    def drain(self) -> list:
        """Flush and run everything still queued (end of stream)."""
        out = []
        while self.batcher.pending():
            self._adopt_pending_plan()
            batch = self.batcher.flush()
            out.extend(self._run_batch(batch))
        self._adopt_pending_plan()  # a re-plan the last batch triggered
        return out

    def serve(self, imgs) -> np.ndarray:
        """Synchronous convenience: submit every (C,H,W) image in `imgs`,
        drain, and return (N, n_classes) logits in submission order. An
        empty stream returns an empty (0, n_classes) array (np.stack on
        zero results would raise)."""
        ids = [self.submit(img) for img in imgs]
        if not ids:
            return np.zeros((0, self.graph.n_classes()), np.float32)
        results = {r.id: r for r in self.drain()}
        return np.stack([results[i].logits for i in ids])

    def warmup(self, buckets=None) -> int:
        """Pre-compile the current plan at the given bucket sizes (default:
        all of them) so the serving path never compiles inline. Returns the
        number of fresh compilations triggered."""
        before = self.cache.compiles
        for b in buckets or self.batcher.exec_buckets():
            self._executable(int(b))
        return self.cache.compiles - before

    def stats(self) -> dict:
        """Serving state + telemetry. Latency percentiles come from the
        tracker's reservoir — fed per COMPLETED request in `_run_batch`, so
        drain()/flush-tail requests are aggregated exactly like
        poll()-completed ones (they used to escape latency accounting
        entirely: latency was only ever computed by external drivers over
        whatever subset of results they kept). The full time-series
        telemetry (occupancy-EMA timeline, re-plan events, per-bucket
        counts) rides under ``"telemetry"`` — `MetricsTracker.snapshot()`
        verbatim, ready for `write_bench_json`."""
        c = self.plan.counts()
        return {
            **self.cache.stats(),
            "devices": self.n_devices,
            "requests": self.n_requests,
            "batches": self.n_batches,
            "pad_samples": self.n_pad_samples,
            "mean_fill": self._fill_sum / max(self.n_batches, 1),
            "replans": self.n_replans,
            "replan_errors": self.replan_errors,
            "hot_swaps": self.n_hot_swaps,
            "verify_rejects": self.verify_rejects,
            "plan_sparse": c["sparse"],
            "plan_dense": c["dense"],
            "plan_bsr": c["bsr"],
            "plan_int8": c["int8"],
            "plan_tiled": sum(1 for lp in self.plan.layers
                              if getattr(lp, "tile", None)),
            "occ_ema": [float(v) for v in np.round(self._occ_ema, 4)],
            **{k: v for k, v in self.metrics.latency.percentiles_ms().items()
               if k != "count"},
            "lat_count": self.metrics.latency.count,
            "telemetry": {**self.metrics.snapshot(),
                          "profile": self._profile_summary},
        }

    def profile(self, imgs=None, *, impls=None, iters: int = 3,
                warmup: int = 1):
        """Per-layer measured-vs-modeled timing of the CURRENT plan
        (`repro.obs.profile.profile_plan` at the engine's real shapes): each
        layer of the plan is timed under every requested impl family and
        paired with the registry's `unit_model_us` prediction. The report's
        digest (per-impl medians + ranking agreement) lands in
        ``stats()["telemetry"]["profile"]`` so serving benchmarks carry it in
        the same artifact as the request-stream metrics; the full report is
        returned (feed it to `CalibrationDB.from_report` to close the loop).

        `imgs` defaults to the most recent real executed batch — same source
        the drift re-planner uses — so an engine that has served traffic can
        be profiled without new inputs."""
        from repro.obs.profile import PROFILE_IMPLS, profile_plan

        calib = self._calib_recent if imgs is None else jnp.asarray(imgs)
        if calib is None:
            raise ValueError("profile() needs imgs= before the engine has "
                             "executed its first batch")
        report = profile_plan(self.plan, self.params, calib,
                              impls=PROFILE_IMPLS if impls is None else impls,
                              iters=iters, warmup=warmup, tracer=self.tracer)
        self._profile_summary = report.summary()
        return report

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def _executable(self, bucket: int):
        key = plan_key(bucket, self.plan, self.mesh)
        plan, params, mesh = self.plan, self.params, self.mesh

        def build():
            with self.tracer.span("compile", bucket=bucket,
                                  devices=self.n_devices):
                c, h, w = plan.layers[0].in_shape
                imgs_s = jax.ShapeDtypeStruct((bucket, c, h, w), jnp.float32)
                nv_s = jax.ShapeDtypeStruct((), jnp.int32)
                if mesh is None:
                    fn = jax.jit(_make_runner(plan))
                else:
                    # pin the AOT input layout: params/n_valid replicated,
                    # batch split over "data" (the batcher's align made it
                    # divisible)
                    fn = jax.jit(_make_runner(plan, mesh), in_shardings=(
                        sharding_for((), (), mesh),
                        self._batch_sharding((bucket, c, h, w)),
                        sharding_for((), (), mesh)))
                return fn.lower(params, imgs_s, nv_s).compile()

        return self.cache.get_or_compile(key, plan, build)

    def _batch_sharding(self, shape):
        """NamedSharding splitting dim 0 over the mesh's data axis (the
        logical-axis rules of parallel/api resolve "batch" -> ("data",))."""
        return sharding_for(shape, ("batch",) + (None,) * (len(shape) - 1),
                            self.mesh)

    def _run_batch(self, batch: MicroBatch) -> list:
        # spans on the engine's own clock: under a SimClock the service time
        # charged to the timeline is exactly the span duration, so traced
        # replays are deterministic (tests/test_obs.py pins the bytes)
        with self.tracer.span("execute_batch", bucket=batch.bucket,
                              n_real=batch.n_real):
            return self._run_batch_traced(batch)

    def _run_batch_traced(self, batch: MicroBatch) -> list:
        imgs = jnp.stack([r.img for r in batch.requests])
        if batch.bucket > batch.n_real:  # ragged tail: all-zero pad samples
            pad = jnp.zeros((batch.bucket - batch.n_real,) + imgs.shape[1:], imgs.dtype)
            imgs = jnp.concatenate([imgs, pad])
        if self.mesh is not None:
            # commit the batch to the compiled layout (a no-op re-put when
            # already placed; uncommitted host arrays would also auto-shard,
            # but an explicitly committed input must never silently reshard)
            imgs = jax.device_put(imgs, self._batch_sharding(imgs.shape))
        exe = self._executable(batch.bucket)
        t0 = time.perf_counter()
        logits, occs = exe(self.params, imgs, jnp.asarray(batch.n_real, jnp.int32))
        jax.block_until_ready(logits)
        wall = time.perf_counter() - t0
        # the time CHARGED to the timeline: measured wall by default, or the
        # deterministic sim_service_s model (fixed or per-bucket) so seeded
        # SimClock replays are bit-identical end to end
        if self.sim_service_s is None:
            dt = wall
        elif callable(self.sim_service_s):
            dt = float(self.sim_service_s(batch.bucket, batch.n_real))
        else:
            dt = float(self.sim_service_s)
        if isinstance(self.clock, SimClock):
            self.clock.advance(dt)  # charge service time to the sim timeline
        t_done = self.clock()
        logits = np.asarray(logits)
        self.n_batches += 1
        self.n_pad_samples += batch.bucket - batch.n_real
        self._fill_sum += batch.fill
        self._calib_recent = imgs[: batch.n_real]
        results = [ServedResult(id=r.id, logits=logits[i], t_arrival=r.t_arrival,
                                t_done=t_done, t_formed=batch.t_formed)
                   for i, r in enumerate(batch.requests)]
        self.metrics.on_batch(t_done, batch.bucket, batch.n_real, dt)
        for r in results:
            self.metrics.on_result(r.latency_s)
        self._observe(np.asarray(occs))  # after results exist: a re-plan
        return results                   # failure must not drop served work

    # ------------------------------------------------------------------
    # occupancy drift -> background re-plan
    # ------------------------------------------------------------------

    def _observe(self, occs: np.ndarray) -> None:
        a = self.ema_alpha
        self._occ_ema = (1.0 - a) * self._occ_ema + a * occs
        self.metrics.on_occupancy(self.clock(), self._occ_ema)
        if self._cooldown > 0:
            self._cooldown -= 1
            return
        if self._replanning:
            return
        planned = np.array([lp.occupancy for lp in self.plan.layers])
        delta = float(np.abs(self._occ_ema - planned).max())
        if delta > self.replan_band:
            self.metrics.on_replan_trigger(self.clock(), delta)
            self._launch_replan()

    def _launch_replan(self) -> None:
        calib = self._calib_recent
        if calib is None:
            return
        self._replanning = True
        plan = self.plan
        gen = self._plan_gen

        def work():
            try:
                with self.tracer.span("replan", trigger="occupancy_drift"):
                    new = plan_network(self.params, calib, self.graph,
                                       occ_threshold=plan.occ_threshold,
                                       block_c=plan.block_c,
                                       use_pallas=self.use_pallas,
                                       calibration=self.calibration,
                                       tiles=self.tiles, int8=self.int8,
                                       int8_budget=self.int8_budget)
            except Exception:
                # a failed re-plan must neither wedge the drift detector nor
                # take down the serving loop — keep the current plan, count
                # the failure (stats()["replan_errors"]), and retry on the
                # next drift trigger
                with self._lock:
                    self._replanning = False
                    self.replan_errors += 1
                self.metrics.on_replan_error(self.clock())
                return
            with self._lock:
                if gen == self._plan_gen:
                    self._pending_plan = new
                else:
                    # a hot_swap landed while this re-plan was in flight: the
                    # result was planned against the swapped-out params, so
                    # adopting it would serve the OLD model's schedule on the
                    # new params — drop it and unblock the drift detector
                    self._replanning = False

        if self.replan_async:
            self._replan_thread = threading.Thread(target=work, daemon=True)
            self._replan_thread.start()
        else:
            work()

    def _adopt_pending_plan(self) -> None:
        """Atomic swap point: a finished re-plan replaces the live plan only
        BETWEEN batches (never mid-execution). Resetting the EMA reference to
        the new plan's calibrated occupancies closes the hysteresis loop —
        drift inside the band never re-plans, and a swap re-centers the band."""
        with self._lock:
            if self._pending_plan is None:
                return
            new, self._pending_plan = self._pending_plan, None
        self._replanning = False
        if not self._verify_candidate(new, self.params):
            return  # erroring re-plan result: keep serving the current plan
        changed = plan_key(0, new) != plan_key(0, self.plan)
        if changed:
            self.n_replans += 1  # schedule changed; same-key swaps only re-center
        self.plan = new
        self._occ_ema = np.array([lp.occupancy for lp in new.layers])
        self._cooldown = self.replan_cooldown
        self.metrics.on_replan_swap(self.clock(), changed)

    def _verify_candidate(self, plan, params) -> bool:
        """Static gate on every plan-adoption path (DESIGN.md §12): any
        error-severity diagnostic rejects the candidate BEFORE the engine
        mutates anything — the reject is counted (stats()
        ["verify_rejects"]), lands in the telemetry event stream, and
        serving continues on the current plan/params."""
        from repro.analysis import errors, verify_plan

        bad = errors(verify_plan(plan, params, graph=self.graph))
        if not bad:
            return True
        self.verify_rejects += 1
        self.metrics.on_verify_reject(self.clock(),
                                      tuple(d.code for d in bad))
        return False

    def hot_swap(self, params, *, plan: PipelinePlan | None = None,
                 calib=None) -> bool:
        """Swap the SERVED MODEL under load — canonically to a
        differently-pruned BSR variant of the same graph (DESIGN.md §7: the
        weight signature in `PlanKey` keeps both variants' programs resident
        side by side, so swapping back and forth never recompiles a warm
        bucket). The swap is atomic between batches exactly like a re-plan
        adoption: callers drive it from the scenario event loop (or any
        other point outside `poll()`/`serve()`), never mid-execution.

        `plan` pins the new schedule; otherwise the new params are planned on
        `calib` (default: the most recent real batch) at the current plan's
        occ_threshold/block_c. An in-flight background re-plan belongs to the
        OLD params — the generation bump makes its eventual result drop on
        arrival instead of clobbering the swapped-in model.

        Every candidate is statically verified against the NEW params before
        anything mutates: an erroring (plan, params) pair is rejected
        atomically — returns False, counts in stats()["verify_rejects"],
        and the engine keeps serving the current model (a freshly planned
        candidate raises from `plan_network` itself instead). Returns True
        on a completed swap."""
        if plan is None:
            calib = self._calib_recent if calib is None else calib
            if calib is None:
                raise ValueError("hot_swap needs plan= or calib= before the "
                                 "engine has executed its first batch")
            with self.tracer.span("plan", graph=self.graph.name,
                                  trigger="hot_swap"):
                plan = plan_network(params, calib, self.graph,
                                    occ_threshold=self.plan.occ_threshold,
                                    block_c=self.plan.block_c,
                                    use_pallas=self.use_pallas,
                                    calibration=self.calibration,
                                    tiles=self.tiles, int8=self.int8,
                                    int8_budget=self.int8_budget)
        elif not self._verify_candidate(plan, params):
            return False
        with self._lock:
            self._plan_gen += 1
            self._pending_plan = None
        self.params = params
        self.plan = plan
        if plan.graph is not None:
            self.graph = plan.graph
        self._occ_ema = np.array([lp.occupancy for lp in plan.layers])
        self._cooldown = self.replan_cooldown
        self.n_hot_swaps += 1
        self.metrics.on_hot_swap(self.clock())
        return True

    def join_replan(self, timeout: float | None = 10.0) -> None:
        """Test/shutdown helper: wait for an in-flight background re-plan."""
        t = self._replan_thread
        if t is not None:
            t.join(timeout)


def replay_stream(engine: Engine, imgs, rate_rps: float,
                  arrivals=None) -> list:
    """Drive the engine's event loop over a deterministic open-loop request
    stream on a `SimClock`: images arrive at `rate_rps` (or at the explicit
    `arrivals` timestamps), the clock jumps to the next event (arrival or
    batcher deadline), and the engine charges service time into the
    simulated timeline (measured wall, or its `sim_service_s` model).
    Returns all `ServedResult`s.

    Thin wrapper over `repro.serving.scenarios.replay_scenario` — the
    steady-rate stream is just the degenerate single-stream `ListScenario`.
    The engine's clock must be a SimClock.
    """
    from repro.serving.scenarios import ListScenario, replay_scenario

    clock = engine.clock
    if not isinstance(clock, SimClock):
        raise ValueError("replay_stream needs an Engine built on a SimClock")
    if arrivals is None:
        t0 = clock()
        arrivals = [t0 + i / rate_rps for i in range(len(imgs))]
    scenario = ListScenario(imgs=tuple(imgs), arrivals=tuple(arrivals))
    return replay_scenario(engine, scenario)[""]
