"""Sparsity-aware serving engine over the pipeline planner (DESIGN.md §4).

Turns the static `PipelinePlan` — over any `LayerGraph` network (VGG-19,
LeNet, AlexNet, ...) — into a request-serving loop: the `MicroBatcher`
collects single-image requests into deadline-bounded power-of-two buckets,
the `PlanCache` compiles one ahead-of-time executable per (bucket, block_c,
occupancy-signature, graph-signature) key, the `Engine` executes batches
while tracking per-layer observed occupancy (EMA) and re-plans — optionally
in the background — when it drifts out of the hysteresis band, and `autotune`
searches (occ_threshold, block_c) offline, selecting by measured wall time
with a cost-model fallback for noisy clocks. With more than one local device
the engine serves data-parallel over a 1-D "data" mesh (shard_map, device-
aligned buckets, cross-shard occupancy aggregation — DESIGN.md §6).

Telemetry and traffic realism (DESIGN.md §8): every engine feeds a
`MetricsTracker` (latency reservoir, per-bucket counts, occupancy-EMA
timeline, re-plan events) whose deterministic `snapshot()` rides in
`Engine.stats()["telemetry"]`, and `scenarios` supplies regime-diverse
seeded traffic — Poisson bursts, diurnal occupancy drift, multi-tenant
streams over one shared `PlanCache`, hot-swap to a pruned variant under
load — replayed by `replay_scenario` (of which `replay_stream` is the
steady-rate special case).

Entry points: `launch/serve_cnn.py` (CLI, `--devices`, `--scenario`),
`benchmarks/serve_vgg19.py` (request-rate sweep),
`benchmarks/serve_sharded.py` (device-count x rate sweep),
`benchmarks/scenarios.py` (scenario x model sweep),
`examples/vgg19_server.py` (walkthrough).
"""
from repro.serving.autotune import (
    AutotuneResult,
    Candidate,
    autotune,
    hlo_model_us,
    plan_model_us,
)
from repro.serving.batcher import (
    MicroBatch,
    MicroBatcher,
    Request,
    SimClock,
    bucket_sizes,
)
from repro.serving.engine import Engine, ServedResult, auto_mesh, replay_stream
from repro.serving.metrics import LatencyReservoir, MetricsTracker
from repro.serving.plan_cache import PlanCache, PlanKey, plan_key
from repro.serving.scenarios import (
    DiurnalDriftScenario,
    HotSwapScenario,
    ListScenario,
    MultiTenantScenario,
    PoissonBurstScenario,
    Scenario,
    ScenarioRequest,
    TenantSpec,
    replay_scenario,
    synth_image,
)

__all__ = [
    "AutotuneResult",
    "Candidate",
    "DiurnalDriftScenario",
    "Engine",
    "HotSwapScenario",
    "LatencyReservoir",
    "ListScenario",
    "MetricsTracker",
    "MicroBatch",
    "MicroBatcher",
    "MultiTenantScenario",
    "PlanCache",
    "PlanKey",
    "PoissonBurstScenario",
    "Request",
    "Scenario",
    "ScenarioRequest",
    "ServedResult",
    "SimClock",
    "TenantSpec",
    "auto_mesh",
    "autotune",
    "bucket_sizes",
    "hlo_model_us",
    "plan_key",
    "plan_model_us",
    "replay_scenario",
    "replay_stream",
    "synth_image",
]
