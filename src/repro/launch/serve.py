"""Serving launcher: batched prefill + greedy decode loop with KV caches."""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp

from repro.configs import DEFAULT_RUN, ShapeConfig, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import model as M
from repro.parallel import sharding as S
from repro.parallel.api import axis_rules

log = logging.getLogger("repro.serve")


def serve(arch: str, *, reduced: bool = True, batch: int = 4, prompt_len: int = 32,
          gen_len: int = 32, model_axis: int = 1, seed: int = 0):
    cfg = get_config(arch, reduced=reduced)
    run = DEFAULT_RUN
    mesh = make_host_mesh(model_axis)
    max_len = prompt_len + gen_len
    with axis_rules(mesh):
        params, _ = M.init_params(cfg, jax.random.PRNGKey(seed), jnp.float32)
        caches, _ = M.init_cache(cfg, batch, max_len, jnp.float32)
        prefill = jax.jit(make_prefill_step(cfg, run))
        step = jax.jit(make_serve_step(cfg, run))

        toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (batch, prompt_len), 0,
                                  cfg.vocab_size, jnp.int32)
        batch_in = {"tokens": toks}
        if cfg.family == "vlm":
            batch_in["img_embeds"] = jnp.zeros((batch, cfg.n_image_tokens, cfg.d_model))
        enc_out = None
        if cfg.is_encoder_decoder:
            batch_in["frames"] = jnp.zeros((batch, prompt_len, cfg.d_model))
            enc_out = jnp.zeros((batch, prompt_len, cfg.d_model))

        t0 = time.time()
        logits, caches = prefill(params, caches, batch_in)
        nxt = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
        out_tokens = [nxt]
        for i in range(gen_len - 1):
            dec = {"tokens": nxt[:, None]}
            if cfg.family == "vlm":
                dec["img_embeds"] = batch_in["img_embeds"]
            if cfg.is_encoder_decoder:
                dec["enc_out"] = enc_out
            nxt, caches = step(params, caches, dec, jnp.int32(prompt_len + i))
            out_tokens.append(nxt)
        jax.block_until_ready(nxt)
        dt = time.time() - t0
    gen = jnp.stack(out_tokens, 1)
    tok_s = batch * gen_len / dt
    log.info("served %d seqs x %d tokens in %.2fs (%.1f tok/s)", batch, gen_len, dt, tok_s)
    return gen


def main():
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()
    serve(args.arch, reduced=not args.full, batch=args.batch,
          prompt_len=args.prompt_len, gen_len=args.gen_len, model_axis=args.model_axis)


if __name__ == "__main__":
    main()
