import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax import: jax locks the device count on first init.
# The dry-run (and only the dry-run) builds the production meshes on 512
# host-platform placeholder devices; smoke tests and benches see 1 device.

import argparse
import json
import math
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, RunConfig, get_config, list_archs, shape_applicable
from repro.launch import hlo_analysis, hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import TrainState, make_prefill_step, make_serve_step, make_train_step
from repro.models import model as M
from repro.parallel import sharding as S
from repro.parallel.api import axis_rules, logical_spec

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# v5e-class hardware constants (roofline terms derive from these; the
# chip-level pair has ONE definition in repro.obs.constants, re-exported by
# the registry). Records also carry the RAW hlo flops/bytes so
# benchmarks/roofline.py can re-price old artifacts under changed or
# calibrated constants without re-running the dry run.
from repro.graph.registry import HBM_BW, PEAK_FLOPS  # noqa: E402

LINK_BW = 50e9  # B/s / link ICI


def run_overrides(cfg, shape) -> RunConfig:
    big = M.count_params_analytic(cfg) > 5e10
    return RunConfig(
        moment_dtype="bfloat16" if big else "float32",
        grad_accum=8 if shape.kind == "train" else 1,
        remat="full" if shape.kind == "train" else "none",
        # §Perf decode lever: int8 KV cache (quantization error property-tested)
        kv_cache_dtype="int8" if shape.kind == "decode" else "bfloat16",
    )


def _rep(mesh, tree):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def build_cell(cfg, shape, run, mesh):
    """Returns (fn, abstract_args, in_shardings, out_shardings)."""
    dt = jnp.bfloat16
    pshard, pshapes = S.params_sharding(cfg, mesh, dt)
    specs = M.input_specs(cfg, shape, dt)
    bshard = S.batch_sharding(specs, mesh)

    if shape.kind == "train":
        oshard, oshapes = S.opt_sharding(cfg, mesh, run, pshapes)
        state_shapes = TrainState(params=pshapes, opt=oshapes)
        state_shard = TrainState(params=pshard, opt=oshard)
        fn = make_train_step(cfg, run, grad_shardings=pshard)
        metrics_abs = {k: jax.ShapeDtypeStruct((), jnp.float32) for k in ("loss", "grad_norm", "lr")}
        return (fn, (state_shapes, specs), (state_shard, bshard),
                (state_shard, _rep(mesh, metrics_abs)))

    cache_dt = jnp.int8 if run.kv_cache_dtype == "int8" else dt
    cshard, cshapes = S.cache_sharding(cfg, mesh, shape.global_batch, shape.seq_len, cache_dt)
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, run)
        logit_shard = NamedSharding(mesh, logical_spec(
            (shape.global_batch, shape.seq_len, cfg.vocab_size), ("batch", None, "vocab"), mesh))
        return (fn, (pshapes, cshapes, specs), (pshard, cshard, bshard),
                (logit_shard, cshard))
    # decode
    fn = make_serve_step(cfg, run)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tok_shard = NamedSharding(mesh, logical_spec((shape.global_batch,), ("batch",), mesh))
    return (fn, (pshapes, cshapes, specs, pos),
            (pshard, cshard, bshard, NamedSharding(mesh, P())),
            (tok_shard, cshard))


def analytic_flash_bytes(cfg, shape, run, qc: int = 256, kc: int = 512) -> float:
    """Global HBM bytes of all attention, if computed by the Pallas flash
    kernel (kernels/flash_attention): per call, Q and O stream once, K/V
    re-stream once per q-block (the kernel's BlockSpec schedule); backward
    re-streams per its two passes; remat='full' runs forward twice.

    This is exactly the operand/result traffic compiled.as_text() would show
    for the pallas custom-call on a real TPU lowering — substituted here
    because the CPU dry-run lowers the (numerically identical) jnp path."""
    import math

    from repro.models.model import AUDIO_DEC_LAYOUT, AUDIO_ENC_LAYOUT
    from repro.models.transformer import group_layout, n_groups

    b = shape.global_batch
    s = shape.seq_len
    kind = shape.kind
    dt = 2  # bf16
    h = cfg.n_heads

    # int8 KV cache: K/V stream at 1 byte (+ scales) in the decode kernel
    kv_dt = 1 if (kind == "decode" and run.kv_cache_dtype == "int8") else dt

    def call_bytes(sq, sk, kv, g, dk, dv, train, kv_bytes=dt):
        nq = max(1, math.ceil(sq / qc))
        nk = max(1, math.ceil(sk / kc))
        qb = b * sq * kv * g * dk * dt
        ob = b * sq * kv * g * dv * dt
        kb = b * sk * kv * dk * kv_bytes + (b * sk * kv * 4 if kv_bytes == 1 else 0)
        vb = b * sk * kv * dv * kv_bytes + (b * sk * kv * 4 if kv_bytes == 1 else 0)
        fwd = qb + ob + nq * (kb + vb)
        if not train:
            return fwd
        bwd = (nq * (kb + vb) + 2 * qb + ob  # dq pass
               + nk * (qb + ob) + kb + vb)  # dk/dv pass
        n_fwd = 2 if run.remat == "full" else 1
        return n_fwd * fwd + bwd

    def sub_dims(sub):
        if sub.kind == "mla":
            return (1, h, cfg.kv_lora_rank + cfg.rope_head_dim, cfg.kv_lora_rank)
        kv = cfg.n_kv_heads
        return (kv, h // kv, cfg.resolved_head_dim, cfg.resolved_head_dim)

    train = kind == "train"
    sq = 1 if kind == "decode" else s
    total = 0.0
    layouts = []
    if cfg.is_encoder_decoder:
        if kind != "decode":
            layouts.append((AUDIO_ENC_LAYOUT, cfg.n_encoder_layers, s))
        layouts.append((AUDIO_DEC_LAYOUT, cfg.n_layers, s))
    else:
        layouts.append((group_layout(cfg), n_groups(cfg), s))
    for lay, groups, sk_default in layouts:
        for sub in lay:
            if sub.kind not in ("attn", "cross", "mla"):
                continue
            sk = sk_default
            if sub.kind == "cross" and cfg.family == "vlm":
                sk = cfg.n_image_tokens
            kv, g, dk, dv = sub_dims(sub)
            kvb = kv_dt if sub.kind == "attn" else dt  # only GQA caches quantize
            total += groups * call_bytes(sq, sk, kv, g, dk, dv, train, kv_bytes=kvb)
    return total


def model_flops(cfg, shape) -> float:
    n = M.count_params_analytic(cfg)
    na = M.count_params_analytic(cfg, active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        return 6.0 * na * tokens
    return 2.0 * na * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool, force: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    run = run_overrides(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.devices.shape)
    try:
        with mesh, axis_rules(mesh, fsdp=run.fsdp):
            fn, args, in_sh, out_sh = build_cell(cfg, shape, run, mesh)
            t0 = time.time()
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            cost = compiled.cost_analysis() or {}
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
        # loop-aware analysis: XLA's cost_analysis counts while bodies once,
        # which undercounts scan-over-layers/grad-accum programs ~100x.
        la = hlo_cost.analyze(hlo, tags=("flash_attention",))
        coll = {
            "bytes_by_kind": la["collective_bytes_by_kind"],
            "counts": la["collective_counts"],
            "total_bytes": la["collective_bytes"],
            # TPU-native dtype normalization: the CPU backend promotes bf16
            # GEMM operands to f32 and hoists converts above collectives;
            # `native` counts bf16 bytes for those (what the TPU target moves)
            "total_bytes_native": la["collective_bytes_native"],
            "native_by_kind": la["collective_native_by_kind"],
        }
        flops = float(la["flops"])
        bytes_hbm = float(la["bytes"])
        mf = model_flops(cfg, shape)
        mem_fields = {}
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                try:
                    mem_fields[f] = int(getattr(mem, f))
                except Exception:
                    pass
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            n_chips=n_chips,
            grad_accum=run.grad_accum,
            moment_dtype=run.moment_dtype,
            hlo_flops_per_device=flops,
            hlo_bytes_per_device=bytes_hbm,
            xla_cost_analysis_raw={"flops": float(cost.get("flops", 0.0)),
                                   "bytes": float(cost.get("bytes accessed", 0.0))},
            collectives=coll,
            memory_analysis=mem_fields,
            model_flops_global=mf,
            # roofline terms (seconds), per the spec's formulas; the collective
            # term uses dtype-normalized bytes (see coll.total_bytes_native)
            compute_term_s=flops / PEAK_FLOPS,
            memory_term_s=bytes_hbm / HBM_BW,
            collective_term_s=coll["total_bytes_native"] / (3 * LINK_BW),
            collective_term_raw_s=coll["total_bytes"] / (3 * LINK_BW),
        )
        terms = {
            "compute": rec["compute_term_s"],
            "memory": rec["memory_term_s"],
            "collective": rec["collective_term_s"],
        }
        rec["dominant_term"] = max(terms, key=terms.get)
        rec["useful_flop_ratio"] = (mf / n_chips) / flops if flops else 0.0
        # beyond-paper §Perf variant: attention via the Pallas flash kernel
        # (validated in kernels/flash_attention) — substitute the tagged jnp
        # attention bytes with the kernel's streaming traffic.
        tagged = float(la["tagged_bytes"].get("flash_attention", 0.0))
        if tagged > 0:
            kern_bytes = analytic_flash_bytes(cfg, shape, run) / n_chips
            bytes_pallas = max(bytes_hbm - tagged + kern_bytes, 0.0)
            rec["pallas_flash"] = {
                "attention_bytes_jnp": tagged,
                "attention_bytes_kernel": kern_bytes,
                "memory_term_pallas_s": bytes_pallas / HBM_BW,
            }
        print(f"[dryrun] {arch} {shape_name} {mesh_name}: lower {rec['lower_s']}s "
              f"compile {rec['compile_s']}s dominant={rec['dominant_term']}")
        if mem is not None:
            print(f"  memory_analysis: {mem_fields}")
        print(f"  cost_analysis: flops={flops:.3e} bytes={bytes_hbm:.3e} "
              f"collective_bytes={coll['total_bytes']:.3e}")
    except Exception as e:  # a failing cell is a bug: record and re-raise visibility
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {arch} {shape_name} {mesh_name}: FAILED {type(e).__name__}: {e}")
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = [a for a in list_archs() if a != "vgg19-sparse"] if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_bad = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, force=args.force)
                n_bad += rec.get("status") == "error"
    if n_bad:
        raise SystemExit(f"{n_bad} cells failed")


if __name__ == "__main__":
    main()
