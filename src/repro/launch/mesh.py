"""Production meshes. Functions only — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host offers (tests / examples): (n_dev/model, model)."""
    n = jax.device_count()
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
