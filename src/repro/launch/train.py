"""Training launcher: mesh + sharded state + supervisor loop.

CPU-scale entry point (examples use it with reduced configs); the same builder
functions drive the production dry-run, so what compiles at 512 chips is what
runs here. XLA latency-hiding/overlap flags are set for the TPU target.
"""
from __future__ import annotations

import argparse
import logging
import os
import time

# compute/communication overlap: structural prerequisite flags for the TPU
# target (harmless on CPU). Set before jax import in real deployments via env.
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_enable_async_all_gather=true --xla_enable_async_collective_permute=true",
)

import jax

# sharding-invariant RNG: with the legacy non-partitionable threefry, params
# initialized under `out_shardings` get DIFFERENT values per mesh layout, so
# the same seed trains a different model on a different topology (and elastic
# reshards silently change init). Partitionable threefry removes the layout
# dependence (tests/test_distributed.py pins loss equality across meshes).
jax.config.update("jax_threefry_partitionable", True)

import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import DEFAULT_RUN, SHAPES, RunConfig, ShapeConfig, get_config
from repro.checkpoint import CheckpointManager
from repro.data import make_pipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import TrainState, init_train_state, make_train_step
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.parallel import sharding as S
from repro.parallel.api import axis_rules
from repro.runtime import FailureInjector, Supervisor

log = logging.getLogger("repro.train")


def build_trainer(cfg, run: RunConfig, shape: ShapeConfig, mesh, total_steps: int, seed=0):
    """Returns (jitted train_step, initial sharded state)."""
    with axis_rules(mesh, fsdp=run.fsdp):
        pshard, pshapes = S.params_sharding(cfg, mesh, jnp.dtype(run.param_dtype))
        oshard, _ = S.opt_sharding(cfg, mesh, run, pshapes)
        state_shard = TrainState(params=pshard, opt=oshard)
        specs = M.input_specs(cfg, shape, jnp.dtype(run.compute_dtype))
        bshard = S.batch_sharding(specs, mesh)
        metrics_shard = {k: NamedSharding(mesh, P()) for k in ("loss", "grad_norm", "lr")}
        step_fn = jax.jit(
            make_train_step(cfg, run, total_steps),
            in_shardings=(state_shard, bshard),
            out_shardings=(state_shard, metrics_shard),
            donate_argnums=(0,),
        )
        init = jax.jit(
            lambda key: init_train_state(cfg, run, key),
            out_shardings=state_shard,
        )
        state = init(jax.random.PRNGKey(seed))
    return step_fn, state


def train(arch: str, *, steps: int = 100, reduced: bool = True,
          global_batch: int = 8, seq_len: int = 128, grad_accum: int = 1,
          ckpt_dir: str = "/tmp/repro_ckpt", checkpoint_every: int = 50,
          fail_at: tuple = (), resume: bool = True, seed: int = 0,
          model_axis: int = 1, log_every: int = 10):
    cfg = get_config(arch, reduced=reduced)
    run = DEFAULT_RUN.replace(grad_accum=grad_accum, checkpoint_every=checkpoint_every,
                              remat="full")
    shape = ShapeConfig("custom_train", seq_len, global_batch, "train")
    mesh = make_host_mesh(model_axis)
    step_fn, state = build_trainer(cfg, run, shape, mesh, steps, seed)
    pipeline = make_pipeline(cfg, shape, seed=seed)
    ckpt = CheckpointManager(ckpt_dir, keep=3)

    start = 0
    if resume and ckpt.latest_step() is not None:
        restored, meta = ckpt.restore(state)
        if restored is not None:
            state, start = restored, int(meta["step"])
            log.info("resumed from step %d", start)

    sup = Supervisor(
        train_step=step_fn, pipeline=pipeline, ckpt=ckpt,
        checkpoint_every=checkpoint_every,
        injector=FailureInjector(fail_at=tuple(fail_at)) if fail_at else None,
    )
    t0 = time.time()
    state, history = sup.run(state, steps, start_step=start)
    dt = time.time() - t0
    if history:
        for h in history[:: max(1, len(history) // 10)]:
            log.info("step %4d loss %.4f", h["step"], h["loss"])
        tok_s = shape.global_batch * shape.seq_len * len(history) / max(dt, 1e-9)
        log.info("done: %d steps in %.1fs (%.0f tok/s), final loss %.4f",
                 len(history), dt, tok_s, history[-1]["loss"])
    return state, history


def main():
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()
    train(args.arch, steps=args.steps, reduced=not args.full,
          global_batch=args.global_batch, seq_len=args.seq_len,
          grad_accum=args.grad_accum, ckpt_dir=args.ckpt_dir,
          resume=not args.no_resume, model_axis=args.model_axis)


if __name__ == "__main__":
    main()
