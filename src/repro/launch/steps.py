"""Step functions: train_step (grad-accum + remat + AdamW) and serve steps.

These are the exact functions the dry-run lowers and the trainer executes; no
separate "dry-run model". Gradient accumulation is a lax.scan over microbatches
(keeps both activation memory and HLO size independent of global batch) with
fp32 (configurable) gradient accumulation; under GSPMD the per-microbatch
gradient reduction becomes reduce-scatter against the FSDP-sharded params —
the overlap-friendly structure XLA's latency-hiding scheduler needs.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model as M
from repro.optim.adamw import OptState, adamw_update, init_opt_state
from repro.optim.schedules import warmup_cosine


class TrainState(NamedTuple):
    params: dict
    opt: OptState


def init_train_state(cfg: ModelConfig, run: RunConfig, key, dtype=None) -> TrainState:
    dtype = dtype or jnp.dtype(run.param_dtype)
    params, _ = M.init_params(cfg, key, dtype)
    opt = init_opt_state(params, jnp.dtype(run.moment_dtype))
    return TrainState(params=params, opt=opt)


def make_train_step(cfg: ModelConfig, run: RunConfig, total_steps: int = 10_000,
                    grad_shardings=None):
    ga = run.grad_accum

    def loss_fn(params, mb):
        return M.lm_loss(cfg, params, mb, remat=run.remat)

    def _constrain(grads):
        # §Perf (arctic iteration B2): without this, GSPMD moves partial f32
        # dW's into the FSDP-sharded accumulator via all-gather + slice;
        # pinning the microbatch grads to the accumulator's sharding makes the
        # reduction a reduce-scatter (the ZeRO-2 pattern), per microbatch.
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, grads, grad_shardings)

    def train_step(state: TrainState, batch: dict):
        params = state.params
        if ga > 1:
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(ga, x.shape[0] // ga, *x.shape[1:]), batch)

            def acc(carry, mb):
                gsum, lsum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype), gsum, _constrain(grads))
                return (gsum, lsum + loss), None

            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc, (gzero, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / ga, gsum)
            loss = lsum / ga
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _constrain(grads)

        lr = warmup_cosine(state.opt.step, peak_lr=run.learning_rate,
                           warmup_steps=run.warmup_steps, total_steps=total_steps)
        new_params, new_opt, gnorm = adamw_update(
            grads, state.opt, params, lr=lr, beta1=run.beta1, beta2=run.beta2,
            weight_decay=run.weight_decay, grad_clip=run.grad_clip)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, run: RunConfig):
    def prefill_step(params, caches, batch):
        return M.prefill(cfg, params, caches, batch, remat="none")

    return prefill_step


def make_serve_step(cfg: ModelConfig, run: RunConfig):
    """One decode step: greedy next token against a seq_len cache."""

    def serve_step(params, caches, batch, pos):
        logits, new_caches = M.decode_step(cfg, params, caches, batch, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    return serve_step
