"""Loop-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts a while-loop body exactly ONCE, which
makes it useless for scan-over-layers / grad-accum / chunked-attention programs
(it undercounts qwen3 train_4k by ~200x). This module re-derives FLOPs, HBM
bytes and collective bytes from the optimized HLO text, multiplying each
computation by the product of enclosing loop trip counts
(`backend_config={"known_trip_count":...}`, with a max-constant-in-condition
fallback).

Validated against cost_analysis on loop-free programs (tests/test_hlo_cost.py):
dot FLOPs match exactly; bytes are the operand+result sum per materializing op
(same convention cost_analysis uses, minus its cross-op reuse modeling).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[\w\[\],{}]+))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that move HBM bytes, with per-op conventions matching HloCostAnalysis:
# slicing ops touch only the sliced region, broadcasts write the result only.
_OPERANDS_PLUS_RESULT = {
    "dot", "fusion", "convolution", "reduce", "concatenate", "custom-call",
    "select-and-scatter", "reduce-window", "sort", "cholesky",
    "triangular-solve", "scatter",
} | set(_COLLECTIVES) | {c + "-start" for c in _COLLECTIVES}
_RESULT_X2 = {"copy", "transpose", "convert", "reverse", "pad", "slice",
              "dynamic-slice", "gather"}
_RESULT_ONLY = {"broadcast", "iota", "rng-bit-generator"}


def _nelems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_nelems(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _SHAPE_RE.findall(type_str) if dt in _DTYPE_BYTES)


def _type_elems(type_str: str) -> int:
    return sum(_nelems(dims) for dt, dims in _SHAPE_RE.findall(type_str)
               if dt in _DTYPE_BYTES)


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)
    coll_native: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)
    tagged_bytes: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.coll_native.items():
            self.coll_native[k] = self.coll_native.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult
        for k, v in other.tagged_bytes.items():
            self.tagged_bytes[k] = self.tagged_bytes.get(k, 0.0) + v * mult


def parse_computations(text: str) -> dict:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = comps.setdefault(m.group(1), [])
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, ty, op, ops, attrs = mi.groups()
        # operands print either bare ("%x, %y") or typed ("f32[2,3]{1,0} %x,
        # ..." — newer HLO text); take the %-token of each comma entry
        operands = [tok.lstrip("%") for o in ops.split(",")
                    for tok in o.strip().split() if tok.startswith("%")]
        cur.append(Instr(name=name, type_str=ty, opcode=op, operands=operands, attrs=attrs))
    return comps


def _dot_flops(instr: Instr, symtab: dict) -> float:
    result_elems = _type_elems(instr.type_str)
    k = 1
    m = _LHS_CONTRACT_RE.search(instr.attrs)
    if m and instr.operands:
        lhs_ty = symtab.get(instr.operands[0], "")
        sm = _SHAPE_RE.search(lhs_ty)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * result_elems * k


def _trip_count(instr: Instr, comps: dict, cond_name: str | None) -> int:
    m = _TRIP_RE.search(instr.attrs)
    if m:
        return int(m.group(1))
    if cond_name and cond_name in comps:  # fallback: max s32 constant in cond
        best = 1
        for ins in comps[cond_name]:
            if ins.opcode == "constant":
                mm = re.search(r"constant\((\d+)\)", f"{ins.opcode}({ins.attrs}")
                if mm:
                    best = max(best, int(mm.group(1)))
        return best
    return 1


def _fusion_operand_bytes(ins: Instr, symtab: dict, comps: dict, called: str | None) -> int:
    """Bytes read by a fusion: operands consumed only through slicing ops inside
    the fused computation are charged at the slice size, not the full array
    (XLA fuses dynamic-slice into consumers; charging full operands would make a
    chunked-attention loop look like it re-reads every hoisted tensor whole)."""
    full = [_type_bytes(symtab.get(o, "")) for o in ins.operands]
    if not called or called not in comps:
        return sum(full)
    finstrs = comps[called]
    # XLA prints fused-computation parameters in index order == operand order.
    pnames = [fi.name for fi in finstrs if fi.opcode == "parameter"]
    sliced_access: dict[str, int] = {}
    nonslice_full: set[str] = set()
    pset = set(pnames)
    for fi in finstrs:
        if fi.opcode == "parameter":
            continue
        for o in fi.operands:
            if o in pset:
                if fi.opcode in ("dynamic-slice", "slice", "gather"):
                    sliced_access[o] = sliced_access.get(o, 0) + _type_bytes(fi.type_str)
                else:
                    nonslice_full.add(o)
    total = 0
    for i, pname in enumerate(pnames):
        fb = full[i] if i < len(full) else 0
        if pname in sliced_access and pname not in nonslice_full:
            total += min(fb, sliced_access[pname])
        else:
            total += fb
    return total


def _fed_by_bf16_convert(ins: Instr, instr_map: dict, comps: dict, depth: int = 3) -> bool:
    """True if the collective's operand chain converts a bf16 tensor to f32
    (the CPU backend's GEMM promotion; the TPU target moves bf16 natively)."""
    frontier = list(ins.operands)
    for _ in range(depth):
        nxt = []
        for name in frontier:
            src = instr_map.get(name)
            if src is None:
                continue
            if "bf16[" in src.type_str:
                return True
            if src.opcode == "fusion":
                cm = _CALLS_RE.search(src.attrs)
                if cm and cm.group(1) in comps:
                    if any("bf16[" in fi.type_str for fi in comps[cm.group(1)]):
                        return True
            if src.opcode in ("convert", "copy", "bitcast", "reshape", "transpose",
                              "fusion", "broadcast"):
                nxt.extend(src.operands)
        frontier = nxt
        if not frontier:
            break
    return False


def analyze(text: str, tags: tuple = ()) -> dict:
    """Loop-aware cost analysis. `tags`: substrings of HLO op_name metadata
    (from jax.named_scope) whose byte contributions are reported separately in
    `tagged_bytes` — used to re-account regions that a Pallas kernel replaces
    (the fused kernel's traffic is the region's boundary tensors only)."""
    comps = parse_computations(text)
    # entry = computation named main* (jax convention) else the last one
    entry = None
    for name in comps:
        if name.startswith("main"):
            entry = name
    if entry is None:
        entry = list(comps)[-1]

    memo: dict[tuple[str, bool], Cost] = {}

    def tag_of(ins: Instr):
        for t in tags:
            if t in ins.attrs:
                return t
        return None

    def comp_cost(name: str, inside_fusion: bool) -> Cost:
        key = (name, inside_fusion)
        if key in memo:
            return memo[key]
        total = Cost()
        instrs = comps.get(name, [])
        symtab = {i.name: i.type_str for i in instrs}
        instr_map = {i.name: i for i in instrs}

        def add_bytes(ins, nbytes):
            total.bytes += nbytes
            t = tag_of(ins)
            if t:
                total.tagged_bytes[t] = total.tagged_bytes.get(t, 0.0) + nbytes

        for ins in instrs:
            op = ins.opcode
            if op == "while":
                body = _BODY_RE.search(ins.attrs)
                cond = _COND_RE.search(ins.attrs)
                trip = _trip_count(ins, comps, cond.group(1) if cond else None)
                if body:
                    total.add(comp_cost(body.group(1), False), trip)
                if cond:
                    total.add(comp_cost(cond.group(1), False), trip)
                continue
            if op == "conditional":
                branches = _BRANCH_RE.search(ins.attrs)
                if branches:
                    costs = [comp_cost(b.strip().lstrip("%"), False)
                             for b in branches.group(1).split(",")]
                    if costs:  # max-flops branch (pessimistic)
                        total.add(max(costs, key=lambda c: c.flops))
                continue
            if op in ("call", "async-start"):
                cm = _CALLS_RE.search(ins.attrs) or _BODY_RE.search(ins.attrs)
                if cm:
                    total.add(comp_cost(cm.group(1), False))
                continue
            if op == "fusion":
                cm = _CALLS_RE.search(ins.attrs)
                if cm:
                    inner = comp_cost(cm.group(1), True)
                    total.flops += inner.flops
                    for k, v in inner.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                if not inside_fusion:
                    add_bytes(ins, _type_bytes(ins.type_str) + _fusion_operand_bytes(
                        ins, symtab, comps, cm.group(1) if cm else None))
                continue
            # leaf ops
            if op == "dot":
                total.flops += _dot_flops(ins, symtab)
            elif op == "convolution":
                # rough: 2 * result_elems * prod(kernel spatial+input feature)
                rhs_ty = symtab.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
                sm = _SHAPE_RE.search(rhs_ty)
                kprod = 1
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    out_sm = _SHAPE_RE.search(ins.type_str)
                    odims = [int(d) for d in out_sm.group(2).split(",") if d] if out_sm else []
                    kprod = max(1, _nelems(sm.group(2)) // max(1, (odims and dims and dims[0]) or 1))
                total.flops += 2.0 * _type_elems(ins.type_str) * kprod
            elif op not in ("parameter", "constant", "tuple", "get-tuple-element",
                            "bitcast", "after-all", "partition-id", "replica-id"):
                total.flops += _type_elems(ins.type_str)  # elementwise-ish

            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                nbytes = _type_bytes(ins.type_str)
                total.coll[base] = total.coll.get(base, 0.0) + nbytes
                # native-dtype normalization: the CPU backend has no bf16 GEMM
                # so it converts matmul operands to f32 and hoists the convert
                # ABOVE gathers/reduces — 2x the bytes the TPU target moves.
                # When an f32 collective is fed by a bf16->f32 convert chain of
                # the same element count, count the bf16 bytes as "native".
                nat = nbytes
                if "f32[" in ins.type_str and _fed_by_bf16_convert(ins, instr_map, comps):
                    nat = nbytes // 2
                total.coll_native[base] = total.coll_native.get(base, 0.0) + nat
                total.coll_counts[base] = total.coll_counts.get(base, 0.0) + 1
            if not inside_fusion:
                if op == "dot":
                    # dtype-normalize dot operands (CPU bf16->f32 GEMM promotion)
                    nb = _type_bytes(ins.type_str)
                    for o in ins.operands:
                        ob = _type_bytes(symtab.get(o, ""))
                        src = instr_map.get(o)
                        if (src is not None and "f32[" in src.type_str
                                and _fed_by_bf16_convert(src, instr_map, comps)):
                            ob //= 2
                        nb += ob
                    add_bytes(ins, nb)
                elif op in _OPERANDS_PLUS_RESULT:
                    add_bytes(ins, _type_bytes(ins.type_str) + sum(
                        _type_bytes(symtab.get(o, "")) for o in ins.operands))
                elif op in _RESULT_X2:
                    add_bytes(ins, 2 * _type_bytes(ins.type_str))
                elif op in _RESULT_ONLY:
                    add_bytes(ins, _type_bytes(ins.type_str))
                elif op == "dynamic-update-slice" and len(ins.operands) > 1:
                    add_bytes(ins, 2 * _type_bytes(symtab.get(ins.operands[1], "")))
                elif (op not in ("parameter", "constant", "tuple", "get-tuple-element",
                                 "bitcast", "after-all", "partition-id", "replica-id",
                                 "while", "conditional", "call")
                      and not op.endswith("-start") and not op.endswith("-done")):
                    # unfused top-level elementwise op: it materializes, so it
                    # moves operands+result like any other leaf (HloCostAnalysis
                    # agrees; backends that fuse these never print them bare).
                    # async -start/-done pairs are excluded: their payload is
                    # already charged by the collective/copy handling above.
                    add_bytes(ins, _type_bytes(ins.type_str) + sum(
                        _type_bytes(symtab.get(o, "")) for o in ins.operands))
        memo[key] = total
        return total

    c = comp_cost(entry, False)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes_by_kind": c.coll,
        "collective_counts": c.coll_counts,
        "collective_bytes": sum(c.coll.values()),
        "collective_bytes_native": sum(c.coll_native.values()),
        "collective_native_by_kind": c.coll_native,
        "tagged_bytes": c.tagged_bytes,
    }
