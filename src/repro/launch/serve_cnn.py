"""CNN serving launcher: single-image requests through the sparsity-aware
serving engine (dynamic batcher + plan cache + adaptive re-planning), over a
deterministic simulated-clock request stream that carries real measured
execution times. Any LayerGraph network serves through the same spine —
pick one with --model.

Run (reduced, CPU-budget):
    PYTHONPATH=src python -m repro.launch.serve_cnn --rate 50 --n-requests 24
Other networks:
    PYTHONPATH=src python -m repro.launch.serve_cnn --model lenet
    PYTHONPATH=src python -m repro.launch.serve_cnn --model alexnet
Autotuned plan:
    PYTHONPATH=src python -m repro.launch.serve_cnn --autotune
Data-parallel over 4 virtual CPU devices (DESIGN.md §6):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python -m repro.launch.serve_cnn --devices 4
Pruned-model serving (weight sparsity, DESIGN.md §7):
    PYTHONPATH=src python -m repro.launch.serve_cnn --prune-density 0.3
Traffic scenarios (telemetry + scenario library, DESIGN.md §8):
    PYTHONPATH=src python -m repro.launch.serve_cnn --scenario burst
    PYTHONPATH=src python -m repro.launch.serve_cnn --scenario diurnal
    PYTHONPATH=src python -m repro.launch.serve_cnn --scenario hotswap
    PYTHONPATH=src python -m repro.launch.serve_cnn --scenario multitenant
Kernel-level trace + measured cost-model calibration (DESIGN.md §9):
    PYTHONPATH=src python -m repro.launch.serve_cnn --trace-out trace.json
    PYTHONPATH=src python -m repro.launch.serve_cnn --calibrate \\
        --calib-out calibration.json
Tile-geometry search + int8 quantized placement (DESIGN.md §10):
    PYTHONPATH=src python -m repro.launch.serve_cnn --tile-search \\
        --calib-out calibration.json
    PYTHONPATH=src python -m repro.launch.serve_cnn --int8
Perf-history ingestion (DESIGN.md §13) — the serving summary + telemetry
snapshot (and any fitted calibration) land as first-class series in the
cross-run BenchDB, gate-able by `repro-bench check`:
    PYTHONPATH=src python -m repro.launch.serve_cnn --history benchdb.jsonl
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vgg19_sparse import CNNConfig, vgg19_graph
from repro.graph import LayerGraph, init_graph
from repro.models.cnn import shift_dead_channels
from repro.parallel import data_mesh
from repro.serving import Engine, SimClock, auto_mesh, autotune, replay_stream

log = logging.getLogger("repro.serve_cnn")

MODELS = ("vgg19", "lenet", "alexnet")
SCENARIOS = ("steady", "burst", "diurnal", "hotswap", "multitenant")


def serving_graph(model: str = "vgg19", full: bool = False) -> LayerGraph:
    """Reduced: stacks CPU tests can serve in seconds. Full: the real
    network depth (VGG at reduced resolution — the CPU budget; 96 is the
    largest such size whose five pooling stages all tile exactly, where the
    old 112 relied on the silent 7 -> 3 truncation PoolSpec now rejects)."""
    if model == "lenet":
        from repro.configs.lenet import LENET, LENET_REDUCED

        return LENET if full else LENET_REDUCED
    if model == "alexnet":
        from repro.configs.alexnet import ALEXNET, ALEXNET_REDUCED

        return ALEXNET if full else ALEXNET_REDUCED
    if model != "vgg19":
        raise ValueError(f"unknown --model {model!r} (choose from {MODELS})")
    if full:
        return vgg19_graph(CNNConfig(img_size=96))
    return vgg19_graph(CNNConfig(name="vgg-tiny", in_channels=16, img_size=16,
                                 plan=((16, 2), (32, 1)), n_classes=16))


def synth_requests(graph, n: int, seed: int = 0, dead_frac: float = 0.5):
    """Single-image requests with a shared dead-channel band (the trained-net
    activation statistic the planner exploits; DESIGN.md §2.2). `graph` is a
    LayerGraph or a legacy CNNConfig."""
    from repro.core import dead_channel_band
    from repro.graph import as_graph

    shape = as_graph(graph).in_shape
    return [dead_channel_band(
        jax.random.uniform(jax.random.PRNGKey(seed * 1000 + i), shape),
        dead_frac) for i in range(n)]


def _scenario_setup(scenario, model, engine, *, n_requests, rate, seed):
    """The non-steady traffic regimes (DESIGN.md §8): returns the scenario
    plus the {stream: Engine} map `replay_scenario` drives. All regimes are
    timed off the stream's midpoint so the interesting event (burst cycle,
    drift onset, swap) lands while requests are still flowing."""
    from repro.serving import (
        DiurnalDriftScenario,
        HotSwapScenario,
        MultiTenantScenario,
        PoissonBurstScenario,
        TenantSpec,
    )

    shape = engine.graph.in_shape
    t_mid = n_requests / (2.0 * rate)
    if scenario == "burst":
        return PoissonBurstScenario(
            in_shape=shape, n_requests=n_requests, base_rps=rate,
            burst_rps=rate * 16, burst_every_s=t_mid,
            burst_len_s=t_mid / 4, seed=seed), {"": engine}
    if scenario == "diurnal":
        return DiurnalDriftScenario(
            in_shape=shape, n_requests=n_requests, rate_rps=rate,
            dead_lo=0.5, dead_hi=0.0, drift="step", t_drift=t_mid,
            seed=seed), {"": engine}
    if scenario == "hotswap":
        from repro.sparse_weights import prune_graph_params

        pruned, report = prune_graph_params(engine.params, 0.3, engine.graph)
        log.info("hot-swap variant: pruned to %.2f achieved block density",
                 report.density)

        def swap(engines):
            engines[""].hot_swap(pruned)

        return HotSwapScenario(
            in_shape=shape, n_requests=n_requests, rate_rps=rate,
            t_swap=t_mid, swap_fn=swap, seed=seed), {"": engine}
    if scenario == "multitenant":
        other = "lenet" if model != "lenet" else "vgg19"
        graph2 = serving_graph(other)
        params2 = shift_dead_channels(init_graph(jax.random.PRNGKey(seed + 1),
                                                 graph2))
        calib2 = jnp.stack(synth_requests(graph2, 2, seed=seed + 3))
        # the second tenant shares the first's clock AND PlanCache — the
        # PlanKey graph/weight signatures keep the programs from colliding
        engine2 = Engine(params2, graph=graph2, calib=calib2,
                         occ_threshold=engine.plan.occ_threshold,
                         block_c=engine.plan.block_c,
                         max_batch=engine.batcher.max_batch,
                         deadline_s=engine.batcher.deadline_s,
                         clock=engine.clock, cache=engine.cache,
                         mesh=engine.mesh)
        engine2.warmup()
        tenants = ((model, TenantSpec(in_shape=shape,
                                      n_requests=n_requests // 2,
                                      rate_rps=rate)),
                   (other, TenantSpec(in_shape=graph2.in_shape,
                                      n_requests=n_requests // 2,
                                      rate_rps=rate)))
        return MultiTenantScenario(tenants=tenants, seed=seed), \
            {model: engine, other: engine2}
    raise ValueError(f"unknown --scenario {scenario!r} "
                     f"(choose from {SCENARIOS})")


def serve_cnn(*, model: str = "vgg19", full: bool = False,
              n_requests: int = 24, rate: float = 50.0,
              max_batch: int = 8, deadline_ms: float = 10.0,
              occ_threshold: float = 0.75, block_c: int = 8,
              do_autotune: bool = False, replan_band: float = 0.15,
              devices: int = 0, prune_density: float = 1.0,
              scenario: str = "steady", seed: int = 0,
              trace_out: str | None = None, calibrate: bool = False,
              calib_out: str | None = None, tile_search: bool = False,
              int8: bool = False, int8_budget: float = 0.98,
              history: str | None = None) -> dict:
    graph = serving_graph(model, full)
    params = shift_dead_channels(init_graph(jax.random.PRNGKey(seed), graph))
    # --devices 0 degrades like the Engine's auto policy (largest local
    # prefix dividing max_batch); an explicit count is honored or raises
    mesh = data_mesh(devices) if devices else auto_mesh(max_batch)
    # calib batch must divide the device count so autotune can time the
    # SHARDED executor the engine will actually run
    calib = jnp.stack(synth_requests(graph, max(2, mesh.size), seed=seed + 1))
    achieved_density = 1.0
    if prune_density < 1.0:
        from repro.sparse_weights import prune_graph_params

        params, report = prune_graph_params(params, prune_density, graph,
                                            probe=calib)
        achieved_density = report.density
        log.info("pruned to %.2f achieved block density (target %.2f): "
                 "max logit drift %.3g, top-1 agreement %.2f",
                 report.density, prune_density, report.max_logit_drift,
                 report.top1_agreement)
    clock = SimClock()
    tracer = None
    if trace_out:
        from repro.obs import Tracer

        # the tracer shares the engine's SimClock, so two identical runs
        # export bit-identical trace files (tests/test_obs.py pins this)
        tracer = Tracer(clock=clock)
    calibration = None
    if calibrate:
        from repro.obs import CalibrationDB, profile_plan
        from repro.pipeline.planner import plan_network

        # measure the DEFAULT-constants plan, fit effective constants from
        # the measured/modeled ratios, then let every later planning step
        # (autotune grid, engine initial plan, drift re-plans) price impls
        # at the fitted numbers (DESIGN.md §9)
        base = plan_network(params, calib, graph, occ_threshold=occ_threshold,
                            block_c=block_c)
        report = profile_plan(base, params, calib, tracer=tracer)
        calibration = CalibrationDB.from_report(report)
        log.info("calibrated %d (kind, impl) keys on %s: %s",
                 len(calibration.entries), calibration.device,
                 calibration.summary())
    tiles = None
    if tile_search:
        from repro.obs import tile_search as run_tile_search
        from repro.pipeline.planner import plan_network

        # search every layer of the base plan at its planned impl; winners
        # land in the tiles table of the calibration DB (shared with
        # --calibrate when both are on), and the per-tile fitted constants
        # make the searched geometries measured-backed in later planning
        base = plan_network(params, calib, graph, occ_threshold=occ_threshold,
                            block_c=block_c, calibration=calibration)
        ts_report, tiles = run_tile_search(base, params, calib,
                                           db=calibration,
                                           calibration=calibration,
                                           tracer=tracer)
        if calibration is None:
            calibration = tiles  # the fits double as measured constants
        log.info("tile search: %d/%d layers improved on defaults "
                 "(modeled speedup %.3fx, floor holds: %s)",
                 len(ts_report.improved_layers()), len(ts_report.layers),
                 ts_report.summary()["model_speedup"],
                 ts_report.floor_holds())
    if calib_out and calibration is not None:
        calibration.save(calib_out)
        log.info("calibration DB written to %s", calib_out)
    plan = None
    if do_autotune:
        result = autotune(params, calib, graph, thresholds=(0.5, 0.75, 0.9),
                          block_cs=(0, 8), mesh=mesh, calibration=calibration,
                          tiles=tiles, int8=int8, int8_budget=int8_budget)
        plan = result.plan
        log.info("autotune picked occ_threshold=%.2f block_c=%d (model fallback: %s)",
                 result.best.occ_threshold, result.best.block_c, result.used_model)
    engine = Engine(params, graph=graph, plan=plan, calib=calib,
                    occ_threshold=occ_threshold, block_c=block_c,
                    max_batch=max_batch, deadline_s=deadline_ms * 1e-3,
                    clock=clock, replan_band=replan_band, mesh=mesh,
                    tracer=tracer, calibration=calibration, tiles=tiles,
                    int8=int8, int8_budget=int8_budget)
    rep8 = engine.plan.int8_report
    if rep8 is not None:
        log.info("int8 probe: %d layers quantized (%d demoted), top-1 "
                 "agreement %.3f, max logit drift %.3g",
                 len(rep8.layers), len(rep8.demoted), rep8.top1_agreement,
                 rep8.max_logit_drift)
    log.info("%s plan: %s", graph.name, " ".join(
        f"conv{lp.index + 1}={lp.impl}@{lp.occupancy:.2f}" for lp in engine.plan.layers))
    compiled = engine.warmup()
    log.info("warmed %d bucket programs (buckets=%s, devices=%d)", compiled,
             engine.batcher.exec_buckets(), engine.n_devices)

    t_start = clock()
    if scenario == "steady":
        results = replay_stream(engine,
                                synth_requests(graph, n_requests, seed=seed + 2),
                                rate_rps=rate)
    else:
        from repro.serving import replay_scenario

        scn, engines = _scenario_setup(scenario, model, engine,
                                       n_requests=n_requests, rate=rate,
                                       seed=seed)
        results = [r for out in replay_scenario(engines, scn).values()
                   for r in out]
    makespan = clock() - t_start
    lat_ms = np.array(sorted(r.latency_s for r in results)) * 1e3
    stats = engine.stats()
    summary = {
        "model": graph.name,
        "scenario": scenario,
        "devices": engine.n_devices,
        "prune_density": achieved_density,
        "plan_bsr": stats["plan_bsr"],
        "plan_int8": stats["plan_int8"],
        "plan_tiled": stats["plan_tiled"],
        "requests": len(results),
        "rate_rps": rate,
        "throughput_rps": len(results) / max(makespan, 1e-9),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p95_ms": float(np.percentile(lat_ms, 95)),
        "mean_fill": stats["mean_fill"],
        **{k: stats[k] for k in ("batches", "compiles", "hits", "replans",
                                 "hot_swaps")},
        "calibrated": 0 if calibration is None else len(calibration.entries),
    }
    if tracer is not None:
        tracer.save(trace_out)
        log.info("wrote %d trace events to %s (chrome://tracing / Perfetto)",
                 len(tracer.events), trace_out)
    if history:
        from repro.obs.history import (
            BenchDB,
            calibration_rows,
            make_payload,
            telemetry_rows,
        )

        db = BenchDB(history)
        # the scalar serving summary + the engine's telemetry snapshot (and
        # the fitted calibration scales, when one was produced this run)
        # become first-class series next to the benchmark sweeps
        rows = [{"name": f"serve/{graph.name}/{scenario}",
                 **{k: v for k, v in summary.items()
                    if isinstance(v, (int, float))
                    and not isinstance(v, bool)}}]
        rows += telemetry_rows(stats["telemetry"],
                               prefix=f"telemetry/{graph.name}/{scenario}")
        if calibration is not None:
            rows += calibration_rows(calibration)
        n_new = db.ingest_payload(make_payload("serve_cnn", rows))
        log.info("perf history: %d point(s) ingested into %s "
                 "(%d total, %d series)", n_new, history, len(db),
                 len(db.series()))
    log.info("served %d requests (%s traffic) at %.0f req/s offered: "
             "%.1f req/s, p50=%.1fms p95=%.1fms, %d batches (fill %.2f), "
             "%d compiles / %d cache hits, %d replans, %d hot swaps",
             summary["requests"], scenario, rate, summary["throughput_rps"],
             summary["p50_ms"], summary["p95_ms"], summary["batches"],
             summary["mean_fill"], summary["compiles"], summary["hits"],
             summary["replans"], summary["hot_swaps"])
    return summary


def main():
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", choices=MODELS, default="vgg19",
                    help="which LayerGraph network to serve")
    ap.add_argument("--full", action="store_true", help="full network depth (slow on CPU)")
    ap.add_argument("--n-requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=50.0, help="offered request rate (req/s)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=10.0)
    ap.add_argument("--occ-threshold", type=float, default=0.75)
    ap.add_argument("--block-c", type=int, default=8,
                    help="channel-block size (0 = auto; auto picks one block "
                         "for the reduced net's 16 channels, so 8 by default)")
    ap.add_argument("--replan-band", type=float, default=0.15)
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="data-parallel device count (0 = auto: the largest "
                         "local count dividing max-batch; an explicit count "
                         "must divide max-batch; run under "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                         "for virtual CPU devices)")
    ap.add_argument("--prune-density", type=float, default=1.0,
                    help="magnitude-prune the weights to this BSR block "
                         "density before planning (1.0 = no pruning); the "
                         "planner then places ('conv','bsr') layers wherever "
                         "weight sparsity beats activation sparsity")
    ap.add_argument("--scenario", choices=SCENARIOS, default="steady",
                    help="traffic regime (DESIGN.md §8): steady open-loop "
                         "stream (default), Poisson bursts, diurnal "
                         "occupancy drift (forces a re-plan), hot swap to a "
                         "0.3-density pruned variant mid-stream, or two "
                         "models multi-tenant over one shared plan cache")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace_event JSON of the run (plan/"
                         "compile/execute/re-plan spans on the sim clock; "
                         "load in chrome://tracing or Perfetto)")
    ap.add_argument("--calibrate", action="store_true",
                    help="profile the base plan per impl, fit a CalibrationDB "
                         "of measured effective roofline constants, and plan "
                         "the served engine with it (DESIGN.md §9)")
    ap.add_argument("--calib-out", default=None, metavar="PATH",
                    help="with --calibrate/--tile-search: persist the fitted "
                         "CalibrationDB (constants + tile winners) as JSON "
                         "for later runs to load")
    ap.add_argument("--tile-search", action="store_true",
                    help="search each planned layer's kernel tile geometry "
                         "(obs.tilesearch), persist measured-best winners, "
                         "and serve with them stamped on the plan "
                         "(DESIGN.md §10)")
    ap.add_argument("--int8", action="store_true",
                    help="let the planner upgrade sparse/BSR layers to the "
                         "int8 quantized kernels where the model says they "
                         "win, gated by the probe accuracy budget")
    ap.add_argument("--int8-budget", type=float, default=0.98,
                    help="minimum top-1 agreement vs the fp32 oracle on the "
                         "calibration batch; int8 layers are demoted until met")
    ap.add_argument("--history", default=None, metavar="DB",
                    help="perf-history BenchDB (JSONL, DESIGN.md §13): "
                         "ingest this run's serving summary + telemetry "
                         "snapshot as cross-run series for repro-bench")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve_cnn(model=args.model, full=args.full, n_requests=args.n_requests,
              rate=args.rate, max_batch=args.max_batch,
              deadline_ms=args.deadline_ms, occ_threshold=args.occ_threshold,
              block_c=args.block_c, do_autotune=args.autotune,
              replan_band=args.replan_band, devices=args.devices,
              prune_density=args.prune_density, scenario=args.scenario,
              seed=args.seed, trace_out=args.trace_out,
              calibrate=args.calibrate, calib_out=args.calib_out,
              tile_search=args.tile_search, int8=args.int8,
              int8_budget=args.int8_budget, history=args.history)


if __name__ == "__main__":
    main()
