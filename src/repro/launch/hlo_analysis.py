"""HLO-text analysis: collective byte accounting for the roofline.

cost_analysis() has FLOPs and HBM bytes but no collective traffic, so we parse
the (post-SPMD, per-device) HLO and sum the result-shape bytes of every
communication op. Ring-algorithm link-byte factors ((n-1)/n, etc.) are folded
into the roofline constants rather than per-op here; what we record is the
per-device payload entering the interconnect.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result bytes per collective kind from post-partitioning HLO text."""
    by_kind: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        m = re.match(r"(\([^)]*\)|[\w\[\],{}/#\s]*?)\s*([\w-]+)\(", rhs)
        if not m:
            continue
        op = m.group(2)
        # strip -start/-done/-cycle fusion suffixes (async collectives)
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        ty = m.group(1)
        nbytes = _shape_bytes(ty)
        by_kind[base] += nbytes
        counts[base] += 1
    total = sum(by_kind.values())
    return {"bytes_by_kind": dict(by_kind), "counts": dict(counts), "total_bytes": total}
