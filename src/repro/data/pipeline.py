"""Deterministic, restart-safe data pipeline.

The batch for step N is a pure function of (seed, N) — no iterator state to
checkpoint, so a supervisor restart (or an elastic re-mesh with a different
host count) resumes bit-identically by just replaying the step counter. Each
host materializes only its shard (`host_slice`), and a background prefetch
thread keeps `steps_ahead` batches in flight (compute/IO overlap).

Synthetic corpus: a fixed-vocab Zipfian token stream (language-model-like
marginals) — the paper's technique needs feature-map/activation sparsity, not
real text, and the examples train on this for a few hundred steps.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> dict:
        """Pure (seed, step) -> batch. Zipfian tokens, next-token labels."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, s, v = self.host_batch, self.seq_len, self.vocab_size
        # Zipf via inverse-CDF on a truncated harmonic distribution
        u = rng.random((b, s + 1))
        ranks = np.minimum((np.exp(u * np.log(v)) - 1).astype(np.int64), v - 1)
        toks = ranks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def iterate(self, start_step: int = 0, steps_ahead: int = 2) -> Iterator[dict]:
        """Prefetching iterator (daemon thread), resumable at any step."""
        q: queue.Queue = queue.Queue(maxsize=steps_ahead)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_pipeline(cfg, shape, seed: int = 0, n_hosts: int = 1, host_id: int = 0) -> TokenPipeline:
    return TokenPipeline(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed, n_hosts=n_hosts, host_id=host_id)
