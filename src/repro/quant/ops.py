"""Jitted int8 conv wrappers + cost hooks: the quantized (kind, impl) family.

`ecr_conv_int8` / `conv2d_bsr_int8` mirror their fp32 siblings
(`kernels.ecr_conv.ops.ecr_conv`, `sparse_weights.conv.conv2d_bsr`) exactly —
same compaction, same schedules, same tile-geometry resolution through
`repro.kernels.tiles` (with dtype_bytes=1: int8 activations fit 4x wider
channel blocks in the same VMEM budget) — and differ only in precision:
operands are absmax-int8 (`repro.quant.quantize`), the MAC accumulates
int32, and the flush rescales to fp32. In/out dtypes are fp32 like every
registry forward, so the planner can swap an int8 impl into any layer
without touching its neighbors.

The `*_ref` oracles compute the SAME quantized math in plain JAX (dense conv
over the int8 values cast to fp32, rescaled), so kernel-vs-ref agreement is
tight (int32 accumulation is exact; the fp32 oracle is exact while
per-output sums stay under 2^24) and quantization ERROR is isolated to the
ref-vs-fp32 comparison the accuracy budget governs.

Cost hooks model the int8 arithmetic at 2x the fp32 MXU peak (flops * 0.5
against the fp-calibrated roofline constants) and operand traffic at 1 byte
per element (output still fp32) — compute-bound layers win ~2x modeled,
bandwidth-bound ones ~4x on the operand side, which is what lets
`plan_network`'s joint comparison place int8 only where it pays.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.schedule_guard import guard_schedule
from repro.kernels.tiles import BsrLaunch, ConvLaunch, TileConfig
from repro.quant.kernels import (
    bsr_matmul_int8_pallas,
    ecr_conv_int8_pallas,
    ecr_conv_int8_pallas_batch,
)
from repro.quant.quantize import quantize_acts, quantize_weights


@dataclass(frozen=True)
class Int8Report:
    """Accuracy probe of a plan's int8 placements — the quantized mirror of
    `sparse_weights.prune.PruneReport`: same probe protocol (dense fp32
    logits vs the planned-with-int8 logits on the calibration batch), same
    acceptance currency (top-1 agreement)."""

    layers: tuple  # conv indices running an int8 impl after planning
    max_logit_drift: float  # max |planned - fp32 dense| over calib logits
    top1_agreement: float  # fraction of calib samples with unchanged argmax
    demoted: tuple = ()  # indices demoted back to fp32 to meet the budget


def ecr_conv_int8_launch(c: int, h: int, w: int, o: int, kh: int = 3,
                         kw: int = 3, *, stride: int = 1, block_c: int = 0,
                         block_o: int = 0, tile: TileConfig | None = None,
                         batch: int = 1) -> ConvLaunch:
    """`ConvLaunch` of one int8 ECR conv call: the fp32 builder at
    dtype_bytes=1 (int8 activations fit 4x wider channel blocks in the same
    VMEM budget) with the int8 contract recorded — int32 accumulation,
    per-output-channel weight scales — for the static checker to verify."""
    from repro.kernels.ecr_conv.ops import ecr_conv_launch

    return ecr_conv_launch(c, h, w, o, kh, kw, stride=stride, block_c=block_c,
                           block_o=block_o, tile=tile, batch=batch,
                           dtype_bytes=1, kernel="ecr_conv_int8",
                           acc_dtype="int32",
                           weight_scales="per_output_channel")


def bsr_conv_int8_launch(o: int, k_taps: int, p: int, *,
                         tile: TileConfig | None = None) -> BsrLaunch:
    """`BsrLaunch` of one int8 BSR conv call (int32 accumulation, per-row =
    per-output-channel weight scales delivered as (bt, 1) tiles)."""
    from repro.sparse_weights.conv import bsr_conv_launch

    return bsr_conv_launch(o, k_taps, p, tile=tile, dtype_bytes=1,
                           kernel="bsr_matmul_int8", acc_dtype="int32",
                           weight_scales="per_output_channel")


@partial(jax.jit, static_argnames=("stride", "interpret", "block_c",
                                   "block_o", "compact"))
def ecr_conv_int8(x_chw, kernels_oihw, stride: int = 1, interpret: bool = True,
                  block_c: int = 0, block_o: int = 0, compact: bool = True):
    """int8 ECR conv: (C,H,W) x (O,C,kh,kw) -> fp32 (O,oh,ow), skipping dead
    input channel blocks; batched (N,C,H,W) -> (N,O,oh,ow) with per-sample
    schedules AND per-sample activation scales. Quantization happens after
    channel compaction (compaction only permutes channels, so scales are
    invariant to it) and the block schedule is computed on the QUANTIZED
    values — a block that rounds to all-zero is skipped, which is exact
    (its dequantized contribution would be zero)."""
    from repro.core.ecr import compact_live_channels, compact_live_channels_batch
    from repro.core.sparsity import block_occupancy, compact_block_ids
    from repro.kernels.ecr_conv.ops import batch_block_schedule

    if x_chw.ndim == 2:
        x_chw = x_chw[None]
    if kernels_oihw.ndim == 3:
        kernels_oihw = kernels_oihw[None]
    batched = x_chw.ndim == 4
    c, h, w = x_chw.shape[-3:]
    o, c2, kh, kw = kernels_oihw.shape
    launch = ecr_conv_int8_launch(c, h, w, o, kh, kw, stride=stride,
                                  block_c=block_c, block_o=block_o,
                                  batch=x_chw.shape[0] if batched else 1)
    bc, bo = launch.block_c, launch.block_o
    cp, op, n_cb = launch.c_pad, launch.o_pad, launch.n_cb

    if batched:
        assert x_chw.shape[0] > 0, "empty batch: ecr_conv_int8 needs N >= 1"
        if compact:
            x_chw, kernels_oihw, _ = compact_live_channels_batch(x_chw, kernels_oihw)
        xq, sx = quantize_acts(x_chw, per_sample=True)  # (N,C,H,W) i8, (N,)
        wq, sw = quantize_weights(kernels_oihw)  # (O,C,kh,kw) i8, (O,)
        x = jnp.pad(xq, ((0, 0), (0, cp), (0, 0), (0, 0))).transpose(0, 2, 3, 1)
        wk = jnp.pad(wq, ((0, op), (0, cp), (0, 0), (0, 0))).transpose(2, 3, 1, 0)
        ids, cnt = batch_block_schedule(x, h, w, bc)
        ids, cnt = guard_schedule(ids, cnt, n_cb)
        out = ecr_conv_int8_pallas_batch(
            x, wk, sx[:, None], jnp.pad(sw, (0, op), constant_values=1.0)[None],
            ids, cnt, stride=stride, block_c=bc, block_o=bo,
            interpret=interpret,
        )
        return out.transpose(0, 3, 1, 2)[:, :o]

    if compact:
        x_chw, kernels_oihw, n_live = compact_live_channels(x_chw, kernels_oihw)
    xq, sx = quantize_acts(x_chw)
    wq, sw = quantize_weights(kernels_oihw)
    x = jnp.pad(xq, ((0, cp), (0, 0), (0, 0))).transpose(1, 2, 0)  # (H,W,C')
    wk = jnp.pad(wq, ((0, op), (0, cp), (0, 0), (0, 0))).transpose(2, 3, 1, 0)
    if compact:
        ids = jnp.arange(n_cb, dtype=jnp.int32)  # identity: prefix is live
        cnt = jnp.minimum((n_live + bc - 1) // bc, n_cb).astype(jnp.int32)
    else:
        occ = block_occupancy(x, (h, w, bc)).reshape(-1)
        ids, cnt = compact_block_ids(occ)
    ids, cnt = guard_schedule(ids, cnt, n_cb)
    out = ecr_conv_int8_pallas(
        x, wk, sx.reshape(1, 1),
        jnp.pad(sw, (0, op), constant_values=1.0)[None],
        ids, cnt[None], stride=stride, block_c=bc, block_o=bo,
        interpret=interpret,
    )
    return out.transpose(2, 0, 1)[:o]


def ecr_conv_int8_ref(x, w, stride: int = 1):
    """Pure-JAX oracle of the int8 path: dense conv over the int8 VALUES cast
    to fp32, rescaled — bit-tight against the kernel (both accumulate the
    same integers exactly) and the right baseline for quantization-error
    tests against the true fp32 conv."""
    from repro.core.ecr import conv2d_dense

    per_sample = x.ndim == 4
    xq, sx = quantize_acts(x, per_sample=per_sample)
    wq, sw = quantize_weights(w)
    y = conv2d_dense(xq.astype(jnp.float32), wq.astype(jnp.float32), stride)
    if per_sample:
        return y * sx[:, None, None, None] * sw[None, :, None, None]
    return y * sx * sw[:, None, None]


@partial(jax.jit, static_argnames=("stride", "interpret", "tile"))
def conv2d_bsr_int8(x, w, stride: int = 1, interpret: bool = True, tile=None):
    """int8 weight-block-sparse conv: the `conv2d_bsr` im2col lowering with
    the quantized weight matrix as the sparse left operand. Weights carry one
    scale per output channel (= per row of W:(O,K), delivered as (bt, 1)
    tiles), patches one per-tensor scale; the (ids, cnt) schedule is computed
    on the QUANTIZED weight blocks so pruned-away and quantized-to-zero
    blocks both cost nothing. Returns fp32 (O,oh,ow) / (N,O,oh,ow)."""
    from repro.core.sparsity import extract_windows
    from repro.kernels.bsr_matmul.ops import block_schedule
    from repro.quant.quantize import absmax_scale, quantize_int8
    from repro.sparse_weights.format import conv_weight_matrix

    single = x.ndim == 3
    if single:
        x = x[None]
    n = x.shape[0]
    o, c, kh, kw = w.shape
    wins = jax.vmap(lambda xi: extract_windows(xi, kh, kw, stride))(
        x.astype(jnp.float32))  # (N, oh, ow, K)
    _, oh, ow, k_taps = wins.shape
    a = wins.reshape(n * oh * ow, k_taps)  # (P, K) patches
    wm = conv_weight_matrix(w).astype(jnp.float32)  # (O, K)
    p = a.shape[0]
    launch = bsr_conv_int8_launch(o, k_taps, p, tile=tile)
    bt, bf, bd = launch.bt, launch.bf, launch.bd
    sw = absmax_scale(wm, axis=1)  # (O,) per-row = per-output-channel
    wm_q = quantize_int8(wm, sw[:, None])
    sa = absmax_scale(a)  # scalar, per-tensor patches
    a_q = quantize_int8(a, sa)
    wm_p = jnp.pad(wm_q, ((0, launch.t_pad), (0, launch.f_pad)))
    at_p = jnp.pad(a_q, ((0, launch.d_pad), (0, launch.f_pad))).T  # (Kp, Pp)
    sw_p = jnp.pad(sw, (0, launch.t_pad), constant_values=1.0)[:, None]  # (Op,1)
    ids, cnt = block_schedule(wm_p, bt, bf)
    ids, cnt = guard_schedule(ids, cnt, launch.nf)
    yt = bsr_matmul_int8_pallas(wm_p, at_p, sw_p, sa.reshape(1, 1), ids, cnt,
                                block=(bt, bf, bd), interpret=interpret)
    y = yt[:o, :p].T.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)
    return y[0] if single else y


def conv2d_bsr_int8_ref(x, w, stride: int = 1):
    """Oracle of the int8 BSR path: same quantization granularity (per-tensor
    patches == per-tensor activations once extracted, per-output-channel
    weights), dense fp32 math over the quantized values."""
    from repro.core.ecr import conv2d_dense
    from repro.quant.quantize import absmax_scale, quantize_int8
    from repro.sparse_weights.format import conv_weight_matrix

    single = x.ndim == 3
    xs = x[None] if single else x
    o, c, kh, kw = w.shape
    wm = conv_weight_matrix(w).astype(jnp.float32)
    sw = absmax_scale(wm, axis=1)  # (O,)
    wq = quantize_int8(wm, sw[:, None]).astype(jnp.float32).reshape(w.shape)
    # patch scale: the im2col matrix holds exactly x's (padded-window) values,
    # so its absmax equals the activation absmax
    from repro.core.sparsity import extract_windows

    wins = jax.vmap(lambda xi: extract_windows(xi, kh, kw, stride))(
        xs.astype(jnp.float32))
    sa = absmax_scale(wins.reshape(-1, wins.shape[-1]))
    xq = quantize_int8(xs, sa).astype(jnp.float32)
    y = conv2d_dense(xq, wq, stride) * sa * sw[None, :, None, None]
    return y[0] if single else y


# ---------------------------------------------------------------------------
# Cost hooks — the registry's ("conv", "ecr_int8" / "bsr_int8") models
# ---------------------------------------------------------------------------


def ecr_conv_int8_cost(c: int, h: int, w: int, o: int, kh: int = 3,
                       kw: int = 3, *, stride: int = 1, occupancy: float = 1.0,
                       batch: int = 1, dtype_bytes: int = 4) -> dict:
    """`ecr_conv_cost` repriced for int8: operand traffic at 1 byte/elem
    (activations, weights — the output still leaves as fp32 at
    `dtype_bytes`), and flops * 0.5 because the int8 MXU path peaks at 2x
    the fp32 OPS (so halved "fp-equivalent" flops model halved time against
    the SAME fp-calibrated roofline constants)."""
    from repro.kernels.ecr_conv.ops import ecr_conv_cost

    base = ecr_conv_cost(c, h, w, o, kh, kw, stride=stride,
                         occupancy=occupancy, batch=batch, dtype_bytes=1)
    return {"flops": base["flops"] * 0.5,
            "bytes": base["bytes"] + (dtype_bytes - 1.0) * base["out_elems"],
            "out_elems": base["out_elems"]}


def bsr_conv_int8_cost(c: int, h: int, w: int, o: int, kh: int = 3,
                       kw: int = 3, *, stride: int = 1, occupancy: float = 1.0,
                       batch: int = 1, weight_density: float = 1.0,
                       dtype_bytes: int = 4) -> dict:
    """`bsr_conv_cost` repriced for int8 (same transform as
    `ecr_conv_int8_cost`; weight density keeps scaling the live traffic)."""
    from repro.sparse_weights.conv import bsr_conv_cost

    base = bsr_conv_cost(c, h, w, o, kh, kw, stride=stride,
                         occupancy=occupancy, batch=batch,
                         weight_density=weight_density, dtype_bytes=1)
    return {"flops": base["flops"] * 0.5,
            "bytes": base["bytes"] + (dtype_bytes - 1.0) * base["out_elems"],
            "out_elems": base["out_elems"]}
