"""int8 quantized kernel family: absmax quantization, int8 ECR/BSR Pallas
kernels, and the planner-facing cost hooks + accuracy report (DESIGN.md §10).
"""
from repro.quant.ops import (
    Int8Report,
    bsr_conv_int8_cost,
    conv2d_bsr_int8,
    conv2d_bsr_int8_ref,
    ecr_conv_int8,
    ecr_conv_int8_cost,
    ecr_conv_int8_ref,
)
from repro.quant.quantize import (
    absmax_scale,
    dequantize_int8,
    quantize_acts,
    quantize_int8,
    quantize_weights,
)

__all__ = [
    "Int8Report",
    "absmax_scale",
    "bsr_conv_int8_cost",
    "conv2d_bsr_int8",
    "conv2d_bsr_int8_ref",
    "dequantize_int8",
    "ecr_conv_int8",
    "ecr_conv_int8_cost",
    "ecr_conv_int8_ref",
    "quantize_acts",
    "quantize_int8",
    "quantize_weights",
]
