"""Symmetric absmax int8 quantization helpers (the repo's one quant scheme).

scale = absmax / 127, q = clip(round(x / scale), -127, 127): zero maps to
zero exactly — load-bearing for this repo, because every sparsity mechanism
(ECR dead channel blocks, BSR pruned weight blocks) detects zeros, and a
quantizer that perturbed them would change the SCHEDULE, not just the values.
The int8 kernels accumulate in int32 (exact), so

    int8_kernel(xq, wq) == conv(xq.astype(f32), wq.astype(f32)) * sx * sw

bit-for-bit while per-output sums stay under 2^24 — which is what the
`*_ref` oracles in `repro.quant.ops` compute and the tests pin tightly.

Granularity: activations get ONE scale per tensor (per sample when batched —
a whole feature map shares post-ReLU dynamics), weights get one scale PER
OUTPUT CHANNEL (`axis=(1,2,3)` over (O,C,kh,kw) — each filter has its own
range, and per-channel scales ride into the kernels as (1, block_o)/(bt, 1)
operand tiles so the rescale fuses into the accumulator flush).
"""
from __future__ import annotations

import jax.numpy as jnp

INT8_MAX = 127.0


def absmax_scale(x, axis=None, keepdims: bool = False):
    """Symmetric scale(s): absmax / 127 over `axis` (None = whole tensor).
    Floored away from zero so an all-zero slice divides cleanly (its
    quantized values are exact zeros either way)."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=keepdims)
    return jnp.maximum(m, 1e-12) / INT8_MAX


def quantize_int8(x, scale):
    """clip(round(x / scale)) -> int8. `scale` broadcasts against x."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_weights(w):
    """(O,C,kh,kw) -> (wq int8, sw (O,) per-output-channel scales)."""
    sw = absmax_scale(w, axis=(1, 2, 3))
    return quantize_int8(w, sw[:, None, None, None]), sw


def quantize_acts(x, per_sample: bool = False):
    """x (C,H,W) or (N,C,H,W) -> (xq int8, sx scale). per_sample=True gives
    one scale per batch sample (shape (N,)); else one scalar."""
    if per_sample:
        sx = absmax_scale(x, axis=tuple(range(1, x.ndim)))
        return quantize_int8(x, sx.reshape((-1,) + (1,) * (x.ndim - 1))), sx
    sx = absmax_scale(x)
    return quantize_int8(x, sx), sx
