"""int8 variants of the ECR conv and BSR matmul Pallas kernels.

Same grids, same scalar-prefetched (ids, cnt) gather schedules, same
`@pl.when(k < cnt)` work skipping as the fp32 kernels in
`repro.kernels.ecr_conv` / `repro.kernels.bsr_matmul` — the sparsity
machinery is precision-independent. What changes:

- operands arrive as int8 (activations one symmetric scale per tensor /
  sample, weights one per output channel — `repro.quant.quantize`);
- the MAC runs `jnp.dot(..., preferred_element_type=jnp.int32)` into an
  int32 VMEM scratch accumulator (exact: |q| <= 127, so products <= 16129
  and int32 holds any realistic reduction length);
- the flush dequantizes in-register: `acc.astype(f32) * sx * sw_tile`,
  where sw rides in as a per-output-channel-block operand tile ((1, bo) for
  the conv's output-channel axis, (bt, 1) for the BSR row axis) so the
  rescale costs one fused multiply per output element and the output leaves
  as fp32 — downstream ReLU/pool/next-layer code sees the same dtype as
  every other impl.

On real hardware the int8 MXU path runs at 2x the fp peak OPS and the
gathered DMAs move 1/4 the bytes; the cost hooks in `repro.quant.ops`
model exactly that.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# int8 ECR conv (single image)
# ---------------------------------------------------------------------------


def _ecr_kernel_i8(ids_ref, cnt_ref, x_ref, w_ref, sx_ref, sw_ref, o_ref,
                   acc_ref, *, kh, kw, stride, n_cb, oh, ow):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[0])
    def _mac():
        x = x_ref[...]  # (H, W, bc) int8 — one channel block, full map
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    x,
                    (i, j, 0),
                    (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1,
                     x.shape[2]),
                    (stride, stride, 1),
                )
                acc_ref[...] += jnp.dot(
                    patch.reshape(oh * ow, -1),
                    w_ref[i, j],
                    preferred_element_type=jnp.int32,
                )

    @pl.when(k == n_cb - 1)
    def _flush():
        # dequantize at flush: (oh*ow, bo) int32 * scalar * (1, bo)
        acc = acc_ref[...].astype(jnp.float32) * sx_ref[0, 0] * sw_ref[...]
        o_ref[...] = acc.reshape(oh, ow, -1).astype(o_ref.dtype)


def ecr_conv_int8_pallas(
    x: jax.Array,  # (H, W, C) int8
    w: jax.Array,  # (kh, kw, C, O) int8
    sx: jax.Array,  # (1, 1) f32 activation scale
    sw: jax.Array,  # (1, O) f32 per-output-channel weight scales
    ids: jax.Array,  # (n_cb,) live channel-block gather list
    cnt: jax.Array,  # (1,) number of live channel blocks
    *,
    stride: int = 1,
    block_c: int = 128,
    block_o: int = 128,
    interpret: bool = True,
) -> jax.Array:
    h, wd, c = x.shape
    kh, kw, c2, o = w.shape
    assert c == c2 and c % block_c == 0 and o % block_o == 0
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    n_cb, n_ob = c // block_c, o // block_o

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_ob, n_cb),
        in_specs=[
            pl.BlockSpec((h, wd, block_c), lambda j, k, ids, cnt: (0, 0, ids[k])),
            pl.BlockSpec((kh, kw, block_c, block_o), lambda j, k, ids, cnt: (0, 0, ids[k], j)),
            pl.BlockSpec((1, 1), lambda j, k, ids, cnt: (0, 0)),
            pl.BlockSpec((1, block_o), lambda j, k, ids, cnt: (0, j)),
        ],
        out_specs=pl.BlockSpec((oh, ow, block_o), lambda j, k, ids, cnt: (0, 0, j)),
        scratch_shapes=[pltpu.VMEM((oh * ow, block_o), jnp.int32)],
    )
    return pl.pallas_call(
        partial(_ecr_kernel_i8, kh=kh, kw=kw, stride=stride, n_cb=n_cb,
                oh=oh, ow=ow),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((oh, ow, o), jnp.float32),
        interpret=interpret,
    )(ids, cnt, x, w, sx, sw)


# ---------------------------------------------------------------------------
# int8 ECR conv (native batched grid, per-sample schedules AND scales)
# ---------------------------------------------------------------------------


def _ecr_kernel_i8_batch(ids_ref, cnt_ref, x_ref, w_ref, sx_ref, sw_ref,
                         o_ref, acc_ref, *, kh, kw, stride, n_cb, oh, ow):
    b = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[b])
    def _mac():
        x = x_ref[0]  # (H, W, bc) int8 — sample b's channel block ids[b, k]
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    x,
                    (i, j, 0),
                    (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1,
                     x.shape[2]),
                    (stride, stride, 1),
                )
                acc_ref[...] += jnp.dot(
                    patch.reshape(oh * ow, -1),
                    w_ref[i, j],
                    preferred_element_type=jnp.int32,
                )

    @pl.when(k == n_cb - 1)
    def _flush():
        acc = acc_ref[...].astype(jnp.float32) * sx_ref[0, 0] * sw_ref[...]
        o_ref[...] = acc.reshape(1, oh, ow, -1).astype(o_ref.dtype)


def ecr_conv_int8_pallas_batch(
    x: jax.Array,  # (N, H, W, C) int8
    w: jax.Array,  # (kh, kw, C, O) int8 — shared across the batch
    sx: jax.Array,  # (N, 1) f32 per-sample activation scales
    sw: jax.Array,  # (1, O) f32 per-output-channel weight scales
    ids: jax.Array,  # (N, n_cb) per-sample gather lists
    cnt: jax.Array,  # (N,) per-sample live block counts
    *,
    stride: int = 1,
    block_c: int = 128,
    block_o: int = 128,
    interpret: bool = True,
) -> jax.Array:
    n, h, wd, c = x.shape
    kh, kw, c2, o = w.shape
    assert c == c2 and c % block_c == 0 and o % block_o == 0
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    n_cb, n_ob = c // block_c, o // block_o

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_ob, n, n_cb),
        in_specs=[
            pl.BlockSpec((1, h, wd, block_c), lambda j, b, k, ids, cnt: (b, 0, 0, ids[b, k])),
            pl.BlockSpec((kh, kw, block_c, block_o), lambda j, b, k, ids, cnt: (0, 0, ids[b, k], j)),
            pl.BlockSpec((1, 1), lambda j, b, k, ids, cnt: (b, 0)),
            pl.BlockSpec((1, block_o), lambda j, b, k, ids, cnt: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, block_o), lambda j, b, k, ids, cnt: (b, 0, 0, j)),
        scratch_shapes=[pltpu.VMEM((oh * ow, block_o), jnp.int32)],
    )
    return pl.pallas_call(
        partial(_ecr_kernel_i8_batch, kh=kh, kw=kw, stride=stride, n_cb=n_cb,
                oh=oh, ow=ow),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, o), jnp.float32),
        interpret=interpret,
    )(ids, cnt, x, w, sx, sw)


# ---------------------------------------------------------------------------
# int8 BSR matmul (sparse left operand = quantized weight matrix)
# ---------------------------------------------------------------------------


def _bsr_kernel_i8(ids_ref, cnt_ref, h_ref, w_ref, sh_ref, sw_ref, o_ref,
                   acc_ref, *, nf: int):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[i])
    def _mac():
        acc_ref[...] += jnp.dot(
            h_ref[...], w_ref[...], preferred_element_type=jnp.int32
        )

    @pl.when(k == nf - 1)
    def _flush():
        # (bt, bd) int32 * (bt, 1) per-row scales * scalar
        acc = acc_ref[...].astype(jnp.float32) * sh_ref[...] * sw_ref[0, 0]
        o_ref[...] = acc.astype(o_ref.dtype)


def bsr_matmul_int8_pallas(
    h: jax.Array,  # (T, F) int8, the block-sparse operand (rows = schedule)
    w: jax.Array,  # (F, D) int8
    sh: jax.Array,  # (T, 1) f32 per-row scales of h
    sw: jax.Array,  # (1, 1) f32 scale of w
    ids: jax.Array,
    cnt: jax.Array,
    *,
    block: tuple = (8, 128, 128),
    interpret: bool = True,
) -> jax.Array:
    from functools import partial

    t, f = h.shape
    f2, d = w.shape
    assert f == f2, (h.shape, w.shape)
    bt, bf, bd = block
    assert t % bt == 0 and f % bf == 0 and d % bd == 0, (h.shape, w.shape, block)
    nt, nf, nd = t // bt, f // bf, d // bd
    assert ids.shape == (nt, nf) and cnt.shape == (nt,), (ids.shape, cnt.shape)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt, nd, nf),
        in_specs=[
            pl.BlockSpec((bt, bf), lambda i, j, k, ids, cnt: (i, ids[i, k])),
            pl.BlockSpec((bf, bd), lambda i, j, k, ids, cnt: (ids[i, k], j)),
            pl.BlockSpec((bt, 1), lambda i, j, k, ids, cnt: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k, ids, cnt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda i, j, k, ids, cnt: (i, j)),
        scratch_shapes=[pltpu.VMEM((bt, bd), jnp.int32)],
    )
    return pl.pallas_call(
        partial(_bsr_kernel_i8, nf=nf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=interpret,
    )(ids, cnt, h, w, sh, sw)
