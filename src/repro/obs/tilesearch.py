"""Per-layer tile-geometry search: measure -> search -> plan, closed.

PR 7 built the measure half (profile_plan -> CalibrationDB); this module is
the SEARCH half. For each conv layer of a plan, at the (kind, impl) the
planner chose, it enumerates candidate `TileConfig` geometries (power-of-two
grids over the dimensions that impl actually tiles), prices each on the
roofline model — re-measuring the layer's channel-block occupancy at the
candidate's block_c and the weight block density at the candidate's (bt, bf),
because geometry changes WHAT the schedule can skip, not just how it tiles —
prunes the obviously-losing geometries without timing them, wall-times the
survivors through the shared `time_callable` harness, and picks a winner by
the rule:

    S      = { timed candidates with measured_us <= default's measured_us }
    winner = argmin over S of (model_us, measured_us)

The default geometry is always timed and always in S, so BY CONSTRUCTION the
winner's modeled time AND measured time are <= the default's — a searched
plan can only tie or beat the shipped constants, never regress them (the
floor `benchmarks/kernels_micro.py --check-floor` pins in CI).

Winners persist into the `CalibrationDB` tiles table
(`put_tile`/`best_tile`, keyed by (device, kind, impl, layer shape)), which
is how the loop closes: `plan_network(tiles=db)` consults the table and
stamps each layer's `LayerPlan.tile`, `run_unit` threads it into the
kernels, and `PlanKey.tile_sig` keeps compiled executables per geometry.
Timings can also be FITTED back as per-tile calibration entries (fit=True),
so `plan_model_us` prices a searched geometry at its measured efficiency.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.kernels.tiles import DEFAULT_TILE, TileConfig

# power-of-two grids per tiled dimension (intersected with each layer's
# extents; the fallback rule would silently map a too-big size onto the
# default, which would only re-time the default under another name)
_CONV_BC = (8, 16, 32, 64, 128)
_CONV_BO = (8, 32, 128)
_BSR_BT = (8, 16, 32)
_BSR_BF = (16, 32, 64, 128)
_BSR_BD = (32, 64, 128)


@dataclass(frozen=True)
class TileCandidate:
    """One priced geometry; measured_us < 0 means pruned before timing."""

    key: tuple  # TileConfig.key()
    model_us: float
    measured_us: float = -1.0
    spread: float = 0.0

    @property
    def timed(self) -> bool:
        return self.measured_us >= 0.0

    def row(self) -> dict:
        return {"tile": list(self.key), "model_us": round(self.model_us, 4),
                "measured_us": round(self.measured_us, 2),
                "spread": round(self.spread, 3), "timed": self.timed}


@dataclass(frozen=True)
class LayerTileSearch:
    """One layer's search result. `best` is the winning candidate; when the
    geometry search does not apply (non-Pallas impl) it is the default with
    no alternatives."""

    index: int
    kind: str
    impl: str
    shape_key: tuple
    best: TileCandidate
    default: TileCandidate
    candidates: tuple  # every priced TileCandidate, default included

    @property
    def improved(self) -> bool:
        return self.best.key != DEFAULT_TILE.key() and (
            self.best.model_us < self.default.model_us
            or self.best.measured_us < self.default.measured_us)

    def row(self) -> dict:
        return {"layer": self.index, "kind": self.kind, "impl": self.impl,
                "shape": list(self.shape_key),
                "best": self.best.row(), "default": self.default.row(),
                "improved": self.improved,
                "n_candidates": len(self.candidates),
                "n_timed": sum(c.timed for c in self.candidates)}


@dataclass(frozen=True)
class TileSearchReport:
    graph_name: str
    device_kind: str
    batch: int
    layers: tuple  # tuple[LayerTileSearch, ...]

    def improved_layers(self) -> tuple:
        return tuple(r for r in self.layers if r.improved)

    def floor_holds(self) -> bool:
        """The by-construction guarantee, re-checked on the recorded numbers:
        every layer's winner models AND measures no slower than its default."""
        return all(r.best.model_us <= r.default.model_us
                   and (not r.best.timed
                        or r.best.measured_us <= r.default.measured_us)
                   for r in self.layers)

    def summary(self) -> dict:
        return {"graph": self.graph_name, "device_kind": self.device_kind,
                "batch": self.batch, "layers": len(self.layers),
                "improved": len(self.improved_layers()),
                "floor_holds": self.floor_holds(),
                "model_speedup": round(
                    sum(r.default.model_us for r in self.layers)
                    / max(sum(r.best.model_us for r in self.layers), 1e-9), 4),
                "rows": [r.row() for r in self.layers]}


def _conv_candidates(c: int, o: int) -> list:
    out = [DEFAULT_TILE]
    for bc in _CONV_BC:
        if bc > max(8, c):
            continue
        for bo in _CONV_BO:
            if bo > max(8, o):
                continue
            out.append(TileConfig(block_c=bc, block_o=bo))
    return out


def _bsr_candidates(o: int, k_taps: int, p: int) -> list:
    out = [DEFAULT_TILE]
    for bt in _BSR_BT:
        if bt > max(8, o):
            continue
        for bf in _BSR_BF:
            if bf > max(8, k_taps):
                continue
            for bd in _BSR_BD:
                if bd > max(8, p):
                    continue
                out.append(TileConfig(bt=bt, bf=bf, bd=bd))
    return out


def layer_tile_candidates(unit, kind: str, impl: str, batch: int) -> list:
    """The geometry grid one (layer, impl) searches over — the dimensions
    that impl tiles, intersected with the layer's extents, default first."""
    from repro.graph.registry import get_op

    op = get_op(kind, impl)
    c, h, w = unit.in_shape
    if op.weight_sparse:
        conv = unit.conv
        k_taps = c * conv.k * conv.k
        _, oh, ow = unit.conv_out_shape
        return _bsr_candidates(conv.c_out, k_taps, batch * oh * ow)
    return _conv_candidates(c, unit.conv.c_out)


def search_layer(unit, w, x, kind: str, impl: str, *, iters: int = 2,
                 warmup: int = 1, prune_factor: float = 1.25,
                 max_timed: int = 4, calibration=None,
                 tracer=None) -> LayerTileSearch:
    """Search one layer's tile geometry at its planned (kind, impl).

    x is the layer's REAL input (the dense-oracle walk of `tile_search`), so
    occupancy — re-measured per candidate block_c, at the impl's operand
    width — prices exactly the schedule each geometry would run. Candidates
    whose modeled time exceeds `prune_factor` x the modeled minimum are not
    timed (the roofline prune); of the rest the `max_timed` modeled-best are
    (the default always is). Winner rule: see module docstring.
    """
    import jax

    from repro.graph.executor import run_unit
    from repro.graph.registry import get_op, unit_model_us
    from repro.obs.calibrate import unit_shape_key
    from repro.obs.profile import time_callable
    from repro.obs.trace import NULL_TRACER
    from repro.pipeline.planner import measure_occupancy
    from repro.sparse_weights.format import conv_weight_matrix, matrix_block_density

    tracer = tracer or NULL_TRACER
    op = get_op(kind, impl)
    shape_key = unit_shape_key(unit)
    batch = int(x.shape[0]) if x.ndim == 4 else 1
    if not op.pallas:
        # nothing to search: non-Pallas impls have no tile geometry
        m = unit_model_us(kind, impl, unit, batch=batch,
                          calibration=calibration)
        cand = TileCandidate(key=DEFAULT_TILE.key(), model_us=m)
        return LayerTileSearch(index=unit.index, kind=kind, impl=impl,
                               shape_key=shape_key, best=cand, default=cand,
                               candidates=(cand,))

    dtype_bytes = 1 if op.quantized else 4
    c, h, wdt = unit.in_shape
    conv = unit.conv
    k_taps = c * conv.k * conv.k
    wm = conv_weight_matrix(w) if op.weight_sparse else None

    priced: list = []
    for t in layer_tile_candidates(unit, kind, impl, batch):
        occ = 1.0
        wd = 1.0
        if op.sparse:
            occ = measure_occupancy(x, tile=t, dtype_bytes=dtype_bytes)
        if op.weight_sparse:
            from repro.kernels.tiles import resolve_bsr_tile

            _, oh, ow = unit.conv_out_shape
            bt, bf, _ = resolve_bsr_tile(conv.c_out, k_taps, batch * oh * ow, t)
            wd = matrix_block_density(wm, (bt, bf))
        priced.append((t, unit_model_us(
            kind, impl, unit, occupancy=occ, weight_density=wd, batch=batch,
            tile=t if t else None, calibration=calibration)))

    best_model = min(m for _, m in priced)
    keep = [(t, m) for t, m in priced
            if not t or m <= prune_factor * best_model]
    # default first, then the modeled-best survivors up to the timing budget
    keep = [keep[0]] + sorted(keep[1:], key=lambda tm: tm[1])[:max_timed]

    cands: dict = {}
    for t, m in priced:
        cands[t.key()] = TileCandidate(key=t.key(), model_us=float(m))
    for t, m in keep:
        def fwd(x_, w_, t=t):
            return run_unit(x_, w_, unit, kind, impl, tile=t if t else None)

        with tracer.span("tile_search_layer", cat="kernel", layer=unit.index,
                         kind=kind, impl=impl, tile=str(t.key())):
            tm = time_callable(jax.jit(fwd), x, w, iters=iters, warmup=warmup,
                               outlier_tol=2.0)
        cands[t.key()] = TileCandidate(key=t.key(), model_us=float(m),
                                       measured_us=tm.median_us,
                                       spread=tm.spread)

    default = cands[DEFAULT_TILE.key()]
    eligible = [cd for cd in cands.values()
                if cd.timed and cd.measured_us <= default.measured_us]
    best = min(eligible, key=lambda cd: (cd.model_us, cd.measured_us))
    return LayerTileSearch(
        index=unit.index, kind=kind, impl=impl, shape_key=shape_key,
        best=best, default=default,
        candidates=tuple(sorted(cands.values(), key=lambda cd: cd.model_us)))


def tile_search(plan, params, calib, *, iters: int = 2, warmup: int = 1,
                prune_factor: float = 1.25, max_timed: int = 4,
                db=None, fit: bool = True, calibration=None,
                tracer=None):
    """Search every layer of `plan` at its planned impl; persist winners.

    Walks the plan's graph on `calib` with the dense oracle (each layer is
    searched on the input distribution the plan was made for), runs
    `search_layer` per conv unit, and writes each non-default winner into
    `db` (a `CalibrationDB`; one is created when None) via `put_tile` — an
    all-default winner ERASES a stale stored winner rather than recording a
    no-op. fit=True additionally fits per-(impl, tile) calibration entries
    from the collected timings (scale = median of modeled-default/measured,
    the `fit_report` rule), so the winners' modeled times are measured-backed
    the next time `plan_model_us` prices them.

    Returns (TileSearchReport, db).
    """
    import jax

    from repro.graph.executor import run_unit
    from repro.graph.ir import graph_weights
    from repro.obs.calibrate import CalibrationDB
    from repro.obs.constants import DEFAULT_ROOFLINE
    from repro.obs.trace import NULL_TRACER

    tracer = tracer or NULL_TRACER
    graph = plan.graph
    if graph is None:
        raise ValueError("tile_search needs a plan that carries its graph "
                         "(pre-IR plans: rebuild with plan_network)")
    if calib.ndim == 3:
        calib = calib[None]
    batch = int(calib.shape[0])
    db = db if db is not None else CalibrationDB()
    conv_ws, _ = graph_weights(params)
    rows: list = []
    x = calib
    with tracer.span("tile_search", graph=graph.name, batch=batch):
        for lp, (unit, w) in zip(plan.layers, zip(graph.units(), conv_ws)):
            r = search_layer(unit, w, x, lp.kind, lp.impl, iters=iters,
                             warmup=warmup, prune_factor=prune_factor,
                             max_timed=max_timed, calibration=calibration,
                             tracer=tracer)
            rows.append(r)
            from repro.graph.registry import get_op

            if get_op(lp.kind, lp.impl).pallas:
                db.put_tile(lp.kind, lp.impl, r.shape_key,
                            TileConfig.from_key(r.best.key))
            x = run_unit(x, w, unit, "conv", "dense")  # dense-oracle walk
    if fit and calibration is None:
        # per-(kind, impl, tile) entries from every timed candidate, the
        # fit_report rule: scale = median(modeled_default_us / measured_us).
        # Only when the candidates were priced at the DEFAULT constants — a
        # ratio against an already-calibrated model would double-apply scales.
        from repro.obs.calibrate import CalibEntry, _median

        ratios: dict = {}
        for r in rows:
            for cd in r.candidates:
                if cd.timed:
                    ratios.setdefault((r.kind, r.impl, cd.key), []).append(
                        cd.model_us / max(cd.measured_us, 1e-9))
        for (kind, impl, tkey), rs in ratios.items():
            rs = sorted(rs)
            s = _median(rs)
            if s <= 0.0:
                continue
            db.put(kind, impl, 0, CalibEntry(
                peak_flops=DEFAULT_ROOFLINE.peak_flops * s,
                hbm_bw=DEFAULT_ROOFLINE.hbm_bw * s, scale=float(s),
                n_samples=len(rs),
                resid_spread=float((rs[-1] - rs[0]) / max(s, 1e-12))),
                tile=TileConfig.from_key(tkey))
    dev = jax.devices()[0]
    report = TileSearchReport(
        graph_name=graph.name,
        device_kind=getattr(dev, "device_kind", dev.platform),
        batch=batch, layers=tuple(rows))
    return report, db
