"""Observability: kernel-level tracing, profiling, cost-model calibration.

The layer below `serving.metrics` (which aggregates the REQUEST stream):
this package observes the EXECUTION itself and closes the loop back into
the planner (DESIGN.md §9):

- `trace`     span tracer (plan -> compile -> per-batch execute ->
              per-layer kernel), deterministic on a SimClock, exported as
              Chrome trace_event JSON loadable in Perfetto;
- `profile`   the wall-time harness (jit warm-up, block_until_ready,
              median-of-k with outlier rejection — shared with
              `serving.autotune`) and `profile_plan`, which times every
              layer of a `PipelinePlan` per impl at its real shapes and
              pairs each measurement with the registry's modeled time;
- `calibrate` `CalibrationDB`: effective roofline constants fitted per
              (device kind x op kind x impl x block geometry) from a
              `ProfileReport`, consumed by `unit_model_us` /
              `plan_model_us` / `plan_network` via `calibration=` — the
              hard-coded `constants` defaults stay the fallback, so an
              empty DB is bit-identical to no calibration;
- `constants` the ONE definition of the datasheet roofline pair every
              modeled time in the repo divides by;
- `tilesearch` the per-layer kernel-geometry search (`tile_search`): price
              candidate `TileConfig`s on the (re-measured-occupancy)
              roofline, wall-time the survivors, persist measured-best
              winners into the CalibrationDB tiles table for
              `plan_network(tiles=...)` — closing measure -> search -> plan;
- `history`   the CROSS-RUN layer (DESIGN.md §13): `BenchDB` append-only
              JSONL trajectory of every BENCH_*.json / telemetry /
              profile / calibration point, noise-aware rolling-baseline
              verdicts, and the `repro-bench` CLI whose `check` is the CI
              regression gate.

Entry points: `launch/serve_cnn.py --trace-out/--calibrate/--tile-search/
--history`, `benchmarks/cost_model.py` (predicted-vs-measured regression
artifact), `benchmarks/kernels_micro.py` (tile-search sweep + floor),
`benchmarks/run.py --history` (auto-ingest), `python -m
repro.obs.history.cli` (repro-bench), `Engine(tracer=..., calibration=...)`
/ `Engine.profile()`.
"""
from repro.obs.calibrate import CalibEntry, CalibrationDB, device_kind, unit_shape_key
from repro.obs.history import (
    BenchDB,
    Thresholds,
    calibration_rows,
    check_db,
    make_payload,
    profile_rows,
    telemetry_rows,
)
from repro.obs.constants import (
    DEFAULT_HBM_BW,
    DEFAULT_PEAK_FLOPS,
    DEFAULT_ROOFLINE,
    RooflineConstants,
)
from repro.obs.profile import (
    PROFILE_IMPLS,
    LayerTiming,
    ProfileReport,
    TimingResult,
    profile_plan,
    time_callable,
)
from repro.obs.tilesearch import (
    LayerTileSearch,
    TileCandidate,
    TileSearchReport,
    layer_tile_candidates,
    search_layer,
    tile_search,
)
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "BenchDB",
    "CalibEntry",
    "CalibrationDB",
    "DEFAULT_HBM_BW",
    "DEFAULT_PEAK_FLOPS",
    "DEFAULT_ROOFLINE",
    "LayerTileSearch",
    "LayerTiming",
    "NULL_TRACER",
    "NullTracer",
    "PROFILE_IMPLS",
    "ProfileReport",
    "RooflineConstants",
    "Thresholds",
    "TileCandidate",
    "TileSearchReport",
    "TimingResult",
    "Tracer",
    "calibration_rows",
    "check_db",
    "device_kind",
    "layer_tile_candidates",
    "make_payload",
    "profile_plan",
    "profile_rows",
    "search_layer",
    "telemetry_rows",
    "tile_search",
    "time_callable",
    "unit_shape_key",
]
