"""Span-based tracer: nested spans -> Chrome trace_event JSON (Perfetto).

The serving stack is a pipeline of phases — plan, compile, per-batch
execute, per-layer kernel (the profiling harness times each layer
individually) — and "where did the time go" questions need those phases as
NESTED intervals on a timeline, not as aggregate counters (which
`serving.metrics.MetricsTracker` already covers). A `Tracer` records
complete-duration spans (`ph: "X"` events) against an injectable clock and
renders them in the Chrome trace_event format, so `trace.json` loads
directly in Perfetto / chrome://tracing.

Determinism contract (same shape as the MetricsTracker's): the clock is any
zero-arg callable returning seconds — `time.perf_counter` live, a
`serving.batcher.SimClock` in replays. Thread ids are LOGICAL (0 for the
first thread to open a span, 1 for the next, ...), not OS idents, and events
are appended in span-exit order, so two identical seeded SimClock replays
produce bit-identical `chrome_trace()` payloads (tests/test_obs.py pins the
serialized bytes).

Disabled tracing must cost nothing on the serving hot path: `NULL_TRACER`
(the engine's default) hands back one shared no-op context manager and never
accumulates state — `span()` allocates nothing.
"""
from __future__ import annotations

import json
import threading
import time


class _NullSpan:
    """The shared no-op context manager `NullTracer.span` returns."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Zero-overhead stand-in when tracing is disabled: every `span()` call
    returns the SAME no-op object and no events are ever recorded."""

    __slots__ = ()
    enabled = False
    events: tuple = ()

    def span(self, name, cat="repro", **args):
        return _NULL_SPAN

    def instant(self, name, cat="repro", **args):
        return None

    def chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        raise ValueError("NullTracer records nothing — construct a Tracer to export a trace")


NULL_TRACER = NullTracer()


class _SpanCtx:
    """One open span: records start on __enter__, emits the complete event
    (ph "X") on __exit__. Exceptions propagate; the event still closes, with
    an "error" arg naming the exception type (a crashed batch must stay
    visible on the timeline)."""

    __slots__ = ("tracer", "name", "cat", "args", "t0", "depth")

    def __init__(self, tracer, name, cat, args):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self.t0, self.depth = self.tracer._push()
        return self

    def annotate(self, **kw) -> None:
        """Attach args discovered mid-span (e.g. the measured batch fill)."""
        self.args.update(kw)

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self.tracer._pop(self)
        return False


class Tracer:
    """Span recorder over an injectable clock (see module docstring).

    `span(name, **args)` is a context manager; spans nest per thread (the
    depth rides into the event args so nesting survives flat JSON). `instant`
    marks point events (re-plan triggers, hot swaps). `chrome_trace()` /
    `save(path)` render the Chrome trace_event JSON.
    """

    def __init__(self, clock=time.perf_counter, pid: int = 0):
        self.clock = clock
        self.pid = pid
        self.enabled = True
        self.events: list = []  # chrome trace_event dicts, span-exit order
        self._lock = threading.Lock()
        self._tids: dict = {}  # OS ident -> logical tid (first-span order)
        self._stacks: dict = {}  # logical tid -> open-span depth counter
        self._t0 = float(clock())  # trace epoch: ts are relative (us)

    # -- span plumbing ------------------------------------------------------

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            if ident not in self._tids:
                self._tids[ident] = len(self._tids)
            return self._tids[ident]

    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def _push(self):
        tid = self._tid()
        with self._lock:
            depth = self._stacks.get(tid, 0)
            self._stacks[tid] = depth + 1
        return float(self.clock()), depth

    def _pop(self, ctx: _SpanCtx) -> None:
        t1 = float(self.clock())
        tid = self._tid()
        args = {"depth": ctx.depth, **ctx.args}
        with self._lock:
            self._stacks[tid] = max(self._stacks.get(tid, 1) - 1, 0)
            self.events.append({
                "name": ctx.name, "cat": ctx.cat, "ph": "X",
                "ts": self._us(ctx.t0), "dur": self._us(t1) - self._us(ctx.t0),
                "pid": self.pid, "tid": tid, "args": args,
            })

    # -- public API ---------------------------------------------------------

    def span(self, name: str, cat: str = "repro", **args) -> _SpanCtx:
        return _SpanCtx(self, name, cat, dict(args))

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        tid = self._tid()  # before the lock: _tid takes it too (non-reentrant)
        with self._lock:
            self.events.append({
                "name": name, "cat": cat, "ph": "i", "s": "t",
                "ts": self._us(float(self.clock())),
                "pid": self.pid, "tid": tid, "args": dict(args),
            })

    def counter(self, name: str, value: float, cat: str = "repro") -> None:
        """A `ph: "C"` counter sample (Perfetto renders it as a track)."""
        tid = self._tid()
        with self._lock:
            self.events.append({
                "name": name, "cat": cat, "ph": "C",
                "ts": self._us(float(self.clock())),
                "pid": self.pid, "tid": tid,
                "args": {name: float(value)},
            })

    def chrome_trace(self) -> dict:
        """The Chrome trace_event payload (JSON Object Format)."""
        with self._lock:
            return {"traceEvents": [dict(e) for e in self.events],
                    "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        """Write the trace JSON (loadable in Perfetto); returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path
