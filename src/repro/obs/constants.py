"""THE roofline constants: one definition, overridable by measurement.

Every modeled time in this repo — the planner's dense/ECR/PECR/BSR
arbitration (`repro.graph.registry.unit_model_us`), the autotuner's
noisy-clock fallback (`repro.serving.autotune.plan_model_us`), the dry-run's
roofline terms (`repro.launch.dryrun`) and the benchmark helpers
(`benchmarks/_util.modeled_tpu_us`) — divides FLOPs and HBM bytes by the pair
defined HERE. The historical copies in `graph/registry.py`,
`benchmarks/_util.py` and the dry-run are now re-exports of this module, so a
calibration (or a new device target) changes one number in one place.

The defaults are v5e-class *guesses* — peak numbers off the datasheet, not
what the Pallas kernels achieve. `repro.obs.calibrate.CalibrationDB` fits
per-(device kind, op kind, impl, block geometry) EFFECTIVE constants from
measured kernel time (`repro.obs.profile`) and overrides these defaults
wherever a cost is modeled; with no calibration present the defaults apply
bit-identically to the pre-calibration behavior.

This module must stay dependency-free (stdlib only): it sits below the op
registry in the import graph.
"""
from __future__ import annotations

from dataclasses import dataclass

# v5e-class datasheet constants (the uncalibrated fallback everywhere)
DEFAULT_PEAK_FLOPS = 197e12  # FLOP/s
DEFAULT_HBM_BW = 819e9  # B/s


@dataclass(frozen=True)
class RooflineConstants:
    """One (compute ceiling, memory ceiling) pair — default or calibrated."""

    peak_flops: float = DEFAULT_PEAK_FLOPS
    hbm_bw: float = DEFAULT_HBM_BW

    def time_us(self, flops: float, nbytes: float) -> float:
        """Roofline time (us): max of the compute and memory terms."""
        return max(flops / self.peak_flops, nbytes / self.hbm_bw) * 1e6

    def scaled(self, s: float) -> "RooflineConstants":
        """Both ceilings scaled by efficiency `s` (the CalibrationDB's fit:
        a kernel running at fraction `s` of the datasheet roofline)."""
        return RooflineConstants(self.peak_flops * s, self.hbm_bw * s)


DEFAULT_ROOFLINE = RooflineConstants()
