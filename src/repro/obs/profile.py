"""Per-layer timing harness: measured vs roofline-modeled time per impl.

The planner ranks impls by `unit_model_us` — a roofline over datasheet
constants that has never been checked against what the kernels actually do
(the paper's speedups are per-kernel WALL measurements; Pietroń & Żurek show
the dense-vs-sparse crossover is device- and shape-specific). This module is
the measurement side of that loop:

- `time_callable` is THE wall-time harness (jit warm-up, `block_until_ready`
  around every sample, median-of-k with outlier rejection) — the serving
  autotuner's `_time_us` is now a thin wrapper, so autotune candidates and
  profile rows report comparable numbers;
- `profile_plan` walks a `PipelinePlan`'s layers at their REAL shapes (the
  same dense-oracle calibration walk `plan_network` does), times each layer's
  forward under every requested impl, and pairs each measurement with the
  registry's modeled cost — one `LayerTiming` per (layer, kind, impl);
- `ProfileReport` aggregates them: per-(kind, impl) measured/modeled ratios
  (the CalibrationDB's fit input), ranking-agreement scores (does the model
  order impls the way the clock does?), and `recalibrated(db)` re-predicts
  every row through a fitted `CalibrationDB` so cost-model accuracy is a
  number a benchmark can regress on (`benchmarks/cost_model.py`).

Timing caveat: on the CPU/interpret Pallas path the measured numbers include
the emulator, so absolute measured-vs-modeled ratios are only meaningful per
impl — exactly the granularity the CalibrationDB fits at.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace

# default impl panel: the four conv families the planner arbitrates between
# (fused-family names resolve per unit through the registry's unit_impl rule)
PROFILE_IMPLS = ("dense", "ecr_pallas", "pecr_pallas", "bsr")


@dataclass(frozen=True)
class TimingResult:
    """One timed callable: median of the KEPT samples after outlier
    rejection; spread = (max-min)/median over the kept samples."""

    median_us: float
    spread: float
    samples_us: tuple
    rejected: int = 0


def time_callable(f, *args, iters: int = 3, warmup: int = 1,
                  outlier_tol: float = 0.0) -> TimingResult:
    """Median wall time of `f(*args)` with the serving-grade protocol:
    `warmup` un-timed calls absorb jit compilation, every timed call is
    bracketed by `block_until_ready` (async dispatch must not leak into the
    next sample), and `outlier_tol > 0` drops samples farther than
    `outlier_tol x median` from the median before re-taking it — a GC pause
    or a noisy-neighbor burst corrupts one sample, not the statistic.
    """
    import jax

    for _ in range(warmup):
        jax.block_until_ready(f(*args))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    med = _median(ts)
    kept = ts
    if outlier_tol > 0.0 and len(ts) > 2:
        lo, hi = med / (1.0 + outlier_tol), med * (1.0 + outlier_tol)
        kept = [t for t in ts if lo <= t <= hi] or ts
        med = _median(kept)
    spread = (max(kept) - min(kept)) / max(med, 1e-9)
    return TimingResult(median_us=float(med), spread=float(spread),
                        samples_us=tuple(float(t) for t in ts),
                        rejected=len(ts) - len(kept))


def _median(vals) -> float:
    s = sorted(vals)
    n = len(s)
    return float(s[n // 2]) if n % 2 else float((s[n // 2 - 1] + s[n // 2]) / 2)


@dataclass(frozen=True)
class LayerTiming:
    """One (layer, kind, impl) measurement next to its model prediction."""

    index: int  # conv index in network order
    kind: str
    impl: str
    occupancy: float  # measured channel-block occupancy of the layer input
    weight_density: float  # measured BSR block density of the layer's params
    batch: int
    block_c: int
    measured_us: float
    spread: float
    predicted_us: float  # unit_model_us at the DEFAULT constants
    flops: float  # the registry's modeled cost (the calibration fit input)
    bytes: float
    tile: tuple = ()  # TileConfig.key() when timed at a searched geometry

    @property
    def ratio(self) -> float:
        """predicted / measured — the per-row cost-model error the
        CalibrationDB's per-impl fit takes the median of."""
        return self.predicted_us / max(self.measured_us, 1e-9)

    def row(self) -> dict:
        return {"layer": self.index, "kind": self.kind, "impl": self.impl,
                "tile": list(self.tile),
                "occupancy": round(self.occupancy, 4),
                "weight_density": round(self.weight_density, 4),
                "measured_us": round(self.measured_us, 2),
                "predicted_us": round(self.predicted_us, 4),
                "ratio": round(self.ratio, 6), "spread": round(self.spread, 3)}


@dataclass(frozen=True)
class ProfileReport:
    """All `LayerTiming`s of one `profile_plan` run, plus the context needed
    to re-predict them (`units` carries each layer's ConvUnit so a fitted
    CalibrationDB can replay the prediction without re-timing)."""

    graph_name: str
    device_kind: str
    batch: int
    block_c: int
    timings: tuple  # tuple[LayerTiming, ...]
    units: tuple = field(default=(), repr=False)  # ConvUnit per conv index

    def by_impl(self) -> dict:
        """{(kind, impl): [LayerTiming, ...]} — the calibration fit groups."""
        groups: dict = {}
        for t in self.timings:
            groups.setdefault((t.kind, t.impl), []).append(t)
        return groups

    def layers(self) -> dict:
        """{conv index: [LayerTiming, ...]} — the ranking-agreement groups."""
        out: dict = {}
        for t in self.timings:
            out.setdefault(t.index, []).append(t)
        return out

    def agreement(self) -> dict:
        """How well the model orders impls the way the clock does, over the
        layers that profiled >= 2 impls:

        - "top1": fraction of layers whose modeled-fastest impl is also the
          measured-fastest (the decision the planner actually takes);
        - "pairwise": fraction of impl PAIRS per layer ordered identically
          by model and measurement, averaged over layers (partial credit for
          a mostly-right ranking);
        - "layers": how many layers contributed.
        """
        top1 = pair_hits = pair_total = n = 0
        for rows in self.layers().values():
            if len(rows) < 2:
                continue
            n += 1
            meas = sorted(rows, key=lambda t: t.measured_us)
            pred = sorted(rows, key=lambda t: t.predicted_us)
            top1 += (meas[0].kind, meas[0].impl) == (pred[0].kind, pred[0].impl)
            for i in range(len(rows)):
                for j in range(i + 1, len(rows)):
                    a, b = rows[i], rows[j]
                    pair_total += 1
                    pair_hits += ((a.measured_us < b.measured_us)
                                  == (a.predicted_us < b.predicted_us))
        return {"top1": top1 / n if n else 0.0,
                "pairwise": pair_hits / pair_total if pair_total else 0.0,
                "layers": n}

    def recalibrated(self, calibration) -> "ProfileReport":
        """The same measurements with `predicted_us` re-derived through a
        `CalibrationDB` — agreement() on the result scores the CALIBRATED
        cost model (the number `benchmarks/cost_model.py` pins a floor on)."""
        from repro.graph.registry import unit_model_us

        unit_by_index = {u.index: u for u in self.units}
        rows = []
        for t in self.timings:
            tile = None
            if t.tile:
                from repro.kernels.tiles import TileConfig

                tile = TileConfig.from_key(t.tile)
            pred = unit_model_us(
                t.kind, t.impl, unit_by_index[t.index], occupancy=t.occupancy,
                weight_density=t.weight_density, batch=t.batch,
                block_c=t.block_c, tile=tile, calibration=calibration)
            rows.append(replace(t, predicted_us=pred))
        return replace(self, timings=tuple(rows))

    def history_rows(self) -> list:
        """This report as perf-history rows (per-impl ratio medians +
        ranking agreement) — `repro.obs.history.profile_rows(self)`, so a
        profile run lands in the cross-run BenchDB next to the benchmark
        sweeps (DESIGN.md §13)."""
        from repro.obs.history.records import profile_rows

        return profile_rows(self)

    def summary(self) -> dict:
        """JSON-ready digest for `Engine.stats()["telemetry"]["profile"]`."""
        per_impl = {}
        for (kind, impl), rows in sorted(self.by_impl().items()):
            ratios = sorted(t.ratio for t in rows)
            per_impl[f"{kind}/{impl}"] = {
                "layers": len(rows),
                "measured_us_total": round(sum(t.measured_us for t in rows), 2),
                "ratio_median": round(_median(ratios), 6),
            }
        return {"graph": self.graph_name, "device_kind": self.device_kind,
                "batch": self.batch, "block_c": self.block_c,
                "per_impl": per_impl, "agreement": self.agreement(),
                "rows": [t.row() for t in self.timings]}


def profile_plan(plan, params, calib, *, impls=PROFILE_IMPLS, iters: int = 3,
                 warmup: int = 1, outlier_tol: float = 2.0,
                 tracer=None) -> ProfileReport:
    """Time every layer of `plan` at its real shapes under each impl family.

    Walks the plan's graph on `calib` with the dense oracle (the exact walk
    `plan_network` calibrates with, so each layer is timed on the input
    distribution the planner measured), resolves each requested impl family
    against the unit's structure (fused families land on fusion-eligible
    units via the registry's `unit_impl`, their conv fallback elsewhere —
    duplicates after resolution are profiled once), and times the jitted
    whole-batch `run_unit` through `time_callable`. Each measurement is
    paired with `unit_model_us` at the DEFAULT constants; feed the report to
    `CalibrationDB.from_report` to fit measured ones.

    `tracer` (a `repro.obs.trace.Tracer`) gets one "profile_layer" span per
    (layer, impl) nested under a "profile" span — the per-layer-kernel level
    of the trace hierarchy.
    """
    import jax

    from repro.graph.executor import run_unit
    from repro.graph.ir import graph_weights
    from repro.graph.registry import unit_cost, unit_impl, unit_model_us
    from repro.obs.trace import NULL_TRACER
    from repro.pipeline.planner import measure_occupancy
    from repro.sparse_weights import weight_block_density

    tracer = tracer or NULL_TRACER
    graph = plan.graph
    if graph is None:
        raise ValueError("profile_plan needs a plan that carries its graph "
                         "(pre-IR plans: rebuild with plan_network)")
    if calib.ndim == 3:
        calib = calib[None]
    batch = int(calib.shape[0])
    conv_ws, _ = graph_weights(params)
    timings: list = []
    units = tuple(graph.units())
    x = calib
    with tracer.span("profile", graph=graph.name, batch=batch):
        for unit, w in zip(units, conv_ws):
            occ = measure_occupancy(x, plan.block_c)
            wd = weight_block_density(w)
            seen: set = set()
            for family in impls:
                kind, impl = unit_impl(unit, family)
                if (kind, impl) in seen:
                    continue
                seen.add((kind, impl))

                def fwd(x_, w_, unit=unit, kind=kind, impl=impl):
                    return run_unit(x_, w_, unit, kind, impl, plan.block_c)

                with tracer.span("profile_layer", cat="kernel",
                                 layer=unit.index, kind=kind, impl=impl):
                    t = time_callable(jax.jit(fwd), x, w, iters=iters,
                                      warmup=warmup, outlier_tol=outlier_tol)
                conv = unit.conv
                c, h, wdt = unit.in_shape
                cost = unit_cost(
                    kind, impl, c=c, h=h + 2 * conv.pad, w=wdt + 2 * conv.pad,
                    o=conv.c_out, k=conv.k, stride=conv.stride,
                    pool=unit.pool.p if unit.pool is not None else None,
                    occupancy=occ, weight_density=wd, batch=batch)
                timings.append(LayerTiming(
                    index=unit.index, kind=kind, impl=impl, occupancy=occ,
                    weight_density=wd, batch=batch, block_c=plan.block_c,
                    measured_us=t.median_us, spread=t.spread,
                    predicted_us=unit_model_us(
                        kind, impl, unit, occupancy=occ, weight_density=wd,
                        batch=batch, block_c=plan.block_c),
                    flops=float(cost["flops"]), bytes=float(cost["bytes"])))
            x = run_unit(x, w, unit, "conv", "dense")  # next layer's input
    dev = jax.devices()[0]
    return ProfileReport(graph_name=graph.name,
                         device_kind=getattr(dev, "device_kind", dev.platform),
                         batch=batch, block_c=plan.block_c,
                         timings=tuple(timings), units=units)
