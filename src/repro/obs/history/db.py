"""BenchDB: the append-only JSONL store of cross-run benchmark points.

One line per record, one record per (bench, row, metric) value of one run.
JSONL because the write path must be append-only — the CI gate restores
yesterday's DB, appends today's points, and re-uploads; a format that
rewrites the whole file on ingest would turn every crash into data loss and
every merge into a conflict. Plain JSON values, no new deps.

The series key is (bench, row, metric, device_kind): `device_kind` is part
of the key, not metadata, so points measured on CPU-interpret Pallas and on
a real TPU form DISJOINT series — a CPU baseline can never absolve (or
accuse) a TPU regression. Within a series, points are ordered by append
position (`seq`): the log IS the clock. The stamped UTC timestamp rides
along for humans and for `diff`, but second-granularity timestamps collide
when two modules write in the same second, so ordering never depends on it.

Identity/dedup: re-ingesting a file is a no-op — a record whose full
payload (series key + run stamp + value) is already present is skipped, so
`benchmarks/run.py --history` can blanket-ingest its output directory after
every module and the CI job can re-ingest a restored artifact without
double-counting points.
"""
from __future__ import annotations

import glob
import json
import os
import time
from dataclasses import dataclass, field

SCHEMA = "benchdb-v1"

# row keys that are labels/configuration echoes, not measurements
_SKIP_KEYS = frozenset({"name", "derived", "layer", "index", "seed"})


def run_context() -> dict:
    """The stamp of the producing run — same fields `write_bench_json`
    embeds in every BENCH payload, computed here for records built outside
    the benchmark harness (telemetry snapshots, profile digests)."""
    import subprocess

    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=5)
        sha = out.stdout.strip() if out.returncode == 0 else ""
    except (OSError, subprocess.SubprocessError):
        sha = ""
    versions = {}
    for mod in ("jax", "jaxlib"):
        try:
            versions[mod] = __import__(mod).__version__
        except Exception:
            versions[mod] = "unknown"
    try:
        import jax

        dev = jax.devices()[0]
        kind = getattr(dev, "device_kind", dev.platform)
        platform = dev.platform
    except Exception:
        kind = platform = "unknown"
    return {"git_sha": sha or "unknown",
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "versions": versions,
            "device_kind": str(kind), "platform": str(platform)}


@dataclass(frozen=True)
class BenchRecord:
    """One perf point: a (bench, row, metric) value stamped with the run
    that produced it. `seq` is the append position in the DB (assigned on
    load/ingest, not serialized) — the series order."""

    bench: str
    row: str
    metric: str
    value: float
    git_sha: str
    timestamp: str
    jax: str
    jaxlib: str
    device_kind: str
    platform: str
    source: str = field(default="", compare=False)
    seq: int = field(default=-1, compare=False)

    @property
    def series_key(self) -> tuple:
        return (self.bench, self.row, self.metric, self.device_kind)

    def identity(self) -> tuple:
        """The dedup key: everything that makes this point THIS point.
        `value` is included on purpose — a bit-identical rerun of the same
        commit in the same second is the same point (skip), while a changed
        measurement at the same stamp is a new one (keep)."""
        return (self.bench, self.row, self.metric, self.value, self.git_sha,
                self.timestamp, self.jax, self.jaxlib, self.device_kind,
                self.platform)

    def to_json(self) -> dict:
        return {"bench": self.bench, "row": self.row, "metric": self.metric,
                "value": self.value, "git_sha": self.git_sha,
                "timestamp": self.timestamp, "jax": self.jax,
                "jaxlib": self.jaxlib, "device_kind": self.device_kind,
                "platform": self.platform, "source": self.source}

    @classmethod
    def from_json(cls, d: dict, seq: int = -1) -> "BenchRecord":
        return cls(bench=str(d["bench"]), row=str(d["row"]),
                   metric=str(d["metric"]), value=float(d["value"]),
                   git_sha=str(d.get("git_sha", "unknown")),
                   timestamp=str(d.get("timestamp", "")),
                   jax=str(d.get("jax", "unknown")),
                   jaxlib=str(d.get("jaxlib", "unknown")),
                   device_kind=str(d.get("device_kind", "unknown")),
                   platform=str(d.get("platform", "unknown")),
                   source=str(d.get("source", "")), seq=seq)


def payload_records(payload: dict, source: str = "") -> list:
    """Flatten one BENCH_*.json payload (the `write_bench_json` shape) into
    records: every numeric field of every row becomes one (bench, row,
    metric) point stamped with the payload's run context. Bools, strings,
    nested structures, and label keys are skipped — only measurements enter
    the trajectory."""
    bench = str(payload.get("name", "unknown"))
    versions = payload.get("versions", {}) or {}
    ctx = {
        "git_sha": str(payload.get("git_sha", "unknown")),
        "timestamp": str(payload.get("timestamp", "")),
        "jax": str(versions.get("jax", "unknown")),
        "jaxlib": str(versions.get("jaxlib", "unknown")),
        # pre-PR-10 payloads lack the device stamp; their points land in an
        # explicit "unknown" series rather than polluting a device baseline
        "device_kind": str(payload.get("device_kind", "unknown")),
        "platform": str(payload.get("platform", "unknown")),
    }
    out = []
    for row in payload.get("rows", []):
        if not isinstance(row, dict):
            continue
        rname = str(row.get("name", "?"))
        for k, v in row.items():
            if k in _SKIP_KEYS or isinstance(v, bool):
                continue
            if not isinstance(v, (int, float)):
                continue
            out.append(BenchRecord(bench=bench, row=rname, metric=str(k),
                                   value=float(v), source=source, **ctx))
    return out


class BenchDB:
    """The trajectory store: load-on-open, append-on-ingest, dedup always.

    `path=None` gives an in-memory DB (tests, ad-hoc analysis); with a path
    the file is created lazily with a one-line schema header and every
    accepted record is appended immediately — two processes alternating
    ingests never clobber each other's points.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self.records: list = []
        self._ids: set = set()
        if path and os.path.exists(path):
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    d = json.loads(line)
                    if "bench" not in d:  # schema header / future metadata
                        continue
                    self._absorb(BenchRecord.from_json(d))

    def __len__(self) -> int:
        return len(self.records)

    def _absorb(self, rec: BenchRecord) -> bool:
        ident = rec.identity()
        if ident in self._ids:
            return False
        self._ids.add(ident)
        object.__setattr__(rec, "seq", len(self.records))
        self.records.append(rec)
        return True

    def append(self, records) -> int:
        """Dedup + append; accepted records are written through to the JSONL
        file (when file-backed). Returns how many were new."""
        fresh = [r for r in records if self._absorb(r)]
        if fresh and self.path:
            new_file = not os.path.exists(self.path) or \
                os.path.getsize(self.path) == 0
            os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                        exist_ok=True)
            with open(self.path, "a") as f:
                if new_file:
                    f.write(json.dumps({"schema": SCHEMA}) + "\n")
                for r in fresh:
                    f.write(json.dumps(r.to_json(), sort_keys=True) + "\n")
        return len(fresh)

    # -- ingest ------------------------------------------------------------

    def ingest_payload(self, payload: dict, source: str = "") -> int:
        return self.append(payload_records(payload, source=source))

    def ingest_file(self, path: str) -> int:
        with open(path) as f:
            payload = json.load(f)
        if not isinstance(payload, dict) or "rows" not in payload:
            raise ValueError(f"{path}: not a BENCH payload (no 'rows')")
        return self.ingest_payload(payload, source=os.path.basename(path))

    def ingest_dir(self, dirpath: str) -> dict:
        """Ingest every BENCH_*.json under `dirpath`; {filename: n_new}.
        Dedup makes this safe to call repeatedly over the same directory —
        the `benchmarks/run.py --history` per-module hook does exactly that."""
        out = {}
        for p in sorted(glob.glob(os.path.join(dirpath, "BENCH_*.json"))):
            out[os.path.basename(p)] = self.ingest_file(p)
        return out

    # -- views -------------------------------------------------------------

    def series(self) -> dict:
        """{(bench, row, metric, device_kind): [BenchRecord, ...]} in append
        order — the trajectory, one list per typed series."""
        out: dict = {}
        for r in self.records:
            out.setdefault(r.series_key, []).append(r)
        return out

    def shas(self) -> list:
        """Distinct git SHAs in first-appearance order."""
        seen: dict = {}
        for r in self.records:
            seen.setdefault(r.git_sha, None)
        return list(seen)

    def latest_sha(self) -> str | None:
        """The SHA of the most recently appended record — `check`'s default
        candidate run."""
        return self.records[-1].git_sha if self.records else None
