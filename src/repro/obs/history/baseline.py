"""Noise-aware regression verdicts over BenchDB series.

The gate has to hold two properties at once: a bit-identical rerun must
come out all-flat (exit 0), and a real perf cliff must trip it — on wall
times measured on shared CI runners whose absolute numbers wander by tens
of percent between runs. The classification therefore never compares two
raw points; it compares the fresh point against a ROLLING BASELINE:

- baseline = median of the last `window` prior points of the series
  (median, not mean: one GC-paused outlier run must not move the bar);
- noise    = MAD of the same window, scaled by 1.4826 (the normal-
  consistency constant, so `mad_k` reads in sigmas);
- tol      = max(rel_tol * |baseline|, mad_k * 1.4826 * MAD, abs_floor) —
  the relative term carries a young series (MAD of one point is 0), the
  MAD term widens the band automatically on metrics that history shows to
  be noisy on this runner, but only once the series has
  `mad_min_samples` prior points (the MAD of two points is just half
  their gap — one noisy early pair must not swallow a real cliff);
- verdict  = regressed / improved when the point leaves the band in the
  metric's bad / good direction, flat inside it.

`min_samples` guards the cold start: a series with fewer prior points than
that reports "no-baseline" and never gates — the default of 1 makes the
second-ever run comparable (the acceptance contract: two ingested runs of
`benchmarks/run.py --json`, identical ⇒ exit 0, perturbed ⇒ nonzero).

Metric direction and noise class are inferred from the metric NAME
(`*_us`/`*_ms`/latency ⇒ lower-better noisy; throughput/speedup/agreement
⇒ higher-better; agreement/counters ⇒ exact-class tight tolerance);
metrics with no inferable direction are tracked but never gate — a changed
`batches` count is trajectory information, not by itself a regression.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

# -- metric policy ---------------------------------------------------------

_LOWER_SUFFIXES = ("_us", "_ms", "_s", "_sec")
_LOWER_TOKENS = ("us_per_call", "latency", "wall", "spread", "resid",
                 "drift", "pad_samples", "stream_compiles", "errors",
                 "rejects", "miss", "service_s")
_HIGHER_TOKENS = ("throughput", "speedup", "agreement", "top1", "pairwise",
                  "accuracy", "mean_fill", "hits")
# deterministic-by-construction metrics: same code + same seed must
# reproduce them exactly, so the tolerance band is tight
_EXACT_TOKENS = ("agreement", "top1", "pairwise", "compiles", "errors",
                 "rejects", "hits", "mean_fill", "accuracy")


def metric_direction(metric: str) -> int:
    """-1 lower-is-better, +1 higher-is-better, 0 ungated (tracked only)."""
    m = metric.lower()
    if any(t in m for t in _HIGHER_TOKENS):
        return 1
    if m.endswith(_LOWER_SUFFIXES) or any(t in m for t in _LOWER_TOKENS):
        return -1
    return 0


def metric_noise_class(metric: str) -> str:
    """"exact" (deterministic counters/scores) or "noisy" (wall clock)."""
    m = metric.lower()
    return "exact" if any(t in m for t in _EXACT_TOKENS) else "noisy"


@dataclass(frozen=True)
class Thresholds:
    """The configurable gate geometry (CLI flags map 1:1).

    `rel_noisy` defaults wide (50%) because CI wall clocks on shared
    runners genuinely move that much run-to-run; the MAD term tightens the
    effective band once a series has history. `rel_exact` is tight — a
    deterministic agreement score or compile count that moves 2% moved
    because the code changed."""

    rel_noisy: float = 0.5
    rel_exact: float = 0.02
    mad_k: float = 4.0
    min_samples: int = 1
    # the MAD term needs this many prior points before it can widen the
    # band: the MAD of two points is just half their gap, so one noisy
    # pair of early runs would otherwise swallow a real 3x cliff forever
    mad_min_samples: int = 3
    window: int = 8
    abs_floor: float = 1e-9

    def rel_for(self, metric: str) -> float:
        return self.rel_exact if metric_noise_class(metric) == "exact" \
            else self.rel_noisy


@dataclass(frozen=True)
class Verdict:
    """One gated point: the fresh value vs its rolling baseline."""

    bench: str
    row: str
    metric: str
    device_kind: str
    value: float
    git_sha: str
    status: str  # regressed | improved | flat | no-baseline | ungated
    direction: int
    baseline: float = 0.0
    baseline_n: int = 0
    mad: float = 0.0
    tol: float = 0.0
    delta: float = 0.0  # value - baseline
    rel_delta: float = 0.0

    def to_json(self) -> dict:
        return {"bench": self.bench, "row": self.row, "metric": self.metric,
                "device_kind": self.device_kind, "git_sha": self.git_sha,
                "value": self.value, "status": self.status,
                "direction": self.direction,
                "baseline": self.baseline, "baseline_n": self.baseline_n,
                "mad": round(self.mad, 9), "tol": round(self.tol, 9),
                "delta": self.delta, "rel_delta": round(self.rel_delta, 6)}


def _median(vals) -> float:
    s = sorted(vals)
    n = len(s)
    return float(s[n // 2]) if n % 2 else float((s[n // 2 - 1] + s[n // 2]) / 2)


def classify(prior_values, value: float, metric: str,
             thresholds: Thresholds | None = None) -> Verdict:
    """Classify one fresh `value` against the series' `prior_values`
    (oldest→newest; only the last `window` are consulted). Series identity
    fields of the returned Verdict are left blank — `check_db` fills them."""
    th = thresholds or Thresholds()
    direction = metric_direction(metric)
    base = Verdict(bench="", row="", metric=metric, device_kind="",
                   value=float(value), git_sha="", status="flat",
                   direction=direction)
    if direction == 0:
        return replace(base, status="ungated")
    recent = list(prior_values)[-th.window:]
    if len(recent) < max(th.min_samples, 1):
        return replace(base, status="no-baseline", baseline_n=len(recent))
    med = _median(recent)
    mad = _median([abs(v - med) for v in recent])
    tol = max(th.rel_for(metric) * abs(med), th.abs_floor)
    if len(recent) >= th.mad_min_samples:
        tol = max(tol, th.mad_k * 1.4826 * mad)
    delta = float(value) - med
    worse = delta if direction < 0 else -delta
    status = "regressed" if worse > tol else \
        "improved" if worse < -tol else "flat"
    return replace(base, status=status, baseline=med, baseline_n=len(recent),
                   mad=mad, tol=tol, delta=delta,
                   rel_delta=delta / abs(med) if med else 0.0)


def check_db(db, sha: str | None = None,
             thresholds: Thresholds | None = None) -> list:
    """Gate the candidate run against the trajectory.

    Candidate = the last point of each series, but only where that point
    belongs to `sha` (default: the SHA of the most recently appended record
    — "the run that just landed"). Series whose freshest point is from an
    older run are NOT judged: a bench that didn't re-run this time has no
    fresh evidence either way. Baseline = the points before the candidate
    in append order. Returns one Verdict per gated series, regressions
    first, then by (bench, row, metric)."""
    sha = sha or db.latest_sha()
    out = []
    for key, recs in sorted(db.series().items()):
        cand = recs[-1]
        if sha is not None and cand.git_sha != sha:
            continue
        v = classify([r.value for r in recs[:-1]], cand.value, cand.metric,
                     thresholds)
        out.append(replace(v, bench=cand.bench, row=cand.row,
                           device_kind=cand.device_kind,
                           git_sha=cand.git_sha))
    rank = {"regressed": 0, "improved": 1, "flat": 2, "no-baseline": 3,
            "ungated": 4}
    out.sort(key=lambda v: (rank.get(v.status, 9), v.bench, v.row, v.metric))
    return out


def diff_db(db, sha_a: str, sha_b: str) -> list:
    """Per-series comparison of two commits: the LATEST point of each
    series at each SHA (a commit benchmarked twice counts its freshest
    measurement). Only series present at both SHAs appear. Each entry is a
    JSON-ready dict with the delta signed in raw units and the classified
    direction, so a `diff` can be read without knowing the metric zoo."""
    out = []
    for key, recs in sorted(db.series().items()):
        at_a = [r for r in recs if r.git_sha == sha_a]
        at_b = [r for r in recs if r.git_sha == sha_b]
        if not at_a or not at_b:
            continue
        a, b = at_a[-1].value, at_b[-1].value
        bench, row, metric, dev = key
        d = metric_direction(metric)
        better = None
        if d != 0 and a != b:
            better = ((b < a) if d < 0 else (b > a))
        out.append({"bench": bench, "row": row, "metric": metric,
                    "device_kind": dev, "a": a, "b": b, "delta": b - a,
                    "rel_delta": (b - a) / abs(a) if a else 0.0,
                    "direction": d,
                    "better": better})
    return out
