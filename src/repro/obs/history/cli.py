"""repro-bench: the perf-history CLI (DESIGN.md §13).

    PYTHONPATH=src python -m repro.obs.history.cli <cmd> --db DB ...

Commands:
- `ingest DB-relative BENCH files / directories` — append (dedup'd) points;
- `diff <shaA> <shaB>` — per-series values of two commits side by side;
- `check [files...]`   — gate the latest run (optionally ingesting the
  given BENCH files first) against each series' rolling baseline; exits
  nonzero iff any gated metric regressed — THE CI gate;
- `report`             — trend tables (terminal or --markdown) and/or the
  self-contained HTML dashboard (--html PATH).

Exit codes: 0 ok, 1 regression detected (check), 2 usage/data errors.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.obs.history.baseline import Thresholds, check_db, diff_db
from repro.obs.history.db import BenchDB
from repro.obs.history.report import html_report, trend_table


def _ingest_paths(db: BenchDB, paths) -> dict:
    """Files and/or directories; directories expand to their BENCH_*.json."""
    out = {}
    for p in paths:
        if os.path.isdir(p):
            out.update(db.ingest_dir(p))
        else:
            out[os.path.basename(p)] = db.ingest_file(p)
    return out


def _thresholds(args) -> Thresholds:
    return Thresholds(rel_noisy=args.rel_noisy, rel_exact=args.rel_exact,
                      mad_k=args.mad_k, min_samples=args.min_samples,
                      mad_min_samples=args.mad_min_samples,
                      window=args.window)


def cmd_ingest(args) -> int:
    db = BenchDB(args.db)
    counts = _ingest_paths(db, args.paths)
    for name, n in sorted(counts.items()):
        print(f"{name}: {n} new point(s)")
    print(f"{args.db}: {len(db)} total points, "
          f"{len(db.series())} series, {len(db.shas())} commits")
    return 0


def cmd_diff(args) -> int:
    db = BenchDB(args.db)
    rows = diff_db(db, args.sha_a, args.sha_b)
    if args.json:
        json.dump({"a": args.sha_a, "b": args.sha_b, "series": rows},
                  sys.stdout, indent=2)
        print()
        return 0
    if not rows:
        print(f"no series present at both {args.sha_a} and {args.sha_b}")
        return 2
    print(f"{'series':<58} {args.sha_a:>12} {args.sha_b:>12} {'delta':>9}")
    for r in rows:
        name = f"{r['bench']}/{r['row']}/{r['metric']}"
        mark = "" if r["better"] is None else \
            (" (better)" if r["better"] else " (worse)")
        print(f"{name:<58} {r['a']:>12.4g} {r['b']:>12.4g} "
              f"{r['rel_delta']:>+8.1%}{mark}")
    return 0


def cmd_check(args) -> int:
    db = BenchDB(args.db)
    if args.paths:
        _ingest_paths(db, args.paths)
    if not len(db):
        print("empty DB: nothing to check", file=sys.stderr)
        return 2
    verdicts = check_db(db, sha=args.sha, thresholds=_thresholds(args))
    regressed = [v for v in verdicts if v.status == "regressed"]
    counts: dict = {}
    for v in verdicts:
        counts[v.status] = counts.get(v.status, 0) + 1
    if args.json:
        json.dump({"sha": args.sha or db.latest_sha(), "counts": counts,
                   "regressed": len(regressed),
                   "verdicts": [v.to_json() for v in verdicts]},
                  sys.stdout, indent=2)
        print()
    else:
        for v in verdicts:
            if v.status in ("regressed", "improved"):
                print(f"{v.status.upper():>10}  {v.bench}/{v.row}/{v.metric}"
                      f"  {v.baseline:.4g} -> {v.value:.4g}"
                      f" ({v.rel_delta:+.1%}, tol {v.tol:.4g})")
        print(f"checked {len(verdicts)} series at "
              f"{args.sha or db.latest_sha()}: " +
              ", ".join(f"{counts.get(s, 0)} {s}" for s in
                        ("regressed", "improved", "flat", "no-baseline",
                         "ungated")))
    return 1 if regressed else 0


def cmd_report(args) -> int:
    db = BenchDB(args.db)
    if not len(db):
        print("empty DB: nothing to report", file=sys.stderr)
        return 2
    if args.html:
        with open(args.html, "w") as f:
            f.write(html_report(db, last=args.last))
        print(f"wrote {args.html}")
    if args.html is None or args.tables:
        print(trend_table(db, last=args.last, markdown=args.markdown))
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro-bench", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("ingest", help="append BENCH payloads to the DB")
    p.add_argument("--db", required=True, help="BenchDB JSONL path")
    p.add_argument("paths", nargs="+",
                   help="BENCH_*.json files and/or directories of them")
    p.set_defaults(fn=cmd_ingest)

    p = sub.add_parser("diff", help="compare two commits series by series")
    p.add_argument("--db", required=True)
    p.add_argument("sha_a")
    p.add_argument("sha_b")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser(
        "check", help="gate the latest run vs rolling baselines (CI gate)")
    p.add_argument("--db", required=True)
    p.add_argument("paths", nargs="*",
                   help="BENCH files/dirs to ingest before checking")
    p.add_argument("--sha", default=None,
                   help="candidate SHA (default: the most recently "
                        "appended record's)")
    p.add_argument("--rel-noisy", type=float, default=Thresholds.rel_noisy,
                   help="relative tolerance for wall-clock metrics")
    p.add_argument("--rel-exact", type=float, default=Thresholds.rel_exact,
                   help="relative tolerance for deterministic metrics")
    p.add_argument("--mad-k", type=float, default=Thresholds.mad_k,
                   help="MAD multiplier (sigmas) for the noise band")
    p.add_argument("--min-samples", type=int, default=Thresholds.min_samples,
                   help="baseline points required before a series gates")
    p.add_argument("--mad-min-samples", type=int,
                   default=Thresholds.mad_min_samples,
                   help="baseline points required before the MAD term can "
                        "widen the band")
    p.add_argument("--window", type=int, default=Thresholds.window,
                   help="rolling-baseline window (points)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_check)

    p = sub.add_parser("report", help="trend tables / HTML dashboard")
    p.add_argument("--db", required=True)
    p.add_argument("--markdown", action="store_true",
                   help="markdown table instead of aligned text")
    p.add_argument("--html", default=None, metavar="PATH",
                   help="write the self-contained HTML dashboard here")
    p.add_argument("--tables", action="store_true",
                   help="with --html: also print the terminal table")
    p.add_argument("--last", type=int, default=10,
                   help="trend window (points per series)")
    p.set_defaults(fn=cmd_report)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except (OSError, ValueError) as e:
        print(f"repro-bench: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    raise SystemExit(main())
