"""Rendering: trend tables (terminal / markdown) and the HTML dashboard.

Both views answer the same question — "where is each series heading, and
did the latest run move it?" — at two fidelities: the table is grep-able
CI-log output (unicode sparkline per series, verdict column), the HTML
report is a single self-contained file (inline CSS + inline SVG
sparklines, zero external assets) that uploads as one CI artifact and
opens anywhere.
"""
from __future__ import annotations

import html as _html

from repro.obs.history.baseline import Thresholds, check_db

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values) -> str:
    """Unicode trend strip: each value binned into the series' own
    min..max range (shape, not scale — the table's value columns carry the
    scale)."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    if hi <= lo:
        return _SPARK[0] * len(vals)
    return "".join(_SPARK[min(int((v - lo) / (hi - lo) * (len(_SPARK) - 1)),
                              len(_SPARK) - 1)] for v in vals)


def _fmt(v: float) -> str:
    a = abs(v)
    if a != 0 and (a >= 1e5 or a < 1e-3):
        return f"{v:.3g}"
    return f"{v:.4g}"


def _series_rows(db, last: int):
    """(series_key, records-window, verdict-status) per series, with the
    verdict map built once from the default-threshold check of the latest
    run."""
    verdicts = {(v.bench, v.row, v.metric, v.device_kind): v
                for v in check_db(db, thresholds=Thresholds())}
    for key, recs in sorted(db.series().items()):
        yield key, recs[-last:], verdicts.get(key)


def trend_table(db, last: int = 10, markdown: bool = False) -> str:
    """One line per series: trend sparkline over the last `last` points,
    latest value, delta vs the rolling baseline, and the verdict of the
    most recent run (blank for series the latest run didn't touch)."""
    header = ["series", "n", "trend", "latest", "baseline", "delta", "verdict"]
    rows = []
    for (bench, row, metric, dev), recs, v in _series_rows(db, last):
        name = f"{bench}/{row}/{metric}" + \
            (f" [{dev}]" if dev != "unknown" else "")
        vals = [r.value for r in recs]
        if v is not None and v.status not in ("no-baseline", "ungated"):
            base, delta = _fmt(v.baseline), f"{v.rel_delta:+.1%}"
            verdict = v.status
        else:
            base, delta = "-", "-"
            verdict = v.status if v is not None else ""
        rows.append([name, str(len(recs)), sparkline(vals), _fmt(vals[-1]),
                     base, delta, verdict])
    if markdown:
        lines = ["| " + " | ".join(header) + " |",
                 "|" + "|".join("---" for _ in header) + "|"]
        lines += ["| " + " | ".join(r) + " |" for r in rows]
        return "\n".join(lines)
    widths = [max(len(header[i]), *(len(r[i]) for r in rows)) if rows
              else len(header[i]) for i in range(len(header))]
    lines = ["  ".join(h.ljust(w) for h, w in zip(header, widths))]
    lines += ["  ".join(c.ljust(w) for c, w in zip(r, widths)) for r in rows]
    return "\n".join(lines)


def _svg_spark(values, width: int = 160, height: int = 28) -> str:
    """Inline SVG polyline of a series (newest right), last point dotted."""
    vals = [float(v) for v in values]
    if len(vals) == 1:
        vals = vals * 2
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    pad = 3
    pts = []
    for i, v in enumerate(vals):
        x = pad + i * (width - 2 * pad) / max(len(vals) - 1, 1)
        y = height - pad - (v - lo) / span * (height - 2 * pad)
        pts.append(f"{x:.1f},{y:.1f}")
    lx, ly = pts[-1].split(",")
    return (f'<svg width="{width}" height="{height}" class="spark">'
            f'<polyline fill="none" stroke="currentColor" stroke-width="1.5" '
            f'points="{" ".join(pts)}"/>'
            f'<circle cx="{lx}" cy="{ly}" r="2.5" fill="currentColor"/></svg>')


_CSS = """
body{font:14px/1.5 -apple-system,Segoe UI,Roboto,sans-serif;margin:2rem;
     color:#1a1a1a;background:#fff}
h1{font-size:1.3rem} h2{font-size:1.05rem;margin:1.6rem 0 .4rem}
table{border-collapse:collapse;width:100%}
th,td{text-align:left;padding:.25rem .6rem;border-bottom:1px solid #e5e5e5;
      white-space:nowrap}
th{font-weight:600;border-bottom:2px solid #bbb}
td.num{font-variant-numeric:tabular-nums}
.spark{color:#4878d0;vertical-align:middle}
.regressed{color:#b4231f;font-weight:600}
.improved{color:#1c7c3c;font-weight:600}
.flat{color:#777}.no-baseline,.ungated{color:#aaa}
.meta{color:#777;font-size:.85rem}
"""


def html_report(db, title: str = "repro-bench perf history",
                last: int = 20) -> str:
    """The whole DB as ONE self-contained HTML page: a section per bench,
    a row per series with an SVG sparkline, the latest value/baseline/
    delta, and the latest run's verdict — colored so a regressed metric is
    findable without reading numbers."""
    sections: dict = {}
    for (bench, row, metric, dev), recs, v in _series_rows(db, last):
        sections.setdefault(bench, []).append((row, metric, dev, recs, v))
    shas = db.shas()
    parts = ["<!doctype html><html><head><meta charset='utf-8'>",
             f"<title>{_html.escape(title)}</title>",
             f"<style>{_CSS}</style></head><body>",
             f"<h1>{_html.escape(title)}</h1>",
             f"<p class='meta'>{len(db)} points · "
             f"{len(db.series())} series · {len(shas)} commits"
             + (f" · latest {_html.escape(shas[-1])}" if shas else "")
             + "</p>"]
    for bench in sorted(sections):
        parts.append(f"<h2>{_html.escape(bench)}</h2>")
        parts.append("<table><tr><th>row</th><th>metric</th><th>trend</th>"
                     "<th>latest</th><th>baseline</th><th>delta</th>"
                     "<th>verdict</th></tr>")
        for row, metric, dev, recs, v in sections[bench]:
            vals = [r.value for r in recs]
            label = _html.escape(metric) + \
                (f" <span class='meta'>[{_html.escape(dev)}]</span>"
                 if dev != "unknown" else "")
            if v is not None and v.status not in ("no-baseline", "ungated"):
                base, delta = _fmt(v.baseline), f"{v.rel_delta:+.1%}"
                status = v.status
            else:
                base, delta = "–", "–"
                status = v.status if v is not None else ""
            parts.append(
                f"<tr><td>{_html.escape(row)}</td><td>{label}</td>"
                f"<td>{_svg_spark(vals)}</td>"
                f"<td class='num'>{_fmt(vals[-1])}</td>"
                f"<td class='num'>{base}</td><td class='num'>{delta}</td>"
                f"<td class='{status}'>{status}</td></tr>")
        parts.append("</table>")
    parts.append("</body></html>")
    return "\n".join(parts)
