"""Exporters: the repo's OTHER measurement products as BenchDB rows.

`payload_records` covers the BENCH_*.json files; this module covers the
three in-process sources the ISSUE makes first-class series — the engine's
telemetry snapshot, the profiler's per-impl digest, and the calibration
DB's fitted scales — each rendered as `write_bench_json`-shaped row dicts
so one `make_payload` + `BenchDB.ingest_payload` call lands them in the
same trajectory as the benchmark sweeps (same stamps, same gate).
"""
from __future__ import annotations

from repro.obs.history.db import run_context


def make_payload(name: str, rows, extra: dict | None = None) -> dict:
    """A BENCH-shaped payload stamped with the CURRENT run context (git
    SHA, UTC timestamp, jax/jaxlib versions, device kind/platform) — the
    in-process twin of `benchmarks/_util.write_bench_json`, for records
    that never pass through a file."""
    ctx = run_context()
    payload = {"name": name, "schema": "name,us_per_call,derived",
               "git_sha": ctx["git_sha"], "timestamp": ctx["timestamp"],
               "versions": ctx["versions"],
               "device_kind": ctx["device_kind"],
               "platform": ctx["platform"],
               "rows": list(rows)}
    if extra:
        payload.update(extra)
    return payload


def telemetry_rows(snapshot: dict, prefix: str = "engine") -> list:
    """`Engine.stats()["telemetry"]` (a `MetricsTracker.snapshot()`) as
    history rows: the scalar serving health of one engine under one row
    name, so p50/p95/p99, fill, and the re-plan counters become series a
    regression gate can watch. The unbounded sub-structures (occupancy
    timeline, event log) stay in the BENCH extras — a trajectory point is
    a scalar."""
    lat = snapshot.get("latency", {}) or {}
    replans = snapshot.get("replans", {}) or {}
    row = {"name": prefix,
           "submitted": snapshot.get("submitted", 0),
           "completed": snapshot.get("completed", 0),
           "batches": snapshot.get("batches", 0),
           "pad_samples": snapshot.get("pad_samples", 0),
           "mean_fill": snapshot.get("mean_fill", 0.0),
           "service_s_total": snapshot.get("service_s_total", 0.0),
           "p50_ms": lat.get("p50_ms", 0.0),
           "p95_ms": lat.get("p95_ms", 0.0),
           "p99_ms": lat.get("p99_ms", 0.0),
           "mean_ms": lat.get("mean_ms", 0.0),
           "max_ms": lat.get("max_ms", 0.0),
           "replan_triggers": replans.get("triggers", 0),
           "replan_swaps": replans.get("swaps", 0),
           "replan_errors": replans.get("errors", 0),
           "hot_swaps": replans.get("hot_swaps", 0),
           "verify_rejects": replans.get("verify_rejects", 0)}
    return [row]


def profile_rows(report) -> list:
    """A `repro.obs.profile.ProfileReport` as history rows: one row per
    (kind, impl) group (measured total + median predicted/measured ratio —
    the calibration-fit input) plus one agreement row (top1/pairwise — the
    cost-model-accuracy series `benchmarks/cost_model.py` floors)."""
    summary = report.summary()
    rows = []
    for key, grp in sorted(summary["per_impl"].items()):
        rows.append({"name": f"profile/{summary['graph']}/{key}",
                     "layers": grp["layers"],
                     "measured_us_total": grp["measured_us_total"],
                     "ratio_median": grp["ratio_median"]})
    agr = summary["agreement"]
    rows.append({"name": f"profile/{summary['graph']}/agreement",
                 "top1_agreement": agr["top1"],
                 "pairwise_agreement": agr["pairwise"],
                 "layers": agr["layers"]})
    return rows


def calibration_rows(db) -> list:
    """A `repro.obs.calibrate.CalibrationDB` as history rows: per fitted
    (device, kind, impl, geometry) key the efficiency scale and its
    residual spread — the series that shows a kernel's measured efficiency
    (or the fit's explanatory power) drifting across commits."""
    from repro.obs.calibrate import _fmt_tkey

    rows = []
    for (dev, kind, impl, tk), e in sorted(db.entries.items()):
        rows.append({"name": f"calib/{dev}/{kind}/{impl}/{_fmt_tkey(tk)}",
                     "scale": e.scale,
                     "resid_spread": e.resid_spread,
                     "n_samples": e.n_samples})
    return rows
