"""Perf history: the cross-run BENCH trajectory store and regression gate.

Every benchmark module already emits a git-SHA/version-stamped
``BENCH_*.json`` — one attributable perf point per run — but until this
package nothing ever READ two of them side by side: the trajectory existed
only as loose artifacts, so the paper's headline speedups were re-measured
from scratch every session and regressions landed silently. This is the
layer that turns those one-shot measurements into a monitored time series
(DESIGN.md §13):

- `db`        `BenchDB`: an append-only JSONL store (no new deps) of typed
              per-(bench, row, metric, device_kind) series; each point
              carries the producing git SHA, UTC timestamp, jax/jaxlib
              versions, and device kind, so CPU-interpret and real-TPU
              points never merge into one baseline;
- `baseline`  noise-aware verdicts: rolling median + MAD over the series
              window with minimum-sample guards, classifying each fresh
              point as regressed / improved / flat per metric, at
              per-noise-class thresholds (wall-clock metrics tolerate more
              than deterministic counters/agreement scores);
- `records`   exporters folding the OTHER measurement products into the
              same record schema: `Engine.stats()["telemetry"]` snapshots,
              `ProfileReport` per-impl ratio digests, `CalibrationDB`
              fitted scales + residual spreads;
- `report`    trend tables (terminal / markdown) and a static
              self-contained HTML dashboard with inline SVG sparklines;
- `cli`       `repro-bench` (`python -m repro.obs.history.cli`):
              `ingest`, `diff <shaA> <shaB>`, `check` (nonzero exit on
              regression — the CI gate), `report`.

Entry points: `benchmarks/run.py --json DIR --history DB` auto-ingests
after each module, `launch/serve_cnn.py --history DB` ingests the serving
summary + telemetry snapshot, and CI's `bench-history` job restores the
previous run's DB, ingests HEAD's BENCH files, and gates on
`repro-bench check`.
"""
from repro.obs.history.baseline import (
    Thresholds,
    Verdict,
    check_db,
    classify,
    diff_db,
    metric_direction,
    metric_noise_class,
)
from repro.obs.history.db import BenchDB, BenchRecord, payload_records, run_context
from repro.obs.history.records import (
    calibration_rows,
    make_payload,
    profile_rows,
    telemetry_rows,
)
from repro.obs.history.report import html_report, trend_table

__all__ = [
    "BenchDB",
    "BenchRecord",
    "Thresholds",
    "Verdict",
    "calibration_rows",
    "check_db",
    "classify",
    "diff_db",
    "html_report",
    "make_payload",
    "metric_direction",
    "metric_noise_class",
    "payload_records",
    "profile_rows",
    "run_context",
    "telemetry_rows",
    "trend_table",
]
