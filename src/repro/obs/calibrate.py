"""CalibrationDB: measured effective roofline constants per (device, impl).

The datasheet constants in `repro.obs.constants` describe what the chip CAN
do; the planner needs what each impl DOES — interpret-mode Pallas on CPU,
an XLA conv, and a gathered sparse kernel on a real accelerator sit at
wildly different fractions of the roofline, and the dense-vs-sparse
crossover moves with them (the measured-not-assumed point of
Pietroń & Żurek, arXiv:2011.06295). The DB stores, per

    (device kind x op kind x impl x tile geometry)        — PlanKey-style

an EFFECTIVE `RooflineConstants` pair fitted from `profile_plan`
measurements, and every modeled time in the repo (`unit_model_us`,
`plan_model_us`, `plan_network`'s occupancy-rule and BSR-displacement
arbitration) consults it through an explicit `calibration=` parameter — the
hard-coded defaults remain the fallback for any key the DB does not cover,
so an EMPTY DB reproduces the uncalibrated behavior bit-identically.

The geometry axis is the full `TileConfig` 5-tuple key (block_c, block_o,
bt, bf, bd); the pre-tile (block_c,)-keyed entries embed as
(block_c, 0, 0, 0, 0), which is also how a v1 JSON loads. Lookup walks
exact tile -> block_c-only -> geometry-agnostic (all-zero), so a coarse fit
covers finer keys until one is measured.

The DB also carries the TILE-SEARCH winners table (`put_tile`/`best_tile`):
per (device, op kind, impl, layer shape) the measured-best `TileConfig` key
that `obs.tilesearch` found — this is the persisted half of the
measure -> search -> plan loop, consulted by `plan_network(tiles=...)` so a
plan built tomorrow starts from today's measured-best geometry.

Fit model: one efficiency scalar per key. A kernel is assumed to run at a
fixed fraction `s` of the datasheet roofline (both ceilings scaled
together), so `s = median over layers of (modeled_default_us /
measured_us)` and the effective constants are `defaults x s`. The median
makes the fit robust to one outlier layer; the per-key residual spread is
recorded so a caller can see when one scalar does NOT explain an impl's
behavior across shapes (the cue to split the block-geometry key further).

Persistence is plain JSON (`save`/`load`) so a calibration survives across
processes and ships next to BENCH artifacts.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass

from repro.obs.constants import DEFAULT_ROOFLINE, RooflineConstants


def device_kind() -> str:
    """The running device's kind string (the DB's device axis)."""
    import jax

    dev = jax.devices()[0]
    return getattr(dev, "device_kind", dev.platform)


def unit_shape_key(unit) -> tuple:
    """The layer-shape key the tile winners table is indexed by: everything
    that determines a conv unit's kernel geometry problem — (c, h, w, o, k,
    stride, pool). Duck-typed over `graph.ir.ConvUnit` so obs stays free of
    a graph import; two units with equal keys face the identical search
    space, whatever network they sit in."""
    c, h, w = unit.in_shape
    conv = unit.conv
    pool = unit.pool.p if unit.pool is not None else 0
    return (int(c), int(h), int(w), int(conv.c_out), int(conv.k),
            int(conv.stride), int(pool))


def _tile_key(block_c: int = 0, tile=None) -> tuple:
    """Normalize (block_c, tile) to the canonical 5-tuple geometry key."""
    if tile is not None and tile:
        return tuple(int(v) for v in tile.key())
    return (int(block_c), 0, 0, 0, 0)


def _fmt_tkey(tkey: tuple) -> str:
    if not any(tkey[1:]):
        return f"bc{tkey[0]}"
    return "t" + ".".join(str(v) for v in tkey)


@dataclass(frozen=True)
class CalibEntry:
    """One fitted key: the effective constants plus fit diagnostics."""

    peak_flops: float
    hbm_bw: float
    scale: float  # fitted efficiency vs the datasheet defaults
    n_samples: int
    resid_spread: float  # (max-min)/median of the per-layer ratios

    def constants(self) -> RooflineConstants:
        return RooflineConstants(self.peak_flops, self.hbm_bw)


class CalibrationDB:
    """{(device_kind, kind, impl, tile_key): CalibEntry} with default fallback,
    plus {(device_kind, kind, impl, shape_key): tile_key} search winners.

    `lookup` tries the exact tile geometry first, then the block_c-only key
    (a fit at one channel-block size covers searched (block_o, bt, bf, bd)
    refinements until one is measured), then the geometry-agnostic all-zero
    key, then gives up (None -> caller uses the defaults).
    `device` pins the device axis; entries fitted on other device kinds are
    never consulted (a CPU calibration must not steer a TPU plan).
    """

    def __init__(self, entries: dict | None = None, device: str | None = None,
                 tiles: dict | None = None):
        self.entries: dict = dict(entries or {})
        self.tiles: dict = dict(tiles or {})
        self.device = device

    def __len__(self) -> int:
        return len(self.entries)

    def __bool__(self) -> bool:
        # an empty DB is falsy ON PURPOSE: `calibration or None` normalizes
        # "no calibration" and "nothing fitted yet" to the same fallback;
        # a DB holding only tile winners still counts as calibration
        return bool(self.entries) or bool(self.tiles)

    def _device(self) -> str:
        if self.device is None:
            self.device = device_kind()
        return self.device

    def put(self, kind: str, impl: str, block_c: int, entry: CalibEntry,
            device: str | None = None, tile=None) -> None:
        key = (device or self._device(), kind, impl, _tile_key(block_c, tile))
        self.entries[key] = entry

    def lookup(self, kind: str, impl: str, block_c: int = 0,
               device: str | None = None, tile=None) -> RooflineConstants | None:
        dev = device or self._device()
        tkey = _tile_key(block_c, tile)
        chain = [tkey]
        if any(tkey[1:]):
            chain.append((tkey[0], 0, 0, 0, 0))  # block_c-only fit
        if tkey[0] != 0 or any(tkey[1:]):
            chain.append((0, 0, 0, 0, 0))  # geometry-agnostic fit
        for k in chain:
            e = self.entries.get((dev, kind, impl, k))
            if e is not None:
                return e.constants()
        return None

    def covers(self, kind: str, impl: str, block_c: int = 0,
               device: str | None = None, tile=None) -> bool:
        return self.lookup(kind, impl, block_c, device, tile=tile) is not None

    def constants_for(self, kind: str, impl: str, block_c: int = 0,
                      device: str | None = None, tile=None) -> RooflineConstants:
        """The effective constants for a key: calibrated, else the defaults
        (the one resolution rule every modeled time goes through)."""
        return self.lookup(kind, impl, block_c, device, tile=tile) \
            or DEFAULT_ROOFLINE

    # -- tile-search winners ---------------------------------------------------

    def put_tile(self, kind: str, impl: str, shape_key: tuple, tile,
                 device: str | None = None) -> None:
        """Record the measured-best geometry for one (impl, layer shape).
        `tile` is a TileConfig (or its 5-tuple key); an all-zero/None tile
        means "defaults won" and ERASES any stored winner instead of storing
        a no-op row."""
        key = (device or self._device(), kind, impl, tuple(shape_key))
        tkey = _tile_key(0, tile) if not isinstance(tile, tuple) else \
            tuple(int(v) for v in tile)
        if not any(tkey):
            self.tiles.pop(key, None)
        else:
            self.tiles[key] = tkey

    def best_tile(self, kind: str, impl: str, shape_key: tuple,
                  device: str | None = None):
        """The stored winner as a `TileConfig`, or None when the defaults are
        (or are assumed) best — callers can pass the result straight to
        `run_unit(..., tile=...)` either way."""
        tkey = self.tiles.get(
            (device or self._device(), kind, impl, tuple(shape_key)))
        if tkey is None:
            return None
        from repro.kernels.tiles import TileConfig

        return TileConfig.from_key(tkey)

    # -- fitting -------------------------------------------------------------

    def fit_report(self, report) -> "CalibrationDB":
        """Fold a `ProfileReport` in: one entry per (kind, impl, geometry)
        group, scale = median(predicted_default / measured) (see module
        docstring). Returns self (chainable)."""
        for (kind, impl), rows in report.by_impl().items():
            by_tk: dict = {}
            for t in rows:
                tk = tuple(getattr(t, "tile", ()) or ()) \
                    or (int(t.block_c), 0, 0, 0, 0)
                by_tk.setdefault(tk, []).append(t)
            for tk, grp in by_tk.items():
                ratios = sorted(t.ratio for t in grp)
                s = _median(ratios)
                if s <= 0.0:
                    continue  # degenerate measurement; keep the defaults
                spread = (ratios[-1] - ratios[0]) / max(s, 1e-12)
                self.entries[(report.device_kind, kind, impl, tk)] = CalibEntry(
                    peak_flops=DEFAULT_ROOFLINE.peak_flops * s,
                    hbm_bw=DEFAULT_ROOFLINE.hbm_bw * s,
                    scale=float(s), n_samples=len(grp),
                    resid_spread=float(spread))
        if self.device is None:
            self.device = report.device_kind
        return self

    @classmethod
    def from_report(cls, report) -> "CalibrationDB":
        return cls(device=report.device_kind).fit_report(report)

    # -- persistence ----------------------------------------------------------

    def to_json(self) -> dict:
        return {"schema": "calibration-v2", "device": self.device,
                "entries": [
                    {"device": d, "kind": k, "impl": i, "tile": list(tk),
                     **asdict(e)}
                    for (d, k, i, tk), e in sorted(self.entries.items())],
                "tiles": [
                    {"device": d, "kind": k, "impl": i, "shape": list(sk),
                     "tile": list(tk)}
                    for (d, k, i, sk), tk in sorted(self.tiles.items())]}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationDB":
        with open(path) as f:
            payload = json.load(f)
        schema = payload.get("schema")
        if schema not in ("calibration-v1", "calibration-v2"):
            raise ValueError(f"{path}: not a calibration DB "
                             f"(schema={schema!r})")
        db = cls(device=payload.get("device"))
        for row in payload["entries"]:
            # v1 rows carry "block_c"; v2 rows the full "tile" 5-tuple
            tk = tuple(row["tile"]) if "tile" in row else \
                (int(row["block_c"]), 0, 0, 0, 0)
            db.entries[(row["device"], row["kind"], row["impl"], tk)] = \
                CalibEntry(peak_flops=row["peak_flops"],
                           hbm_bw=row["hbm_bw"], scale=row["scale"],
                           n_samples=row["n_samples"],
                           resid_spread=row["resid_spread"])
        for row in payload.get("tiles", []):
            db.tiles[(row["device"], row["kind"], row["impl"],
                      tuple(row["shape"]))] = tuple(row["tile"])
        return db

    def history_rows(self) -> list:
        """The fitted entries as perf-history rows (scale + residual spread
        per key) — `repro.obs.history.calibration_rows(self)`, so kernel
        efficiency drift across commits is a gate-able BenchDB series
        (DESIGN.md §13)."""
        from repro.obs.history.records import calibration_rows

        return calibration_rows(self)

    def summary(self) -> dict:
        """JSON-ready digest (scales per key) for logs and BENCH extras."""
        out = {f"{d}/{k}/{i}/{_fmt_tkey(tk)}": round(e.scale, 6)
               for (d, k, i, tk), e in sorted(self.entries.items())}
        for (d, k, i, sk), tk in sorted(self.tiles.items()):
            out[f"{d}/{k}/{i}/shape{'x'.join(map(str, sk))}"] = \
                _fmt_tkey(tk)
        return out


def _median(sorted_vals) -> float:
    n = len(sorted_vals)
    if n == 0:
        return 0.0
    return float(sorted_vals[n // 2]) if n % 2 else \
        float((sorted_vals[n // 2 - 1] + sorted_vals[n // 2]) / 2)
