"""CalibrationDB: measured effective roofline constants per (device, impl).

The datasheet constants in `repro.obs.constants` describe what the chip CAN
do; the planner needs what each impl DOES — interpret-mode Pallas on CPU,
an XLA conv, and a gathered sparse kernel on a real accelerator sit at
wildly different fractions of the roofline, and the dense-vs-sparse
crossover moves with them (the measured-not-assumed point of
Pietroń & Żurek, arXiv:2011.06295). The DB stores, per

    (device kind x op kind x impl x block geometry)      — PlanKey-style

an EFFECTIVE `RooflineConstants` pair fitted from `profile_plan`
measurements, and every modeled time in the repo (`unit_model_us`,
`plan_model_us`, `plan_network`'s occupancy-rule and BSR-displacement
arbitration) consults it through an explicit `calibration=` parameter — the
hard-coded defaults remain the fallback for any key the DB does not cover,
so an EMPTY DB reproduces the uncalibrated behavior bit-identically.

Fit model: one efficiency scalar per key. A kernel is assumed to run at a
fixed fraction `s` of the datasheet roofline (both ceilings scaled
together), so `s = median over layers of (modeled_default_us /
measured_us)` and the effective constants are `defaults x s`. The median
makes the fit robust to one outlier layer; the per-key residual spread is
recorded so a caller can see when one scalar does NOT explain an impl's
behavior across shapes (the cue to split the block-geometry key further).

Persistence is plain JSON (`save`/`load`) so a calibration survives across
processes and ships next to BENCH artifacts.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass

from repro.obs.constants import DEFAULT_ROOFLINE, RooflineConstants


def device_kind() -> str:
    """The running device's kind string (the DB's device axis)."""
    import jax

    dev = jax.devices()[0]
    return getattr(dev, "device_kind", dev.platform)


@dataclass(frozen=True)
class CalibEntry:
    """One fitted key: the effective constants plus fit diagnostics."""

    peak_flops: float
    hbm_bw: float
    scale: float  # fitted efficiency vs the datasheet defaults
    n_samples: int
    resid_spread: float  # (max-min)/median of the per-layer ratios

    def constants(self) -> RooflineConstants:
        return RooflineConstants(self.peak_flops, self.hbm_bw)


class CalibrationDB:
    """{(device_kind, kind, impl, block_c): CalibEntry} with default fallback.

    `lookup` tries the exact block geometry first, then the geometry-agnostic
    `block_c=0` entry (a fit at auto block size covers explicit sizes until
    one is measured), then gives up (None -> caller uses the defaults).
    `device` pins the device axis; entries fitted on other device kinds are
    never consulted (a CPU calibration must not steer a TPU plan).
    """

    def __init__(self, entries: dict | None = None, device: str | None = None):
        self.entries: dict = dict(entries or {})
        self.device = device

    def __len__(self) -> int:
        return len(self.entries)

    def __bool__(self) -> bool:
        # an empty DB is falsy ON PURPOSE: `calibration or None` normalizes
        # "no calibration" and "nothing fitted yet" to the same fallback
        return bool(self.entries)

    def _device(self) -> str:
        if self.device is None:
            self.device = device_kind()
        return self.device

    def put(self, kind: str, impl: str, block_c: int, entry: CalibEntry,
            device: str | None = None) -> None:
        self.entries[(device or self._device(), kind, impl, int(block_c))] = entry

    def lookup(self, kind: str, impl: str, block_c: int = 0,
               device: str | None = None) -> RooflineConstants | None:
        dev = device or self._device()
        for bc in (int(block_c), 0):
            e = self.entries.get((dev, kind, impl, bc))
            if e is not None:
                return e.constants()
        return None

    def covers(self, kind: str, impl: str, block_c: int = 0,
               device: str | None = None) -> bool:
        return self.lookup(kind, impl, block_c, device) is not None

    def constants_for(self, kind: str, impl: str, block_c: int = 0,
                      device: str | None = None) -> RooflineConstants:
        """The effective constants for a key: calibrated, else the defaults
        (the one resolution rule every modeled time goes through)."""
        return self.lookup(kind, impl, block_c, device) or DEFAULT_ROOFLINE

    # -- fitting -------------------------------------------------------------

    def fit_report(self, report) -> "CalibrationDB":
        """Fold a `ProfileReport` in: one entry per (kind, impl, block_c)
        group, scale = median(predicted_default / measured) (see module
        docstring). Returns self (chainable)."""
        for (kind, impl), rows in report.by_impl().items():
            by_bc: dict = {}
            for t in rows:
                by_bc.setdefault(int(t.block_c), []).append(t)
            for bc, grp in by_bc.items():
                ratios = sorted(t.ratio for t in grp)
                s = _median(ratios)
                if s <= 0.0:
                    continue  # degenerate measurement; keep the defaults
                spread = (ratios[-1] - ratios[0]) / max(s, 1e-12)
                self.put(kind, impl, bc, CalibEntry(
                    peak_flops=DEFAULT_ROOFLINE.peak_flops * s,
                    hbm_bw=DEFAULT_ROOFLINE.hbm_bw * s,
                    scale=float(s), n_samples=len(grp),
                    resid_spread=float(spread)),
                    device=report.device_kind)
        if self.device is None:
            self.device = report.device_kind
        return self

    @classmethod
    def from_report(cls, report) -> "CalibrationDB":
        return cls(device=report.device_kind).fit_report(report)

    # -- persistence ----------------------------------------------------------

    def to_json(self) -> dict:
        return {"schema": "calibration-v1", "device": self.device,
                "entries": [
                    {"device": d, "kind": k, "impl": i, "block_c": bc,
                     **asdict(e)}
                    for (d, k, i, bc), e in sorted(self.entries.items())]}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationDB":
        with open(path) as f:
            payload = json.load(f)
        if payload.get("schema") != "calibration-v1":
            raise ValueError(f"{path}: not a calibration DB "
                             f"(schema={payload.get('schema')!r})")
        db = cls(device=payload.get("device"))
        for row in payload["entries"]:
            db.put(row["kind"], row["impl"], row["block_c"],
                   CalibEntry(peak_flops=row["peak_flops"],
                              hbm_bw=row["hbm_bw"], scale=row["scale"],
                              n_samples=row["n_samples"],
                              resid_spread=row["resid_spread"]),
                   device=row["device"])
        return db

    def summary(self) -> dict:
        """JSON-ready digest (scales per key) for logs and BENCH extras."""
        return {f"{d}/{k}/{i}/bc{bc}": round(e.scale, 6)
                for (d, k, i, bc), e in sorted(self.entries.items())}


def _median(sorted_vals) -> float:
    n = len(sorted_vals)
    if n == 0:
        return 0.0
    return float(sorted_vals[n // 2]) if n % 2 else \
        float((sorted_vals[n // 2 - 1] + sorted_vals[n // 2]) / 2)
