"""BSR weight format: the block geometry shared by pruning, planning and the
conv lowering.

Weight sparsity only pays on the MXU at *block* granularity (same argument as
DESIGN.md §2.1 for activations): the `kernels/bsr_matmul` Pallas kernel skips
whole (bt, bf) blocks of its LEFT operand via the scalar-prefetched
(ids, cnt) gather, so the pruner must zero whole blocks of the weight matrix
in exactly the tiling the kernel will later schedule. This module is the
single source of that geometry:

- a conv weight (O, C, kh, kw) is viewed as the GEMM operand W:(O, K) with
  K = C*kh*kw — the matrix `conv2d_bsr` hands the kernel as its sparse left
  operand (y^T = W @ patches^T, so sparsity varies along W's row-blocks =
  output-channel blocks, which is what a per-row-block schedule can express);
- `weight_block(o, k_taps)` picks the (bt, bf) block for that matrix — one
  deterministic function of the shape, so the pruner, the density
  measurement, the planner's cost model and the forward all agree without
  threading a block tuple through every call;
- `weight_block_density` is the achieved-density statistic everything above
  reports and `validate_plan` re-checks at run time.
"""
from __future__ import annotations

import jax.numpy as jnp


def _pow2_le(n: int) -> int:
    """Largest power of two <= n (n >= 1)."""
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def weight_block(o: int, k_taps: int) -> tuple:
    """(bt, bf) BSR block of an (O, K) weight matrix — callers pass the
    matrix shape so the geometry contract is explicit, though only K moves
    the answer today.

    bt = 8 rows always (the MXU sublane tile — matches `bsr_matmul`'s
    default; small O just pads, shrinking bt would change pruning
    granularity for no kernel benefit). bf is capped at the 128-lane tile
    but shrinks on small layers so a row-block still spans >= ~4 schedulable
    K-blocks: a reduced LeNet conv with K = 25 taps pruned at bf = 128 would
    be a single all-or-nothing block, which is no sparsity at all.
    """
    del o
    bf = max(8, min(128, _pow2_le(max(8, k_taps // 4))))
    return 8, bf


def conv_weight_matrix(w) -> jnp.ndarray:
    """(O, C, kh, kw) -> the (O, K) GEMM view `conv2d_bsr` runs (K = C*kh*kw,
    taps in (c, kh, kw) scan order — the same flattening `extract_windows`
    produces for the patches)."""
    o = w.shape[0]
    return w.reshape(o, -1)


def block_norms(m, block: tuple):
    """(n_row_blocks, n_col_blocks) L2 norms of the (bt, bf) blocks of a 2-D
    matrix (padded with zeros to block multiples — pad blocks norm 0)."""
    bt, bf = block
    r, c = m.shape
    mp = jnp.pad(m, ((0, (-r) % bt), (0, (-c) % bf)))
    nr, nc = mp.shape[0] // bt, mp.shape[1] // bf
    return jnp.sqrt((mp.reshape(nr, bt, nc, bf) ** 2).sum(axis=(1, 3)))


def matrix_block_density(m, block: tuple) -> float:
    """Fraction of (bt, bf) blocks of a 2-D matrix with any nonzero entry
    (every block overlaps real weight — a ragged edge pads by less than one
    block — so the grid size is the denominator)."""
    norms = block_norms(m, block)
    return float((norms > 0).sum()) / max(norms.size, 1)


def weight_block_density(w) -> float:
    """Achieved block density of one conv weight (O, C, kh, kw) — or of a
    dense-head weight (d_in, d_out), measured on its (d_out, d_in) GEMM
    orientation — at the layer's own `weight_block` tiling. 1.0 for any
    unpruned (fully dense) weight."""
    if w.ndim == 4:
        m = conv_weight_matrix(w)
    elif w.ndim == 2:
        m = w.T  # (d_out, d_in): rows = output features, like conv's O
    else:
        raise ValueError(f"weight_block_density expects a conv (O,C,kh,kw) or "
                         f"dense (d_in,d_out) weight, got shape {w.shape}")
    return matrix_block_density(m, weight_block(m.shape[0], m.shape[1]))
