"""Weight-sparsity subsystem (DESIGN.md §7): the static counterpart of the
activation-sparsity spine.

Three pieces, all keyed to the SAME block geometry (`format.weight_block`):

- `prune_graph_params` — offline magnitude pruning of a LayerGraph's
  conv/dense params to (bt, bf) block patterns, with a `PruneReport` of
  achieved per-layer density and probe logit drift;
- `conv2d_bsr` — the forward: im2col lowered onto the existing
  `kernels/bsr_matmul` Pallas kernel with the weight matrix as the sparse
  operand (registered as `("conv", "bsr")` in `repro.graph.registry`, cost
  hook `bsr_conv_cost`);
- the planner integration lives in `repro.pipeline.planner`: `plan_network`
  measures each layer's static weight block density next to its activation
  occupancy and picks dense/ECR/PECR/BSR per layer by modeled cost.
"""
from repro.sparse_weights.conv import bsr_conv_cost, conv2d_bsr, conv2d_bsr_ref
from repro.sparse_weights.format import (
    conv_weight_matrix,
    matrix_block_density,
    weight_block,
    weight_block_density,
)
from repro.sparse_weights.prune import (
    LayerPruneStat,
    PruneReport,
    prune_graph_params,
    prune_matrix,
)

__all__ = [
    "LayerPruneStat",
    "PruneReport",
    "bsr_conv_cost",
    "conv2d_bsr",
    "conv2d_bsr_ref",
    "conv_weight_matrix",
    "matrix_block_density",
    "prune_graph_params",
    "prune_matrix",
    "weight_block",
    "weight_block_density",
]
