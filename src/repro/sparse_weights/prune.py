"""Magnitude pruning of LayerGraph params to BSR block patterns.

Offline model surgery (numpy, not traced): per layer, rank the (bt, bf)
blocks of the weight's GEMM view by L2 norm and zero everything outside the
top ceil(density * n_blocks) — the block shape comes from
`format.weight_block`, so the zeros land exactly on the tiles the
`kernels/bsr_matmul` schedule can skip. The returned `PruneReport` carries
what serving actually needs to know before trusting a pruned model: the
achieved per-layer block density (coarse block grids on tiny layers quantize
hard — ceil(0.3 * 4 blocks) is half the layer, not 30%) and the logit drift
of the dense forward on a probe batch (the accuracy proxy available without
labels).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.graph.ir import graph_weights
from repro.sparse_weights.format import block_norms, conv_weight_matrix, weight_block


@dataclass(frozen=True)
class LayerPruneStat:
    """One pruned weight: what was asked for vs what the block grid allowed."""

    name: str  # "conv_1" / "dense_2"
    shape: tuple  # original weight shape
    block: tuple  # (bt, bf) tiling the zeros are aligned to
    target_density: float
    achieved_density: float  # kept_blocks / total_blocks (real blocks only)
    kept_blocks: int
    total_blocks: int


@dataclass(frozen=True)
class PruneReport:
    layers: tuple  # tuple[LayerPruneStat, ...]
    density: float  # block-weighted overall achieved density
    max_logit_drift: float | None = None  # max |dense(pruned) - dense(orig)|
    top1_agreement: float | None = None  # argmax match rate on the probe

    def by_name(self) -> dict:
        return {s.name: s for s in self.layers}


def prune_matrix(m, density: float, block: tuple):
    """Zero all but the top-|norm| ceil(density * n) (bt, bf) blocks of a 2-D
    matrix. Returns (pruned, kept_blocks, total_blocks); total counts only
    blocks that overlap real weight (not the zero padding of a ragged edge),
    and kept counts only LIVE blocks — density >= 1 leaves the values
    untouched but still reports the measured live-block count."""
    bt, bf = block
    m = np.asarray(m)
    r, c = m.shape
    nr, nc = -(-r // bt), -(-c // bf)
    total = nr * nc
    mp = np.zeros((nr * bt, nc * bf), m.dtype)
    mp[:r, :c] = m
    # the ranking statistic comes from format.block_norms — the one owner of
    # the block geometry — so the prune pattern can never diverge from what
    # weight_block_density / the planner cost model will measure
    norms = np.asarray(block_norms(m, block))
    keep = int(np.ceil(np.clip(density, 0.0, 1.0) * total))
    mask = np.zeros(total, bool)
    if keep:
        # stable top-k by descending norm: ties break on block scan order, so
        # the same weights always prune to the same pattern
        order = np.argsort(-norms.ravel(), kind="stable")
        mask[order[:keep]] = True
    # a zero-norm block ranked into the top-k (already-dead weight, e.g. a
    # re-pruned checkpoint) is not a LIVE block: dropping it from the mask
    # keeps kept_blocks equal to what weight_block_density — the value the
    # planner and validate_plan consume — will actually measure
    mask &= norms.ravel() > 0
    mask = mask.reshape(nr, nc)
    mp = mp.reshape(nr, bt, nc, bf) * mask[:, None, :, None]
    return mp.reshape(nr * bt, nc * bf)[:r, :c], int(mask.sum()), total


def prune_graph_params(params, density: float, graph=None, *,
                       per_layer: dict | None = None, prune_dense: bool = True,
                       probe=None):
    """Prune a params dict to BSR block patterns at a per-layer target density.

    params: graph-native {"conv": [...], "dense": [...]} or the legacy VGG
    layout (anything `graph_weights` reads); the pruned params come back
    graph-native. `density` is the default target for every layer;
    `per_layer` overrides it for individual conv layers by 0-based conv index
    (the paper-style schedule where early layers stay denser). Dense-head
    weights are pruned at the default target unless `prune_dense=False` —
    zeros flow through the head's plain GEMMs for free, so this is a model
    -size/accuracy knob, not an executor change.

    `probe` (optional (N,C,H,W) batch, requires `graph`) measures accuracy
    drift: the max |Δlogit| and top-1 agreement of the dense forward before
    vs after pruning. Returns (pruned_params, PruneReport).
    """
    conv_ws, dense_ws = graph_weights(params)
    per_layer = per_layer or {}
    stats = []
    new_conv = []
    for i, w in enumerate(conv_ws):
        target = float(per_layer.get(i, density))
        mat = np.asarray(conv_weight_matrix(w))
        block = weight_block(mat.shape[0], mat.shape[1])
        pruned, kept, total = prune_matrix(mat, target, block)
        new_conv.append(jnp.asarray(pruned.reshape(w.shape), w.dtype))
        stats.append(LayerPruneStat(
            name=f"conv_{i + 1}", shape=tuple(w.shape), block=block,
            target_density=target, achieved_density=kept / total,
            kept_blocks=kept, total_blocks=total))
    new_dense = []
    for i, w in enumerate(dense_ws):
        if not prune_dense:
            new_dense.append(w)
            continue
        mat = np.asarray(w).T  # (d_out, d_in), rows = outputs like conv's O
        block = weight_block(mat.shape[0], mat.shape[1])
        pruned, kept, total = prune_matrix(mat, float(density), block)
        new_dense.append(jnp.asarray(pruned.T, w.dtype))
        stats.append(LayerPruneStat(
            name=f"dense_{i + 1}", shape=tuple(w.shape), block=block,
            target_density=float(density), achieved_density=kept / total,
            kept_blocks=kept, total_blocks=total))
    pruned_params = {"conv": new_conv, "dense": new_dense}
    kept = sum(s.kept_blocks for s in stats)
    total = sum(s.total_blocks for s in stats)
    drift = agree = None
    if probe is not None:
        if graph is None:
            raise ValueError("prune_graph_params needs graph= to measure "
                             "probe logit drift")
        from repro.graph import as_graph
        from repro.graph.executor import run_graph

        g = as_graph(graph)
        ref = np.asarray(run_graph(g, params, probe, impl="dense"))
        got = np.asarray(run_graph(g, pruned_params, probe, impl="dense"))
        drift = float(np.abs(got - ref).max())
        agree = float((got.argmax(-1) == ref.argmax(-1)).mean())
    return pruned_params, PruneReport(
        layers=tuple(stats), density=kept / max(total, 1),
        max_logit_drift=drift, top1_agreement=agree)
