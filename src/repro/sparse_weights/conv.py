"""conv2d_bsr: weight-block-sparse convolution via im2col onto `bsr_matmul`.

The activation kernels (DESIGN.md §2) skip work the *input* happens to make
zero; this is the complementary static axis — work the *pruner* made zero.
Lowering: im2col the (padded) input into patches A:(P, K), view the weight as
W:(O, K) (K = C*kh*kw), and compute

    y^T = W @ A^T

on the existing `kernels/bsr_matmul` Pallas kernel with W as the sparse LEFT
operand: the (ids, cnt) schedule — `block_schedule` over W's (bt, bf) blocks,
a compile-time constant once the weights are, since pruning is offline —
gathers only the live weight blocks, so a pruned-away block costs neither the
weight DMA, nor the MXU MACs, nor the DMA of the patch block it would have
multiplied (the A^T BlockSpec is indexed by the same ids). Orienting the
sparse operand as W (row-blocks = output-channel blocks) is what makes the
kernel's per-row-block schedule express per-output-channel-block raggedness;
A as the left operand would need a per-COLUMN schedule the kernel does not
have.

`conv2d_bsr_ref` is the pure-JAX ground truth: dense lax conv on the same
(pruned) weights — zeros contribute zero, so the two must agree to float32
tolerance on ANY weights, pruned or not.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparsity import extract_windows
from repro.kernels.bsr_matmul.kernel import bsr_matmul_pallas
from repro.kernels.bsr_matmul.ops import block_schedule
from repro.kernels.schedule_guard import guard_schedule
from repro.kernels.tiles import BsrLaunch, resolve_bsr_tile
from repro.sparse_weights.format import conv_weight_matrix


def bsr_conv_launch(o: int, k_taps: int, p: int, *, tile=None,
                    dtype_bytes: int = 4, kernel: str = "bsr_matmul",
                    acc_dtype: str = "float32",
                    weight_scales: str = "none") -> BsrLaunch:
    """The resolved `BsrLaunch` descriptor of one conv2d_bsr call: the
    (O, K) weight against (K, P) patches at `resolve_bsr_tile`'s geometry —
    exactly the resolution the op executes with (it reads its block sizes
    back out of this record), so the static checker sees the real grid."""
    bt, bf, bd = resolve_bsr_tile(o, k_taps, p, tile)
    tp, fp, dp = (-o) % bt, (-k_taps) % bf, (-p) % bd
    return BsrLaunch(
        kernel=kernel, t=o, f=k_taps, d=p, bt=bt, bf=bf, bd=bd,
        t_pad=tp, f_pad=fp, d_pad=dp, nt=(o + tp) // bt,
        nf=(k_taps + fp) // bf, nd=(p + dp) // bd, dtype_bytes=dtype_bytes,
        acc_dtype=acc_dtype, weight_scales=weight_scales)


def conv2d_bsr_ref(x, w, stride: int = 1):
    """Dense-on-(possibly-pruned)-weights reference: lax conv, VALID padding.
    (C,H,W) -> (O,oh,ow) or (N,C,H,W) -> (N,O,oh,ow)."""
    from repro.core.ecr import conv2d_dense

    return conv2d_dense(x, w, stride)


@partial(jax.jit, static_argnames=("stride", "interpret", "tile"))
def conv2d_bsr(x, w, stride: int = 1, interpret: bool = True, tile=None):
    """Weight-block-sparse conv. x: (C,H,W) or (N,C,H,W) already padded
    (VALID semantics, like every registry conv forward); w: (O,C,kh,kw).
    Returns float32 (O,oh,ow) / (N,O,oh,ow).

    Activation sparsity is NOT exploited here — every patch is read. The
    planner's job is exactly this trade: BSR wins when the static weight
    density undercuts the measured activation occupancy (`plan_network`'s
    joint cost comparison), and loses to ECR/PECR on very sparse inputs.

    `tile` (a `repro.kernels.tiles.TileConfig`) overrides the (bt, bf, bd)
    block geometry per dimension (`resolve_bsr_tile`'s fallback contract);
    the (ids, cnt) schedule is computed on the actual weight VALUES at the
    resolved tiling, so any geometry is numerically exact — a tile finer
    than the pruner's `weight_block` just skips MORE blocks, a coarser one
    fewer.
    """
    single = x.ndim == 3
    if single:
        x = x[None]
    n = x.shape[0]
    o, c, kh, kw = w.shape
    wins = jax.vmap(lambda xi: extract_windows(xi, kh, kw, stride))(
        x.astype(jnp.float32))  # (N, oh, ow, K)
    _, oh, ow, k_taps = wins.shape
    a = wins.reshape(n * oh * ow, k_taps)  # (P, K) patches
    wm = conv_weight_matrix(w).astype(jnp.float32)  # (O, K)
    p = a.shape[0]
    launch = bsr_conv_launch(o, k_taps, p, tile=tile)
    bt, bf, bd = launch.bt, launch.bf, launch.bd
    wm_p = jnp.pad(wm, ((0, launch.t_pad), (0, launch.f_pad)))
    at_p = jnp.pad(a, ((0, launch.d_pad), (0, launch.f_pad))).T  # (Kp, Pp)
    ids, cnt = block_schedule(wm_p, bt, bf)
    ids, cnt = guard_schedule(ids, cnt, launch.nf)
    yt = bsr_matmul_pallas(wm_p, at_p, ids, cnt, block=(bt, bf, bd),
                           interpret=interpret)  # (Op, Pp) = y^T
    y = yt[:o, :p].T.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)
    return y[0] if single else y


def bsr_conv_cost(c: int, h: int, w: int, o: int, kh: int = 3, kw: int = 3, *,
                  stride: int = 1, occupancy: float = 1.0, batch: int = 1,
                  weight_density: float = 1.0, dtype_bytes: int = 4) -> dict:
    """Modeled FLOPs / HBM bytes of the BSR conv at a static weight block
    density — the op-level cost hook `("conv", "bsr")` registers, mirroring
    `ecr_conv_cost` with the sparsity on the other operand.

    Models the production lowering, where the im2col extension is folded into
    the gather DMA (the same way the ECR kernel's window extension is
    implicit): a dead weight block skips its MACs, its weight bytes AND the
    activation taps it would have read — so activation bytes scale by
    `weight_density`, not by the (ignored) activation `occupancy`, and the
    weight read amortizes by 1/batch like every kernel tensor
    (DESIGN.md §2.4). Spatial dims are the padded input.
    """
    del occupancy  # BSR reads every window: activation sparsity buys nothing
    oh, ow = (h - kh) // stride + 1, (w - kw) // stride + 1
    wd = weight_density
    flops = 2.0 * oh * ow * o * c * kh * kw * wd * batch
    act_bytes = wd * c * h * w * dtype_bytes * batch
    out_bytes = o * oh * ow * dtype_bytes * batch
    k_bytes = wd * o * c * kh * kw * dtype_bytes  # read once per batch
    return {"flops": flops, "bytes": act_bytes + out_bytes + k_bytes,
            "out_elems": o * oh * ow * batch}
