"""TileConfig: kernel tile geometry as a first-class, searched quantity.

Every Pallas kernel in this repo tiles its operands — the ECR/PECR conv
grids over (block_c input-channel, block_o output-channel) blocks, the BSR
matmul over (bt, bf, bd) blocks — and until now every one of those sizes was
a hard-coded constant (`block_o=128` everywhere, BSR pinned at
`(8, 128, 128)`, `_pick_block_c` a static fp32-only heuristic). The paper's
own results say that is always wrong somewhere: which geometry wins is
shape- and occupancy-dependent (Figs 9/11), so geometry must be a *planned*
quantity like the impl choice itself.

This module is the single owner of that geometry:

- `TileConfig` — one frozen, hashable record of every tile knob (0 = "use
  the current default"), threaded from `obs.tilesearch` winners through
  `CalibrationDB` -> `plan_network` -> `LayerPlan.tile` -> `run_unit` ->
  the kernel ops. An all-zero TileConfig is falsy and means "defaults",
  so legacy `block_c`-only call paths stay bit-identical.
- `resolve_conv_tile` — THE (bc, bo) defaulting rule the ECR and PECR ops
  used to duplicate, now shared (and `dtype_bytes`-aware: the VMEM budget
  is in bytes, so int8 activations fit 4x wider channel blocks).
- `resolve_bsr_tile` — the (bt, bf, bd) rule for the BSR lowering, with the
  same contract.

Divisibility fallback contract: a requested tile dimension that does not
conform to the operand (larger than the dimension it tiles, or <= 0) falls
back to the CURRENT default for that dimension — never an error, and never
a silently different schedule than the default path would run. Dimensions
the requested tile *does* conform to are honored exactly; the ops pad the
operand up to a block multiple, so conforming means "no more than one
block of padding", the same rule the hand-fixed defaults satisfy. This is
also the rule `planner.occupancy_stat` and `channel_block_occupancy`
resolve through, so the measured statistic and the executed schedule can
never disagree about the block size (the geometry bug this file fixed).

Stdlib-only (no jax import): sits below kernels/, graph/ and obs/ in the
import graph so every layer can share it.
"""
from __future__ import annotations

from dataclasses import dataclass

VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # conservative half of v5e VMEM for x tile


@dataclass(frozen=True)
class TileConfig:
    """One kernel-geometry choice. 0 anywhere = the current default.

    block_c / block_o: ECR/PECR conv input- and output-channel block sizes.
    bt / bf / bd:      BSR matmul row- / reduction- / column-block sizes
                       (weight output-channel blocks, K-tap blocks, patch
                       blocks in the conv lowering).
    An all-zero config is falsy ("all defaults") so `tile or fallback`
    composes with the legacy block_c-only plumbing.
    """

    block_c: int = 0
    block_o: int = 0
    bt: int = 0
    bf: int = 0
    bd: int = 0

    def key(self) -> tuple:
        """The hashable 5-tuple the CalibrationDB / PlanKey key on."""
        return (self.block_c, self.block_o, self.bt, self.bf, self.bd)

    def __bool__(self) -> bool:
        return any(self.key())

    @classmethod
    def from_key(cls, key) -> "TileConfig":
        bc, bo, bt, bf, bd = (int(v) for v in key)
        return cls(block_c=bc, block_o=bo, bt=bt, bf=bf, bd=bd)


DEFAULT_TILE = TileConfig()


def as_tile(tile=None, block_c: int = 0) -> TileConfig:
    """Normalize the (tile, legacy block_c) pair every threaded call site
    carries: an explicit non-default tile wins, else block_c lifts into one."""
    if tile:
        return tile
    return TileConfig(block_c=int(block_c)) if block_c else DEFAULT_TILE


def pick_block_c(h: int, w: int, c: int, dtype_bytes: int = 4) -> int:
    """Largest power-of-two channel block whose (h, w, bc) activation tile
    fits the VMEM budget — `dtype_bytes` matters: int8 activations fit 4x
    the channels of fp32 at the same spatial extent."""
    bc = 128
    while bc > 8 and h * w * bc * dtype_bytes > VMEM_BUDGET_BYTES:
        bc //= 2
    return bc


def resolve_block_c(h: int, w: int, c: int, tile: TileConfig | None = None,
                    dtype_bytes: int = 4) -> int:
    """The ECR/PECR channel-block size actually run for a (C, h, w) input.

    A requested block_c is honored iff 0 < block_c <= max(8, c) (at most one
    block of channel padding — the same bound the default satisfies);
    anything else falls back to the default policy: the VMEM-budget pick,
    clamped so a small layer is at most one block."""
    bc = tile.block_c if tile is not None else 0
    if bc <= 0 or bc > max(8, c):
        bc = min(pick_block_c(h, w, c, dtype_bytes), max(8, c))
    return bc


def resolve_conv_tile(h: int, w: int, c: int, o: int,
                      tile: TileConfig | None = None,
                      dtype_bytes: int = 4) -> tuple:
    """(bc, bo) for the ECR / PECR conv ops — the one defaulting rule both
    `ecr_conv` and `fused_conv_pool` resolve through (they used to carry
    duplicated copies). bo is clamped into [.., max(8, o)] like the
    hand-fixed default always was; a non-positive request means default."""
    bc = resolve_block_c(h, w, c, tile, dtype_bytes)
    bo = tile.block_o if tile is not None and tile.block_o > 0 else 128
    bo = min(bo, max(8, o))
    return bc, bo


def resolve_bsr_tile(o: int, k_taps: int, p: int,
                     tile: TileConfig | None = None) -> tuple:
    """(bt, bf, bd) for the BSR conv lowering of an (O, K) weight against
    (K, P) patches. Defaults are `sparse_weights.format.weight_block` for
    (bt, bf) — the geometry the pruner aligned its zeros to — and the
    largest power of two <= min(128, P) for bd. Each requested dimension is
    honored iff 0 < dim <= max(8, its operand extent); a non-conforming
    dimension falls back to ITS default independently (a good bf request
    must not be discarded because bd was silly)."""
    from repro.sparse_weights.format import _pow2_le, weight_block

    dbt, dbf = weight_block(o, k_taps)
    dbd = _pow2_le(min(128, max(1, p)))
    if tile is None:
        return dbt, dbf, dbd
    bt = tile.bt if 0 < tile.bt <= max(8, o) else dbt
    bf = tile.bf if 0 < tile.bf <= max(8, k_taps) else dbf
    bd = tile.bd if 0 < tile.bd <= max(8, p) else dbd
    return bt, bf, bd
