"""TileConfig: kernel tile geometry as a first-class, searched quantity.

Every Pallas kernel in this repo tiles its operands — the ECR/PECR conv
grids over (block_c input-channel, block_o output-channel) blocks, the BSR
matmul over (bt, bf, bd) blocks — and until now every one of those sizes was
a hard-coded constant (`block_o=128` everywhere, BSR pinned at
`(8, 128, 128)`, `_pick_block_c` a static fp32-only heuristic). The paper's
own results say that is always wrong somewhere: which geometry wins is
shape- and occupancy-dependent (Figs 9/11), so geometry must be a *planned*
quantity like the impl choice itself.

This module is the single owner of that geometry:

- `TileConfig` — one frozen, hashable record of every tile knob (0 = "use
  the current default"), threaded from `obs.tilesearch` winners through
  `CalibrationDB` -> `plan_network` -> `LayerPlan.tile` -> `run_unit` ->
  the kernel ops. An all-zero TileConfig is falsy and means "defaults",
  so legacy `block_c`-only call paths stay bit-identical.
- `resolve_conv_tile` — THE (bc, bo) defaulting rule the ECR and PECR ops
  used to duplicate, now shared (and `dtype_bytes`-aware: the VMEM budget
  is in bytes, so int8 activations fit 4x wider channel blocks).
- `resolve_bsr_tile` — the (bt, bf, bd) rule for the BSR lowering, with the
  same contract.

Divisibility fallback contract: a requested tile dimension that does not
conform to the operand (larger than the dimension it tiles, or <= 0) falls
back to the CURRENT default for that dimension — never an error, and never
a silently different schedule than the default path would run. Dimensions
the requested tile *does* conform to are honored exactly; the ops pad the
operand up to a block multiple, so conforming means "no more than one
block of padding", the same rule the hand-fixed defaults satisfy. This is
also the rule `planner.occupancy_stat` and `channel_block_occupancy`
resolve through, so the measured statistic and the executed schedule can
never disagree about the block size (the geometry bug this file fixed).

Stdlib-only (no jax import): sits below kernels/, graph/ and obs/ in the
import graph so every layer can share it.
"""
from __future__ import annotations

from dataclasses import dataclass

VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # conservative half of v5e VMEM for x tile


@dataclass(frozen=True)
class TileConfig:
    """One kernel-geometry choice. 0 anywhere = the current default.

    block_c / block_o: ECR/PECR conv input- and output-channel block sizes.
    bt / bf / bd:      BSR matmul row- / reduction- / column-block sizes
                       (weight output-channel blocks, K-tap blocks, patch
                       blocks in the conv lowering).
    An all-zero config is falsy ("all defaults") so `tile or fallback`
    composes with the legacy block_c-only plumbing.
    """

    block_c: int = 0
    block_o: int = 0
    bt: int = 0
    bf: int = 0
    bd: int = 0

    def key(self) -> tuple:
        """The hashable 5-tuple the CalibrationDB / PlanKey key on."""
        return (self.block_c, self.block_o, self.bt, self.bf, self.bd)

    def __bool__(self) -> bool:
        return any(self.key())

    @classmethod
    def from_key(cls, key) -> "TileConfig":
        bc, bo, bt, bf, bd = (int(v) for v in key)
        return cls(block_c=bc, block_o=bo, bt=bt, bf=bf, bd=bd)


DEFAULT_TILE = TileConfig()


def as_tile(tile=None, block_c: int = 0) -> TileConfig:
    """Normalize the (tile, legacy block_c) pair every threaded call site
    carries: an explicit non-default tile wins, else block_c lifts into one."""
    if tile:
        return tile
    return TileConfig(block_c=int(block_c)) if block_c else DEFAULT_TILE


def pick_block_c(h: int, w: int, c: int, dtype_bytes: int = 4) -> int:
    """Largest power-of-two channel block whose (h, w, bc) activation tile
    fits the VMEM budget — `dtype_bytes` matters: int8 activations fit 4x
    the channels of fp32 at the same spatial extent."""
    bc = 128
    while bc > 8 and h * w * bc * dtype_bytes > VMEM_BUDGET_BYTES:
        bc //= 2
    return bc


def resolve_block_c(h: int, w: int, c: int, tile: TileConfig | None = None,
                    dtype_bytes: int = 4) -> int:
    """The ECR/PECR channel-block size actually run for a (C, h, w) input.

    A requested block_c is honored iff 0 < block_c <= max(8, c) (at most one
    block of channel padding — the same bound the default satisfies);
    anything else falls back to the default policy: the VMEM-budget pick,
    clamped so a small layer is at most one block."""
    bc = tile.block_c if tile is not None else 0
    if bc <= 0 or bc > max(8, c):
        bc = min(pick_block_c(h, w, c, dtype_bytes), max(8, c))
    return bc


def resolve_conv_tile(h: int, w: int, c: int, o: int,
                      tile: TileConfig | None = None,
                      dtype_bytes: int = 4) -> tuple:
    """(bc, bo) for the ECR / PECR conv ops — the one defaulting rule both
    `ecr_conv` and `fused_conv_pool` resolve through (they used to carry
    duplicated copies). bo is clamped into [.., max(8, o)] like the
    hand-fixed default always was; a non-positive request means default."""
    bc = resolve_block_c(h, w, c, tile, dtype_bytes)
    bo = tile.block_o if tile is not None and tile.block_o > 0 else 128
    bo = min(bo, max(8, o))
    return bc, bo


@dataclass(frozen=True)
class ConvLaunch:
    """Resolved launch geometry of one ECR / PECR conv kernel call.

    Built by `ecr_conv_launch` / `conv_pool_launch` (and their int8 siblings)
    from the SAME `resolve_conv_tile` resolution the op then executes with —
    the ops read their block sizes and paddings back out of this record, so
    the geometry the static checker (`repro.analysis.launch`) sees is by
    construction the geometry the Pallas grid runs. All fields are stored
    (not derived on access) so a corrupted descriptor is representable: the
    checker re-derives every expectation from the primitive extents and
    flags any disagreement.

    c/h/w are the input extents as the kernel sees them (h/w already carry
    the ConvSpec's spatial padding; c is pre-channel-pad), `pool` is the
    fused pool window (0 = unfused), `acc_dtype`/`weight_scales` record the
    accumulation/scale contract the int8 kernels must satisfy.
    """

    kernel: str  # "ecr_conv" | "conv_pool" | "ecr_conv_int8"
    batch: int
    c: int
    h: int
    w: int
    o: int
    kh: int
    kw: int
    stride: int
    pool: int  # fused pool window (0 = no fused epilogue)
    block_c: int
    block_o: int
    c_pad: int  # channel padding up to a block_c multiple
    o_pad: int  # output-channel padding up to a block_o multiple
    n_cb: int  # input-channel blocks = schedule length
    n_ob: int  # output-channel blocks = grid dim 0
    oh: int  # conv output spatial dims (pre-pool)
    ow: int
    dtype_bytes: int
    acc_dtype: str = "float32"
    weight_scales: str = "none"  # "none" | "per_output_channel"

    @property
    def grid(self) -> tuple:
        """(n_ob, batch, n_cb) — the batched Pallas grid."""
        return (self.n_ob, self.batch, self.n_cb)

    @property
    def x_tile_bytes(self) -> int:
        """One (h, w, block_c) activation tile — the VMEM-budget governor
        `pick_block_c` sizes against."""
        return self.h * self.w * self.block_c * self.dtype_bytes

    @property
    def scratch_bytes(self) -> int:
        """The (oh*ow, block_o) accumulator scratch (fp32/int32: 4 B)."""
        return self.oh * self.ow * self.block_o * 4


@dataclass(frozen=True)
class BsrLaunch:
    """Resolved launch geometry of one BSR matmul kernel call: a (t, f)
    sparse left operand against (f, d), tiled (bt, bf, bd). Built by
    `sparse_weights.conv.bsr_conv_launch` (t = output channels, f = K taps,
    d = patches) from the same `resolve_bsr_tile` call the op executes with;
    same stored-fields-vs-rederived-expectations contract as `ConvLaunch`."""

    kernel: str  # "bsr_matmul" | "bsr_matmul_int8"
    t: int
    f: int
    d: int
    bt: int
    bf: int
    bd: int
    t_pad: int
    f_pad: int
    d_pad: int
    nt: int  # row blocks (per-row-block (ids, cnt) schedules)
    nf: int  # reduction blocks = schedule width
    nd: int  # column blocks
    dtype_bytes: int
    acc_dtype: str = "float32"
    weight_scales: str = "none"

    @property
    def grid(self) -> tuple:
        """(nt, nd, nf) — reduction innermost, like the kernel."""
        return (self.nt, self.nd, self.nf)

    @property
    def tile_bytes(self) -> int:
        """Resident VMEM per grid step: one block of each operand + the
        (bt, bd) fp32/int32 accumulator scratch."""
        operands = (self.bt * self.bf + self.bf * self.bd) * self.dtype_bytes
        return operands + self.bt * self.bd * 4


def resolve_bsr_tile(o: int, k_taps: int, p: int,
                     tile: TileConfig | None = None) -> tuple:
    """(bt, bf, bd) for the BSR conv lowering of an (O, K) weight against
    (K, P) patches. Defaults are `sparse_weights.format.weight_block` for
    (bt, bf) — the geometry the pruner aligned its zeros to — and the
    largest power of two <= min(128, P) for bd. Each requested dimension is
    honored iff 0 < dim <= max(8, its operand extent); a non-conforming
    dimension falls back to ITS default independently (a good bf request
    must not be discarded because bd was silly)."""
    from repro.sparse_weights.format import _pow2_le, weight_block

    dbt, dbf = weight_block(o, k_taps)
    dbd = _pow2_le(min(128, max(1, p)))
    if tile is None:
        return dbt, dbf, dbd
    bt = tile.bt if 0 < tile.bt <= max(8, o) else dbt
    bf = tile.bf if 0 < tile.bf <= max(8, k_taps) else dbf
    bd = tile.bd if 0 < tile.bd <= max(8, p) else dbd
    return bt, bf, bd
