"""Pallas flash attention (GQA-aware), forward + backward.

This is the paper's PECR insight applied to attention (DESIGN.md §2): the
(qc, kc) score tile never leaves VMEM — only Q, K, V stream in and O streams
out, exactly like PECR's conv tile never reaching HBM. The dry-run roofline
showed score-tile materialization dominating the memory term of every train
cell; this kernel removes it (EXPERIMENTS.md §Perf iteration log).

Layouts (ops.py reshapes from the model's (B,S,KV,G,D)):
  q: (BKV, G, Sq, D)   k,v: (BKV, Sk, D)   out: (BKV, G, Sq, D)
GQA is native: the k/v BlockSpecs ignore the G grid axis, so each kv block is
DMA'd once per (q-block, group) pair without materializing repeated heads.

Backward = standard two-pass flash: dq accumulates over k blocks; dk/dv
accumulate over (g, q) blocks; scores are recomputed from (q, k, m, l) — no
S^2 residuals. fp32 accumulators in VMEM scratch throughout.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mask(qpos, kpos, causal: bool, kv_len):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if kv_len is not None:
        m &= (kpos < kv_len)[None, :]
    return m


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, m_sc, l_sc,
                *, scale, causal, q_offset, kv_len, nk, qc, kc):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0] * scale  # (qc, D)
    k = k_ref[0]  # (kc, D)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (qc, kc)
    qpos = q_offset + pl.program_id(2) * qc + jnp.arange(qc)
    kpos = ki * kc + jnp.arange(kc)
    s = jnp.where(_mask(qpos, kpos, causal, kv_len), s, NEG)
    m_prev, l_prev = m_sc[...], l_sc[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_sc[...] = m_new
    l_sc[...] = l_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        m_ref[0, 0] = m_sc[...]
        l_ref[0, 0] = l

def flash_fwd_pallas(q, k, v, *, scale, causal, q_offset=0, kv_len=None,
                     qc=256, kc=512, interpret=True):
    """q:(BKV,G,Sq,D) k,v:(BKV,Sk,D) -> (out, m, l) with m,l:(BKV,G,Sq)."""
    bkv, g, sq, d = q.shape
    sk = k.shape[1]
    qc = qc if sq % qc == 0 else sq
    kc = kc if sk % kc == 0 else sk
    nq, nk = sq // qc, sk // kc
    grid = (bkv, g, nq, nk)
    out_shapes = (
        jax.ShapeDtypeStruct((bkv, g, sq, d), q.dtype),
        jax.ShapeDtypeStruct((bkv, g, sq), jnp.float32),
        jax.ShapeDtypeStruct((bkv, g, sq), jnp.float32),
    )
    kern = partial(_fwd_kernel, scale=scale, causal=causal, q_offset=q_offset,
                   kv_len=kv_len, nk=nk, qc=qc, kc=kc)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qc, d), lambda b, g_, qi, ki: (b, g_, qi, 0)),
            pl.BlockSpec((1, kc, d), lambda b, g_, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kc, d), lambda b, g_, qi, ki: (b, ki, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, qc, d), lambda b, g_, qi, ki: (b, g_, qi, 0)),
            pl.BlockSpec((1, 1, qc), lambda b, g_, qi, ki: (b, g_, qi)),
            pl.BlockSpec((1, 1, qc), lambda b, g_, qi, ki: (b, g_, qi)),
        ),
        scratch_shapes=[
            pltpu.VMEM((qc, d), jnp.float32),
            pltpu.VMEM((qc,), jnp.float32),
            pltpu.VMEM((qc,), jnp.float32),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# quantized-KV forward (decode serving: int8 cache dequantized per-block in
# VMEM — K/V stream from HBM at 1 byte/elem, §Perf decode lever)
# ---------------------------------------------------------------------------


def _fwd_q8_kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, acc_ref, m_sc, l_sc,
                   *, scale, causal, q_offset, kv_len, nk, qc, kc):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32) * ks_ref[0][:, None]  # dequant in VMEM
    v = v_ref[0].astype(jnp.float32) * vs_ref[0][:, None]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    qpos = q_offset + pl.program_id(2) * qc + jnp.arange(qc)
    kpos = ki * kc + jnp.arange(kc)
    s = jnp.where(_mask(qpos, kpos, causal, kv_len), s, NEG)
    m_prev, l_prev = m_sc[...], l_sc[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = l_prev * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_fwd_q8_pallas(q, k_q8, v_q8, k_scale, v_scale, *, scale, causal,
                        q_offset=0, kv_len=None, qc=256, kc=512, interpret=True):
    """q:(BKV,G,Sq,D) bf16/f32; k_q8/v_q8:(BKV,Sk,D) int8; scales:(BKV,Sk)."""
    bkv, g, sq, d = q.shape
    sk = k_q8.shape[1]
    qc = qc if sq % qc == 0 else sq
    kc = kc if sk % kc == 0 else sk
    nq, nk = sq // qc, sk // kc
    kern = partial(_fwd_q8_kernel, scale=scale, causal=causal, q_offset=q_offset,
                   kv_len=kv_len, nk=nk, qc=qc, kc=kc)
    return pl.pallas_call(
        kern,
        grid=(bkv, g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qc, d), lambda b, g_, qi, ki: (b, g_, qi, 0)),
            pl.BlockSpec((1, kc, d), lambda b, g_, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kc, d), lambda b, g_, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kc), lambda b, g_, qi, ki: (b, ki)),
            pl.BlockSpec((1, kc), lambda b, g_, qi, ki: (b, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, qc, d), lambda b, g_, qi, ki: (b, g_, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((qc, d), jnp.float32),
            pltpu.VMEM((qc,), jnp.float32),
            pltpu.VMEM((qc,), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bkv, g, sq, d), q.dtype),
        interpret=interpret,
    )(q, k_q8, v_q8, k_scale, v_scale)


# ---------------------------------------------------------------------------
# backward: dq pass (accumulate over k blocks)
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dl_ref, dq_ref, acc,
               *, scale, causal, q_offset, kv_len, nk, qc, kc):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    q = q_ref[0, 0] * scale
    k = k_ref[0]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    qpos = q_offset + pl.program_id(2) * qc + jnp.arange(qc)
    kpos = ki * kc + jnp.arange(kc)
    s = jnp.where(_mask(qpos, kpos, causal, kv_len), s, NEG)
    p = jnp.exp(s - m_ref[0, 0][:, None]) / jnp.maximum(l_ref[0, 0], 1e-30)[:, None]
    dp = jnp.dot(do_ref[0, 0].astype(jnp.float32),
                 v.astype(jnp.float32).T, preferred_element_type=jnp.float32)
    ds = p * (dp - dl_ref[0, 0][:, None])  # (qc, kc)
    acc[...] += jnp.dot(ds.astype(k.dtype), k, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _flush():
        dq_ref[0, 0] = (acc[...] * scale).astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# backward: dk/dv pass (accumulate over g and q blocks)
# ---------------------------------------------------------------------------


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dl_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, scale, causal, q_offset, kv_len, ng, nq, qc, kc):
    gi = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when((gi == 0) & (qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0] * scale
    k = k_ref[0]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    qpos = q_offset + qi * qc + jnp.arange(qc)
    kpos = pl.program_id(1) * kc + jnp.arange(kc)
    s = jnp.where(_mask(qpos, kpos, causal, kv_len), s, NEG)
    p = jnp.exp(s - m_ref[0, 0][:, None]) / jnp.maximum(l_ref[0, 0], 1e-30)[:, None]
    do = do_ref[0, 0].astype(jnp.float32)
    dv_acc[...] += jnp.dot(p.T.astype(do.dtype), do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.astype(jnp.float32).T, preferred_element_type=jnp.float32)
    ds = p * (dp - dl_ref[0, 0][:, None])
    dk_acc[...] += jnp.dot(ds.T.astype(q.dtype), q, preferred_element_type=jnp.float32)

    @pl.when((gi == ng - 1) & (qi == nq - 1))
    def _flush():
        # q was pre-scaled when forming ds, so dk = ds^T @ (scale*q) already
        # carries the scale factor — no second multiplication here.
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_bwd_pallas(q, k, v, out, m, l, do, *, scale, causal, q_offset=0,
                     kv_len=None, qc=256, kc=512, interpret=True):
    bkv, g, sq, d = q.shape
    sk = k.shape[1]
    qc = qc if sq % qc == 0 else sq
    kc = kc if sk % kc == 0 else sk
    nq, nk = sq // qc, sk // kc
    # delta = rowsum(do * out) — tiny, compute in jnp
    dl = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        partial(_dq_kernel, scale=scale, causal=causal, q_offset=q_offset,
                kv_len=kv_len, nk=nk, qc=qc, kc=kc),
        grid=(bkv, g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qc, d), lambda b, g_, qi, ki: (b, g_, qi, 0)),
            pl.BlockSpec((1, kc, d), lambda b, g_, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, kc, d), lambda b, g_, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, 1, qc, d), lambda b, g_, qi, ki: (b, g_, qi, 0)),
            pl.BlockSpec((1, 1, qc), lambda b, g_, qi, ki: (b, g_, qi)),
            pl.BlockSpec((1, 1, qc), lambda b, g_, qi, ki: (b, g_, qi)),
            pl.BlockSpec((1, 1, qc), lambda b, g_, qi, ki: (b, g_, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, qc, d), lambda b, g_, qi, ki: (b, g_, qi, 0)),
        scratch_shapes=[pltpu.VMEM((qc, d), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v, do, m, l, dl)

    dk, dv = pl.pallas_call(
        partial(_dkv_kernel, scale=scale, causal=causal, q_offset=q_offset,
                kv_len=kv_len, ng=g, nq=nq, qc=qc, kc=kc),
        grid=(bkv, nk, g, nq),
        in_specs=[
            pl.BlockSpec((1, 1, qc, d), lambda b, ki, g_, qi: (b, g_, qi, 0)),
            pl.BlockSpec((1, kc, d), lambda b, ki, g_, qi: (b, ki, 0)),
            pl.BlockSpec((1, kc, d), lambda b, ki, g_, qi: (b, ki, 0)),
            pl.BlockSpec((1, 1, qc, d), lambda b, ki, g_, qi: (b, g_, qi, 0)),
            pl.BlockSpec((1, 1, qc), lambda b, ki, g_, qi: (b, g_, qi)),
            pl.BlockSpec((1, 1, qc), lambda b, ki, g_, qi: (b, g_, qi)),
            pl.BlockSpec((1, 1, qc), lambda b, ki, g_, qi: (b, g_, qi)),
        ],
        out_specs=(
            pl.BlockSpec((1, kc, d), lambda b, ki, g_, qi: (b, ki, 0)),
            pl.BlockSpec((1, kc, d), lambda b, ki, g_, qi: (b, ki, 0)),
        ),
        scratch_shapes=[
            pltpu.VMEM((kc, d), jnp.float32),
            pltpu.VMEM((kc, d), jnp.float32),
        ],
        out_shape=(
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ),
        interpret=interpret,
    )(q, k, v, do, m, l, dl)
    return dq, dk, dv
