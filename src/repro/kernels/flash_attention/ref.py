"""Oracle for the flash attention kernel: plain softmax attention in fp32."""
import jax.numpy as jnp


def attention_ref(q, k, v, *, scale, causal, q_offset=0, kv_len=None):
    """q:(BKV,G,Sq,D) k,v:(BKV,Sk,D) -> (BKV,G,Sq,D), fp32 math."""
    bkv, g, sq, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bgqd,bkd->bgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if kv_len is not None:
        mask &= (kpos < kv_len)[None, :]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bgqk,bkd->bgqd", p, v.astype(jnp.float32)).astype(q.dtype)
