"""custom_vjp wrapper: differentiable Pallas flash attention (GQA layout)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_bwd_pallas, flash_fwd_pallas


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_p(q, k, v, scale, causal, q_offset, kv_len, qc, kc):
    out, _, _ = flash_fwd_pallas(q, k, v, scale=scale, causal=causal,
                                 q_offset=q_offset, kv_len=kv_len, qc=qc, kc=kc)
    return out


def _fwd(q, k, v, scale, causal, q_offset, kv_len, qc, kc):
    out, m, l = flash_fwd_pallas(q, k, v, scale=scale, causal=causal,
                                 q_offset=q_offset, kv_len=kv_len, qc=qc, kc=kc)
    return out, (q, k, v, out, m, l)


def _bwd(scale, causal, q_offset, kv_len, qc, kc, res, do):
    q, k, v, out, m, l = res
    dq, dk, dv = flash_bwd_pallas(q, k, v, out, m, l, do, scale=scale,
                                  causal=causal, q_offset=q_offset, kv_len=kv_len,
                                  qc=qc, kc=kc)
    return dq, dk, dv


flash_attention_p.defvjp(_fwd, _bwd)


def flash_mha(q, k, v, *, causal=True, scale=None, q_offset=0, kv_len=None,
              qc=256, kc=512):
    """Model-facing entry: q (B,Sq,KV,G,D), k/v (B,Sk,KV,D) -> (B,Sq,KV,G,D).

    Folds (B,KV) into the kernel's BKV grid axis; GQA groups ride the G axis
    so K/V blocks are never repeated in HBM.
    """
    b, sq, kvh, g, d = q.shape
    sk = k.shape[1]
    scale = d ** -0.5 if scale is None else scale
    qk = q.transpose(0, 2, 3, 1, 4).reshape(b * kvh, g, sq, d)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, d)
    vk = v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, d)
    out = flash_attention_p(qk, kk, vk, scale, causal, q_offset, kv_len, qc, kc)
    return out.reshape(b, kvh, g, sq, d).transpose(0, 3, 1, 2, 4)
