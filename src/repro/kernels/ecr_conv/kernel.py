"""ECR sparse convolution on TPU — paper §IV adapted per DESIGN.md §2.

One `pallas_call` fuses what the GPU kernel fused: *extension* (windows are
formed by index arithmetic on the VMEM-resident tile — the im2col matrix never
exists), *compression* (the scalar-prefetched (ids, cnt) schedule — ECR's
F_data/Ptr at channel-block granularity), and the *SpMV* (per kernel tap, a
(OH*OW, bc) x (bc, bo) MXU contraction, accumulated in fp32 VMEM scratch).

Dead channel-blocks of the input feature map (ReLU kills whole channels —
measured in benchmarks/fig2_sparsity.py) are skipped: the gather index_map
repeats the last live block (no DMA re-issue) and `@pl.when(k < cnt)` skips
the MACs, exactly as Algorithm 2 bounds its loop by Ptr.

Layouts: x (H, W, C) / w (kh, kw, C, O) / out (OH, OW, O); the whole spatial
map is VMEM-resident per channel-block (the paper's shared-memory design —
its regime is the small, deep, very sparse layers; ops.py shrinks bc to fit a
VMEM budget for early layers). VALID padding; stride in {1,2,3} as evaluated
by the paper (Figs 9-10).

Batched form (`ecr_conv_pallas_batch`, DESIGN.md §2.4): grid (n_ob, N, n_cb)
— output-block j outermost, batch next — so the kernel tensor block for j is
revisited by every sample before j advances (the batch-level kernel reuse of
Shi & Chu), with a PER-SAMPLE (ids, cnt) schedule: ids is (N, n_cb) and
sample b skips its own dead channel blocks via `@pl.when(k < cnt[b])`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, kh, kw, stride, n_cb, oh, ow):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[0])
    def _mac():
        x = x_ref[...]  # (H, W, bc) — one channel block, full map (VMEM)
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    x,
                    (i, j, 0),
                    (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, x.shape[2]),
                    (stride, stride, 1),
                )  # (oh, ow, bc): the T-th window row, never materialized in HBM
                acc_ref[...] += jnp.dot(
                    patch.reshape(oh * ow, -1),
                    w_ref[i, j],
                    preferred_element_type=jnp.float32,
                )

    @pl.when(k == n_cb - 1)
    def _flush():
        o_ref[...] = acc_ref[...].reshape(oh, ow, -1).astype(o_ref.dtype)


def ecr_conv_pallas(
    x: jax.Array,  # (H, W, C)
    w: jax.Array,  # (kh, kw, C, O)
    ids: jax.Array,  # (n_cb,) live channel-block gather list
    cnt: jax.Array,  # (1,) number of live channel blocks
    *,
    stride: int = 1,
    block_c: int = 128,
    block_o: int = 128,
    interpret: bool = True,
    out_dtype=None,
) -> jax.Array:
    h, wd, c = x.shape
    kh, kw, c2, o = w.shape
    assert c == c2 and c % block_c == 0 and o % block_o == 0
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    n_cb, n_ob = c // block_c, o // block_o

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_ob, n_cb),
        in_specs=[
            pl.BlockSpec((h, wd, block_c), lambda j, k, ids, cnt: (0, 0, ids[k])),
            pl.BlockSpec((kh, kw, block_c, block_o), lambda j, k, ids, cnt: (0, 0, ids[k], j)),
        ],
        out_specs=pl.BlockSpec((oh, ow, block_o), lambda j, k, ids, cnt: (0, 0, j)),
        scratch_shapes=[pltpu.VMEM((oh * ow, block_o), jnp.float32)],
    )
    return pl.pallas_call(
        partial(_kernel, kh=kh, kw=kw, stride=stride, n_cb=n_cb, oh=oh, ow=ow),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((oh, ow, o), out_dtype or x.dtype),
        interpret=interpret,
    )(ids, cnt, x, w)


# ---------------------------------------------------------------------------
# Native batched grid (DESIGN.md §2.4)
# ---------------------------------------------------------------------------


def _kernel_batch(ids_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, kh, kw, stride, n_cb, oh, ow):
    b = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[b])
    def _mac():
        x = x_ref[0]  # (H, W, bc) — sample b's channel block ids[b, k]
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    x,
                    (i, j, 0),
                    (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, x.shape[2]),
                    (stride, stride, 1),
                )
                acc_ref[...] += jnp.dot(
                    patch.reshape(oh * ow, -1),
                    w_ref[i, j],
                    preferred_element_type=jnp.float32,
                )

    @pl.when(k == n_cb - 1)
    def _flush():
        o_ref[...] = acc_ref[...].reshape(1, oh, ow, -1).astype(o_ref.dtype)


def ecr_conv_pallas_batch(
    x: jax.Array,  # (N, H, W, C)
    w: jax.Array,  # (kh, kw, C, O) — shared across the batch
    ids: jax.Array,  # (N, n_cb) per-sample live channel-block gather lists
    cnt: jax.Array,  # (N,) per-sample live channel-block counts
    *,
    stride: int = 1,
    block_c: int = 128,
    block_o: int = 128,
    interpret: bool = True,
    out_dtype=None,
) -> jax.Array:
    n, h, wd, c = x.shape
    kh, kw, c2, o = w.shape
    assert c == c2 and c % block_c == 0 and o % block_o == 0
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    n_cb, n_ob = c // block_c, o // block_o

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_ob, n, n_cb),
        in_specs=[
            pl.BlockSpec((1, h, wd, block_c), lambda j, b, k, ids, cnt: (b, 0, 0, ids[b, k])),
            pl.BlockSpec((kh, kw, block_c, block_o), lambda j, b, k, ids, cnt: (0, 0, ids[b, k], j)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, block_o), lambda j, b, k, ids, cnt: (b, 0, 0, j)),
        scratch_shapes=[pltpu.VMEM((oh * ow, block_o), jnp.float32)],
    )
    return pl.pallas_call(
        partial(_kernel_batch, kh=kh, kw=kw, stride=stride, n_cb=n_cb, oh=oh, ow=ow),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, o), out_dtype or x.dtype),
        interpret=interpret,
    )(ids, cnt, x, w)
