"""Pure-jnp oracle for the ECR conv kernel: dense VALID conv, NCHW semantics."""
import jax
import jax.numpy as jnp


def ecr_conv_ref(x_chw, kernels_oihw, stride: int = 1):
    """(C,H,W) -> (O,oh,ow) or batched (N,C,H,W) -> (N,O,oh,ow), fp32 truth."""
    batched = x_chw.ndim == 4
    out = jax.lax.conv_general_dilated(
        (x_chw if batched else x_chw[None]).astype(jnp.float32),
        kernels_oihw.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out if batched else out[0]
