"""Jitted wrapper: channel-block occupancy ("compression") + pallas ECR conv.

Registered as ("conv", "ecr_pallas") in `repro.graph.registry` (forward =
`ecr_conv`, cost hook = `ecr_conv_cost`); the stride/kernel parameters a
`ConvSpec` carries flow straight through — the kernel supports any k and the
strides the paper evaluates (Figs 9-10) plus AlexNet's stride-4 first conv.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparsity import block_occupancy, compact_block_ids
from repro.kernels.ecr_conv.kernel import ecr_conv_pallas, ecr_conv_pallas_batch
from repro.kernels.schedule_guard import guard_schedule
from repro.kernels.tiles import (
    VMEM_BUDGET_BYTES,  # noqa: F401  (re-exported legacy name)
    ConvLaunch,
    TileConfig,
    pick_block_c as _pick_block_c,  # noqa: F401  (re-exported legacy name)
    resolve_conv_tile,
)


def ecr_conv_launch(c: int, h: int, w: int, o: int, kh: int = 3, kw: int = 3,
                    *, stride: int = 1, block_c: int = 0, block_o: int = 0,
                    tile: TileConfig | None = None, batch: int = 1,
                    dtype_bytes: int = 4, pool: int = 0,
                    kernel: str = "ecr_conv", acc_dtype: str = "float32",
                    weight_scales: str = "none") -> ConvLaunch:
    """The resolved `ConvLaunch` descriptor of one ECR conv call: block sizes
    through `resolve_conv_tile` (exactly the resolution `ecr_conv` executes
    with — the op reads its geometry back out of this record, so there is ONE
    derivation), paddings/blocks/output dims derived once. `tile` wins over
    the legacy (block_c, block_o) scalars; `pool`/`kernel`/`acc_dtype` are
    pass-throughs for the fused and int8 variants that share this builder."""
    t = tile if tile is not None else TileConfig(block_c=block_c, block_o=block_o)
    bc, bo = resolve_conv_tile(h, w, c, o, t, dtype_bytes=dtype_bytes)
    cp, op = (-c) % bc, (-o) % bo
    return ConvLaunch(
        kernel=kernel, batch=batch, c=c, h=h, w=w, o=o, kh=kh, kw=kw,
        stride=stride, pool=pool, block_c=bc, block_o=bo, c_pad=cp, o_pad=op,
        n_cb=(c + cp) // bc, n_ob=(o + op) // bo,
        oh=(h - kh) // stride + 1, ow=(w - kw) // stride + 1,
        dtype_bytes=dtype_bytes, acc_dtype=acc_dtype,
        weight_scales=weight_scales)


def batch_block_schedule(x_nhwc, h, w, bc):
    """Per-sample (ids, cnt) channel-block schedules for a batched (N,H,W,C')
    tensor: each sample skips its own dead blocks (ragged batch sparsity)."""
    n = x_nhwc.shape[0]
    occ = block_occupancy(x_nhwc, (h, w, bc)).reshape(n, -1)  # (N, n_cb)
    return jax.vmap(compact_block_ids)(occ)  # ids (N, n_cb), cnt (N,)


@partial(jax.jit, static_argnames=("stride", "interpret", "block_c", "block_o", "compact"))
def ecr_conv(x_chw, kernels_oihw, stride: int = 1, interpret: bool = True,
             block_c: int = 0, block_o: int = 0, compact: bool = True):
    """(C,H,W) x (O,C,kh,kw) -> (O,oh,ow), skipping dead input channel blocks.
    Batched: (N,C,H,W) -> (N,O,oh,ow) through the native batched grid.

    compact=True (default): ECR channel compaction first — live channels pack
    into a dense prefix so unstructured channel death still becomes contiguous
    skippable blocks (cnt = ceil(n_live / bc)). For a batch the pack uses one
    shared permutation (union of live channels — kernels stay shared) and
    per-sample raggedness is recovered by per-sample block schedules."""
    from repro.core.ecr import compact_live_channels, compact_live_channels_batch

    if x_chw.ndim == 2:
        x_chw = x_chw[None]
    if kernels_oihw.ndim == 3:
        kernels_oihw = kernels_oihw[None]
    batched = x_chw.ndim == 4
    c, h, w = x_chw.shape[-3:]
    o, c2, kh, kw = kernels_oihw.shape
    launch = ecr_conv_launch(c, h, w, o, kh, kw, stride=stride,
                             block_c=block_c, block_o=block_o,
                             batch=x_chw.shape[0] if batched else 1,
                             dtype_bytes=jnp.dtype(x_chw.dtype).itemsize)
    bc, bo = launch.block_c, launch.block_o
    cp, op, n_cb = launch.c_pad, launch.o_pad, launch.n_cb

    if batched:
        assert x_chw.shape[0] > 0, "empty batch: ecr_conv needs N >= 1"
        if compact:
            x_chw, kernels_oihw, _ = compact_live_channels_batch(x_chw, kernels_oihw)
        x = jnp.pad(x_chw, ((0, 0), (0, cp), (0, 0), (0, 0))).transpose(0, 2, 3, 1)
        wk = jnp.pad(kernels_oihw, ((0, op), (0, cp), (0, 0), (0, 0))).transpose(2, 3, 1, 0)
        ids, cnt = batch_block_schedule(x, h, w, bc)
        ids, cnt = guard_schedule(ids, cnt, n_cb)
        out = ecr_conv_pallas_batch(
            x, wk, ids, cnt, stride=stride, block_c=bc, block_o=bo,
            interpret=interpret,
        )
        return out.transpose(0, 3, 1, 2)[:, :o]  # (N, O, oh, ow)

    if compact:
        x_chw, kernels_oihw, n_live = compact_live_channels(x_chw, kernels_oihw)
    x = jnp.pad(x_chw, ((0, cp), (0, 0), (0, 0))).transpose(1, 2, 0)  # (H,W,C')
    wk = jnp.pad(kernels_oihw, ((0, op), (0, cp), (0, 0), (0, 0))).transpose(2, 3, 1, 0)
    if compact:
        ids = jnp.arange(n_cb, dtype=jnp.int32)  # identity: prefix is live
        cnt = jnp.minimum((n_live + bc - 1) // bc, n_cb).astype(jnp.int32)
    else:
        occ = block_occupancy(x, (h, w, bc)).reshape(-1)  # (n_cb,)
        ids, cnt = compact_block_ids(occ)
    ids, cnt = guard_schedule(ids, cnt, n_cb)
    out = ecr_conv_pallas(
        x, wk, ids, cnt[None], stride=stride, block_c=bc, block_o=bo,
        interpret=interpret
    )
    return out.transpose(2, 0, 1)[:o]  # (O, oh, ow)


def ecr_conv_cost(c: int, h: int, w: int, o: int, kh: int = 3, kw: int = 3, *,
                  stride: int = 1, occupancy: float = 1.0, batch: int = 1,
                  dtype_bytes: int = 4) -> dict:
    """Modeled FLOPs / HBM bytes of the gathered-schedule ECR conv at a given
    channel-block occupancy (occupancy=1.0 models the dense path).

    This is the op-level cost hook the serving autotuner falls back to when
    wall-clock timing is too noisy: the skipped blocks save BOTH the MACs and
    the activation/weight DMA (the (ids, cnt) schedule never issues them), and
    the kernel tensor's read amortizes by 1/batch across the batched grid
    (DESIGN.md §2.4). Spatial dims are the padded input (pass h+2/w+2 for the
    SAME 3x3 layers). Returns {"flops", "bytes"} totals for the whole batch.
    """
    oh, ow = (h - kh) // stride + 1, (w - kw) // stride + 1
    flops = 2.0 * oh * ow * o * c * kh * kw * occupancy * batch
    act_bytes = occupancy * c * h * w * dtype_bytes * batch
    out_bytes = o * oh * ow * dtype_bytes * batch
    k_bytes = occupancy * o * c * kh * kw * dtype_bytes  # read once per batch
    return {"flops": flops, "bytes": act_bytes + out_bytes + k_bytes,
            "out_elems": o * oh * ow * batch}


def channel_block_occupancy(x_chw, block_c: int = 128, compact: bool = False) -> float:
    """Fraction of live channel blocks = fraction of MXU/DMA work not skipped.

    Measured at the block size `ecr_conv` ACTUALLY resolves for this shape
    (the `resolve_conv_tile` fallback rule): a block_c that does not divide C
    pads the tail channels up to a block multiple — never the silent
    block-size-1 degradation this statistic used to report, which made the
    stat disagree with the executed schedule on every non-dividing shape.

    compact=True reports the post-channel-compaction occupancy the kernel
    actually runs at: ceil(n_live / bc) / n_blocks."""
    import math

    c, h, w = x_chw.shape
    bc = resolve_conv_tile(h, w, c, c, TileConfig(block_c=block_c))[0]
    n_cb = math.ceil(c / bc)
    if compact:
        n_live = int(jnp.any(x_chw != 0, axis=(1, 2)).sum())
        return math.ceil(n_live / bc) / n_cb
    xp = jnp.pad(x_chw, ((0, n_cb * bc - c), (0, 0), (0, 0)))
    occ = block_occupancy(xp.transpose(1, 2, 0), (h, w, bc))
    return float(occ.mean())
