"""Pure-jnp oracle for the block-sparse matmul kernel."""
import jax.numpy as jnp


def bsr_matmul_ref(h, w, out_dtype=None):
    """The dense-equivalent ground truth: zeros contribute zero."""
    return jnp.dot(
        h.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(out_dtype or h.dtype)


def bsr_matmul_schedule_ref(h, w, ids, cnt, block, out_dtype=None):
    """Executes the *schedule* (ids/cnt) literally — distinguishes schedule bugs
    from kernel bugs: must equal bsr_matmul_ref when ids/cnt cover all live
    blocks, by construction of ECR compaction."""
    bt, bf, bd = block
    t, f = h.shape
    _, d = w.shape
    nt, nf = t // bt, f // bf
    out = jnp.zeros((t, d), jnp.float32)
    for i in range(nt):
        acc = jnp.zeros((bt, d), jnp.float32)
        for k in range(int(cnt[i])):
            fb = int(ids[i, k])
            acc += h[i * bt : (i + 1) * bt, fb * bf : (fb + 1) * bf].astype(jnp.float32) @ w[
                fb * bf : (fb + 1) * bf
            ].astype(jnp.float32)
        out = out.at[i * bt : (i + 1) * bt].set(acc)
    return out.astype(out_dtype or h.dtype)
