"""Block-sparse (activation-sparse) matmul — ECR's compress-then-SpMV on the MXU.

y = h @ w where h:(T,F) carries data-dependent *block* sparsity (post-ReLU FFN
hidden states, dead channel blocks of feature maps, ...). The caller provides,
per (bt)-row-block, the ECR-style compacted schedule:

  ids:(nt,nf) int32 — ids[i,k] = index of the k-th LIVE f-block of row-block i,
                      padded by repeating the last live id (no re-DMA: Pallas
                      skips the copy when the mapped block index is unchanged);
  cnt:(nt,)   int32 — number of live f-blocks (ECR's Ptr at block granularity).

Grid = (nt, nd, nf), k innermost. The index_map gathers only live blocks
(scalar prefetch), and `@pl.when(k < cnt[i])` bounds the reduction exactly as
Algorithm 2 bounds its loop by Ptr — dead blocks cost neither DMA nor MXU
cycles on real hardware. fp32 accumulation in VMEM scratch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, cnt_ref, h_ref, w_ref, o_ref, acc_ref, *, nf: int):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[i])
    def _mac():
        acc_ref[...] += jnp.dot(
            h_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == nf - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bsr_matmul_pallas(
    h: jax.Array,
    w: jax.Array,
    ids: jax.Array,
    cnt: jax.Array,
    *,
    block: tuple[int, int, int] = (8, 128, 128),
    interpret: bool = True,
    out_dtype=None,
) -> jax.Array:
    """h:(T,F) @ w:(F,D) with gathered live blocks. Shapes must divide blocks."""
    from functools import partial

    t, f = h.shape
    f2, d = w.shape
    assert f == f2, (h.shape, w.shape)
    bt, bf, bd = block
    assert t % bt == 0 and f % bf == 0 and d % bd == 0, (h.shape, w.shape, block)
    nt, nf, nd = t // bt, f // bf, d // bd
    assert ids.shape == (nt, nf) and cnt.shape == (nt,), (ids.shape, cnt.shape)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt, nd, nf),
        in_specs=[
            pl.BlockSpec((bt, bf), lambda i, j, k, ids, cnt: (i, ids[i, k])),
            pl.BlockSpec((bf, bd), lambda i, j, k, ids, cnt: (ids[i, k], j)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda i, j, k, ids, cnt: (i, j)),
        scratch_shapes=[pltpu.VMEM((bt, bd), jnp.float32)],
    )
    return pl.pallas_call(
        partial(_kernel, nf=nf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, d), out_dtype or h.dtype),
        interpret=interpret,
    )(ids, cnt, h, w)
