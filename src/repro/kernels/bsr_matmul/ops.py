"""Jitted wrapper: ECR-style block compaction + pallas BSR matmul."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparsity import block_occupancy
from repro.kernels.bsr_matmul.kernel import bsr_matmul_pallas
from repro.kernels.schedule_guard import guard_schedule


def block_schedule(h: jax.Array, bt: int, bf: int):
    """Compute (ids, cnt) — the block-granularity ECR compression of h."""
    occ = block_occupancy(h, (bt, bf))  # (nt, nf) bool
    nt, nf = occ.shape
    order = jnp.argsort(~occ, axis=1, stable=True).astype(jnp.int32)
    cnt = occ.sum(1).astype(jnp.int32)
    lane = jnp.arange(nf, dtype=jnp.int32)[None, :]
    ids = jnp.where(lane < cnt[:, None], order, order[:, :1])
    return ids, cnt


@partial(jax.jit, static_argnames=("block", "interpret", "tile"))
def sparse_matmul(h, w, block=(8, 128, 128), interpret: bool = True,
                  tile=None):
    """y = h @ w skipping all-zero (bt,bf) blocks of h. Pads to block multiples.

    `tile` (a `repro.kernels.tiles.TileConfig`) overrides the (bt, bf, bd)
    geometry per dimension; a non-conforming dimension (<= 0 or larger than
    the extent it tiles, up to the one-block padding rule) keeps the
    `block` default — the same fallback contract as the conv ops."""
    t, f = h.shape
    f2, d = w.shape
    bt, bf, bd = block
    if tile is not None and tile:
        bt = tile.bt if 0 < tile.bt <= max(8, t) else bt
        bf = tile.bf if 0 < tile.bf <= max(8, f) else bf
        bd = tile.bd if 0 < tile.bd <= max(8, d) else bd
    tp, fp, dp = (-t) % bt, (-f) % bf, (-d) % bd
    hp = jnp.pad(h, ((0, tp), (0, fp)))
    wp = jnp.pad(w, ((0, fp), (0, dp)))
    ids, cnt = block_schedule(hp, bt, bf)
    ids, cnt = guard_schedule(ids, cnt, (f + fp) // bf)
    # launch at the RESOLVED geometry — passing the default `block` here while
    # padding/scheduling at the tile override was exactly the silent
    # grid-vs-schedule mismatch repro.analysis' RPA101 check exists to catch
    y = bsr_matmul_pallas(hp, wp, ids, cnt, block=(bt, bf, bd),
                          interpret=interpret)
    return y[:t, :d]


def schedule_occupancy(h, bt: int = 8, bf: int = 128) -> float:
    """Fraction of blocks that are live (== fraction of MXU work not skipped)."""
    occ = block_occupancy(h, (bt, bf))
    return float(occ.mean())
