"""Host-side (ids, cnt) bounds guard at the Pallas op entry points.

The kernels trust their scalar-prefetched (ids, cnt) schedules blindly: an
out-of-range id gathers a wrong (or out-of-bounds) operand block and a cnt
beyond n_blocks walks the grid off the schedule — both silently, since the
index maps are baked into the compiled grid. The static checker
(`repro.analysis`) verifies schedules it can see at plan time, but schedules
are computed inside jit from traced VALUES, so this is the complementary
dynamic guard: a traced-safe clamp of both fields into range, applied at the
`ecr_conv` / `fused_conv_pool` / `sparse_matmul` / `conv2d_bsr` entry points.

Gated by REPRO_CHECK_SCHEDULES=1 (read at trace time, like the interpret
flag): the default hot path is bit-identical to before — no extra ops in the
compiled program. On valid schedules the clamp is the identity, so enabling
the guard never changes correct results; it exists to turn a corrupted
schedule's silent garbage into in-range (wrong-but-bounded) reads while the
static pass pinpoints the source.
"""
from __future__ import annotations

import os


def schedules_checked() -> bool:
    """Whether the REPRO_CHECK_SCHEDULES=1 guard is on (checked per call, so
    tests can flip the env var without re-importing)."""
    return os.environ.get("REPRO_CHECK_SCHEDULES", "") == "1"


def guard_schedule(ids, cnt, n_blocks: int):
    """Clamp (ids, cnt) into the kernel's valid range when the guard is on.

    ids -> [0, n_blocks); cnt -> [0, n_blocks]. Works on traced values
    (the schedules are computed inside jit) and on any batching layout —
    ids (n_cb,) or (N, n_cb), cnt scalar, (1,) or (N,).
    """
    if not schedules_checked():
        return ids, cnt
    import jax.numpy as jnp

    ids = jnp.clip(ids, 0, max(n_blocks - 1, 0)).astype(ids.dtype)
    cnt = jnp.clip(cnt, 0, n_blocks).astype(cnt.dtype)
    return ids, cnt
