"""Jitted wrapper for the PECR fused conv+ReLU+maxpool kernel.

Registered as ("conv_pool", "pecr_pallas") in `repro.graph.registry`
(forward = `fused_conv_pool`, cost hook = `conv_pool_cost`). The kernel form
requires pooling stride == pool size; the registry's `fusion_eligible` rule
only routes units here when that (and exact tiling) holds — overlapping or
ceil-mode pools run as ECR conv + an unfused pool instead.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparsity import block_occupancy, compact_block_ids
from repro.kernels.conv_pool.kernel import conv_pool_pallas, conv_pool_pallas_batch
from repro.kernels.ecr_conv.ops import batch_block_schedule, ecr_conv_launch
from repro.kernels.schedule_guard import guard_schedule
from repro.kernels.tiles import ConvLaunch, TileConfig


def conv_pool_launch(c: int, h: int, w: int, o: int, kh: int = 3, kw: int = 3,
                     *, stride: int = 1, pool: int = 2, block_c: int = 0,
                     block_o: int = 0, tile: TileConfig | None = None,
                     batch: int = 1, dtype_bytes: int = 4,
                     kernel: str = "conv_pool", acc_dtype: str = "float32",
                     weight_scales: str = "none") -> ConvLaunch:
    """`ConvLaunch` descriptor of one fused PECR conv+ReLU+pool call — the
    ECR builder with the pool window recorded, so the checker can verify the
    fused epilogue tiles the conv output exactly (the kernel floors)."""
    return ecr_conv_launch(c, h, w, o, kh, kw, stride=stride, block_c=block_c,
                           block_o=block_o, tile=tile, batch=batch,
                           dtype_bytes=dtype_bytes, pool=pool, kernel=kernel,
                           acc_dtype=acc_dtype, weight_scales=weight_scales)


@partial(jax.jit, static_argnames=("stride", "pool", "p_s", "interpret", "block_c", "block_o", "compact"))
def fused_conv_pool(x_chw, kernels_oihw, stride: int = 1, pool: int = 2,
                    p_s=None, interpret: bool = True, block_c: int = 0,
                    block_o: int = 0, compact: bool = True):
    """(C,H,W) x (O,C,kh,kw) -> (O, oh//p, ow//p). p_s must equal pool (kernel form).
    Batched: (N,C,H,W) -> (N, O, oh//p, ow//p) through the native batched grid
    with per-sample channel-block schedules (shared-union compaction)."""
    from repro.core.ecr import compact_live_channels, compact_live_channels_batch

    assert p_s is None or p_s == pool, "pallas kernel supports pooling stride == pool"
    if x_chw.ndim == 2:
        x_chw = x_chw[None]
    if kernels_oihw.ndim == 3:
        kernels_oihw = kernels_oihw[None]
    batched = x_chw.ndim == 4
    c, h, w = x_chw.shape[-3:]
    o, c2, kh, kw = kernels_oihw.shape
    # the ONE shared (bc, bo) defaulting rule (repro.kernels.tiles), not a
    # drifting copy of ecr_conv's — dtype_bytes rides the VMEM-budget pick
    launch = conv_pool_launch(c, h, w, o, kh, kw, stride=stride, pool=pool,
                              block_c=block_c, block_o=block_o,
                              batch=x_chw.shape[0] if batched else 1,
                              dtype_bytes=jnp.dtype(x_chw.dtype).itemsize)
    bc, bo = launch.block_c, launch.block_o
    cp, op, n_cb = launch.c_pad, launch.o_pad, launch.n_cb

    if batched:
        assert x_chw.shape[0] > 0, "empty batch: fused_conv_pool needs N >= 1"
        if compact:
            x_chw, kernels_oihw, _ = compact_live_channels_batch(x_chw, kernels_oihw)
        x = jnp.pad(x_chw, ((0, 0), (0, cp), (0, 0), (0, 0))).transpose(0, 2, 3, 1)
        wk = jnp.pad(kernels_oihw, ((0, op), (0, cp), (0, 0), (0, 0))).transpose(2, 3, 1, 0)
        ids, cnt = batch_block_schedule(x, h, w, bc)
        ids, cnt = guard_schedule(ids, cnt, n_cb)
        out = conv_pool_pallas_batch(
            x, wk, ids, cnt, stride=stride, pool=pool, block_c=bc, block_o=bo,
            interpret=interpret,
        )
        return out.transpose(0, 3, 1, 2)[:, :o]

    if compact:
        x_chw, kernels_oihw, n_live = compact_live_channels(x_chw, kernels_oihw)
    x = jnp.pad(x_chw, ((0, cp), (0, 0), (0, 0))).transpose(1, 2, 0)
    wk = jnp.pad(kernels_oihw, ((0, op), (0, cp), (0, 0), (0, 0))).transpose(2, 3, 1, 0)
    if compact:
        ids = jnp.arange(n_cb, dtype=jnp.int32)
        cnt = jnp.minimum((n_live + bc - 1) // bc, n_cb).astype(jnp.int32)
    else:
        occ = block_occupancy(x, (h, w, bc)).reshape(-1)
        ids, cnt = compact_block_ids(occ)
    ids, cnt = guard_schedule(ids, cnt, n_cb)
    out = conv_pool_pallas(
        x, wk, ids, cnt[None], stride=stride, pool=pool, block_c=bc, block_o=bo,
        interpret=interpret,
    )
    return out.transpose(2, 0, 1)[:o]


def conv_pool_cost(c: int, h: int, w: int, o: int, kh: int = 3, kw: int = 3, *,
                   stride: int = 1, pool: int = 2, occupancy: float = 1.0,
                   batch: int = 1, dtype_bytes: int = 4) -> dict:
    """Modeled FLOPs / HBM bytes of the fused PECR conv+ReLU+pool at a given
    channel-block occupancy — the serving autotuner's cost hook for fused
    stage-final layers.

    Relative to the unfused `ecr_conv_cost` + pool, the fusion (a) divides the
    output write by pool^2 (only the pooled tile leaves VMEM, DESIGN.md §2.3)
    and (b) deletes the intermediate conv-result write/read round trip that an
    unfused pool would pay. The pool max itself adds ~1 op per conv output
    element on the VPU.
    """
    from repro.kernels.ecr_conv.ops import ecr_conv_cost

    base = ecr_conv_cost(c, h, w, o, kh, kw, stride=stride, occupancy=occupancy,
                         batch=batch, dtype_bytes=dtype_bytes)
    oh, ow = (h - kh) // stride + 1, (w - kw) // stride + 1
    conv_out_bytes = o * oh * ow * dtype_bytes * batch
    pooled_bytes = o * (oh // pool) * (ow // pool) * dtype_bytes * batch
    return {"flops": base["flops"] + o * oh * ow * batch,  # pool max on the VPU
            "bytes": base["bytes"] - conv_out_bytes + pooled_bytes,
            "out_elems": o * (oh // pool) * (ow // pool) * batch}
