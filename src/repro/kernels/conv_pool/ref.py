"""Pure-jnp oracle for the fused conv+ReLU+maxpool kernel."""
import jax
import jax.numpy as jnp


def conv_pool_ref(x_chw, kernels_oihw, stride: int = 1, pool: int = 2):
    """(C,H,W) -> (O, oh//p, ow//p) or batched (N,C,H,W) -> (N, O, oh//p, ow//p)."""
    batched = x_chw.ndim == 4
    conv = jax.lax.conv_general_dilated(
        (x_chw if batched else x_chw[None]).astype(jnp.float32),
        kernels_oihw.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    conv = jnp.maximum(conv, 0.0)
    oh, ow = conv.shape[-2:]
    poh, pow_ = oh // pool, ow // pool
    conv = conv[..., : poh * pool, : pow_ * pool]
    lead = conv.shape[:-2]
    pooled = conv.reshape(*lead, poh, pool, pow_, pool).max(axis=(-3, -1))
    return pooled if batched else pooled[0]
