"""Pure-jnp oracle for the fused conv+ReLU+maxpool kernel."""
import jax
import jax.numpy as jnp


def conv_pool_ref(x_chw, kernels_oihw, stride: int = 1, pool: int = 2):
    """(C,H,W) x (O,C,kh,kw) -> (O, oh//p, ow//p) fp32 ground truth."""
    conv = jax.lax.conv_general_dilated(
        x_chw[None].astype(jnp.float32),
        kernels_oihw.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    conv = jnp.maximum(conv, 0.0)
    o, oh, ow = conv.shape
    poh, pow_ = oh // pool, ow // pool
    conv = conv[:, : poh * pool, : pow_ * pool]
    return conv.reshape(o, poh, pool, pow_, pool).max(axis=(2, 4))
