"""PECR fused conv+ReLU+maxpool on TPU — paper §V adapted per DESIGN.md §2.

Extends the ECR conv kernel with the PECR epilogue: the convolution tile is
accumulated in fp32 VMEM scratch across channel blocks; on the LAST channel
block the kernel applies ReLU and a p x p max-reduction on the VPU and writes
ONLY the pooled tile to HBM. The conv result never leaves VMEM — the TPU
realization of the paper's "pooling result obtained in one thread without
outputting it" (Algorithm 4), with the CPU<->GPU saving mapped to HBM<->VMEM:
output traffic drops by p^2 x and the intermediate write/read pair vanishes.

Same gathered channel-block sparsity schedule as ecr_conv (ids/cnt == ECR's
F_data/Ptr at block granularity). Pooling stride == pool size (the VGG/paper
evaluation setting); the general-stride form lives in the jnp reference.

Batched form (`conv_pool_pallas_batch`): same (n_ob, N, n_cb) grid as the
batched ECR conv (DESIGN.md §2.4) — per-sample (ids, cnt) schedules, kernel
block resident across the batch — with the PECR epilogue run per sample.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, kh, kw, stride, n_cb, oh, ow, p):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[0])
    def _mac():
        x = x_ref[...]  # (H, W, bc)
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    x,
                    (i, j, 0),
                    (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, x.shape[2]),
                    (stride, stride, 1),
                )
                acc_ref[...] += jnp.dot(
                    patch.reshape(oh * ow, -1),
                    w_ref[i, j],
                    preferred_element_type=jnp.float32,
                )

    @pl.when(k == n_cb - 1)
    def _epilogue():  # PECR: ReLU + max-pool in VMEM, pooled tile is the only HBM write
        conv = acc_ref[...].reshape(oh, ow, -1)
        conv = jnp.maximum(conv, 0.0)  # ReLU (paper §V-D)
        poh, pow_ = oh // p, ow // p
        pooled = (
            conv[: poh * p, : pow_ * p, :]
            .reshape(poh, p, pow_, p, -1)
            .max(axis=(1, 3))
        )
        o_ref[...] = pooled.astype(o_ref.dtype)


def conv_pool_pallas(
    x: jax.Array,  # (H, W, C)
    w: jax.Array,  # (kh, kw, C, O)
    ids: jax.Array,
    cnt: jax.Array,
    *,
    stride: int = 1,
    pool: int = 2,
    block_c: int = 128,
    block_o: int = 128,
    interpret: bool = True,
    out_dtype=None,
) -> jax.Array:
    h, wd, c = x.shape
    kh, kw, c2, o = w.shape
    assert c == c2 and c % block_c == 0 and o % block_o == 0
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    poh, pow_ = oh // pool, ow // pool
    assert poh > 0 and pow_ > 0, "map too small for pooling window"
    n_cb, n_ob = c // block_c, o // block_o

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_ob, n_cb),
        in_specs=[
            pl.BlockSpec((h, wd, block_c), lambda j, k, ids, cnt: (0, 0, ids[k])),
            pl.BlockSpec((kh, kw, block_c, block_o), lambda j, k, ids, cnt: (0, 0, ids[k], j)),
        ],
        out_specs=pl.BlockSpec((poh, pow_, block_o), lambda j, k, ids, cnt: (0, 0, j)),
        scratch_shapes=[pltpu.VMEM((oh * ow, block_o), jnp.float32)],
    )
    return pl.pallas_call(
        partial(_kernel, kh=kh, kw=kw, stride=stride, n_cb=n_cb, oh=oh, ow=ow, p=pool),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((poh, pow_, o), out_dtype or x.dtype),
        interpret=interpret,
    )(ids, cnt, x, w)


# ---------------------------------------------------------------------------
# Native batched grid (DESIGN.md §2.4)
# ---------------------------------------------------------------------------


def _kernel_batch(ids_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, kh, kw, stride, n_cb, oh, ow, p):
    b = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[b])
    def _mac():
        x = x_ref[0]  # (H, W, bc) — sample b's channel block ids[b, k]
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    x,
                    (i, j, 0),
                    (i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, x.shape[2]),
                    (stride, stride, 1),
                )
                acc_ref[...] += jnp.dot(
                    patch.reshape(oh * ow, -1),
                    w_ref[i, j],
                    preferred_element_type=jnp.float32,
                )

    @pl.when(k == n_cb - 1)
    def _epilogue():  # PECR: ReLU + max-pool in VMEM, pooled tile is the only HBM write
        conv = acc_ref[...].reshape(oh, ow, -1)
        conv = jnp.maximum(conv, 0.0)  # ReLU (paper §V-D)
        poh, pow_ = oh // p, ow // p
        pooled = (
            conv[: poh * p, : pow_ * p, :]
            .reshape(poh, p, pow_, p, -1)
            .max(axis=(1, 3))
        )
        o_ref[...] = pooled[None].astype(o_ref.dtype)


def conv_pool_pallas_batch(
    x: jax.Array,  # (N, H, W, C)
    w: jax.Array,  # (kh, kw, C, O) — shared across the batch
    ids: jax.Array,  # (N, n_cb)
    cnt: jax.Array,  # (N,)
    *,
    stride: int = 1,
    pool: int = 2,
    block_c: int = 128,
    block_o: int = 128,
    interpret: bool = True,
    out_dtype=None,
) -> jax.Array:
    n, h, wd, c = x.shape
    kh, kw, c2, o = w.shape
    assert c == c2 and c % block_c == 0 and o % block_o == 0
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    poh, pow_ = oh // pool, ow // pool
    assert poh > 0 and pow_ > 0, "map too small for pooling window"
    n_cb, n_ob = c // block_c, o // block_o

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_ob, n, n_cb),
        in_specs=[
            pl.BlockSpec((1, h, wd, block_c), lambda j, b, k, ids, cnt: (b, 0, 0, ids[b, k])),
            pl.BlockSpec((kh, kw, block_c, block_o), lambda j, b, k, ids, cnt: (0, 0, ids[b, k], j)),
        ],
        out_specs=pl.BlockSpec((1, poh, pow_, block_o), lambda j, b, k, ids, cnt: (b, 0, 0, j)),
        scratch_shapes=[pltpu.VMEM((oh * ow, block_o), jnp.float32)],
    )
    return pl.pallas_call(
        partial(_kernel_batch, kh=kh, kw=kw, stride=stride, n_cb=n_cb, oh=oh, ow=ow, p=pool),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, poh, pow_, o), out_dtype or x.dtype),
        interpret=interpret,
    )(ids, cnt, x, w)
