"""Gradient compression with error feedback (distributed-optimization trick).

Two schemes, both applied at the microbatch-accumulation boundary of
train_step (where cross-replica reduction happens under GSPMD):

- int8: per-tensor absmax scaling + stochastic rounding. 4x traffic reduction
  on the gradient all-reduce/reduce-scatter; the quantization residual is
  carried in an error-feedback buffer so the bias vanishes over steps.
- topk: keep the largest |g| fraction per tensor, accumulate the rest in the
  error-feedback buffer (Deep Gradient Compression style).

`compress -> (reduce) -> decompress` is numerically a drop-in for the raw
gradient; convergence equivalence on a quadratic is property-tested.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _int8_one(g, err, key):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return (q, scale), g - deq


def _topk_one(g, err, frac):
    g = g.astype(jnp.float32) + err
    k = max(1, int(g.size * frac))
    flat = g.reshape(-1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    kept = jnp.where(mask, flat, 0.0).reshape(g.shape)
    return kept, g - kept


def compress_grads(grads, err, *, scheme: str, key=None, topk_frac: float = 0.01):
    """Returns (compressed_tree, new_err_tree)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    errs = treedef.flatten_up_to(err)
    if scheme == "int8":
        keys = jax.random.split(key, len(leaves))
        out = [_int8_one(g, e, k) for g, e, k in zip(leaves, errs, keys)]
    elif scheme == "topk":
        out = [_topk_one(g, e, topk_frac) for g, e in zip(leaves, errs)]
    else:
        raise ValueError(scheme)
    comp = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    return comp, new_err


def decompress_grads(comp, *, scheme: str):
    if scheme == "int8":
        return jax.tree_util.tree_map(
            lambda qs: qs[0].astype(jnp.float32) * qs[1],
            comp, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x[0], tuple))
    if scheme == "topk":
        return comp
    raise ValueError(scheme)
