from repro.optim.adamw import OptState, adamw_update, init_opt_state
from repro.optim.schedules import warmup_cosine
from repro.optim.compression import (
    compress_grads,
    decompress_grads,
    init_error_feedback,
)

__all__ = [
    "OptState",
    "adamw_update",
    "init_opt_state",
    "warmup_cosine",
    "compress_grads",
    "decompress_grads",
    "init_error_feedback",
]
