"""AdamW with configurable moment dtype (bf16 moments for the 480B-class archs
— fp32 m/v for 480B params is 3.8TB of optimizer state; bf16 halves it, and
both moments shard with the params under FSDP so HBM cost is per-chip tiny).

Grad clipping by global norm is fused into the update (single pass).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array  # () int32
    m: dict
    v: dict


def init_opt_state(params, moment_dtype=jnp.float32) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, state: OptState, params, *, lr, beta1=0.9, beta2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state, grad_norm)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.where(grad_clip > 0, jnp.minimum(1.0, grad_clip / (gnorm + 1e-9)), 1.0)

    bc1 = 1.0 - beta1 ** step.astype(jnp.float32)
    bc2 = 1.0 - beta2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = beta1 * m32 + (1 - beta1) * g
        v_new = beta2 * v32 + (1 - beta2) * jnp.square(g)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + weight_decay * p32)
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), gnorm
