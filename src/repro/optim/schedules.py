"""LR schedules (pure functions of the step scalar; safe inside jit)."""
import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr, warmup_steps, total_steps, min_frac=0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = peak_lr * s / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = peak_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup_steps, warm, cos)
