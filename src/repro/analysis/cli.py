"""repro-lint: sweep the model zoo through the static verifier.

For each selected model this mirrors the serving launcher's setup exactly
(same reduced graphs, same synthetic dead-channel calibration batch, same
pruning path), plans the network, and verifies the plan + params WITHOUT
serving anything. Exit status is nonzero iff any error-severity diagnostic
fires, so CI can gate on it.

Run:
    PYTHONPATH=src python -m repro.analysis.cli --model lenet
    PYTHONPATH=src python -m repro.analysis.cli --model all \\
        --prune-density 0.3 --int8 --json
    PYTHONPATH=src python -m repro.analysis.cli --dead-imports
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.deadcode import check_dead_imports
from repro.analysis.diagnostics import (
    DiagnosticSink,
    errors,
    format_diagnostics,
    sort_diagnostics,
)
from repro.analysis.verify import PlanVerificationError, verify_plan


def lint_model(model: str, *, full: bool = False, prune_density: float = 1.0,
               int8: bool = False, occ_threshold: float = 0.75,
               block_c: int = 0, seed: int = 0) -> dict:
    """Plan one zoo model the way `serve_cnn` would and verify the result.
    Returns {"model", "plan", "diagnostics"} (diagnostics as Diagnostic
    objects; the caller formats)."""
    import jax
    import jax.numpy as jnp

    from repro.graph import init_graph
    from repro.launch.serve_cnn import serving_graph, synth_requests
    from repro.models.cnn import shift_dead_channels
    from repro.pipeline.planner import plan_network

    graph = serving_graph(model, full)
    params = shift_dead_channels(init_graph(jax.random.PRNGKey(seed), graph))
    calib = jnp.stack(synth_requests(graph, 2, seed=seed + 1))
    if prune_density < 1.0:
        from repro.sparse_weights import prune_graph_params

        params, _ = prune_graph_params(params, prune_density, graph,
                                       probe=calib)
    try:
        plan = plan_network(params, calib, graph, occ_threshold=occ_threshold,
                            block_c=block_c, int8=int8)
    except PlanVerificationError as e:
        # plan_network itself verifies before returning — surface its
        # findings instead of a traceback so the sweep keeps going
        return {"model": graph.name, "plan": None,
                "diagnostics": list(e.diagnostics)}
    diags = verify_plan(plan, params, batch=int(calib.shape[0]))
    return {"model": graph.name,
            "plan": {"layers": [f"{lp.kind}/{lp.impl}" for lp in plan.layers],
                     **plan.counts()},
            "diagnostics": diags}


def main(argv=None) -> int:
    from repro.launch.serve_cnn import MODELS

    ap = argparse.ArgumentParser(
        prog="repro-lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--model", choices=MODELS + ("all",), default="all",
                    help="which zoo model to lint (default: the whole zoo)")
    ap.add_argument("--full", action="store_true",
                    help="full network depth (slow on CPU)")
    ap.add_argument("--prune-density", type=float, default=1.0,
                    help="magnitude-prune to this BSR block density before "
                         "planning (1.0 = no pruning)")
    ap.add_argument("--int8", action="store_true",
                    help="plan with int8 upgrades (probed, like serving)")
    ap.add_argument("--occ-threshold", type=float, default=0.75)
    ap.add_argument("--block-c", type=int, default=0,
                    help="channel-block size (0 = auto)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dead-imports", action="store_true",
                    help="also report modules unreachable from the CNN "
                         "spine (RPA901, info)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output (one JSON document)")
    args = ap.parse_args(argv)

    models = MODELS if args.model == "all" else (args.model,)
    reports = [lint_model(m, full=args.full,
                          prune_density=args.prune_density, int8=args.int8,
                          occ_threshold=args.occ_threshold,
                          block_c=args.block_c, seed=args.seed)
               for m in models]
    if args.dead_imports:
        sink = DiagnosticSink()
        src = Path(__file__).resolve().parents[2]  # .../src
        check_dead_imports(src, sink)
        reports.append({"model": "<repo>", "plan": None,
                        "diagnostics": sink.items})

    n_err = sum(len(errors(r["diagnostics"])) for r in reports)
    if args.as_json:
        doc = {"n_errors": n_err,
               "reports": [{**r, "diagnostics": [
                   d.to_json() for d in sort_diagnostics(r["diagnostics"])]}
                   for r in reports]}
        print(json.dumps(doc, indent=2))
    else:
        for r in reports:
            n_e = len(errors(r["diagnostics"]))
            verdict = "FAIL" if n_e else "ok"
            print(f"== {r['model']}: {verdict} "
                  f"({n_e} errors, {len(r['diagnostics']) - n_e} notes)")
            if r["plan"]:
                print(f"   plan: {' '.join(r['plan']['layers'])}")
            out = format_diagnostics(r["diagnostics"])
            if out:
                print("\n".join(f"   {line}" for line in out.splitlines()))
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
