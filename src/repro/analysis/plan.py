"""Graph / plan / params invariants — the RPA2xx / RPA3xx checks.

`check_plan` re-derives everything a `PipelinePlan` claims from first
principles — shape inference over its graph, the registry's fusion rule,
the tile-conformance contract of `kernels/tiles.py`, the launch geometry of
every Pallas layer via the registry's `unit_launch` seam, the params'
measured weight density — and reports every disagreement as a `Diagnostic`.
Nothing here compiles or executes a kernel: it is pure arithmetic over the
plan's static fields, so it is safe to run at plan time, at cache-miss time
and inside the serving engine's hot-swap path.

Value-dependent checks (BSR density, static weight schedules) only run on
CONCRETE params: under a jit trace the weights are tracers with no values,
so those checks are skipped exactly like `validate_plan` always did — the
traced path is covered by `guard_schedule` (REPRO_CHECK_SCHEDULES=1)
instead.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.analysis.diagnostics import DiagnosticSink
from repro.analysis.launch import check_launch
from repro.analysis.schedules import check_schedule
from repro.graph.ir import graph_weights
from repro.graph.registry import fusion_eligible, get_op, unit_launch
from repro.kernels.tiles import BsrLaunch, ConvLaunch


def _check_tile_conformance(lp, unit, op, sink) -> None:
    """RPA204: a requested tile dimension the resolver will NOT honor (it
    falls back to the default for that dimension — tiles.py's contract).
    The plan still runs, just not at the geometry it recorded, which is
    exactly the statistic/schedule divergence the tile search exists to
    avoid."""
    tile = lp.tile
    if tile is None or not tile or op.launch is None:
        return  # no request, or a non-Pallas impl that ignores tiles
    loc = dict(layer=lp.index, kind=lp.kind, impl=lp.impl)
    c, h, w = unit.in_shape
    o = unit.conv.c_out
    if op.weight_sparse:
        k_taps = c * unit.conv.k * unit.conv.k
        _, oh, ow = unit.conv_out_shape
        p = oh * ow  # per-sample patches; batch only scales d upward
        for name, req, ext in (("bt", tile.bt, o), ("bf", tile.bf, k_taps),
                               ("bd", tile.bd, p)):
            if req and not 0 < req <= max(8, ext):
                sink.add("RPA204",
                         f"requested {name}={req} does not conform to "
                         f"extent {ext} (honored iff 0 < {name} <= "
                         f"max(8, {ext})); the kernel falls back to its "
                         f"default for this dimension", **loc)
        return
    del h, w  # conformance depends only on the channel extents
    if tile.block_c and not 0 < tile.block_c <= max(8, c):
        sink.add("RPA204",
                 f"requested block_c={tile.block_c} does not conform to "
                 f"c={c} (honored iff 0 < block_c <= max(8, {c})); the "
                 f"kernel falls back to the VMEM-budget default", **loc)
    if tile.block_o and not 0 < tile.block_o <= max(8, o):
        sink.add("RPA204",
                 f"requested block_o={tile.block_o} does not conform to "
                 f"o={o} (clamped to max(8, {o}))", **loc)


def _check_bsr_schedule(lp, w, launch, sink) -> None:
    """RPA207 for the STATIC axis: the (ids, cnt) weight schedule a BSR
    layer would prefetch is a pure function of the concrete params, so it
    can be derived and verified without running anything."""
    from repro.kernels.bsr_matmul.ops import block_schedule
    from repro.sparse_weights.format import conv_weight_matrix

    if not isinstance(launch, BsrLaunch):
        return
    wm = np.asarray(conv_weight_matrix(w))
    wm = np.pad(wm, ((0, launch.t_pad), (0, launch.f_pad)))
    ids, cnt = block_schedule(wm, launch.bt, launch.bf)
    check_schedule(np.asarray(ids), np.asarray(cnt), launch.nf, sink,
                   layer=lp.index, kind=lp.kind, impl=lp.impl)


def check_plan(plan, params=None, graph=None, batch: int = 1) -> list:
    """Verify a `PipelinePlan` (and optionally its params / graph) without
    executing it. Returns the full diagnostic list; `verify.assert_plan_ok`
    turns error-severity findings into a raise.

    `graph` is a fallback `LayerGraph` for pre-IR plans that carry none
    (plan.graph wins); `batch` sizes the launch descriptors' grid (geometry
    validity is batch-independent, so 1 is always safe). `params` may be
    absent (structure-only check, the PlanCache case) or traced (shape
    checks only, like `validate_plan` under jit)."""
    sink = DiagnosticSink()

    # --- plan-level sanity (RPA201 / RPA209) -----------------------------
    if not getattr(plan, "layers", None):
        sink.add("RPA201", "run_plan got an empty PipelinePlan (no layers)")
        return sink.items
    if plan.block_c < 0:
        sink.add("RPA209",
                 f"PipelinePlan.block_c must be >= 0 (0 = auto), "
                 f"got {plan.block_c}")

    # --- per-layer checks -------------------------------------------------
    units = {}
    for lp in plan.layers:
        loc = dict(layer=lp.index, kind=lp.kind, impl=lp.impl)
        if not 0.0 <= lp.occupancy <= 1.0:
            sink.add("RPA209",
                     f"occupancy {lp.occupancy} outside [0, 1]", **loc)
        if not 0.0 <= lp.weight_density <= 1.0:
            sink.add("RPA209",
                     f"weight_density {lp.weight_density} outside [0, 1]",
                     **loc)
        try:
            op = get_op(lp.kind, lp.impl)
        except ValueError as e:
            sink.add("RPA208", str(e), **loc)
            continue
        try:
            unit = lp.to_unit()
        except ValueError as e:
            sink.add("RPA201", str(e), **loc)  # "predates the LayerGraph IR"
            continue
        units[lp.index] = unit
        if lp.kind == "conv_pool" and not fusion_eligible(unit):
            sink.add("RPA203",
                     f"planned as fused conv+ReLU+pool but the unit fails "
                     f"the fusion rule (needs adjacent ReLU + pool, "
                     f"stride == p, exact tiling of the "
                     f"{unit.conv_out_shape[1]}x{unit.conv_out_shape[2]} "
                     f"conv output)",
                     hint="re-plan, or run conv + unfused pool", **loc)
        if op.quantized:
            rep = plan.int8_report
            if rep is None or lp.index not in getattr(rep, "layers", ()):
                sink.add("RPA206",
                         "int8 layer has no Int8Report entry — its accuracy "
                         "cost was never probed against the fp32 oracle",
                         hint="plan with plan_network(int8=True) so the "
                              "probe gates the placement", **loc)
        _check_tile_conformance(lp, unit, op, sink)
        if op.launch is not None:
            try:
                L = unit_launch(lp.kind, lp.impl, unit, tile=lp.tile,
                                block_c=plan.block_c, batch=batch)
            except ValueError as e:
                sink.add("RPA102", f"launch resolution failed: {e}", **loc)
                L = None
            check_launch(L, sink, **loc)

    # --- in-shape chain (each layer consumes its predecessor) -------------
    for prev, nxt in zip(plan.layers, plan.layers[1:]):
        if tuple(prev.out_shape) != tuple(nxt.in_shape):
            sink.add("RPA201",
                     f"plan/graph mismatch: conv_{nxt.index + 1} expects "
                     f"input {tuple(nxt.in_shape)} but conv_{prev.index + 1} "
                     f"produces {tuple(prev.out_shape)}",
                     layer=nxt.index, kind=nxt.kind, impl=nxt.impl)

    # --- graph cross-check (RPA201 / RPA202) ------------------------------
    g = plan.graph if plan.graph is not None else graph
    g_units = g_head = None
    if g is not None:
        try:
            g_units, g_head = g.units(), g.head()
        except ValueError as e:
            sink.add("RPA202", f"graph fails shape inference / topology "
                               f"validation: {e}")
    if g_units is not None:
        if len(g_units) != len(plan.layers):
            sink.add("RPA201",
                     f"plan has {len(plan.layers)} layers but its graph has "
                     f"{len(g_units)} conv units (plan/graph mismatch)")
        else:
            for lp, gu in zip(plan.layers, g_units):
                u = units.get(lp.index)
                if u is None:
                    continue
                drift = [f"{f}: plan {getattr(u, f)!r} vs graph "
                         f"{getattr(gu, f)!r}"
                         for f in ("conv", "relu", "pool", "in_shape",
                                   "out_shape")
                         if getattr(u, f) != getattr(gu, f)]
                if drift:
                    sink.add("RPA201",
                             "plan/graph mismatch: " + "; ".join(drift),
                             layer=lp.index, kind=lp.kind, impl=lp.impl)

    # --- params cross-check (RPA301 / RPA205 / static RPA207) -------------
    if params is None:
        return sink.items
    try:
        conv_ws, dense_ws = graph_weights(params)
    except Exception as e:
        sink.add("RPA301", f"params not readable as graph weights: {e}")
        return sink.items
    if len(conv_ws) != len(plan.layers):
        sink.add("RPA301",
                 f"plan has {len(plan.layers)} conv layers but params carry "
                 f"{len(conv_ws)} conv weights (zip would silently truncate)")
        return sink.items
    for lp, w in zip(plan.layers, conv_ws):
        loc = dict(layer=lp.index, kind=lp.kind, impl=lp.impl)
        if w.ndim != 4:
            sink.add("RPA301",
                     f"conv weight has {w.ndim} dims, want (O, C, kh, kw)",
                     **loc)
            continue
        if w.shape[1] != lp.in_shape[0]:
            sink.add("RPA301",
                     f"plan expects C_in={lp.in_shape[0]}, weight has "
                     f"C_in={w.shape[1]}", **loc)
        conv = lp.conv
        if conv.c_out and (w.shape[0] != conv.c_out
                           or w.shape[2:] != (conv.k, conv.k)):
            sink.add("RPA301",
                     f"plan's ConvSpec wants weight "
                     f"({conv.c_out}, {lp.in_shape[0]}, {conv.k}, {conv.k}) "
                     f"but params carry {tuple(w.shape)}", **loc)
        traced = isinstance(w, jax.core.Tracer)
        try:
            op = get_op(lp.kind, lp.impl)
        except ValueError:
            continue  # already an RPA208
        if op.weight_sparse and not traced:
            from repro.sparse_weights import weight_block_density

            d = weight_block_density(w)
            if abs(d - lp.weight_density) > 0.1:
                sink.add("RPA205",
                         f"plan runs '{lp.impl}' at weight block density "
                         f"{lp.weight_density:.2f} but the params measure "
                         f"{d:.2f} — a BSR plan must execute with the "
                         f"pruned params it was planned over "
                         f"(re-run plan_network)", **loc)
            u = units.get(lp.index)
            if u is not None and op.launch is not None:
                try:
                    L = unit_launch(lp.kind, lp.impl, u, tile=lp.tile,
                                    block_c=plan.block_c, batch=batch)
                except ValueError:
                    L = None  # already an RPA102 above
                if L is not None and isinstance(L, BsrLaunch) \
                        and not [d for d in sink.items
                                 if d.code == "RPA101" and d.layer == lp.index]:
                    _check_bsr_schedule(lp, w, L, sink)
    if g_head is not None and len(dense_ws) != len(g_head):
        sink.add("RPA301",
                 f"graph head has {len(g_head)} dense layers but params "
                 f"carry {len(dense_ws)} dense weights (zip would silently "
                 f"truncate)")
    return sink.items


def check_launch_descriptor(L) -> list:
    """Standalone descriptor check (ConvLaunch / BsrLaunch) -> diagnostics."""
    sink = DiagnosticSink()
    check_launch(L, sink)
    return sink.items


__all__ = ["check_plan", "check_launch_descriptor", "ConvLaunch", "BsrLaunch"]
