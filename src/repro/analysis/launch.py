"""Launch-geometry contracts: verify a resolved Pallas launch descriptor
(`repro.kernels.tiles.ConvLaunch` / `BsrLaunch`) WITHOUT compiling it.

The descriptors store every geometry field the ops execute with (the ops
read their block sizes back out of the record — one derivation); the checks
here re-derive every expectation from the primitive extents and flag any
disagreement. That is the division of labor that makes corruption
representable: a mutated descriptor field cannot silently re-derive itself
back to consistency.

Checks (DESIGN.md §12):
  RPA101  grid x block tiles each output element exactly once — pads are
          the minimal fill to a block multiple, block counts match, output
          spatial dims match the conv arithmetic.
  RPA102  every index-map gather stays in bounds — the last conv window
          must fit the (already spatially padded) input; block sizes are
          positive so no zero-size BlockSpec divides anything.
  RPA103  the per-grid-step VMEM tile fits `VMEM_BUDGET_BYTES`. A default
          resolution can only exceed the budget at the block_c floor of 8
          (a huge spatial map) — that is a warn; any over-budget tile
          ABOVE the floor can only come from an explicit request the
          resolver would otherwise have shrunk — that is an error.
  RPA104  int8 kernels accumulate in int32 and carry per-output-channel
          scales (fp32 accumulation would silently saturate; a single
          tensor scale loses the per-channel dynamic range the quantizer
          calibrated).
  RPA105  a fused pool epilogue tiles the conv output exactly (the kernel
          floors, so a remainder would silently truncate rows/cols).
"""
from __future__ import annotations

from repro.analysis.diagnostics import DiagnosticSink
from repro.kernels.tiles import VMEM_BUDGET_BYTES, BsrLaunch, ConvLaunch


def _pad_ok(extent: int, pad: int, block: int, n_blocks: int) -> bool:
    """pad is the minimal fill of `extent` to a multiple of `block`, and
    `n_blocks` covers it exactly once."""
    return (block > 0 and 0 <= pad < block
            and (extent + pad) % block == 0
            and n_blocks * block == extent + pad)


def check_conv_launch(L: ConvLaunch, sink: DiagnosticSink, *,
                      layer: int | None = None, kind: str = "",
                      impl: str = "") -> None:
    loc = dict(layer=layer, kind=kind, impl=impl)
    is_int8 = L.dtype_bytes == 1 or L.kernel.endswith("_int8")

    # --- RPA102: positive extents / in-bounds gathers --------------------
    if min(L.block_c, L.block_o, L.batch, L.stride) <= 0 or \
            min(L.c, L.h, L.w, L.o, L.kh, L.kw) <= 0:
        sink.add("RPA102",
                 f"{L.kernel}: non-positive launch dimension "
                 f"(c={L.c} h={L.h} w={L.w} o={L.o} k={L.kh}x{L.kw} "
                 f"stride={L.stride} block_c={L.block_c} block_o={L.block_o} "
                 f"batch={L.batch})",
                 hint="every extent and block size must be >= 1", **loc)
        return  # the remaining arithmetic would divide by zero
    oh = (L.h - L.kh) // L.stride + 1
    ow = (L.w - L.kw) // L.stride + 1
    if oh < 1 or ow < 1:
        sink.add("RPA102",
                 f"{L.kernel}: kernel {L.kh}x{L.kw} does not fit the padded "
                 f"{L.h}x{L.w} input (conv output {oh}x{ow})",
                 hint="the ConvSpec padding must leave >= one window", **loc)
        return
    last_h = (oh - 1) * L.stride + L.kh
    last_w = (ow - 1) * L.stride + L.kw
    if last_h > L.h or last_w > L.w:
        sink.add("RPA102",
                 f"{L.kernel}: last window reads row {last_h}/col {last_w} "
                 f"of a {L.h}x{L.w} input (index map out of bounds)", **loc)

    # --- RPA101: grid x block covers the output exactly once -------------
    if not _pad_ok(L.c, L.c_pad, L.block_c, L.n_cb):
        sink.add("RPA101",
                 f"{L.kernel}: channel blocking c={L.c}+{L.c_pad} pad != "
                 f"{L.n_cb} x block_c={L.block_c}",
                 hint="n_cb must equal ceil(c / block_c) with minimal pad",
                 **loc)
    if not _pad_ok(L.o, L.o_pad, L.block_o, L.n_ob):
        sink.add("RPA101",
                 f"{L.kernel}: output blocking o={L.o}+{L.o_pad} pad != "
                 f"{L.n_ob} x block_o={L.block_o}",
                 hint="n_ob must equal ceil(o / block_o) with minimal pad",
                 **loc)
    if (L.oh, L.ow) != (oh, ow):
        sink.add("RPA101",
                 f"{L.kernel}: descriptor says conv output {L.oh}x{L.ow} but "
                 f"(h,w,k,stride)=({L.h},{L.w},{L.kh},{L.kw},{L.stride}) "
                 f"gives {oh}x{ow}",
                 hint="oh/ow must be (h - kh) // stride + 1", **loc)

    # --- RPA105: fused pool tiles the conv output exactly ----------------
    if L.pool:
        if L.pool < 0 or L.oh % L.pool or L.ow % L.pool:
            sink.add("RPA105",
                     f"{L.kernel}: pool {L.pool}x{L.pool} does not tile the "
                     f"{L.oh}x{L.ow} conv output exactly — the fused "
                     f"epilogue floors, silently truncating the remainder",
                     hint="run the unit unfused (conv + pool) instead", **loc)

    # --- RPA103: VMEM budget ---------------------------------------------
    tile_bytes = L.x_tile_bytes + L.scratch_bytes
    if tile_bytes > VMEM_BUDGET_BYTES:
        explicit = L.block_c > 8  # the default policy shrinks to the floor
        sink.add("RPA103",
                 f"{L.kernel}: {tile_bytes} B tile "
                 f"(x {L.h}x{L.w}x{L.block_c} + acc {L.oh}x{L.ow}x"
                 f"{L.block_o}) exceeds the {VMEM_BUDGET_BYTES} B VMEM "
                 f"budget",
                 severity="error" if explicit else "warn",
                 hint=("shrink the requested tile" if explicit else
                       "spatial map too large even at the block_c floor"),
                 **loc)

    # --- RPA104: int8 accumulation / scale contract ----------------------
    if is_int8:
        if L.acc_dtype != "int32":
            sink.add("RPA104",
                     f"{L.kernel}: int8 operands accumulate in "
                     f"{L.acc_dtype!r}, must be int32",
                     hint="int8 MACs overflow anything narrower", **loc)
        if L.weight_scales != "per_output_channel":
            sink.add("RPA104",
                     f"{L.kernel}: int8 weight scales are "
                     f"{L.weight_scales!r}, must be per_output_channel",
                     hint="quantize_weight calibrates one scale per output "
                          "channel", **loc)


def check_bsr_launch(L: BsrLaunch, sink: DiagnosticSink, *,
                     layer: int | None = None, kind: str = "",
                     impl: str = "") -> None:
    loc = dict(layer=layer, kind=kind, impl=impl)
    is_int8 = L.dtype_bytes == 1 or L.kernel.endswith("_int8")

    # --- RPA102: positive extents ----------------------------------------
    if min(L.bt, L.bf, L.bd) <= 0 or min(L.t, L.f, L.d) <= 0:
        sink.add("RPA102",
                 f"{L.kernel}: non-positive launch dimension "
                 f"(t={L.t} f={L.f} d={L.d} blocks {L.bt}x{L.bf}x{L.bd})",
                 hint="every extent and block size must be >= 1", **loc)
        return

    # --- RPA101: blocking covers each operand exactly once ---------------
    for name, ext, pad, blk, n in (("t", L.t, L.t_pad, L.bt, L.nt),
                                   ("f", L.f, L.f_pad, L.bf, L.nf),
                                   ("d", L.d, L.d_pad, L.bd, L.nd)):
        if not _pad_ok(ext, pad, blk, n):
            sink.add("RPA101",
                     f"{L.kernel}: {name}={ext}+{pad} pad != {n} x "
                     f"block={blk} — the grid would tile dimension "
                     f"{name!r} {'short' if n * blk < ext + pad else 'over'}",
                     hint=f"n{name} must equal ceil({name} / b{name}) with "
                          "minimal pad", **loc)

    # --- RPA103: VMEM budget (defaults are tiny; over-budget => explicit)
    if L.tile_bytes > VMEM_BUDGET_BYTES:
        sink.add("RPA103",
                 f"{L.kernel}: {L.tile_bytes} B resident tile "
                 f"({L.bt}x{L.bf} + {L.bf}x{L.bd} operands + {L.bt}x{L.bd} "
                 f"acc) exceeds the {VMEM_BUDGET_BYTES} B VMEM budget",
                 hint="shrink the requested (bt, bf, bd)", **loc)

    # --- RPA104: int8 contract -------------------------------------------
    if is_int8:
        if L.acc_dtype != "int32":
            sink.add("RPA104",
                     f"{L.kernel}: int8 operands accumulate in "
                     f"{L.acc_dtype!r}, must be int32", **loc)
        if L.weight_scales != "per_output_channel":
            sink.add("RPA104",
                     f"{L.kernel}: int8 weight scales are "
                     f"{L.weight_scales!r}, must be per_output_channel",
                     **loc)


def check_launch(L, sink: DiagnosticSink, *, layer: int | None = None,
                 kind: str = "", impl: str = "") -> None:
    """Dispatch on descriptor type (the registry's `unit_launch` returns
    either family, or None for impls with no Pallas grid)."""
    if L is None:
        return
    if isinstance(L, ConvLaunch):
        check_conv_launch(L, sink, layer=layer, kind=kind, impl=impl)
    elif isinstance(L, BsrLaunch):
        check_bsr_launch(L, sink, layer=layer, kind=kind, impl=impl)
    else:
        raise TypeError(f"unknown launch descriptor {type(L).__name__}")
