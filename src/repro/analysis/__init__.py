"""repro.analysis: diagnostics-coded static verification (DESIGN.md §12).

Verifies graphs, plans and Pallas launch geometry WITHOUT compiling or
executing anything: `verify_plan(plan, params)` returns structured
`Diagnostic` records with stable RPAxxx codes; `assert_plan_ok` raises a
`PlanVerificationError` (a ValueError) on error-severity findings. The
planner, the plan cache and the serving engine's hot-swap/re-plan paths all
verify through here; `python -m repro.analysis.cli` (repro-lint) sweeps the
model zoo from the command line.
"""
from repro.analysis.diagnostics import (
    CODES,
    Diagnostic,
    DiagnosticSink,
    diag,
    diagnostics_json,
    errors,
    format_diagnostics,
    sort_diagnostics,
)
from repro.analysis.launch import (
    check_bsr_launch,
    check_conv_launch,
    check_launch,
)
from repro.analysis.plan import check_launch_descriptor, check_plan
from repro.analysis.schedules import check_schedule, schedule_ok
from repro.analysis.verify import (
    PlanVerificationError,
    assert_plan_ok,
    verify_plan,
)

__all__ = [
    "CODES",
    "Diagnostic",
    "DiagnosticSink",
    "PlanVerificationError",
    "assert_plan_ok",
    "check_bsr_launch",
    "check_conv_launch",
    "check_launch",
    "check_launch_descriptor",
    "check_plan",
    "check_schedule",
    "diag",
    "diagnostics_json",
    "errors",
    "format_diagnostics",
    "schedule_ok",
    "sort_diagnostics",
    "verify_plan",
]
