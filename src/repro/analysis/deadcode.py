"""Dead-import analysis — RPA901 (info).

The repo grew from a generic LLM-training seed, and several seed modules
(`configs/arctic_480b.py`, `launch/train.py`, the optimizer stack, ...) are
not reachable from the
CNN serving spine this paper reproduction actually exercises. This walks
the static import graph — `ast` only, nothing is imported or executed — from
the spine's entry points and reports every module no import path reaches as
an info diagnostic, so the dormant surface stays visible (and the ruff
per-file-ignores list in pyproject.toml stays honest) without anyone
manually curating a list.

Imports are collected at ANY depth (the repo idiom is function-local lazy
imports), so a module only imported inside a function still counts as
reachable. Importing a submodule marks every ancestor package reachable
(their __init__ executes on import).
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.diagnostics import DiagnosticSink

#: the CNN spine: every module a `repro-lint` / serving run can enter through.
DEFAULT_ROOTS = ("repro.launch.serve_cnn", "repro.analysis.cli")


def _module_name(path: Path, src: Path) -> str:
    rel = path.relative_to(src).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _imports_of(path: Path, mod: str, known: set) -> set:
    """Module names (within `known`) this file can import, any depth."""
    tree = ast.parse(path.read_text(), filename=str(path))
    pkg_parts = mod.split(".")
    if path.name != "__init__.py":
        pkg_parts = pkg_parts[:-1]
    out = set()

    def add(name: str) -> None:
        # importing a.b.c executes a and a.b too
        parts = name.split(".")
        for i in range(1, len(parts) + 1):
            cand = ".".join(parts[:i])
            if cand in known:
                out.add(cand)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg_parts[: len(pkg_parts) - node.level + 1]
                prefix = ".".join(base + ([node.module] if node.module else []))
            else:
                prefix = node.module or ""
            if prefix:
                add(prefix)
            for alias in node.names:
                if prefix and alias.name != "*":
                    add(f"{prefix}.{alias.name}")
    return out


def import_graph(src: Path) -> tuple:
    """({module -> set of imported modules}, {module -> file}) over src/**/*.py."""
    files = {_module_name(p, src): p for p in sorted(src.rglob("*.py"))}
    known = set(files)
    return {m: _imports_of(p, m, known) for m, p in files.items()}, files


def dead_modules(src: Path, roots=DEFAULT_ROOTS) -> tuple:
    """(module names unreachable from `roots`, {module -> file})."""
    graph, files = import_graph(src)
    seen: set = set()
    frontier = [r for r in roots if r in graph]
    while frontier:
        m = frontier.pop()
        if m in seen:
            continue
        seen.add(m)
        # entering a module executes every ancestor package __init__
        parts = m.split(".")
        for i in range(1, len(parts)):
            pkg = ".".join(parts[:i])
            if pkg in graph and pkg not in seen:
                frontier.append(pkg)
        frontier.extend(graph[m] - seen)
    return sorted(m for m in graph if m not in seen), files


def check_dead_imports(src, sink: DiagnosticSink,
                       roots=DEFAULT_ROOTS) -> None:
    """Emit one RPA901 info diagnostic per unreachable module."""
    src = Path(src)
    dead, files = dead_modules(src, roots)
    for m in dead:
        sink.add("RPA901",
                 f"{m} ({files[m].relative_to(src)}) is unreachable from "
                 f"the CNN spine ({', '.join(roots)})",
                 kind="repo",
                 hint="seed leftover — candidates for removal or for the "
                      "ruff per-file-ignores list")
