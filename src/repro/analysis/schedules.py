"""(ids, cnt) schedule invariants — RPA207.

The ECR compression (`compact_block_ids`, `block_schedule`,
`batch_block_schedule`) always produces schedules satisfying:

  - 0 <= cnt <= n_blocks            (the kernel loops cnt times)
  - every id in [0, n_blocks)       (ids index block gathers — an
                                     out-of-range id is an OOB DMA)
  - ids[:cnt] strictly increasing   (argsort over a boolean mask is stable,
                                     so live blocks keep original order;
                                     duplicates would double-accumulate)

Entries BEYOND cnt are padding and deliberately unconstrained beyond the
range check (both builders pad with an arbitrary valid id so speculative
gathers stay in bounds — `compact_block_ids` uses order[0], `block_schedule`
the row's first live id, and the conv compact path identity ids).

These checks run on CONCRETE values (numpy), so they apply to the static
schedules — BSR weight schedules, which are compile-time constants once the
params are — and to eager test values. Traced activations have no values to
check; `repro.analysis.plan` skips them, and the run-time `guard_schedule`
clamp (`REPRO_CHECK_SCHEDULES=1`) covers the traced path instead.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.diagnostics import DiagnosticSink


def check_schedule(ids, cnt, n_blocks: int, sink: DiagnosticSink, *,
                   layer: int | None = None, kind: str = "",
                   impl: str = "") -> None:
    """Verify one schedule: ids (n,) with scalar cnt, or ids (rows, n) with
    cnt (rows,) — the per-row-block (BSR) / per-sample (batched conv) forms.
    Appends RPA207 diagnostics for every violated invariant."""
    loc = dict(layer=layer, kind=kind, impl=impl)
    ids = np.asarray(ids)
    cnt = np.asarray(cnt)
    if ids.ndim == 1:
        ids, cnt = ids[None], cnt.reshape(1)
    if ids.ndim != 2 or cnt.shape != (ids.shape[0],):
        sink.add("RPA207",
                 f"schedule shape mismatch: ids {ids.shape} with cnt "
                 f"{cnt.shape} (want (rows, n) ids with (rows,) cnt)", **loc)
        return
    if n_blocks <= 0:
        sink.add("RPA207", f"schedule over n_blocks={n_blocks} (must be >= 1)",
                 **loc)
        return
    for r in range(ids.shape[0]):
        row, c = ids[r], int(cnt[r])
        tag = f"row {r}: " if ids.shape[0] > 1 else ""
        if not 0 <= c <= n_blocks:
            sink.add("RPA207",
                     f"{tag}cnt={c} outside [0, n_blocks={n_blocks}] — the "
                     f"kernel would loop past the schedule",
                     hint="cnt counts live blocks; it can never exceed the "
                          "grid", **loc)
            continue
        if row.size and (row.min() < 0 or row.max() >= n_blocks):
            sink.add("RPA207",
                     f"{tag}ids outside [0, {n_blocks}): min={int(row.min())} "
                     f"max={int(row.max())} — an out-of-range id is an "
                     f"out-of-bounds block gather", **loc)
            continue
        live = row[:c]
        if live.size > 1 and not (np.diff(live) > 0).all():
            sink.add("RPA207",
                     f"{tag}ids[:cnt] not strictly increasing — a repeated "
                     f"id double-accumulates its block, an unsorted one "
                     f"breaks the compaction order the kernels assume",
                     **loc)


def schedule_ok(ids, cnt, n_blocks: int) -> bool:
    """Boolean convenience wrapper (tests / REPL)."""
    sink = DiagnosticSink()
    check_schedule(ids, cnt, n_blocks, sink)
    return not sink.items
