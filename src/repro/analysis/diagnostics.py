"""Diagnostic records for the static verifier (DESIGN.md §12).

Every check in `repro.analysis` reports through one currency: a `Diagnostic`
with a stable machine-readable code (RPAxxx), a severity, a location (layer
index + (kind, impl)), a human message and a fix hint. Stability of the codes
is the contract — tests assert on codes, CI greps for them, and the serving
telemetry counts them — so a code is never renumbered or reused; retired
checks leave a tombstone in the table below.

Code space:
  RPA1xx  launch geometry (Pallas grid/block/VMEM/dtype contracts)
  RPA2xx  graph / plan invariants (shapes, fusion legality, schedules, tiles)
  RPA3xx  plan-vs-params consistency (weight counts, shapes, density)
  RPA9xx  informational (dead modules, advisory notes)
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

ERROR = "error"
WARN = "warn"
INFO = "info"

#: code -> (default severity, one-line meaning). THE stable registry: every
#: diagnostic the subsystem can emit appears here, and tests/test_analysis.py
#: proves each one fires under a targeted corruption.
CODES: dict = {
    "RPA101": (ERROR, "grid x block does not tile the output exactly once"),
    "RPA102": (ERROR, "index map / input gather out of bounds"),
    "RPA103": (ERROR, "kernel tile exceeds the VMEM budget"),
    "RPA104": (ERROR, "int8 kernel without int32 accumulation or "
                      "per-output-channel scales"),
    "RPA105": (ERROR, "fused pool epilogue does not tile the conv output "
                      "exactly (the kernel floors)"),
    "RPA201": (ERROR, "plan/graph mismatch (layer count, shapes, specs)"),
    "RPA202": (ERROR, "graph topology or shape inference fails"),
    "RPA203": (ERROR, "fused layer fails the fusion-eligibility rule"),
    "RPA204": (WARN, "requested tile does not conform; kernel falls back "
                     "to defaults"),
    "RPA205": (ERROR, "BSR plan density disagrees with the params' measured "
                      "weight block density"),
    "RPA206": (WARN, "int8 layer without an Int8Report entry (accuracy "
                     "never probed)"),
    "RPA207": (ERROR, "(ids, cnt) schedule invariant violation"),
    "RPA208": (ERROR, "unknown (kind, impl) pair"),
    "RPA209": (ERROR, "plan field out of range (occupancy, density, "
                      "block_c)"),
    "RPA301": (ERROR, "params do not match the plan (weight counts or "
                      "shapes)"),
    "RPA901": (INFO, "module unreachable from the CNN spine (dead import)"),
}

_SEV_RANK = {ERROR: 0, WARN: 1, INFO: 2}


@dataclass(frozen=True)
class Diagnostic:
    """One verifier finding. `layer` is the 0-based conv index (None = whole
    plan / whole repo), (kind, impl) locate the op the finding is about."""

    code: str
    severity: str
    message: str
    layer: int | None = None
    kind: str = ""
    impl: str = ""
    hint: str = ""

    def where(self) -> str:
        loc = [] if self.layer is None else [f"conv_{self.layer + 1}"]
        if self.kind or self.impl:
            loc.append(f"{self.kind}/{self.impl}".strip("/"))
        return ":".join(loc) or "plan"

    def format(self) -> str:
        s = f"{self.code} [{self.severity}] {self.where()}: {self.message}"
        return f"{s} (hint: {self.hint})" if self.hint else s

    def to_json(self) -> dict:
        return asdict(self)


def diag(code: str, message: str, *, layer: int | None = None, kind: str = "",
         impl: str = "", hint: str = "", severity: str | None = None
         ) -> Diagnostic:
    """Build a `Diagnostic`, pulling the severity from the CODES table (an
    explicit `severity` overrides — RPA103 escalates warn->error when the
    over-budget tile was explicitly requested)."""
    default_sev, _ = CODES[code]
    return Diagnostic(code=code, severity=severity or default_sev,
                      message=message, layer=layer, kind=kind, impl=impl,
                      hint=hint)


def errors(diags) -> list:
    return [d for d in diags if d.severity == ERROR]


def sort_diagnostics(diags) -> list:
    """Errors first, then warns, then infos; stable within a severity."""
    return sorted(diags, key=lambda d: (_SEV_RANK.get(d.severity, 9),
                                        d.layer if d.layer is not None else -1))


def format_diagnostics(diags) -> str:
    return "\n".join(d.format() for d in sort_diagnostics(diags))


def diagnostics_json(diags, **extra) -> str:
    doc = {"diagnostics": [d.to_json() for d in sort_diagnostics(diags)],
           "n_errors": len(errors(diags)), **extra}
    return json.dumps(doc, indent=2)


@dataclass
class DiagnosticSink:
    """Tiny accumulator the checkers append into (keeps the check functions
    free of list plumbing)."""

    items: list = field(default_factory=list)

    def add(self, code: str, message: str, **kw) -> None:
        self.items.append(diag(code, message, **kw))
