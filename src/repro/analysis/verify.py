"""verify_plan: THE entry point callers integrate against.

- `verify_plan(plan, params=None, ...)` -> the full diagnostic list (the
  CLI and telemetry consumers want everything, warns included);
- `assert_plan_ok(...)` raises `PlanVerificationError` — a `ValueError`
  subclass, so every existing `pytest.raises(ValueError, match=...)`
  contract over the old `validate_plan` messages keeps holding — carrying
  the error-severity diagnostics on `.diagnostics`.

Hook points (DESIGN.md §12): `pipeline.planner.plan_network` asserts before
returning a freshly planned schedule; `pipeline.planner.validate_plan` is
now a thin wrapper (input-batch checks + this); `serving.plan_cache
.PlanCache.get_or_compile` refuses to AOT-compile an erroring plan;
`serving.engine.Engine.hot_swap` / re-plan adoption reject an erroring
candidate atomically and keep serving the old plan.
"""
from __future__ import annotations

from repro.analysis.diagnostics import errors, format_diagnostics
from repro.analysis.plan import check_plan


class PlanVerificationError(ValueError):
    """An error-severity diagnostic in a plan. Subclasses ValueError so
    callers that guarded the old validate_plan keep working unchanged."""

    def __init__(self, diagnostics):
        self.diagnostics = list(diagnostics)
        super().__init__(format_diagnostics(self.diagnostics))


def verify_plan(plan, params=None, *, graph=None, batch: int = 1) -> list:
    """Statically verify a plan (and optionally its params). Returns every
    diagnostic — errors, warns, infos; never raises. See `plan.check_plan`
    for the check inventory."""
    return check_plan(plan, params, graph=graph, batch=batch)


def assert_plan_ok(plan, params=None, *, graph=None, batch: int = 1) -> list:
    """`verify_plan`, raising `PlanVerificationError` on any error-severity
    finding. Returns the (warn/info-only) diagnostics otherwise."""
    diags = verify_plan(plan, params, graph=graph, batch=batch)
    bad = errors(diags)
    if bad:
        raise PlanVerificationError(bad)
    return diags
