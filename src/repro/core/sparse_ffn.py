"""The paper's technique lifted to transformer FFNs (DESIGN.md §4).

ReLU-family MLPs (minitron's squared-ReLU here; cf. Shi & Chu 2017, which the
paper builds on) produce activation tensors h = act(x @ W1) with exact zeros.
The down-projection h @ W2 is then a sparse x dense matmul with *data-dependent*
sparsity — structurally identical to ECR's compress-then-SpMV:

  occupancy(h, block)          == Ptr        (block granularity)
  compact_block_ids(occupancy) == F_data     (packed live-block list)
  bsr_matmul(h, W2, ids, cnt)  == Algorithm 2 SpMV

Inside the pjit'd model forward we keep the *dense-equivalent* formulation
(mask-and-matmul — numerically identical, SPMD-friendly); the actual skipping
is realized by `repro.kernels.bsr_matmul` and measured in the kernel
benchmarks. `sparse_ffn_stats` feeds the roofline's "useful FLOPs" accounting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparsity import block_occupancy


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def sparse_ffn_apply(x, w1, w2, activation: str = "relu2", block=(8, 128)):
    """x:(T,D) w1:(D,F) w2:(F,D). Returns (y, occupancy_fraction)."""
    h = activation_fn(activation)(x @ w1)
    t, f = h.shape
    bt = block[0] if t % block[0] == 0 else 1
    bf = block[1] if f % block[1] == 0 else f
    occ = block_occupancy(h, (bt, bf))  # (T/bt, F/bf) bool
    occ_e = jnp.repeat(jnp.repeat(occ, bt, 0), bf, 1)
    h = jnp.where(occ_e, h, 0.0)  # dense-equivalent of block skipping
    return h @ w2, occ.mean(dtype=jnp.float32)


def sparse_ffn_stats(x, w1, activation: str = "relu2", block=(8, 128)) -> dict:
    """Measured block/element sparsity of the FFN hidden state (roofline input)."""
    h = activation_fn(activation)(x @ w1)
    t, f = h.shape
    bt = block[0] if t % block[0] == 0 else 1
    bf = block[1] if f % block[1] == 0 else f
    occ = block_occupancy(h, (bt, bf))
    return {
        "element_sparsity": float((h == 0).mean()),
        "block_occupancy": float(occ.mean()),
        "skippable_flop_frac": float(1.0 - occ.mean()),
    }
