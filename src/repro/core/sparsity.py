"""Sparsity machinery shared by the ECR/PECR paths and the LM-side reuse.

The paper's "compression" step (Algorithm 1) counts and packs nonzero
activations per convolution window. On TPU the profitable granularity is a
*block* (DESIGN.md §2), so this module provides both:

- element-wise window statistics (faithful to the paper; used by the oracle,
  the MAC-reduction accounting, and the Θ = sparsity/size analysis), and
- block occupancy bitmaps ((8,128)-aligned by default) consumed by the Pallas
  kernels' scalar-prefetch grids and by the MoE dispatch (which is the same
  "compact the nonzero blocks" scheduling problem).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Window extraction (the paper's "extension"; im2col without HBM round-trip)
# ---------------------------------------------------------------------------


def extract_windows(x: jax.Array, kh: int, kw: int, stride: int = 1) -> jax.Array:
    """(C,H,W) -> (n_oh, n_ow, C*kh*kw) window matrix (im2col rows).

    This materializes the paper Fig. 1 extension — used only by the reference
    path and the GEMM baseline; the Pallas kernels form windows implicitly.
    """
    if x.ndim == 2:
        x = x[None]
    c, h, w = x.shape
    n_oh = (h - kh) // stride + 1
    n_ow = (w - kw) // stride + 1
    # gather via dynamic slicing vmapped over output coords
    oh = jnp.arange(n_oh) * stride
    ow = jnp.arange(n_ow) * stride

    def one(i, j):
        win = jax.lax.dynamic_slice(x, (0, i, j), (c, kh, kw))
        return win.reshape(-1)

    return jax.vmap(lambda i: jax.vmap(lambda j: one(i, j))(ow))(oh)


# ---------------------------------------------------------------------------
# Element-wise (paper-faithful) sparsity statistics
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WindowStats:
    """MAC accounting for one feature map, paper §IV-D / Fig. 6."""

    n_windows: int
    dense_muls: int
    dense_adds: int
    sparse_muls: int
    sparse_adds: int
    sparsity: float
    theta: float  # paper Fig. 11: Θ = (sparsity*100) / feature-map width

    @property
    def mul_reduction(self) -> float:
        return 1.0 - self.sparse_muls / max(self.dense_muls, 1)

    @property
    def add_reduction(self) -> float:
        return 1.0 - self.sparse_adds / max(self.dense_adds, 1)


def window_stats(x: np.ndarray, kh: int, kw: int, stride: int = 1) -> WindowStats:
    x = np.asarray(x)
    if x.ndim == 2:
        x = x[None]
    wins = np.asarray(extract_windows(jnp.asarray(x), kh, kw, stride))
    nnz = (wins != 0).sum(-1).reshape(-1)
    n_win = nnz.size
    k = wins.shape[-1]
    return WindowStats(
        n_windows=int(n_win),
        dense_muls=int(n_win * k),
        dense_adds=int(n_win * (k - 1)),
        sparse_muls=int(nnz.sum()),
        sparse_adds=int(np.maximum(nnz - 1, 0).sum()),
        sparsity=float((x == 0).mean()),
        theta=float((x == 0).mean() * 100.0 / x.shape[-1]),
    )


# ---------------------------------------------------------------------------
# Block occupancy (TPU-native granularity)
# ---------------------------------------------------------------------------


def block_occupancy(x: jax.Array, block: tuple[int, ...]) -> jax.Array:
    """Boolean map: True where the corresponding block of `x` has any nonzero.

    x is reshaped into blocks along its last len(block) dims (must divide).
    Returns shape = blocked grid dims. This is ECR's `Ptr != -1` at block
    granularity: the Pallas kernels prefetch it to skip dead blocks.
    """
    nb = len(block)
    lead, tail = x.shape[: x.ndim - nb], x.shape[x.ndim - nb :]
    for t, b in zip(tail, block):
        if t % b:
            raise ValueError(f"block {block} does not divide {tail}")
    grid = tuple(t // b for t, b in zip(tail, block))
    shp = lead + tuple(v for tb in zip(grid, block) for v in tb)
    xr = x.reshape(shp)
    # move block dims last and reduce them
    perm = list(range(len(lead)))
    perm += [len(lead) + 2 * i for i in range(nb)]
    perm += [len(lead) + 2 * i + 1 for i in range(nb)]
    xr = xr.transpose(perm)
    return jnp.any(xr != 0, axis=tuple(range(len(lead) + nb, len(lead) + 2 * nb)))


def compact_block_ids(occ: jax.Array, max_blocks: int | None = None):
    """ECR compression at block granularity.

    Given a 1-D occupancy vector, return (ids, count): `ids[i]` = index of the
    i-th nonzero block (padded with the last valid id so gathers stay in
    bounds) and `count` = number of live blocks. Mirrors F_data/Ptr: the kernel
    loops `count` times over `ids` instead of over the full grid.
    """
    occ = occ.reshape(-1)
    n = occ.shape[0] if max_blocks is None else max_blocks
    order = jnp.argsort(~occ, stable=True)  # live blocks first, original order
    count = occ.sum().astype(jnp.int32)
    # pad with a valid index (order[0]) so downstream gathers stay in bounds;
    # consumers mask by `count` exactly as Algorithm 2 masks by Ptr.
    ids = jnp.where(jnp.arange(occ.shape[0]) < count, order, order[0])
    return ids[:n].astype(jnp.int32), count


def occupancy_fraction(occ: jax.Array) -> jax.Array:
    return occ.mean(dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Feature-map dataset helpers (paper §VI-A provides a VGG-19 feature-map set)
# ---------------------------------------------------------------------------


def dead_channel_band(x, frac: float):
    """Zero the TRAILING `int(C * frac)` channels of a (C,H,W) / (N,C,H,W)
    feature map — the deterministic shared dead-channel band the serving
    stack calibrates and benchmarks with (every sample kills the same band,
    so co-batched requests share a live-channel union and the engine's
    exactness contract holds; DESIGN.md §2.2/§4). Contrast with
    `synth_feature_map(channel_dead_frac=...)`, which kills random channels.
    """
    c = x.shape[-3]
    n_dead = int(c * frac)
    if n_dead <= 0:
        return x
    mask = (jnp.arange(c) < c - n_dead).astype(x.dtype)[:, None, None]
    return x * mask


def synth_feature_map(key, shape, sparsity: float, dtype=jnp.float32,
                      channel_dead_frac: float | None = None) -> jax.Array:
    """Random feature map with target sparsity — post-ReLU-like (non-negative).

    Deep-layer sparsity in trained nets is partly *structured*: whole filters
    die (ReLU + BN shift), which `benchmarks/fig2_sparsity.py` measures on a
    VGG forward pass. `channel_dead_frac` controls how much of the target
    sparsity comes from fully-dead channels (default: half); the remainder is
    unstructured element sparsity. The TPU block-ECR win tracks the structured
    part (DESIGN.md §2) — benchmarks report both bounds.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    vals = jax.random.uniform(k1, shape, dtype, 1e-3, 1.0)
    if len(shape) == 3 and shape[0] > 1:
        cdf = sparsity * 0.5 if channel_dead_frac is None else channel_dead_frac
        ch_keep = jax.random.uniform(k3, (shape[0], 1, 1)) >= cdf
        # element sparsity on surviving channels to hit the overall target
        resid = jnp.clip((sparsity - cdf) / jnp.maximum(1 - cdf, 1e-6), 0.0, 1.0)
        keep = (jax.random.uniform(k2, shape) >= resid) & ch_keep
    else:
        keep = jax.random.uniform(k2, shape) >= sparsity
    return jnp.where(keep, vals, 0.0).astype(dtype)
