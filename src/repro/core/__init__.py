"""The paper's contribution as a composable library.

- ECR sparse convolution (paper §IV): `repro.core.ecr`
- PECR fused conv+ReLU+pool (paper §V): `repro.core.pecr`
- Sparsity machinery shared with the LM stack: `repro.core.sparsity`
- The technique lifted to FFNs: `repro.core.sparse_ffn`
"""
from repro.core.ecr import (
    ECR,
    compact_live_channels,
    compact_live_channels_batch,
    conv2d,
    conv2d_dense,
    conv2d_ecr,
    conv2d_im2col,
    ecr_compress,
    ecr_spmv,
)
from repro.core.pecr import PECR, conv_pool, conv_pool_pecr, conv_pool_unfused, pecr_compress, pecr_conv_pool
from repro.core.sparsity import (
    block_occupancy,
    compact_block_ids,
    dead_channel_band,
    synth_feature_map,
    window_stats,
)

__all__ = [
    "ECR",
    "PECR",
    "block_occupancy",
    "compact_block_ids",
    "compact_live_channels",
    "compact_live_channels_batch",
    "conv2d",
    "conv2d_dense",
    "conv2d_ecr",
    "conv2d_im2col",
    "conv_pool",
    "conv_pool_pecr",
    "conv_pool_unfused",
    "ecr_compress",
    "ecr_spmv",
    "pecr_compress",
    "pecr_conv_pool",
    "dead_channel_band",
    "synth_feature_map",
    "window_stats",
]
