"""ECR (Extended & Compressed Row) format and sparse convolution — paper §IV.

Faithful functional port of Algorithms 1 & 2:

- `ecr_compress`  = Algorithm 1. One "thread" per convolution window packs the
  window's nonzero activations into F_data and the co-indexed kernel taps into
  K_data; Ptr holds the nonzero count (-1 sentinel for an all-zero window).
  JAX needs static shapes, so F_data/K_data are (n_windows, C*kh*kw) with the
  live entries packed to the front (a stable partition — exactly the order the
  sequential loop in Algorithm 1 produces).
- `ecr_spmv`      = Algorithm 2. Each row is an SpMV dot of length Ptr[row].

The element-wise zero *skipping* of the GPU kernel becomes element-wise zero
*masking* here (a vector machine does not win by skipping lanes); the MAC
accounting (`repro.core.sparsity.window_stats`) still reports the paper's
skipped-op counts, and the TPU-profitable realization is the block-sparse
Pallas kernel in `repro.kernels.ecr_conv` (scalar-prefetched occupancy ==
block-granularity Ptr).

Layout conventions: feature maps are (C, H, W) or batched (N, C, H, W);
kernels are (C, kh, kw) for one output channel, or (O, C, kh, kw); padding is
VALID (the paper's setting), stride configurable (paper evaluates 1, 2, 3).
Batched inputs vmap the per-image algorithms (the kernel tensor is shared
across the batch — the batch-level reuse of Shi & Chu); the TPU-profitable
batched realization is the native batched grid in `repro.kernels.ecr_conv`.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparsity import extract_windows

# ---------------------------------------------------------------------------
# ECR format
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("f_data", "k_data", "ptr"),
    meta_fields=("out_shape",),
)
@dataclass
class ECR:
    """One feature map x one kernel, in ECR form (paper Fig. 4)."""

    f_data: jax.Array  # (n_oh*n_ow, C*kh*kw) nonzeros packed front
    k_data: jax.Array  # (n_oh*n_ow, C*kh*kw) co-indexed kernel taps
    ptr: jax.Array  # (n_oh*n_ow,) int32 nonzero count, -1 if window empty
    out_shape: tuple  # (n_oh, n_ow)


@partial(jax.jit, static_argnames=("kh", "kw", "stride"))
def ecr_compress(x: jax.Array, kernel: jax.Array, kh: int, kw: int, stride: int = 1) -> ECR:
    """Algorithm 1 (vectorized over windows): extension + compression fused.

    x: (C,H,W) one image, or (N,C,H,W) a batch — batched form returns an ECR
    whose f_data/k_data/ptr carry a leading batch dim (shared out_shape).
    """
    if x.ndim == 2:
        x = x[None]
    if kernel.ndim == 2:
        kernel = kernel[None]
    if x.ndim == 4:
        return jax.vmap(lambda xi: ecr_compress(xi, kernel, kh, kw, stride))(x)
    wins = extract_windows(x, kh, kw, stride)  # (oh, ow, K)
    oh, ow, K = wins.shape
    rows = wins.reshape(-1, K)
    kvec = kernel.reshape(-1)  # (K,)
    nz = rows != 0
    # stable partition: nonzero entries first, preserving scan order (== the
    # order `temp++` writes them in Algorithm 1)
    order = jnp.argsort(~nz, axis=1, stable=True)
    f_data = jnp.take_along_axis(rows, order, axis=1)
    k_data = jnp.take_along_axis(jnp.broadcast_to(kvec, rows.shape), order, axis=1)
    counts = nz.sum(1).astype(jnp.int32)
    ptr = jnp.where(counts > 0, counts, -1)
    # zero out the padding tail so masked SpMV cannot pick up stale taps
    lane = jnp.arange(K)[None, :]
    live = lane < counts[:, None]
    f_data = jnp.where(live, f_data, 0)
    k_data = jnp.where(live, k_data, 0)
    return ECR(f_data=f_data, k_data=k_data, ptr=ptr, out_shape=(oh, ow))


@jax.jit
def ecr_spmv(ecr: ECR) -> jax.Array:
    """Algorithm 2: one SpMV row -> one convolution output.

    Accepts single-image ECR (2-D f_data) or batched ECR (3-D f_data, from a
    batched `ecr_compress`) and returns (oh, ow) / (N, oh, ow) accordingly.
    """
    lane = jnp.arange(ecr.f_data.shape[-1])
    live = lane < jnp.maximum(ecr.ptr, 0)[..., None]
    out = jnp.sum(jnp.where(live, ecr.f_data * ecr.k_data, 0.0), axis=-1)
    out = jnp.where(ecr.ptr == -1, 0.0, out)  # Algorithm 2 line 1-2
    return out.reshape(out.shape[:-1] + ecr.out_shape)


# ---------------------------------------------------------------------------
# Channel compaction (ECR packing at channel granularity, TPU-native)
# ---------------------------------------------------------------------------


def compact_live_channels(x: jax.Array, kernels: jax.Array):
    """Pack live (any-nonzero) input channels into a dense prefix.

    Convolution is invariant under a shared permutation of x's channels and
    the kernels' input-channel dim, so a stable live-first argsort turns
    element/channel sparsity into *contiguous block* sparsity: the gathered
    Pallas schedule then skips ceil(n_live/bc)..n_cb entirely (DMA + MXU).
    This is exactly ECR's "pack nonzeros to the front" lifted to the channel
    axis; in production the pack is fused into the producing layer's epilogue
    (it already writes this tensor), the same way PECR fuses pooling.

    Returns (x_packed, kernels_packed, n_live).
    """
    live = jnp.any(x != 0, axis=(1, 2))  # (C,)
    order = jnp.argsort(~live, stable=True).astype(jnp.int32)
    n_live = live.sum().astype(jnp.int32)
    return x[order], kernels[:, order], n_live


def compact_live_channels_batch(x: jax.Array, kernels: jax.Array):
    """Batched channel compaction with ONE shared permutation.

    A per-sample permutation would need a per-sample copy of the kernel
    tensor, defeating the batch-level weight reuse the batched kernels exist
    for. Instead the pack is over the *union* of live channels across the
    batch (a channel is kept if any sample uses it); per-sample raggedness is
    recovered downstream by per-sample block-occupancy schedules on the packed
    tensor. Returns (x_packed (N,C,H,W), kernels_packed, n_live_union).
    """
    live = jnp.any(x != 0, axis=(0, 2, 3))  # (C,) union over batch + space
    order = jnp.argsort(~live, stable=True).astype(jnp.int32)
    n_live = live.sum().astype(jnp.int32)
    return x[:, order], kernels[:, order], n_live


# ---------------------------------------------------------------------------
# Public conv entry points — (C,H,W) single image or (N,C,H,W) batch
# ---------------------------------------------------------------------------


def conv2d_ecr(x: jax.Array, kernels: jax.Array, stride: int = 1) -> jax.Array:
    """Sparse convolution via ECR. x: (C,H,W) -> (O,oh,ow), or batched
    (N,C,H,W) -> (N,O,oh,ow); kernels: (O,C,kh,kw), shared across the batch.

    Multi-channel handling per paper §V-E: all channels of a window are
    compressed together, then SpMV runs once. The batch dim rides the batched
    ECR format: compression is per-sample, the kernel taps are gathered once
    per output channel.
    """
    if kernels.ndim == 3:
        kernels = kernels[None]
    o, c, kh, kw = kernels.shape

    def per_out(kern):
        return ecr_spmv(ecr_compress(x, kern, kh, kw, stride))

    out = jax.vmap(per_out)(kernels)  # (O, ...) — batch dim, if any, is axis 1
    return jnp.moveaxis(out, 0, 1) if x.ndim == 4 else out


def conv2d_dense(x: jax.Array, kernels: jax.Array, stride: int = 1) -> jax.Array:
    """Dense baseline (the cuDNN stand-in): lax conv, VALID padding.

    (C,H,W) -> (O,oh,ow) or (N,C,H,W) -> (N,O,oh,ow) (native lax batching).
    """
    if x.ndim == 2:
        x = x[None]
    if kernels.ndim == 3:
        kernels = kernels[None]
    batched = x.ndim == 4
    out = jax.lax.conv_general_dilated(
        (x if batched else x[None]).astype(jnp.float32),
        kernels.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out if batched else out[0]


def conv2d_im2col(x: jax.Array, kernels: jax.Array, stride: int = 1) -> jax.Array:
    """im2col + GEMM baseline (paper §VII 'im2col'): materialized extension."""
    if x.ndim == 2:
        x = x[None]
    if kernels.ndim == 3:
        kernels = kernels[None]
    if x.ndim == 4:
        return jax.vmap(lambda xi: conv2d_im2col(xi, kernels, stride))(x)
    o, c, kh, kw = kernels.shape
    wins = extract_windows(x, kh, kw, stride)  # (oh, ow, K)
    oh, ow, K = wins.shape
    a = wins.reshape(-1, K)  # (P, K)
    b = kernels.reshape(o, K).T  # (K, O)
    return (a @ b).T.reshape(o, oh, ow)


def conv2d(x, kernels, stride: int = 1, impl: str = "dense") -> jax.Array:
    """Multi-impl conv entry point; dispatch lives in the op registry
    (`repro.graph.registry`), not in a local if/elif chain."""
    from repro.graph.registry import get_op

    return get_op("conv", impl).forward(x, kernels, stride=stride)
