"""PECR (Pooling-pack ECR): fused convolution + ReLU + max-pool — paper §V.

Algorithm 3 packs, per *pooling* window, the `p_w*p_h` convolution windows that
feed one pooled output (Data/Index/Count); Algorithm 4 runs the SpMV for each
packed conv window, applies ReLU, and max-reduces in registers so the conv
result never touches off-chip memory.

Functional port: `pecr_compress` builds (n_pool_windows, p*p, C*kh*kw) packed
tensors; `pecr_conv_pool` consumes them. The fused-traffic claim is what
matters on TPU — realized for real in `repro.kernels.conv_pool` (single
pallas_call, pooled tile is the only HBM write); here we provide the faithful
oracle + the byte accounting used by `benchmarks/fig12_pecr.py`.

Note: paper Algorithm 3 line 11 stores ``Index[cnt] <- i*j+i``; the worked
figures require ``i*k_w+j`` (row-major tap index). We implement the corrected
form; the equivalence property test pins this against direct convolution.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.ecr import conv2d_dense
from repro.core.sparsity import extract_windows


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("data", "index", "count"),
    meta_fields=("out_shape",),
)
@dataclass
class PECR:
    data: jax.Array  # (n_pool, p*p, K) nonzero activations, packed front
    index: jax.Array  # (n_pool, p*p, K) kernel-tap indices for each value
    count: jax.Array  # (n_pool, p*p) nonzeros per conv window
    out_shape: tuple  # (n_poh, n_pow)


@partial(jax.jit, static_argnames=("kh", "kw", "c_s", "p", "p_s"))
def pecr_compress(x: jax.Array, kh: int, kw: int, c_s: int = 1, p: int = 2, p_s: int | None = None) -> PECR:
    """Algorithm 3, vectorized. One row of `data` = one pooling unit.

    x: (C,H,W) one image, or (N,C,H,W) a batch — batched form returns a PECR
    whose data/index/count carry a leading batch dim (shared out_shape).
    """
    if x.ndim == 2:
        x = x[None]
    if x.ndim == 4:
        return jax.vmap(lambda xi: pecr_compress(xi, kh, kw, c_s, p, p_s))(x)
    p_s = p if p_s is None else p_s  # pooling stride (paper uses p_s == p or 1)
    wins = extract_windows(x, kh, kw, c_s)  # (oh, ow, K) conv windows
    oh, ow, K = wins.shape
    n_poh = (oh - p) // p_s + 1
    n_pow = (ow - p) // p_s + 1
    # gather the p*p conv windows per pooling unit
    ph = jnp.arange(n_poh) * p_s
    pw = jnp.arange(n_pow) * p_s
    dh, dw = jnp.meshgrid(jnp.arange(p), jnp.arange(p), indexing="ij")

    def pool_unit(i, j):
        rows = wins[i + dh.reshape(-1), j + dw.reshape(-1)]  # (p*p, K)
        return rows

    packed = jax.vmap(lambda i: jax.vmap(lambda j: pool_unit(i, j))(pw))(ph)
    packed = packed.reshape(-1, p * p, K)
    nz = packed != 0
    order = jnp.argsort(~nz, axis=-1, stable=True)
    data = jnp.take_along_axis(packed, order, axis=-1)
    index = jnp.take_along_axis(
        jnp.broadcast_to(jnp.arange(K, dtype=jnp.int32), packed.shape), order, axis=-1
    )
    count = nz.sum(-1).astype(jnp.int32)
    lane = jnp.arange(K)[None, None, :]
    data = jnp.where(lane < count[..., None], data, 0)
    return PECR(data=data, index=index, count=count, out_shape=(n_poh, n_pow))


@jax.jit
def pecr_conv_pool(pecr: PECR, kernel: jax.Array) -> jax.Array:
    """Algorithm 4: per pooling unit, p*p SpMVs -> ReLU -> max.

    Accepts single-image PECR (3-D data) or batched PECR (4-D data, from a
    batched `pecr_compress`); the kernel is shared across the batch.
    """
    kvec = kernel.reshape(-1)
    taps = kvec[pecr.index]  # (..., n_pool, p*p, K)
    lane = jnp.arange(pecr.data.shape[-1])
    live = lane < pecr.count[..., None]
    conv = jnp.sum(jnp.where(live, pecr.data * taps, 0.0), axis=-1)  # (..., n_pool, p*p)
    conv = jnp.maximum(conv, 0.0)  # ReLU, paper §V-D
    pooled = conv.max(axis=-1)
    return pooled.reshape(pooled.shape[:-1] + pecr.out_shape)


# ---------------------------------------------------------------------------
# Public fused entry points
# ---------------------------------------------------------------------------


def conv_pool_pecr(x, kernels, c_s: int = 1, p: int = 2, p_s: int | None = None):
    """(C,H,W) x (O,C,kh,kw) -> (O, n_poh, n_pow) fused conv+ReLU+maxpool.

    Batched: (N,C,H,W) -> (N, O, n_poh, n_pow); compression is per-sample,
    the PECR packed tensors carry the batch dim, kernels are shared.
    """
    if kernels.ndim == 3:
        kernels = kernels[None]
    o, c, kh, kw = kernels.shape
    pecr = pecr_compress(x, kh, kw, c_s, p, p_s)

    def per_out(kern):
        return pecr_conv_pool(pecr, kern)

    out = jax.vmap(per_out)(kernels)  # (O, ...) — batch dim, if any, is axis 1
    return jnp.moveaxis(out, 0, 1) if x.ndim == 4 else out


def conv_pool_unfused(x, kernels, c_s: int = 1, p: int = 2, p_s: int | None = None):
    """Baseline: dense conv -> materialize -> ReLU -> maxpool (separate ops)."""
    p_s = p if p_s is None else p_s
    conv = conv2d_dense(x, kernels, c_s)
    conv = jnp.maximum(conv, 0.0)
    pool_dims = (1,) * (conv.ndim - 2) + (p, p)
    pool_strides = (1,) * (conv.ndim - 2) + (p_s, p_s)
    return jax.lax.reduce_window(
        conv,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=pool_dims,
        window_strides=pool_strides,
        padding="VALID",
    )


def conv_pool(x, kernels, c_s=1, p=2, p_s=None, impl="unfused"):
    """Multi-impl fused/unfused conv+ReLU+pool entry point; dispatch lives in
    the op registry (`repro.graph.registry`), not in a local if/elif chain."""
    from repro.graph.ir import PoolSpec
    from repro.graph.registry import get_op

    pool = PoolSpec(p, stride=0 if p_s is None else p_s, mode="floor")
    return get_op("conv_pool", impl).forward(x, kernels, stride=c_s, pool=pool)


# ---------------------------------------------------------------------------
# Traffic accounting (paper Fig. 3 / Fig. 12 argument, in bytes)
# ---------------------------------------------------------------------------


def fused_traffic_bytes(x_shape, o, kh, kw, c_s=1, p=2, dtype_bytes=4) -> dict:
    """Model HBM traffic of fused vs unfused conv+pool for one layer."""
    c, h, w = x_shape
    oh, ow = (h - kh) // c_s + 1, (w - kw) // c_s + 1
    poh, pow_ = oh // p, ow // p
    read_x = c * h * w * dtype_bytes
    read_k = o * c * kh * kw * dtype_bytes
    conv_out = o * oh * ow * dtype_bytes
    pool_out = o * poh * pow_ * dtype_bytes
    unfused = read_x + read_k + conv_out + conv_out + pool_out  # write conv, re-read conv
    fused = read_x + read_k + pool_out
    return {"unfused_bytes": unfused, "fused_bytes": fused, "saved_frac": 1 - fused / unfused}
