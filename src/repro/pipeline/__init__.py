"""Batched sparse-CNN inference pipeline (planner + executor).

`plan_network` walks a `CNNConfig` + params with a calibration batch, measures
the channel-block occupancy each conv layer actually runs at, and decides per
layer between the dense path, the ECR sparse kernel, and the fused PECR
conv+ReLU+pool kernel. `run_plan` executes the emitted layer sequence over a
whole batch, one jitted op per fused layer. Future serving/autotuning PRs
hang off the `PipelinePlan` artifact (it is a plain, inspectable schedule).
"""
from repro.pipeline.planner import (
    LayerPlan,
    PipelinePlan,
    measure_occupancy,
    occupancy_stat,
    plan_network,
    run_plan,
    validate_plan,
)

__all__ = [
    "LayerPlan",
    "PipelinePlan",
    "measure_occupancy",
    "occupancy_stat",
    "plan_network",
    "run_plan",
    "validate_plan",
]
