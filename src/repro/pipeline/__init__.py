"""Batched sparse-CNN inference pipeline (planner + executor).

`plan_network` walks any `LayerGraph` (VGG-19, LeNet, AlexNet, ...; a legacy
`CNNConfig` is lowered automatically) + params with a calibration batch,
measures the channel-block occupancy each conv unit actually runs at, and
decides per layer between the dense path, the ECR sparse kernel, and — where
the registry's fusion rule admits it — the fused PECR conv+ReLU+pool kernel.
`run_plan` executes the emitted layer sequence over a whole batch, one jitted
op per planned layer, every op resolved through `repro.graph.registry`.
Serving and autotuning hang off the `PipelinePlan` artifact (a plain,
inspectable schedule that carries its graph).
"""
from repro.pipeline.planner import (
    LayerPlan,
    PipelinePlan,
    measure_occupancy,
    occupancy_stat,
    plan_network,
    run_plan,
    run_plan_sharded,
    validate_plan,
)

__all__ = [
    "LayerPlan",
    "PipelinePlan",
    "measure_occupancy",
    "occupancy_stat",
    "plan_network",
    "run_plan",
    "run_plan_sharded",
    "validate_plan",
]
