"""Per-layer dense/ECR/PECR/BSR planning over the LayerGraph IR.

The paper's win is layer-dependent (Fig. 9: early layers are dense and big,
deep layers are small and very sparse), so a whole-network setting is always
wrong somewhere. The planner walks a `LayerGraph` (any linear CNN — VGG-19,
LeNet, AlexNet; a `CNNConfig` is lowered via `as_graph`) on a calibration
batch, measures per conv unit the channel-block occupancy the ECR kernel
would actually run at — the post-compaction ceil(n_live/bc)/n_cb of
DESIGN.md §2.2, averaged over samples — and emits a `PipelinePlan`: one
`LayerPlan` per conv unit, fused with its pooling (PECR) when the unit is
sparse AND the registry's fusion rule admits it (adjacent ReLU+pool,
stride == p, exact tiling), left as conv + unfused pool otherwise.

Weight sparsity is the second, STATIC axis (DESIGN.md §7): each layer's
params carry a measured BSR block density, and a pruned layer may run
`("conv", "bsr")` — weight blocks skipped instead of activation blocks.
The two axes trade off per layer (BSR reads every window but only the live
weight blocks; ECR reads every weight but only the live activation blocks),
so the planner arbitrates by the registry's modeled cost: below the density
gate, BSR displaces the occupancy-rule choice iff its roofline time wins.

The plan is a static, hashable schedule that carries its graph: `run_plan`
executes it over any batch of the calibrated shape, one jitted whole-batch op
per layer, every op resolved through the registry (`repro.graph.registry`) —
there is no impl dispatch here. This is the seam where serving (plan once,
execute per request batch) and autotuning (search over thresholds/block
sizes, keep the best plan) attach.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.graph import as_graph
from repro.graph.executor import run_head, run_unit
from repro.graph.ir import ConvSpec, LayerGraph, PoolSpec, graph_weights
from repro.graph.registry import fusion_eligible, get_op, unit_model_us
from repro.kernels.tiles import TileConfig, resolve_block_c


@dataclass(frozen=True)
class LayerPlan:
    """One conv unit's placement decision."""

    index: int  # conv index in network order (0-based)
    stage: int  # pooling stage (number of pools crossed before this conv)
    slot: int  # index within the stage
    kind: str  # "conv" | "conv_pool" (the chosen op kind; fused == conv_pool)
    impl: str  # "dense" | "ecr_pallas" | "pecr_pallas" | "ecr" | "pecr" | "bsr"
    occupancy: float  # measured mean channel-block occupancy of the input
    in_shape: tuple  # (C, H, W) entering the layer (pre-padding)
    out_shape: tuple  # (C, H, W) leaving the layer (post-pool if any)
    conv: ConvSpec = ConvSpec(0)  # the unit's conv node (k, stride, pad)
    relu: bool = True  # adjacent ReLU present
    pool: PoolSpec | None = None  # adjacent pool node (None = in-stage conv)
    weight_density: float = 1.0  # measured BSR block density of the params
    tile: TileConfig | None = None  # searched kernel geometry (None = defaults)

    def to_unit(self):
        """The `ConvUnit` this plan entry executes. The LayerPlan is the
        single source of structural truth at run time — `run_plan` executes
        from here, never by re-walking `plan.graph` (a mismatched graph must
        not be able to change what a validated plan runs)."""
        from repro.graph.ir import ConvUnit

        if self.conv.c_out == 0:
            raise ValueError(
                f"conv_{self.index + 1} carries no ConvSpec — this plan "
                "predates the LayerGraph IR; rebuild it with plan_network")
        return ConvUnit(index=self.index, stage=self.stage, slot=self.slot,
                        conv=self.conv, relu=self.relu, pool=self.pool,
                        in_shape=self.in_shape, out_shape=self.out_shape)


@dataclass(frozen=True)
class PipelinePlan:
    layers: tuple  # tuple[LayerPlan, ...]
    occ_threshold: float
    block_c: int  # 0 = auto per layer (ops._pick_block_c)
    graph: LayerGraph | None = None  # the IR the plan was made for
    int8_report: object = None  # quant.Int8Report when int8 planning probed

    def counts(self) -> dict:
        c = {"dense": 0, "sparse": 0, "fused": 0, "bsr": 0, "int8": 0}
        for lp in self.layers:
            op = get_op(lp.kind, lp.impl)
            if op.quantized:
                c["int8"] += 1  # counted in its own bucket AND its family's
            if op.weight_sparse:
                c["bsr"] += 1
            elif op.sparse:
                c["sparse"] += 1
                if lp.kind == "conv_pool":
                    c["fused"] += 1
            else:
                c["dense"] += 1
        return c


def occupancy_stat(x, block_c: int = 0, n_valid=None, tile=None,
                   dtype_bytes: int = 4):
    """Traced (jit-safe) channel-block occupancy, measured the way the batched
    kernel schedules: shared-union channel compaction, then PER-SAMPLE block
    occupancy on the packed layout (== mean_b cnt_b / n_cb of
    `batch_block_schedule`). For one image this reduces to the compacted
    ceil(n_live / bc) / n_cb of DESIGN.md §2.2.

    The block size is the one the kernel ACTUALLY resolves for this shape
    (`resolve_block_c` — same rule, same fallbacks), so the statistic and
    the executed schedule can never disagree about the geometry; `tile`
    (a TileConfig) takes precedence over the legacy `block_c` scalar.

    x: (N,C,H,W) or (C,H,W). `n_valid` (optional, traced) restricts the
    statistic to the first `n_valid` samples — the serving engine measures
    occupancy over the real requests of a padded bucket, and the all-zero pad
    samples contribute nothing to the union so the masked measurement equals
    what the kernel's per-sample schedules do for the real samples. `n_valid`
    is clamped to [0, N]: 0 (a bucket of pure pads) reports 0.0 occupancy,
    and a count beyond the batch cannot deflate the mean. Returns a scalar
    array (fraction of channel-block work NOT skipped).
    """
    if x.ndim == 3:
        x = x[None]
    n, c, h, w = x.shape
    t = tile if tile is not None and tile else TileConfig(block_c=block_c)
    bc = resolve_block_c(h, w, c, t, dtype_bytes)
    n_cb = -(-c // bc)
    live = jnp.any(x != 0, axis=(2, 3))  # (N, C) per-sample live channels
    if n_valid is not None:
        nv = jnp.clip(jnp.asarray(n_valid, jnp.int32), 0, n)
        live = live & (jnp.arange(n) < nv)[:, None]
    union_order = jnp.argsort(~jnp.any(live, axis=0), stable=True)
    packed = live[:, union_order]  # one shared permutation, like the kernel
    packed = jnp.pad(packed, ((0, 0), (0, n_cb * bc - c)))
    blk_live = packed.reshape(n, n_cb, bc).any(axis=2)  # (N, n_cb)
    if n_valid is None:
        return blk_live.mean()
    per_sample = blk_live.mean(axis=1)  # (N,)
    return jnp.where(jnp.arange(n) < nv, per_sample, 0.0).sum() / jnp.maximum(nv, 1)


def measure_occupancy(x, block_c: int = 0, tile=None,
                      dtype_bytes: int = 4) -> float:
    """Concrete-value wrapper of `occupancy_stat` (see its docstring)."""
    return float(occupancy_stat(x, block_c, tile=tile, dtype_bytes=dtype_bytes))


def plan_network(
    params,
    calib,
    graph=None,
    *,
    occ_threshold: float = 0.75,
    block_c: int = 0,
    use_pallas: bool = True,
    bsr_threshold: float = 0.5,
    calibration=None,
    tiles=None,
    int8: bool = False,
    int8_budget: float = 0.98,
) -> PipelinePlan:
    """Walk the graph's conv units on a calibration batch, emit the schedule.

    `graph` is a `LayerGraph` or a legacy `CNNConfig` (lowered via
    `as_graph`; None = full VGG-19). A unit goes sparse when its measured
    occupancy is <= occ_threshold (the skipped blocks must pay for the
    compaction gather; at occupancy ~1.0 the sparse path is pure overhead).
    A sparse unit whose structure passes the registry's fusion rule runs the
    fused conv+ReLU+pool op; any other pool stays unfused.

    The STATIC axis rides next to the measured one: each layer's weights
    carry a BSR block density (`repro.sparse_weights`; 1.0 for unpruned
    params, so nothing below fires on a dense model). When a layer's density
    is <= `bsr_threshold`, the `("conv", "bsr")` impl competes against the
    occupancy-rule choice on the registry's modeled roofline time
    (`unit_model_us`) and displaces it iff it wins — BSR trades reading
    every window for reading only live weight blocks, so it beats ECR
    exactly when the weight density undercuts the activation occupancy (and
    beats dense almost always once pruned).

    `calibration` (a `repro.obs.calibrate.CalibrationDB`) puts every one of
    those modeled-time comparisons on MEASURED effective constants
    (DESIGN.md §9): the BSR-displacement race runs calibrated, and the
    occupancy-rule choice itself is re-checked — a layer the threshold sent
    sparse falls back to dense when the calibrated model says the measured
    sparse kernel loses to the measured dense path at this occupancy (the
    device-specific crossover the hard-coded constants cannot see). The
    re-check only fires for (kind, impl) keys the DB actually covers, so an
    empty or absent DB reproduces the uncalibrated plan bit-identically.

    `tiles` (a `CalibrationDB`, typically the one `obs.tilesearch.tile_search`
    persisted winners into — it may be the same object as `calibration`)
    closes the measure -> search -> plan loop: after the (kind, impl) choice,
    the layer's shape is looked up in the winners table and the stored
    measured-best `TileConfig` is stamped onto `LayerPlan.tile`, with the
    occupancy re-measured at that geometry so the recorded statistic matches
    the schedule the kernel will actually run. No stored winner (or no
    `tiles`) leaves `tile=None` — the impl's default geometry, bit-identical
    to before.

    `int8=True` adds the PRECISION axis: a layer placed on a Pallas sparse or
    BSR impl is upgraded to its int8 sibling (`ecr_int8` / `bsr_int8`) iff
    the quantized roofline time wins — with occupancy re-measured at the
    int8 geometry (dtype_bytes=1 fits 4x wider channel blocks) and the int8
    impl's own stored tile winner. Because quantization trades accuracy, the
    upgrades are then PROBED: planned logits vs the dense fp32 oracle on the
    calibration batch, and int8 layers are demoted back to their fp32 choice
    (least modeled saving first) until top-1 agreement >= `int8_budget`.
    The probe lands on the plan as `plan.int8_report` (an `Int8Report`,
    mirroring how pruning reports `PruneReport`).
    """
    from repro.obs.calibrate import unit_shape_key
    from repro.sparse_weights import weight_block_density

    graph = as_graph(graph)
    if calib.ndim == 3:
        calib = calib[None]
    if calibration is not None and not calibration:
        calibration = None  # empty DB == no calibration, one code path
    sparse_conv = "ecr_pallas" if use_pallas else "ecr"
    conv_ws, _ = graph_weights(params)
    layers = []
    fp32_alt: dict = {}  # conv index -> the (kind, impl, tile, occ) int8 displaced
    q_saving: dict = {}  # conv index -> modeled us the int8 upgrade saved
    x = calib
    batch = int(calib.shape[0])
    for unit, w in zip(graph.units(), conv_ws):
        occ = measure_occupancy(x, block_c)
        wd = weight_block_density(w)
        go_sparse = occ <= occ_threshold
        if go_sparse:
            fused = get_op("conv", sparse_conv).fused_with
            if fused is not None and fusion_eligible(unit):
                kind, impl = "conv_pool", fused
            else:
                kind, impl = "conv", sparse_conv
        else:
            kind, impl = "conv", "dense"
        if go_sparse and calibration is not None and (
                calibration.covers(kind, impl, block_c)
                or calibration.covers("conv", "dense", block_c)):
            sparse_us = unit_model_us(kind, impl, unit, occupancy=occ,
                                      batch=batch, block_c=block_c,
                                      calibration=calibration)
            dense_us = unit_model_us("conv", "dense", unit, batch=batch,
                                     block_c=block_c, calibration=calibration)
            if dense_us < sparse_us:
                kind, impl = "conv", "dense"
        if use_pallas and wd <= bsr_threshold:
            base_us = unit_model_us(kind, impl, unit, occupancy=occ,
                                    batch=batch, block_c=block_c,
                                    calibration=calibration)
            bsr_us = unit_model_us("conv", "bsr", unit, weight_density=wd,
                                   batch=batch, block_c=block_c,
                                   calibration=calibration)
            if bsr_us < base_us:
                kind, impl = "conv", "bsr"
        tile = None
        if tiles is not None and get_op(kind, impl).pallas:
            stored = tiles.best_tile(kind, impl, unit_shape_key(unit))
            if stored:
                tile = stored
                if get_op(kind, impl).sparse:
                    # the stat must describe the schedule the winner runs
                    occ = measure_occupancy(x, block_c, tile=tile)
        if int8 and use_pallas:
            op = get_op(kind, impl)
            q_impl = "bsr_int8" if op.weight_sparse else (
                "ecr_int8" if op.sparse else None)
            if q_impl is not None:
                q_tile = tiles.best_tile("conv", q_impl, unit_shape_key(unit)) \
                    if tiles is not None else None
                q_occ = occ
                if get_op("conv", q_impl).sparse:
                    # int8 operands fit 4x wider channel blocks per VMEM
                    q_occ = measure_occupancy(x, block_c, tile=q_tile,
                                              dtype_bytes=1)
                base_us = unit_model_us(kind, impl, unit, occupancy=occ,
                                        weight_density=wd, batch=batch,
                                        block_c=block_c, tile=tile,
                                        calibration=calibration)
                q_us = unit_model_us("conv", q_impl, unit, occupancy=q_occ,
                                     weight_density=wd, batch=batch,
                                     block_c=block_c, tile=q_tile,
                                     calibration=calibration)
                if q_us < base_us:
                    fp32_alt[unit.index] = (kind, impl, tile, occ)
                    q_saving[unit.index] = base_us - q_us
                    kind, impl, tile, occ = "conv", q_impl, q_tile, q_occ
        # the dense oracle produces the next calibration input
        x = run_unit(x, w, unit, "conv", "dense")
        layers.append(
            LayerPlan(
                index=unit.index,
                stage=unit.stage,
                slot=unit.slot,
                kind=kind,
                impl=impl,
                occupancy=occ,
                in_shape=unit.in_shape,
                out_shape=unit.out_shape,
                conv=unit.conv,
                relu=unit.relu,
                pool=unit.pool,
                weight_density=wd,
                tile=tile,
            )
        )
    plan = PipelinePlan(layers=tuple(layers), occ_threshold=occ_threshold,
                        block_c=block_c, graph=graph)
    if int8:
        plan = _probe_int8(plan, params, calib, fp32_alt, q_saving,
                           int8_budget)
    # a freshly planned schedule must verify clean before anyone caches,
    # compiles or serves it (DESIGN.md §12) — any error here is a planner bug
    from repro.analysis import assert_plan_ok

    assert_plan_ok(plan, params, graph=graph, batch=batch)
    return plan


def _probe_int8(plan: PipelinePlan, params, calib, fp32_alt: dict,
                q_saving: dict, budget: float) -> PipelinePlan:
    """Accuracy-gate a plan's int8 placements (`plan_network(int8=True)`).

    Probe: planned logits vs the dense fp32 oracle on the calibration batch
    (the fp32 plan is exact vs dense — DESIGN.md §3 — so ALL drift here is
    quantization). While top-1 agreement < `budget`, demote the int8 layer
    with the least modeled saving back to its recorded fp32 alternative and
    re-probe. The loop terminates: with every int8 layer demoted the plan is
    fp32-exact and agreement is 1.0. Returns the plan with `int8_report`."""
    from dataclasses import replace

    from repro.graph.executor import run_graph
    from repro.quant import Int8Report

    def probe(p):
        got = run_plan(p, params, calib)
        ref = run_graph(p.graph, params, calib, "dense")
        agree = float((jnp.argmax(got, -1) == jnp.argmax(ref, -1)).mean())
        drift = float(jnp.max(jnp.abs(got - ref)))
        return agree, drift

    agree, drift = probe(plan)
    demoted = []
    order = sorted(fp32_alt, key=lambda i: q_saving[i])  # cheapest give-back
    layers = list(plan.layers)
    while agree < budget and order:
        i = order.pop(0)
        kind, impl, tile, occ = fp32_alt[i]
        pos = next(p for p, lp in enumerate(layers) if lp.index == i)
        layers[pos] = replace(layers[pos], kind=kind, impl=impl, tile=tile,
                              occupancy=occ)
        demoted.append(i)
        plan = replace(plan, layers=tuple(layers))
        agree, drift = probe(plan)
    report = Int8Report(
        layers=tuple(i for i in sorted(fp32_alt) if i not in demoted),
        max_logit_drift=drift, top1_agreement=agree,
        demoted=tuple(demoted))
    return replace(plan, int8_report=report)


def _plan_graph(plan: PipelinePlan, fallback=None) -> LayerGraph:
    """The graph a plan executes (pre-IR plans fall back to a CNNConfig)."""
    return plan.graph if plan.graph is not None else as_graph(fallback)


def validate_plan(plan: PipelinePlan, params, imgs, graph=None) -> None:
    """Raise a clear ValueError on any plan/params/input mismatch.

    `run_plan` zips the plan with the params' weights and runs whatever the
    shapes allow — without these checks a wrong-resolution batch or a
    mismatched network executes silently and returns garbage logits. The
    serving engine depends on this contract: a plan only ever executes on the
    (C,H,W) it was calibrated for, against the params it was planned over.

    The input-batch checks live here (only this call site has the images);
    everything else — plan/graph/params invariants, fusion legality, launch
    geometry, BSR density — is the static verifier's job (DESIGN.md §12):
    `repro.analysis.assert_plan_ok`, which raises a `PlanVerificationError`
    (a ValueError subclass) listing every error-severity diagnostic.
    """
    from repro.analysis import assert_plan_ok

    if imgs.ndim not in (3, 4):
        raise ValueError(f"run_plan expects (C,H,W) or (N,C,H,W) images, got shape {tuple(imgs.shape)}")
    if not plan.layers:
        raise ValueError("run_plan got an empty PipelinePlan (no layers)")
    in_shape = tuple(imgs.shape[-3:])
    if in_shape != tuple(plan.layers[0].in_shape):
        raise ValueError(
            f"plan was calibrated for input shape {tuple(plan.layers[0].in_shape)}, "
            f"got images of shape {in_shape}")
    batch = int(imgs.shape[0]) if imgs.ndim == 4 else 1
    assert_plan_ok(plan, params, graph=_plan_graph(plan, graph), batch=batch)


def run_plan(plan: PipelinePlan, params, imgs, ccfg=None, *,
             collect_occupancy: bool = False, n_valid=None,
             axis_name: str | None = None):
    """Execute the planned layer sequence over a batch: (N,C,H,W) -> logits.

    Each entry is one whole-batch op resolved through the registry: the fused
    Pallas grid for sparse fused units, conv + ReLU (+ unfused pool)
    otherwise. Pallas layers run at the plan's `block_c` — the block size the
    occupancy was measured (and the sparse/dense decision made) at. `ccfg` is
    only consulted for pre-IR plans that carry no graph.

    collect_occupancy=True additionally returns the per-layer observed
    channel-block occupancy of each layer's INPUT (a (n_layers,) array,
    jit-traceable) — the signal the serving engine's drift detector consumes.
    `n_valid` (traced) masks the statistic to the first n_valid samples of a
    padded serving bucket.

    `axis_name` marks a call from inside a shard_map body (see
    `run_plan_sharded`): the per-layer math is per-sample and needs no
    collective, but the occupancy statistic is then shard-local, so it is
    aggregated across the mesh axis — weighted by each shard's valid-sample
    count when `n_valid` is given (a ragged bucket's tail shard holds fewer
    real samples), which reduces to a plain `lax.pmean` for full buckets.
    """
    if imgs.ndim == 3:
        imgs = imgs[None]
    validate_plan(plan, params, imgs, ccfg)
    graph = _plan_graph(plan, ccfg)
    conv_ws, dense_ws = graph_weights(params)
    x = imgs
    occs = []
    for lp, w in zip(plan.layers, conv_ws):
        lp_tile = getattr(lp, "tile", None)
        if collect_occupancy:
            occs.append(occupancy_stat(x, plan.block_c, n_valid, tile=lp_tile))
        x = run_unit(x, w, lp.to_unit(), lp.kind, lp.impl, plan.block_c,
                     tile=lp_tile)
    logits = run_head(x, dense_ws, graph.head())
    if collect_occupancy:
        occs = jnp.stack(occs)
        if axis_name is not None:
            import jax

            if n_valid is None:
                occs = jax.lax.pmean(occs, axis_name)
            else:
                wt = jnp.clip(jnp.asarray(n_valid, jnp.float32), 0.0,
                              float(imgs.shape[0]))
                occs = jax.lax.psum(occs * wt, axis_name) / jnp.maximum(
                    jax.lax.psum(wt, axis_name), 1.0)
        return logits, occs
    return logits


def run_plan_sharded(plan: PipelinePlan, params, imgs, mesh, *,
                     collect_occupancy: bool = False, n_valid=None):
    """`run_plan` under `shard_map` over a 1-D "data" mesh (DESIGN.md §6).

    The batch dim is sharded across the mesh's data axis; params are
    replicated; each shard executes its slice with DEVICE-LOCAL per-sample
    (ids, cnt) schedules — sparsity skipping never needs a collective, so the
    only cross-device traffic is the occupancy aggregation above. `n_valid`
    is the GLOBAL count of real (non-pad) samples; each shard derives its
    local count from its `lax.axis_index` (pad samples sit at the tail of the
    batch, so they land on the highest-index shards).

    Exactness: shard-local logits are bit-identical to the single-device
    `run_plan` whenever every shard's local batch is >= 2 (the same XLA
    M=1-GEMV caveat as `MicroBatcher.min_bucket`) and co-batched samples
    share a live-channel union (all-zero pads never perturb it) — the serving
    engine's device-aligned buckets enforce both. `mesh=None` (or a 1-device
    mesh) falls back to plain `run_plan`, bit-identical to today.

    The batch must divide the data-axis size; the batcher's device-aligned
    buckets guarantee it, and anything else raises here rather than silently
    replicating.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if imgs.ndim == 3:
        imgs = imgs[None]
    if mesh is None or mesh.size == 1:
        return run_plan(plan, params, imgs,
                        collect_occupancy=collect_occupancy, n_valid=n_valid)
    if "data" not in mesh.axis_names:
        raise ValueError(
            f"run_plan_sharded needs a mesh with a 'data' axis, got axes "
            f"{tuple(mesh.axis_names)}")
    n_dev = int(mesh.shape["data"])
    n = int(imgs.shape[0])
    if n % n_dev:
        raise ValueError(
            f"batch of {n} does not divide the {n_dev}-device data axis — "
            "pad to a device-aligned bucket (MicroBatcher(align=n_dev))")
    validate_plan(plan, params, imgs)  # fail eagerly, outside the trace
    local_n = n // n_dev

    if collect_occupancy:
        import jax

        nv = jnp.asarray(n if n_valid is None else n_valid, jnp.int32)

        def mapped(params, imgs_local, nv):
            shard_i = jax.lax.axis_index("data")
            nv_local = jnp.clip(nv - shard_i * local_n, 0, local_n)
            return run_plan(plan, params, imgs_local, collect_occupancy=True,
                            n_valid=nv_local, axis_name="data")

        fn = shard_map(mapped, mesh=mesh, in_specs=(P(), P("data"), P()),
                       out_specs=(P("data"), P()), check_rep=False)
        return fn(params, imgs, nv)

    def mapped(params, imgs_local):
        return run_plan(plan, params, imgs_local)

    fn = shard_map(mapped, mesh=mesh, in_specs=(P(), P("data")),
                   out_specs=P("data"), check_rep=False)
    return fn(params, imgs)
