"""Per-layer dense/ECR/PECR planning for batched VGG-style inference.

The paper's win is layer-dependent (Fig. 9: early layers are dense and big,
deep layers are small and very sparse), so a whole-network setting is always
wrong somewhere. The planner measures, per conv layer, the channel-block
occupancy the ECR kernel would actually run at on a calibration batch — the
post-compaction ceil(n_live/bc)/n_cb of DESIGN.md §2.2, averaged over samples
— and emits a `PipelinePlan`: one `LayerPlan` per conv, stage-final layers
fused with their pooling when the sparse path is chosen (PECR) and left as
conv + unfused pool otherwise.

The plan is a static, hashable schedule: `run_plan` executes it over any
batch of the calibrated shape, one jitted whole-batch op per layer. This is
the seam where serving (plan once, execute per request batch) and autotuning
(search over thresholds/block sizes, keep the best plan) attach.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.vgg19_sparse import CNNConfig
from repro.core.ecr import conv2d
from repro.core.pecr import conv_pool
from repro.models.cnn import _maxpool, _pad1


@dataclass(frozen=True)
class LayerPlan:
    """One conv layer's placement decision."""

    index: int  # conv index in network order (0-based)
    stage: int  # VGG stage
    slot: int  # index within the stage
    kind: str  # "conv" | "conv_pool" (stage-final fuses/bundles the pool)
    impl: str  # "dense" | "ecr_pallas" | "pecr_pallas" | "ecr" | "pecr"
    occupancy: float  # measured mean channel-block occupancy of the input
    in_shape: tuple  # (C, H, W) entering the layer (pre-padding)
    out_shape: tuple  # (C, H, W) leaving the layer (post-pool if any)


@dataclass(frozen=True)
class PipelinePlan:
    layers: tuple  # tuple[LayerPlan, ...]
    occ_threshold: float
    block_c: int  # 0 = auto per layer (ops._pick_block_c)

    def counts(self) -> dict:
        c = {"dense": 0, "sparse": 0, "fused": 0}
        for lp in self.layers:
            if lp.impl == "dense":
                c["dense"] += 1
            else:
                c["sparse"] += 1
                if lp.kind == "conv_pool":
                    c["fused"] += 1
        return c


def occupancy_stat(x, block_c: int = 0, n_valid=None):
    """Traced (jit-safe) channel-block occupancy, measured the way the batched
    kernel schedules: shared-union channel compaction, then PER-SAMPLE block
    occupancy on the packed layout (== mean_b cnt_b / n_cb of
    `batch_block_schedule`). For one image this reduces to the compacted
    ceil(n_live / bc) / n_cb of DESIGN.md §2.2.

    x: (N,C,H,W) or (C,H,W). `n_valid` (optional, traced) restricts the
    statistic to the first `n_valid` samples — the serving engine measures
    occupancy over the real requests of a padded bucket, and the all-zero pad
    samples contribute nothing to the union so the masked measurement equals
    what the kernel's per-sample schedules do for the real samples. Returns a
    scalar array (fraction of channel-block work NOT skipped).
    """
    from repro.kernels.ecr_conv.ops import _pick_block_c

    if x.ndim == 3:
        x = x[None]
    n, c, h, w = x.shape
    bc = block_c or min(_pick_block_c(h, w, c), max(8, c))
    bc = min(bc, c)
    n_cb = -(-c // bc)
    live = jnp.any(x != 0, axis=(2, 3))  # (N, C) per-sample live channels
    if n_valid is not None:
        live = live & (jnp.arange(n) < jnp.asarray(n_valid, jnp.int32))[:, None]
    union_order = jnp.argsort(~jnp.any(live, axis=0), stable=True)
    packed = live[:, union_order]  # one shared permutation, like the kernel
    packed = jnp.pad(packed, ((0, 0), (0, n_cb * bc - c)))
    blk_live = packed.reshape(n, n_cb, bc).any(axis=2)  # (N, n_cb)
    if n_valid is None:
        return blk_live.mean()
    nv = jnp.maximum(jnp.asarray(n_valid, jnp.int32), 1)
    per_sample = blk_live.mean(axis=1)  # (N,)
    return jnp.where(jnp.arange(n) < nv, per_sample, 0.0).sum() / nv


def measure_occupancy(x, block_c: int = 0) -> float:
    """Concrete-value wrapper of `occupancy_stat` (see its docstring)."""
    return float(occupancy_stat(x, block_c))


def _dense_oracle_step(x, w, last, p):
    """Reference forward step used only to produce the next calibration input."""
    x = jnp.maximum(conv2d(_pad1(x), w, 1, "dense"), 0.0)
    return _maxpool(x, p) if last else x


def plan_network(
    params,
    calib,
    ccfg: CNNConfig = CNNConfig(),
    *,
    occ_threshold: float = 0.75,
    block_c: int = 0,
    use_pallas: bool = True,
) -> PipelinePlan:
    """Walk the conv stack on a calibration batch and emit the layer schedule.

    A layer goes sparse when its measured occupancy is <= occ_threshold (the
    skipped blocks must pay for the compaction gather; at occupancy ~1.0 the
    sparse path is pure overhead). A stage-final sparse layer is fused with
    its pooling (PECR); a stage-final dense layer keeps the unfused pool.
    """
    if calib.ndim == 3:
        calib = calib[None]
    sparse_conv = "ecr_pallas" if use_pallas else "ecr"
    fused_conv = "pecr_pallas" if use_pallas else "pecr"
    p = ccfg.pool_size
    layers = []
    x = calib
    idx = 0
    for s, convs in enumerate(params["stages"]):
        for i, w in enumerate(convs):
            last = i == len(convs) - 1
            occ = measure_occupancy(x, block_c)
            in_shape = tuple(x.shape[1:])
            go_sparse = occ <= occ_threshold
            x = _dense_oracle_step(x, w, last, p)
            layers.append(
                LayerPlan(
                    index=idx,
                    stage=s,
                    slot=i,
                    kind="conv_pool" if last else "conv",
                    impl=(fused_conv if last else sparse_conv) if go_sparse else "dense",
                    occupancy=occ,
                    in_shape=in_shape,
                    out_shape=tuple(x.shape[1:]),
                )
            )
            idx += 1
    return PipelinePlan(layers=tuple(layers), occ_threshold=occ_threshold, block_c=block_c)


def validate_plan(plan: PipelinePlan, params, imgs) -> None:
    """Raise a clear ValueError on any plan/params/input mismatch.

    `run_plan` zips the plan with the params' weights and runs whatever the
    shapes allow — without these checks a wrong-resolution batch or a
    mismatched network executes silently and returns garbage logits. The
    serving engine depends on this contract: a plan only ever executes on the
    (C,H,W) it was calibrated for, against the params it was planned over.
    """
    if imgs.ndim not in (3, 4):
        raise ValueError(f"run_plan expects (C,H,W) or (N,C,H,W) images, got shape {tuple(imgs.shape)}")
    if not plan.layers:
        raise ValueError("run_plan got an empty PipelinePlan (no layers)")
    if plan.block_c < 0:
        raise ValueError(f"PipelinePlan.block_c must be >= 0 (0 = auto), got {plan.block_c}")
    in_shape = tuple(imgs.shape[-3:])
    if in_shape != tuple(plan.layers[0].in_shape):
        raise ValueError(
            f"plan was calibrated for input shape {tuple(plan.layers[0].in_shape)}, "
            f"got images of shape {in_shape}")
    flat_weights = [w for convs in params["stages"] for w in convs]
    if len(flat_weights) != len(plan.layers):
        raise ValueError(
            f"plan has {len(plan.layers)} conv layers but params carry "
            f"{len(flat_weights)} conv weights (zip would silently truncate)")
    for lp, w in zip(plan.layers, flat_weights):
        if w.shape[1] != lp.in_shape[0]:
            raise ValueError(
                f"conv_{lp.index + 1}: plan expects C_in={lp.in_shape[0]}, "
                f"weight has C_in={w.shape[1]}")


def run_plan(plan: PipelinePlan, params, imgs, ccfg: CNNConfig = CNNConfig(), *,
             collect_occupancy: bool = False, n_valid=None):
    """Execute the planned layer sequence over a batch: (N,C,H,W) -> logits.

    Each entry is one whole-batch op: the fused Pallas grid for sparse
    stage-final layers, `conv2d` + ReLU (+ unfused pool) otherwise. Pallas
    layers run at the plan's `block_c` — the block size the occupancy was
    measured (and the sparse/dense decision made) at.

    collect_occupancy=True additionally returns the per-layer observed
    channel-block occupancy of each layer's INPUT (a (n_layers,) array,
    jit-traceable) — the signal the serving engine's drift detector consumes.
    `n_valid` (traced) masks the statistic to the first n_valid samples of a
    padded serving bucket.
    """
    from repro.kernels.conv_pool.ops import fused_conv_pool
    from repro.kernels.ecr_conv.ops import ecr_conv

    if imgs.ndim == 3:
        imgs = imgs[None]
    validate_plan(plan, params, imgs)
    p = ccfg.pool_size
    x = imgs
    occs = []
    flat_weights = [w for convs in params["stages"] for w in convs]
    for lp, w in zip(plan.layers, flat_weights):
        if collect_occupancy:
            occs.append(occupancy_stat(x, plan.block_c, n_valid))
        xp = _pad1(x)
        if lp.kind == "conv_pool" and lp.impl in ("pecr", "pecr_pallas"):
            if lp.impl == "pecr_pallas":
                x = fused_conv_pool(xp, w, 1, p, block_c=plan.block_c)
            else:
                x = conv_pool(xp, w, 1, p, None, lp.impl)
        else:
            if lp.impl == "ecr_pallas":
                x = ecr_conv(xp, w, block_c=plan.block_c)
            else:
                x = conv2d(xp, w, 1, lp.impl)
            x = jnp.maximum(x, 0.0)
            if lp.kind == "conv_pool":
                x = _maxpool(x, p)
    x = x.reshape(x.shape[0], -1)
    x = jnp.maximum(x @ params["fc1"], 0.0)
    logits = x @ params["fc2"]
    if collect_occupancy:
        return logits, jnp.stack(occs)
    return logits
