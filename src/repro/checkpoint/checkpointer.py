"""Sharded, async, atomic checkpointing with elastic (re-mesh) restore.

- Atomic: writes go to `<dir>/tmp.<step>`, fsync'd, then `os.replace`d to
  `<dir>/step_<N>` — a crash mid-save never corrupts the latest checkpoint
  (the restart test kills the trainer mid-run and restores).
- Async: `save()` snapshots to host RAM synchronously (cheap) and writes in a
  background thread, overlapping the next train steps.
- Sharded/elastic: leaves are stored whole (single-host container) with their
  tree paths; `restore_tree(..., shardings=...)` device_puts each leaf under
  the *target* sharding, so a restore onto a different mesh (elastic shrink /
  grow) or a different parallelism layout is just a different shardings tree.
- keep-k retention with a `latest` pointer file.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_tree(tree, directory: str | os.PathLike, extra: Optional[dict] = None):
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    arrays = {}
    for key, leaf in flat:
        a = np.asarray(leaf)
        if a.dtype.kind not in "fiub" or str(a.dtype) == "bfloat16":
            # npz has no bf16/f8 codec; store widened (restore re-narrows via
            # the like-tree's dtype, lossless for bf16->f32)
            a = a.astype(np.float32)
        arrays[key] = a
    np.savez(d / "arrays.npz", **arrays)
    (d / "meta.json").write_text(json.dumps(extra or {}))


def restore_tree(like_tree, directory: str | os.PathLike, shardings=None):
    """Restore into the structure of `like_tree`; device_put under `shardings`
    (a matching tree of NamedSharding) for elastic/resharded restore."""
    d = pathlib.Path(directory)
    with np.load(d / "arrays.npz") as z:
        flat, treedef = _flatten_with_paths(like_tree)
        leaves = []
        for key, like in flat:
            arr = z[key]
            if hasattr(like, "dtype"):
                arr = arr.astype(like.dtype)
            leaves.append(arr)
    restored = treedef.unflatten(leaves)
    if shardings is not None:
        restored = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), restored, shardings)
    else:
        restored = jax.tree_util.tree_map(jax.numpy.asarray, restored)
    return restored


def load_meta(directory) -> dict:
    p = pathlib.Path(directory) / "meta.json"
    return json.loads(p.read_text()) if p.exists() else {}


class CheckpointManager:
    def __init__(self, root: str | os.PathLike, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # ---- save ----------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None, block: bool = False):
        """Snapshot to host RAM now; write + commit in the background."""
        snapshot = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        meta = dict(extra or {}, step=int(step))
        self.wait()  # one in-flight save at a time

        def _write():
            tmp = self.root / f"tmp.{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            save_tree(snapshot, tmp, meta)
            final = self.root / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            (self.root / "latest").write_text(final.name)
            self._gc()

        self._pending = self._pool.submit(_write)
        if block:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # ---- restore -------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            m = re.match(r"step_(\d+)$", p.name)
            if m and (p / "arrays.npz").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: Optional[int] = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        d = self.root / f"step_{step:08d}"
        return restore_tree(like_tree, d, shardings), load_meta(d)
