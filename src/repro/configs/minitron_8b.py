"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Pruned nemotron [arXiv:2407.14679; hf]. Plain (non-gated) ReLU^2 MLP in nemotron
style is approximated with gated silu per the shared transformer block; the
pruned-width config is what matters for the shapes.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    mlp_activation="relu2",  # squared-relu (nemotron) => activation sparsity >0
    ffn_sparsity="block_ecr",  # paper technique applies: ReLU-family FFN
)

REDUCED = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    mlp_activation="relu2",
    ffn_sparsity="block_ecr",
    attn_chunk=64,
)

register(FULL, REDUCED)
