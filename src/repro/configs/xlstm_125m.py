"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]. d_ff=0: the xLSTM blocks
carry their own up/down projections (mLSTM pre-up-projection expand=2, sLSTM
gated FFN 4/3) instead of a separate transformer MLP.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attn_type="none",
    ssm_expand=2,
    xlstm_slstm_every=2,  # alternate mLSTM / sLSTM
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=2,
    d_model=128,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    attn_type="none",
    ssm_expand=2,
    xlstm_slstm_every=2,
)

register(FULL, REDUCED)
