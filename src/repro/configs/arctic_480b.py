"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual FFN. [hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,  # dense residual FFN width
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual_ff=True,
    mlp_activation="silu",
    # moments in bf16: 480B params x 12B fp32 moments would not fit 16G/chip
)

REDUCED = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    n_experts=8,
    top_k=2,
    moe_d_ff=128,
    dense_residual_ff=True,
    mlp_activation="silu",
    attn_chunk=64,
)

register(FULL, REDUCED)
