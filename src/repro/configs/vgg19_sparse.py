"""vgg19_sparse [cnn] — the paper's own evaluation network (VGG-19), with the
conv+pool stacks runnable through the dense, ECR-sparse, and PECR-fused paths.

This is the 11th ("paper's own") architecture; it is not part of the 40 LM
dry-run cells but has its own configs, smoke tests and benchmarks (Figs 9-12).
"""
from dataclasses import dataclass, field

from repro.configs.base import ModelConfig, register

# VGG-19 conv plan: (out_channels, n_convs) per stage; 2x2 maxpool after each.
VGG19_PLAN = ((64, 2), (128, 2), (256, 4), (512, 4), (512, 4))


@dataclass(frozen=True)
class CNNConfig:
    name: str = "vgg19"
    in_channels: int = 3
    img_size: int = 224
    plan: tuple = VGG19_PLAN
    kernel_size: int = 3
    pool_size: int = 2
    n_classes: int = 1000
    conv_impl: str = "dense"  # dense | ecr | pecr  (paper's three paths)


FULL = ModelConfig(
    name="vgg19-sparse",
    family="cnn",
    n_layers=16,  # 16 conv layers
    d_model=512,
    n_heads=1,
    n_kv_heads=1,
    d_ff=4096,
    vocab_size=1000,  # classes
    attn_type="none",
)

REDUCED = ModelConfig(
    name="vgg19-sparse",
    family="cnn",
    n_layers=4,
    d_model=32,
    n_heads=1,
    n_kv_heads=1,
    d_ff=64,
    vocab_size=16,
    attn_type="none",
)

register(FULL, REDUCED)

CNN_FULL = CNNConfig()
CNN_REDUCED = CNNConfig(name="vgg-tiny", img_size=32, plan=((8, 1), (16, 1)), n_classes=16)
