"""vgg19_sparse [cnn] — the paper's own evaluation network (VGG-19), with the
conv+pool stacks runnable through the dense, ECR-sparse, and PECR-fused paths.

This is the 11th ("paper's own") architecture; it is not part of the 40 LM
dry-run cells but has its own configs, smoke tests and benchmarks (Figs 9-12).
`vgg19_graph` lowers a `CNNConfig` onto the LayerGraph IR — VGG-19 is one
graph constructor among several (see `repro.configs.lenet` / `.alexnet`).
"""
from dataclasses import dataclass

from repro.configs.base import ModelConfig, register
from repro.graph.ir import ConvSpec, DenseSpec, Flatten, LayerGraph, PoolSpec, ReLU

# VGG-19 conv plan: (out_channels, n_convs) per stage; 2x2 maxpool after each.
VGG19_PLAN = ((64, 2), (128, 2), (256, 4), (512, 4), (512, 4))


@dataclass(frozen=True)
class CNNConfig:
    name: str = "vgg19"
    in_channels: int = 3
    img_size: int = 224
    plan: tuple = VGG19_PLAN
    kernel_size: int = 3
    pool_size: int = 2
    n_classes: int = 1000
    conv_impl: str = "dense"  # dense | ecr | pecr  (paper's three paths)


FULL = ModelConfig(
    name="vgg19-sparse",
    family="cnn",
    n_layers=16,  # 16 conv layers
    d_model=512,
    n_heads=1,
    n_kv_heads=1,
    d_ff=4096,
    vocab_size=1000,  # classes
    attn_type="none",
)

REDUCED = ModelConfig(
    name="vgg19-sparse",
    family="cnn",
    n_layers=4,
    d_model=32,
    n_heads=1,
    n_kv_heads=1,
    d_ff=64,
    vocab_size=16,
    attn_type="none",
)

register(FULL, REDUCED)

CNN_FULL = CNNConfig()
CNN_REDUCED = CNNConfig(name="vgg-tiny", img_size=32, plan=((8, 1), (16, 1)), n_classes=16)


def vgg19_graph(ccfg: CNNConfig = CNNConfig()) -> LayerGraph:
    """Lower a VGG-style `CNNConfig` onto the LayerGraph IR: per stage,
    `n_convs` SAME convs (k x k, stride 1, pad k//2) each followed by ReLU,
    a stage-final non-overlapping pool, then the 2-layer dense head. Pool
    mode is "valid": every VGG resolution divides exactly, and anything that
    doesn't should fail loudly rather than silently truncate."""
    nodes = []
    k = ccfg.kernel_size
    for c_out, n_convs in ccfg.plan:
        for _ in range(n_convs):
            nodes += [ConvSpec(c_out, k=k, stride=1, pad=k // 2), ReLU()]
        nodes.append(PoolSpec(ccfg.pool_size))
    nodes += [Flatten(), DenseSpec(512, relu=True), DenseSpec(ccfg.n_classes)]
    return LayerGraph(name=ccfg.name,
                      in_shape=(ccfg.in_channels, ccfg.img_size, ccfg.img_size),
                      nodes=tuple(nodes))
