from repro.configs.base import (
    DEFAULT_RUN,
    SHAPES,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    get_config,
    list_archs,
    shape_applicable,
)

__all__ = [
    "DEFAULT_RUN",
    "SHAPES",
    "ModelConfig",
    "RunConfig",
    "ShapeConfig",
    "get_config",
    "list_archs",
    "shape_applicable",
]
