"""whisper-tiny [audio] — 4L d_model=384 6H d_ff=1536 vocab=51865, enc-dec.

[arXiv:2212.04356; unverified]. The conv frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (batch, frames, d_model).
(The real conv frontend, built on the paper's ECR sparse conv, lives in
``repro.models.cnn.whisper_conv_frontend`` and is exercised in unit tests only.)
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,  # decoder layers
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    is_encoder_decoder=True,
    mlp_activation="gelu",
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not rope
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=2,
    n_encoder_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    is_encoder_decoder=True,
    mlp_activation="gelu",
    rope_theta=0.0,
    attn_chunk=64,
)

register(FULL, REDUCED)
