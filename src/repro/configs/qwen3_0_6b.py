"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.

qk_norm, GQA [hf:Qwen/Qwen3-8B family; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,  # qwen3 uses head_dim 128 (not d_model/n_heads)
    qk_norm=True,
    mlp_activation="silu",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    qk_norm=True,
    mlp_activation="silu",
    tie_embeddings=True,
    attn_chunk=64,
)

register(FULL, REDUCED)
