"""deepseek-v2-236b [moe] — 60L d_model=5120 128H (MLA kv_lora=512) d_ff=1536
vocab=102400, MoE 2 shared + 160 routed top-6. [arXiv:2405.04434; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,  # MLA: per-head latent up-projection; no GQA grouping
    d_ff=1536,  # routed-expert intermediate width
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1536,
    mlp_activation="silu",
)

REDUCED = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=512,
    attn_type="mla",
    kv_lora_rank=32,
    q_lora_rank=48,
    rope_head_dim=16,
    nope_head_dim=32,
    v_head_dim=32,
    n_experts=8,
    top_k=2,
    n_shared_experts=1,
    moe_d_ff=64,
    mlp_activation="silu",
    attn_chunk=64,
)

register(FULL, REDUCED)
