"""Config system: model / shape / run configs and the architecture registry.

Every assigned architecture is a ``ModelConfig`` in ``src/repro/configs/<id>.py``.
Shapes are global (same four for every LM arch). ``RunConfig`` carries the
distribution knobs (mesh, remat, grad-accum, dtypes, parallelism strategy).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention
    attn_type: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    attn_chunk: int = 1024  # kv-chunk for blockwise (flash-style) attention

    # MLA (deepseek)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    dense_residual_ff: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01

    # hybrid (jamba): one attention layer every `attn_every` layers (rest mamba);
    # MoE on every `moe_every`-th layer (0 = never).
    attn_every: int = 0
    moe_every: int = 0

    # ssm (mamba / xlstm)
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    xlstm_slstm_every: int = 2  # alternate mLSTM / sLSTM blocks

    # vlm (llama-3.2-vision): cross-attention to image embeddings every k layers
    cross_attn_every: int = 0
    n_image_tokens: int = 1024

    # audio enc-dec (whisper): encoder length fixed by frontend stub
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # FFN
    mlp_activation: str = "silu"  # silu | gelu | relu | relu2
    ffn_sparsity: str = "none"  # none | block_ecr (paper technique lifted to FFN)

    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: recurrent/SSM state or hybrid w/ few attn layers."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND roofline)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def n_active_params(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# Shape config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason recorded in the dry-run table."""
    if shape.name == "long_500k" and not model.supports_long_context:
        return False, "full-attention arch: 500k dense KV/O(L^2) attn — needs sub-quadratic attention (DESIGN.md §5)"
    return True, ""


# ---------------------------------------------------------------------------
# Run config (distribution knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    # mesh
    multi_pod: bool = False
    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"  # bf16 for the very large archs to fit HBM
    # memory
    remat: str = "full"  # none | full | dots  (activation-checkpoint policy)
    grad_accum: int = 1  # microbatch count inside train_step (scan + accumulate)
    # parallelism
    fsdp: bool = True  # shard params/opt-state over the data (+pod) axes
    seq_shard: bool = True  # Megatron-SP style activation sharding over "model"
    pipeline_stages: int = 0  # >0: GPipe-style PP over the "pod" axis
    # serving
    kv_cache_dtype: str = "bfloat16"  # int8: quantized KV (decode memory lever)
    # optimizer
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # gradient compression (distributed-optimization trick; off by default)
    grad_compression: str = "none"  # none | int8 | topk
    grad_topk_frac: float = 0.01
    # fault tolerance
    checkpoint_every: int = 200
    keep_checkpoints: int = 3

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


DEFAULT_RUN = RunConfig()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}
_REDUCED: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, reduced: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _REDUCED[cfg.name] = reduced
    return cfg


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False

_ARCH_MODULES = [
    "stablelm_12b",
    "mistral_large_123b",
    "minitron_8b",
    "qwen3_0_6b",
    "xlstm_125m",
    "arctic_480b",
    "deepseek_v2_236b",
    "jamba_v0_1_52b",
    "llama_3_2_vision_90b",
    "whisper_tiny",
    "vgg19_sparse",
]


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib

    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _LOADED = True
