"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attn image layers. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (batch, n_image_tokens, d_model); every 5th layer
is a gated cross-attention layer over them (100L = 80 self + 20 cross).
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    n_image_tokens=1024,
    mlp_activation="silu",
)

REDUCED = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=5,  # one cross-attn group
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    cross_attn_every=5,
    n_image_tokens=16,
    mlp_activation="silu",
    attn_chunk=64,
)

register(FULL, REDUCED)
