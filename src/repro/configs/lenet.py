"""lenet [cnn] — LeNet-5 on the LayerGraph IR (paper Table III's first row).

The classic 32x32 LeNet-5: two 5x5 VALID convs (6 then 16 filters), each
followed by ReLU and a 2x2/2 max-pool, then the 120/84/10 dense head. The
paper extracts Conv2 at 0.95 input sparsity and reports ECR beating cuDNN on
it — the layer `benchmarks/table3_single_layer.py` now pulls from THIS graph
instead of a synthetic one-off.

Both pools are fusion-eligible (stride == p, exact tiling: 28 -> 14, 10 -> 5),
so a sparse plan runs the whole body as PECR — the shapes here are the ones
that exercise the 5x5-kernel / pad-0 paths the VGG-only spine never hit.

`LENET_REDUCED` is the CI-scale variant (16x16 input, fewer filters) the
model-zoo smoke benchmark and the serving tests run end-to-end.
"""
from __future__ import annotations

from repro.graph.ir import ConvSpec, DenseSpec, Flatten, LayerGraph, PoolSpec, ReLU

# published input sparsity of each conv (paper Table III; Conv1 sees the
# dense image, Conv2 the 0.95-sparse post-ReLU/pool map)
TABLE3_SPARSITY = {"conv2": 0.95}


def lenet_graph(*, img_size: int = 32, in_channels: int = 1,
                filters: tuple = (6, 16), k: int = 5,
                head: tuple = (120, 84), n_classes: int = 10,
                name: str = "lenet5") -> LayerGraph:
    nodes = []
    for c_out in filters:
        nodes += [ConvSpec(c_out, k=k, stride=1, pad=0), ReLU(), PoolSpec(2)]
    nodes.append(Flatten())
    for d in head:
        nodes.append(DenseSpec(d, relu=True))
    nodes.append(DenseSpec(n_classes))
    return LayerGraph(name=name, in_shape=(in_channels, img_size, img_size),
                      nodes=tuple(nodes))


LENET = lenet_graph()
LENET_REDUCED = lenet_graph(img_size=16, filters=(4, 8), head=(32,),
                            n_classes=8, name="lenet-tiny")
