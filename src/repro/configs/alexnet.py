"""alexnet [cnn] — AlexNet on the LayerGraph IR (paper Table III rows 2-3).

The standard single-tower AlexNet: 11x11/4 then 5x5 then three 3x3 convs,
ReLU after each, with the OVERLAPPING 3x3/2 max-pools of the original. Those
pools are exactly what the VGG-only spine could not express — pooling stride
!= pool size makes them ineligible for the PECR fusion rule
(`repro.graph.registry.fusion_eligible`), so a sparse plan runs the
stage-final convs as ECR + an unfused overlapping pool, and the 11x11/stride-4
first conv exercises the kernel's large-k / strided paths.

The paper extracts Conv3/Conv4 at 0.90 input sparsity (Table III);
`benchmarks/table3_single_layer.py` pulls those layers from this graph.

`ALEXNET_REDUCED` is the CI-scale variant; its 3x2/2 pools land on maps the
overlapping windows do not tile, so they run in "ceil" mode — the explicit
partial-tail handling the old `_maxpool` silently truncated away.
"""
from __future__ import annotations

from repro.graph.ir import ConvSpec, DenseSpec, Flatten, LayerGraph, PoolSpec, ReLU

# published input sparsity of the extracted layers (paper Table III)
TABLE3_SPARSITY = {"conv3": 0.90, "conv4": 0.90}


def alexnet_graph(*, img_size: int = 224, in_channels: int = 3,
                  n_classes: int = 1000, name: str = "alexnet") -> LayerGraph:
    pool = PoolSpec(3, stride=2)  # overlapping; 55/27/13 all tile exactly
    nodes = (
        ConvSpec(64, k=11, stride=4, pad=2), ReLU(), pool,
        ConvSpec(192, k=5, stride=1, pad=2), ReLU(), pool,
        ConvSpec(384, k=3, stride=1, pad=1), ReLU(),
        ConvSpec(256, k=3, stride=1, pad=1), ReLU(),
        ConvSpec(256, k=3, stride=1, pad=1), ReLU(), pool,
        Flatten(),
        DenseSpec(4096, relu=True), DenseSpec(4096, relu=True),
        DenseSpec(n_classes),
    )
    return LayerGraph(name=name, in_shape=(in_channels, img_size, img_size),
                      nodes=nodes)


def alexnet_reduced_graph(*, img_size: int = 32, in_channels: int = 3,
                          n_classes: int = 10,
                          name: str = "alexnet-tiny") -> LayerGraph:
    pool = PoolSpec(3, stride=2, mode="ceil")  # partial tails kept, not dropped
    nodes = (
        ConvSpec(16, k=5, stride=2, pad=2), ReLU(), pool,
        ConvSpec(24, k=5, stride=1, pad=2), ReLU(), pool,
        ConvSpec(32, k=3, stride=1, pad=1), ReLU(),
        ConvSpec(32, k=3, stride=1, pad=1), ReLU(),
        ConvSpec(24, k=3, stride=1, pad=1), ReLU(), pool,
        Flatten(),
        DenseSpec(64, relu=True),
        DenseSpec(n_classes),
    )
    return LayerGraph(name=name, in_shape=(in_channels, img_size, img_size),
                      nodes=nodes)


ALEXNET = alexnet_graph()
ALEXNET_REDUCED = alexnet_reduced_graph()
