"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave. [arXiv:2403.19887; hf]
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_d_ff=14336,
    attn_every=8,  # 1 attention layer per 8 (1:7 attn:mamba)
    moe_every=2,  # MoE every other layer
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    mlp_activation="silu",
)

REDUCED = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=8,  # one full interleave group
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    n_experts=4,
    top_k=2,
    moe_d_ff=256,
    attn_every=8,
    moe_every=2,
    ssm_state_dim=8,
    ssm_conv_width=4,
    ssm_expand=2,
    mlp_activation="silu",
    attn_chunk=64,
)

register(FULL, REDUCED)
