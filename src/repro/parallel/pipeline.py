"""GPipe-style pipeline parallelism over a mesh axis (the "pod" axis).

`pipeline_apply` runs S stages on S mesh slices with M microbatches using the
classic (M + S - 1)-tick schedule: at tick t, stage s processes microbatch
t - s; activations hop stage->stage via collective_permute. Differentiable
(the transpose of ppermute is the reverse hop, so jax.grad yields the 1F1B-
equivalent backward wave automatically).

This is the PP building block offered by the framework (RunConfig.
pipeline_stages); the production default for the multi-pod mesh is FSDP over
"pod", with PP as the alternative when cross-pod bandwidth is the binding
constraint — activations/S vs gradients/step is the trade. (The CNN serving
path uses the simpler 1-D "data" mesh of `api.data_mesh`; see DESIGN.md §6.)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn, stage_params, x_micro, *, mesh: Mesh, axis: str = "pod"):
    """stage_params: tree with leaves stacked (S, ...); x_micro: (M, mb, ...).

    Returns (M, mb, ...) outputs of the full S-stage pipeline.
    stage_fn(params_for_one_stage, x) -> y with y.shape == x.shape.
    """
    s_count = mesh.shape[axis]
    m_count = x_micro.shape[0]

    def per_stage(params_local, x_local):
        # params_local: (1, ...) slice for this stage; x_local: full (M, ...)
        # (microbatches replicated along the stage axis; only stage 0 consumes)
        params_me = jax.tree_util.tree_map(lambda p: p[0], params_local)
        sid = jax.lax.axis_index(axis)
        mb_shape = x_local.shape[1:]
        perm = [(i, i + 1) for i in range(s_count - 1)]

        def tick(carry, t):
            incoming, outputs = carry
            mb_idx = jnp.clip(t, 0, m_count - 1)
            first_in = jax.lax.dynamic_index_in_dim(x_local, mb_idx, 0, keepdims=False)
            x_in = jnp.where(sid == 0, first_in, incoming)
            y = stage_fn(params_me, x_in)
            out_idx = t - (s_count - 1)
            valid_out = (sid == s_count - 1) & (out_idx >= 0) & (out_idx < m_count)
            outputs = jax.lax.cond(
                valid_out,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_idx, 0, m_count - 1), 0),
                lambda o: o,
                outputs,
            )
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, outputs), None

        init = (jnp.zeros(mb_shape, x_local.dtype),
                jnp.zeros((m_count,) + mb_shape, x_local.dtype))
        (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(m_count + s_count - 1))
        # only the last stage holds real outputs; sum over the stage axis
        outputs = jnp.where(sid == s_count - 1, outputs, 0)
        return jax.lax.psum(outputs, axis)

    pspec = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(pspec, P()),  # params split by stage, microbatches replicated
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x_micro)


def split_stages(stacked_params, n_stages: int):
    """Reshape (L, ...) stacked layer params into (S, L/S, ...) stage stacks."""
    def r(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    return jax.tree_util.tree_map(r, stacked_params)
