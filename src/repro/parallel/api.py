"""Logical-axis sharding: model code names dimensions, rules map them to mesh axes.

Models annotate tensors with *logical* dimension names ("batch", "heads",
"experts", ...). A rules table maps each name to an ordered list of candidate
mesh-axis tuples. Resolution per tensor:

  for each dim (left to right), take the first candidate whose axes are all
  (a) present in the mesh, (b) not already used by an earlier dim of this
  tensor, and (c) divide the dim size evenly. Otherwise the dim is replicated.

This pruning is what lets one rule set serve every (arch x shape x mesh) cell:
e.g. "batch" -> ("pod","data") shrinks to ("data",) on the single-pod mesh and
prunes away entirely for the batch=1 long-context cell (where "cache_seq" then
picks up the data axes).

Outside an `axis_rules` context everything is a no-op, so smoke tests and the
CPU examples never touch device state.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# fsdp: parameter dims that shard over the data axes (ZeRO-3); the "pod" axis
# joins both the batch and the fsdp shardings on the multi-pod mesh.
DEFAULT_RULES: dict[str, list[tuple[str, ...]]] = {
    # activations
    "batch": [("pod", "data"), ("data",)],
    "seq_sp": [("model",)],  # Megatron-SP activation sequence sharding
    "act_embed": [],
    # caches / recurrent state
    "cache_seq": [("pod", "data"), ("data",)],
    "cache_kv": [("model",)],
    "cache_hd": [("model",)],
    # params
    "vocab": [("model",)],
    "embed": [("pod", "data"), ("data",)],  # FSDP dim
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [],
    "mlp": [("model",)],
    "experts": [("model",)],  # EP
    "expert_cap": [("pod", "data"), ("data",)],
    "kv_lora": [],
    "q_lora": [],
    "layers": [],
    "none": [],
}


def data_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D mesh over the first `n_devices` local devices on the "data" axis
    (None = all of them) — the serving engine's data-parallel layout
    (DESIGN.md §6). A 1-device mesh is valid and degenerates to replication
    everywhere, so callers can treat device count as just another knob."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"data_mesh({n_devices}): this host exposes {len(devs)} device(s)")
    return Mesh(np.array(devs[:n]), ("data",))


def is_axes_leaf(x) -> bool:
    """A logical-axes annotation: tuple of axis names / None (incl. empty)."""
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x) and (
        not hasattr(x, "_fields") or len(x) == 0)


def axes_leaves(tree) -> list:
    return jax.tree_util.tree_leaves(tree, is_leaf=is_axes_leaf)


class _Ctx:
    mesh: Optional[Mesh] = None
    rules: dict = DEFAULT_RULES


_CTX = _Ctx()


@contextmanager
def axis_rules(mesh: Mesh, rules: dict | None = None, fsdp: bool = True):
    prev = (_CTX.mesh, _CTX.rules)
    r = dict(rules or DEFAULT_RULES)
    if not fsdp:
        r["embed"] = []
    _CTX.mesh, _CTX.rules = mesh, r
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def logical_spec(shape: Sequence[int], names: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None, rules: dict | None = None) -> P:
    """Resolve logical names -> PartitionSpec with conflict/divisibility pruning."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        return P()
    assert len(shape) == len(names), (shape, names)
    mesh_axes = set(mesh.axis_names)
    used: set[str] = set()
    out = []
    for size, name in zip(shape, names):
        assigned: tuple[str, ...] | None = None
        for cand in rules.get(name or "none", []):
            axes = tuple(a for a in cand if a in mesh_axes)
            if not axes or any(a in used for a in axes):
                continue
            k = math.prod(mesh.shape[a] for a in axes)
            if k > 1 and size % k == 0:
                assigned = axes
                used.update(axes)
                break
        out.append(assigned if assigned is None or len(assigned) > 1 else assigned[0])
    return P(*out)


def sharding_for(shape, names, mesh=None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(shape, names, mesh))


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical names; identity outside a context."""
    if _CTX.mesh is None:
        return x
    spec = logical_spec(x.shape, names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))
