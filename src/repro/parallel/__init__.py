from repro.parallel.api import (
    axis_rules,
    current_mesh,
    data_mesh,
    logical_spec,
    shard,
    sharding_for,
)

__all__ = ["axis_rules", "current_mesh", "data_mesh", "logical_spec", "shard",
           "sharding_for"]
