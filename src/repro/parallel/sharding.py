"""Concrete sharding trees for params / optimizer state / caches / batches."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model as M
from repro.optim.adamw import OptState
from repro.parallel.api import axes_leaves, logical_spec


def _zip_spec(shapes_tree, axes_tree, mesh) -> object:
    """Map (ShapeDtypeStruct, logical axes) leaves -> NamedSharding tree."""
    flat_s, treedef = jax.tree_util.tree_flatten(shapes_tree)
    flat_a = axes_leaves(axes_tree)
    assert len(flat_s) == len(flat_a), (len(flat_s), len(flat_a))
    out = [NamedSharding(mesh, logical_spec(s.shape, a, mesh)) for s, a in zip(flat_s, flat_a)]
    return treedef.unflatten(out)


def params_sharding(cfg: ModelConfig, mesh: Mesh, dtype=jnp.bfloat16):
    shapes, axes = M.abstract_params(cfg, dtype)
    return _zip_spec(shapes, axes, mesh), shapes


def opt_sharding(cfg: ModelConfig, mesh: Mesh, run: RunConfig, param_shapes):
    """Moments shard exactly like the params (FSDP/ZeRO: state lives with shard)."""
    _, axes = M.abstract_params(cfg)
    mdt = jnp.dtype(run.moment_dtype)
    mom_shapes = jax.tree_util.tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), param_shapes)
    mom_shard = _zip_spec(mom_shapes, axes, mesh)
    state_shapes = OptState(step=jax.ShapeDtypeStruct((), jnp.int32), m=mom_shapes, v=mom_shapes)
    state_shard = OptState(step=NamedSharding(mesh, P()), m=mom_shard, v=mom_shard)
    return state_shard, state_shapes


def cache_sharding(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int, dtype=jnp.bfloat16):
    shapes, axes = M.abstract_cache(cfg, batch, max_len, dtype)
    return _zip_spec(shapes, axes, mesh), shapes


_BATCH_AXES = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "img_embeds": ("batch", None, None),
    "frames": ("batch", None, None),
    "enc_out": ("batch", None, None),
}


def batch_sharding(specs: dict, mesh: Mesh):
    return {
        k: NamedSharding(mesh, logical_spec(v.shape, _BATCH_AXES[k], mesh))
        for k, v in specs.items()
    }
