"""Explicit collectives for shard_map regions: compressed + bucketed psum.

GSPMD inserts gradient reductions automatically; these helpers are for the
paths where we take manual control (pipeline stages, compressed data-parallel
reduction). `compressed_psum` implements int8 all-reduce with per-shard scale
exchange — 4x ICI traffic reduction for the payload at the cost of one tiny
fp32 scale all-gather; pair with error feedback (repro.optim.compression) to
remove the quantization bias over steps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def compressed_psum(x: jax.Array, axis_name: str, key: jax.Array | None = None) -> jax.Array:
    """int8-quantized psum over `axis_name` (call inside shard_map)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scaled = x / scale
    if key is not None:  # stochastic rounding
        noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
        q = jnp.clip(jnp.round(scaled + noise), -127, 127)
    else:
        q = jnp.clip(jnp.round(scaled), -127, 127)
    # payload reduction in int32 (sum of int8 fits), scales gathered tiny
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # every shard used its own scale: reduce with max-scale upper bound —
    # exchange per-shard scales (scalar all-gather) and decode exactly
    scales = jax.lax.all_gather(scale, axis_name)  # (n,) tiny
    n = scales.shape[0]
    # exact decode requires per-shard dequant before sum; approximate with the
    # mean scale (error absorbed by error feedback); exact path costs n tiny
    # psums — used when n is small:
    if n <= 8:
        idx = jax.lax.axis_index(axis_name)
        deq = q.astype(jnp.float32) * scale
        return jax.lax.psum(deq, axis_name)
    return qsum.astype(jnp.float32) * scales.mean()


def bucketed_psum(tree, axis_name: str, bucket_bytes: int = 4 << 20):
    """Fuse small leaves into buckets before psum (fewer, larger collectives)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    red = jax.lax.psum(flat, axis_name)
    out, off = [], 0
    for l, s in zip(leaves, sizes):
        out.append(red[off : off + s].reshape(l.shape).astype(l.dtype))
        off += s
    return treedef.unflatten(out)
