"""Paper Fig. 9: per-conv-layer comparison on VGG-19 (ECR vs dense vs im2col).

Claim checked: ECR sparse convolution beats the dense (cuDNN-stand-in) and
im2col baselines layer-by-layer on VGG-19, and the win grows with depth (the
paper reports 3.5-4.3X whole-network over cuDNN-FAST). The paper's y-metric
is wall-clock speedup over cuDNN per layer; here we report measured CPU wall
times for the three algorithm paths plus the paper's MAC-reduction metric and
the modeled-TPU speedup, per layer, at the Fig. 2 sparsity schedule.

`batch_rows` extends the figure beyond the paper: the same per-layer
comparison swept over batch sizes (the serving regime), so the perf
trajectory captures batch scaling — us/img should fall with batch as the
kernel tensor is reused across samples (Shi & Chu's batch-level reuse).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from benchmarks._util import VGG19_CONVS, VGG19_SPARSITY, modeled_tpu_us, time_fn
from repro.core import conv2d, synth_feature_map, window_stats
from repro.kernels.ecr_conv.ops import channel_block_occupancy


def rows(stride: int = 1, layers=None):
    out = []
    sel = layers if layers is not None else range(len(VGG19_CONVS))
    for i in sel:
        name, c, o, res = VGG19_CONVS[i]
        sp = VGG19_SPARSITY[i]
        x = synth_feature_map(jax.random.PRNGKey(i), (c, res, res), sp)
        k = jax.random.normal(jax.random.PRNGKey(100 + i), (o, c, 3, 3)) * 0.05
        t = {}
        for impl in ("dense", "im2col", "ecr"):
            f = jax.jit(partial(conv2d, stride=stride, impl=impl))
            t[impl] = time_fn(f, x, k, iters=2, warmup=1)
        st = window_stats(jax.device_get(x), 3, 3, stride)
        occ = channel_block_occupancy(x, 8, compact=True)  # the kernel's schedule
        m = modeled_tpu_us(c, res, res, o, 3, 3, stride, occ)
        out.append({
            "name": f"fig9/{name}/s{stride}",
            "us_per_call": t["ecr"],
            "derived": (f"dense_us={t['dense']:.0f} im2col_us={t['im2col']:.0f} "
                        f"sparsity={sp:.2f} mac_red={st.mul_reduction:.2f} "
                        f"occ_compacted={occ:.2f} tpu_model_speedup={m['speedup']:.2f}"),
        })
    return out


def batch_rows(batch_sizes=(1, 2, 4), layers=(8, 12), stride: int = 1):
    """Batch-size sweep on representative deep layers (CPU-budget subset).

    Reports measured us/img for the batched dense and batched ECR paths (the
    batch flows through the compressed format as one call — no python loop),
    and the modeled-TPU us/img at the layer's compacted occupancy, which is
    batch-invariant per image except for the kernel-tensor read amortized
    across the batch.
    """
    out = []
    for i in layers:
        name, c, o, res = VGG19_CONVS[i]
        sp = VGG19_SPARSITY[i]
        k = jax.random.normal(jax.random.PRNGKey(100 + i), (o, c, 3, 3)) * 0.05
        for n in batch_sizes:
            x = jnp.stack([
                synth_feature_map(jax.random.PRNGKey(i * 97 + b), (c, res, res), sp)
                for b in range(n)
            ])
            t = {}
            for impl in ("dense", "ecr"):
                f = jax.jit(partial(conv2d, stride=stride, impl=impl))
                t[impl] = time_fn(f, x, k, iters=2, warmup=1)
            occ = channel_block_occupancy(x[0], 8, compact=True)
            m = modeled_tpu_us(c, res, res, o, 3, 3, stride, occ, batch=n)
            out.append({
                "name": f"fig9b/{name}/n{n}",
                "us_per_call": t["ecr"] / n,
                "derived": (f"dense_us_img={t['dense'] / n:.0f} "
                            f"ecr_us_img={t['ecr'] / n:.0f} batch={n} "
                            f"occ_compacted={occ:.2f} "
                            f"tpu_model_ecr_us_img={m['ecr_us']:.2f} "
                            f"tpu_model_speedup={m['speedup']:.2f}"),
            })
    return out


def main(stride: int = 1, batches: bool = True):
    for r in rows(stride):
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if batches:
        for r in batch_rows():
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
