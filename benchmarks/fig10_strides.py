"""Paper Fig. 10: convolution with strides 2 and 3 on the VGG-19 layer set.

Claim checked: ECR's advantage survives strided convolution (the paper shows
comparable speedups at stride 2 and 3 — the compression step is per-window,
so fewer windows shrink the work on both sides of the comparison). Reuses the
fig9 row machinery at strides {2, 3} on every other layer."""
from benchmarks.fig9_vgg19 import rows


def main():
    # a representative subset (every other layer) at strides 2 and 3
    for stride in (2, 3):
        for r in rows(stride, layers=range(0, 16, 2)):
            print(f"{r['name'].replace('fig9', 'fig10')},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
