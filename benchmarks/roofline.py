"""§Roofline report: renders the per-(arch x shape x mesh) table from the
dry-run JSONs in experiments/dryrun/ (see repro.launch.dryrun).

The compute/memory terms are RECOMPUTED here from each record's raw HLO
flops/bytes under the repo's unified roofline constants
(`repro.obs.constants` — the single definition every modeled time divides
by), so a constants change re-prices old dry-run artifacts instead of
reading terms frozen at record-production time. `--calib-db` prices them at
a fitted `CalibrationDB`'s measured effective constants instead (the
('conv','dense') key — dry-run programs are whole-network XLA, the plain
dense family); records predating the raw fields fall back to their recorded
terms. The collective term always comes from the record: link bandwidth is
a topology constant, not a roofline one.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.obs.constants import DEFAULT_ROOFLINE

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"


def load_records(mesh: str | None = None):
    recs = []
    for p in sorted(DRY.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def reprice(rec: dict, calibration=None) -> dict:
    """Record with compute/memory terms recomputed from the raw per-device
    HLO flops/bytes under the unified (or calibrated) constants; the
    dominant term is re-derived to match. No-op for error/skip records and
    for old records without the raw fields."""
    if rec.get("status") != "ok" or "hlo_flops_per_device" not in rec:
        return rec
    consts = DEFAULT_ROOFLINE if calibration is None else \
        calibration.constants_for("conv", "dense")
    out = dict(rec)
    out["compute_term_s"] = rec["hlo_flops_per_device"] / consts.peak_flops
    out["memory_term_s"] = rec["hlo_bytes_per_device"] / consts.hbm_bw
    terms = {"compute": out["compute_term_s"],
             "memory": out["memory_term_s"],
             "collective": rec.get("collective_term_s", 0.0)}
    out["dominant_term"] = max(terms, key=terms.get)
    return out


def render_table(mesh: str = "16x16", calibration=None) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "MODEL/HLO flops | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for r in load_records(mesh):
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP: "
                        f"{r['reason'][:60]}… | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR {r.get('error','')[:50]} | | | | | |")
            continue
        r = reprice(r, calibration)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_term_s']:.3f} | "
            f"{r['memory_term_s']:.3f} | {r['collective_term_s']:.3f} | "
            f"{r['dominant_term']} | {r['useful_flop_ratio']:.2f} | {r['compile_s']:.0f} |")
    return "\n".join(rows)


def main(calib_db: str | None = None):
    calibration = None
    if calib_db:
        from repro.obs.calibrate import CalibrationDB

        calibration = CalibrationDB.load(calib_db)
    for mesh in ("16x16", "2x16x16"):
        recs = load_records(mesh)
        if not recs:
            continue
        ok = [reprice(r, calibration) for r in recs if r.get("status") == "ok"]
        for r in ok:
            mfu_proxy = r["compute_term_s"] / max(
                r["compute_term_s"], r["memory_term_s"], r["collective_term_s"])
            print(f"roofline/{r['arch']}/{r['shape']}/{mesh},0.0,"
                  f"compute={r['compute_term_s']:.3f}s memory={r['memory_term_s']:.3f}s "
                  f"collective={r['collective_term_s']:.3f}s dom={r['dominant_term']} "
                  f"roofline_frac={mfu_proxy:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calib-db", default=None, metavar="PATH",
                    help="price the terms at a fitted CalibrationDB's "
                         "measured effective constants (obs.calibrate JSON) "
                         "instead of the datasheet defaults")
    args = ap.parse_args()
    main(calib_db=args.calib_db)
