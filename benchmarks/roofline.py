"""§Roofline report: renders the per-(arch x shape x mesh) table from the
dry-run JSONs in experiments/dryrun/ (see repro.launch.dryrun)."""
from __future__ import annotations

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"


def load_records(mesh: str | None = None):
    recs = []
    for p in sorted(DRY.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def render_table(mesh: str = "16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "MODEL/HLO flops | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for r in load_records(mesh):
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP: "
                        f"{r['reason'][:60]}… | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR {r.get('error','')[:50]} | | | | | |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_term_s']:.3f} | "
            f"{r['memory_term_s']:.3f} | {r['collective_term_s']:.3f} | "
            f"{r['dominant_term']} | {r['useful_flop_ratio']:.2f} | {r['compile_s']:.0f} |")
    return "\n".join(rows)


def main():
    for mesh in ("16x16", "2x16x16"):
        recs = load_records(mesh)
        if not recs:
            continue
        ok = [r for r in recs if r.get("status") == "ok"]
        for r in ok:
            mfu_proxy = r["compute_term_s"] / max(
                r["compute_term_s"], r["memory_term_s"], r["collective_term_s"])
            print(f"roofline/{r['arch']}/{r['shape']}/{mesh},0.0,"
                  f"compute={r['compute_term_s']:.3f}s memory={r['memory_term_s']:.3f}s "
                  f"collective={r['collective_term_s']:.3f}s dom={r['dominant_term']} "
                  f"roofline_frac={mfu_proxy:.3f}")


if __name__ == "__main__":
    main()
