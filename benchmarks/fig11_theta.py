"""Paper Fig. 11: speedup tracks Θ = (sparsity x 100) / feature-map width.

Claim checked: the *trend* — deeper layers (smaller, sparser maps) gain more,
and Θ is a usable single predictor of the per-layer win (the planner's
occupancy threshold is the block-granularity version of this predictor). We
sweep (size, sparsity), compute Θ and the modeled-TPU speedup + MAC
reduction, and report the Spearman-style rank agreement between Θ and
speedup — reproducing the figure's monotonicity."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks._util import modeled_tpu_us
from repro.core import synth_feature_map, window_stats
from repro.kernels.ecr_conv.ops import channel_block_occupancy


def main():
    sizes = [7, 14, 28, 56]
    sparsities = [0.3, 0.5, 0.7, 0.9]
    c, o = 256, 256
    thetas, speeds = [], []
    for size in sizes:
        for sp in sparsities:
            x = synth_feature_map(jax.random.PRNGKey(size * 100 + int(sp * 10)),
                                  (c, size, size), sp)
            occ = channel_block_occupancy(x, 8, compact=True)
            st = window_stats(jax.device_get(x), 3, 3, 1)
            m = modeled_tpu_us(c, size, size, o, 3, 3, 1, occ)
            theta = sp * 100.0 / size
            thetas.append(theta)
            speeds.append(m["speedup"])
            print(f"fig11/size{size}_sp{sp},{m['ecr_us']:.2f},"
                  f"theta={theta:.2f} tpu_model_speedup={m['speedup']:.2f} "
                  f"mac_red={st.mul_reduction:.2f}")
    # rank correlations (paper: speedup and Θ rise together). Θ = sparsity/size
    # couples two effects: zero-skipping (sparsity) and cuDNN's small-GEMM
    # underutilization (1/size). The TPU kernel keeps small maps whole in VMEM,
    # removing the size penalty — so our speedup tracks the sparsity component
    # of Θ (strong) more than Θ itself (diluted by the size axis).
    def rank_corr(a, b):
        return float(np.corrcoef(np.argsort(np.argsort(a)), np.argsort(np.argsort(b)))[0, 1])

    sp_axis = [sp for _ in sizes for sp in sparsities]
    print(f"fig11/rank_correlation,0.0,spearman_theta={rank_corr(thetas, speeds):.3f} "
          f"spearman_sparsity={rank_corr(sp_axis, speeds):.3f}")


if __name__ == "__main__":
    main()
