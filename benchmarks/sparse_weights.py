"""Weight-density x model sweep: pruned serving through the planned pipeline.

For each reduced LayerGraph network (LeNet / AlexNet / VGG) and each target
BSR block density, magnitude-prune the params (`repro.sparse_weights`), let
`plan_network` arbitrate dense/ECR/PECR/BSR per layer from the measured
activation occupancy AND the achieved weight density, and report:

- wall time of the jitted planned executor (`run_plan`) over a small batch,
- the plan's per-impl layer counts (how many layers the joint cost model
  actually handed to the BSR path at this density),
- the achieved block density + probe logit drift from the `PruneReport`,
- the max logits deviation of the planned executor vs the dense-on-pruned
  reference — the correctness gate that says the im2col/BSR lowering is
  numerically sound on this topology.

density=1.0 is the unpruned control row: it must plan ZERO bsr layers and
match the activation-only plan of `benchmarks/model_zoo.py`.

Emits BENCH_sparse_weights.json (the machine-readable perf-trajectory
artifact CI uploads next to the serve benches).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks._util import dead_band_calib, time_fn, write_bench_json
from repro.graph import init_graph
from repro.graph.executor import run_graph
from repro.models.cnn import shift_dead_channels
from repro.pipeline import plan_network, run_plan
from repro.sparse_weights import prune_graph_params

DENSITIES = (1.0, 0.6, 0.3, 0.1)


def _zoo():
    from repro.configs.alexnet import ALEXNET_REDUCED
    from repro.configs.lenet import LENET_REDUCED
    from repro.configs.vgg19_sparse import CNNConfig, vgg19_graph

    vgg_tiny = vgg19_graph(CNNConfig(name="vgg-tiny", in_channels=16,
                                     img_size=16, plan=((16, 2), (32, 1)),
                                     n_classes=16))
    return (LENET_REDUCED, ALEXNET_REDUCED, vgg_tiny)


def rows(densities=DENSITIES, batch: int = 4):
    out = []
    for graph in _zoo():
        base = shift_dead_channels(init_graph(jax.random.PRNGKey(0), graph))
        calib = dead_band_calib(graph, batch)
        for density in densities:
            params, report = prune_graph_params(base, density, graph,
                                                probe=calib)
            plan = plan_network(params, calib, graph, block_c=8)
            got = run_plan(plan, params, calib)
            ref = run_graph(graph, params, calib, impl="dense")
            dev = float(jnp.abs(jnp.asarray(got) - jnp.asarray(ref)).max())
            t = time_fn(jax.jit(lambda p, x, pl=plan: run_plan(pl, p, x)),
                        params, calib, iters=2, warmup=1)
            c = plan.counts()
            out.append({
                "name": f"sparse_weights/{graph.name}/d{density:g}",
                "us_per_call": t,
                "derived": (f"batch={batch} bsr={c['bsr']} sparse={c['sparse']} "
                            f"dense={c['dense']} achieved={report.density:.2f} "
                            f"drift={report.max_logit_drift:.3g} "
                            f"max_dev_vs_dense={dev:.2e}"),
                "target_density": density,
                "achieved_density": round(report.density, 4),
                "max_logit_drift": report.max_logit_drift,
                "top1_agreement": report.top1_agreement,
                "counts": c,
                "max_dev_vs_dense": dev,
            })
    return out


def main(batch: int = 4, json_dir: str | None = None):
    rs = rows(batch=batch)
    for r in rs:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if json_dir:
        return write_bench_json("sparse_weights", rs, json_dir,
                                extra={"densities": list(DENSITIES)})
    return None


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--json", nargs="?", const=".", default=None, metavar="DIR",
                    help="also write BENCH_sparse_weights.json (default dir: cwd)")
    args = ap.parse_args()
    main(batch=args.batch, json_dir=args.json)
