"""Scenario x model serving sweep: regime-diverse traffic through the engine.

Claim checked: the serving stack's ADAPTIVE machinery — occupancy-EMA
re-planning, the shared plan cache, deadline-bounded bucketing, hot model
swap — holds up under the traffic regimes it exists for, not just the steady
rates `serve_vgg19.py` sweeps. One point per (scenario, model):

- ``burst``   — Markov-modulated Poisson arrivals (base/burst rate cycle):
  the queue must drain whole due buckets, never strand a request;
- ``diurnal`` — the dead-channel band narrows mid-stream (fig. 3's diurnal
  sparsity story): the EMA must leave the hysteresis band and re-plan;
- ``hot_swap`` — the engine swaps to a 0.3-density BSR-pruned variant under
  load (DESIGN.md §7): both variants' programs coexist in the cache;
- ``multi_tenant`` — VGG + LeNet streams interleaved over ONE shared
  PlanCache: compiles stay bounded by the distinct PlanKeys.

Each point carries throughput/latency percentiles (from the engine's
MetricsTracker reservoir) plus the full telemetry snapshot — including the
per-layer occupancy-EMA timeline and the re-plan event log — so
BENCH_scenarios.json is a time series of how the engine adapted, not just a
scalar summary.

Run: PYTHONPATH=src:. python benchmarks/scenarios.py [--reduced] [--json DIR]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks._util import write_bench_json
from repro.configs.lenet import LENET_REDUCED
from repro.configs.vgg19_sparse import CNNConfig, vgg19_graph
from repro.graph import init_graph
from repro.serving import (
    DiurnalDriftScenario,
    Engine,
    HotSwapScenario,
    MultiTenantScenario,
    PlanCache,
    PoissonBurstScenario,
    SimClock,
    TenantSpec,
    replay_scenario,
    synth_image,
)

DEAD_FRAC = 0.5  # the steady regime's dead-channel band (occ ~0.5 at entry)


def _vgg_tiny():
    return vgg19_graph(CNNConfig(name="vgg-tiny", in_channels=16, img_size=16,
                                 plan=((16, 1), (32, 1)), n_classes=16))


MODELS = {"vgg19": _vgg_tiny, "lenet": lambda: LENET_REDUCED}


def _engine(graph, *, clock, cache=None, seed=0, occ_threshold=0.75,
            max_batch=4, deadline_s=0.005):
    """One scenario engine: planned on the steady DEAD_FRAC regime, with a
    snappy drift detector (alpha=0.5, cooldown=0) so the reduced-scale
    streams are long enough to show a re-plan."""
    params = init_graph(jax.random.PRNGKey(seed), graph)
    calib = jnp.stack([synth_image(graph.in_shape, seed + 1, i, DEAD_FRAC)
                       for i in range(2)])
    return Engine(params, graph=graph, calib=calib,
                  occ_threshold=occ_threshold, block_c=8, max_batch=max_batch,
                  deadline_s=deadline_s, clock=clock, cache=cache,
                  ema_alpha=0.5, replan_band=0.15, replan_cooldown=0)


def _point(scenario_name, model_name, engines, results, makespan):
    """Merge one replay into a BENCH point: throughput over the simulated
    makespan, latency percentiles from the trackers' reservoirs, adaptation
    counters, and the full telemetry snapshot(s) as the time series."""
    n = sum(len(v) for v in results.values())
    stats = {k: e.stats() for k, e in engines.items()}
    any_cache = next(iter(engines.values())).cache
    lat = [s["telemetry"]["latency"] for s in stats.values()]
    weight = [s["lat_count"] for s in stats.values()]
    tot = max(sum(weight), 1)
    point = {
        "scenario": scenario_name,
        "model": model_name,
        "requests": n,
        "throughput_rps": n / max(makespan, 1e-9),
        # single-stream points report the reservoir percentiles verbatim;
        # multi-tenant merges per-stream percentiles count-weighted (the
        # per-stream exact values ride in "telemetry")
        "p50_ms": sum(lt["p50_ms"] * w for lt, w in zip(lat, weight)) / tot,
        "p95_ms": sum(lt["p95_ms"] * w for lt, w in zip(lat, weight)) / tot,
        "p99_ms": sum(lt["p99_ms"] * w for lt, w in zip(lat, weight)) / tot,
        "mean_ms": sum(lt["mean_ms"] * w for lt, w in zip(lat, weight)) / tot,
        "batches": sum(s["batches"] for s in stats.values()),
        "mean_fill": sum(s["mean_fill"] * s["batches"] for s in stats.values())
        / max(sum(s["batches"] for s in stats.values()), 1),
        "replans": sum(s["replans"] for s in stats.values()),
        "replan_errors": sum(s["replan_errors"] for s in stats.values()),
        "hot_swaps": sum(s["hot_swaps"] for s in stats.values()),
        "cache": any_cache.stats(),
        "telemetry": {k: s["telemetry"] for k, s in stats.items()}
        if len(stats) > 1 else stats[next(iter(stats))]["telemetry"],
    }
    return point


def _run(scenario_name, model_name, engines, scenario):
    clock = next(iter(engines.values())).clock
    for e in engines.values():
        e.warmup()
    t0 = clock()
    results = replay_scenario(engines, scenario)
    return _point(scenario_name, model_name, engines, results, clock() - t0)


def sweep(n_requests: int = 16, seed: int = 0):
    points = []
    for model_name, build in MODELS.items():
        graph = build()
        shape = graph.in_shape

        clock = SimClock()
        eng = _engine(graph, clock=clock, seed=seed)
        points.append(_run("burst", model_name, {"": eng},
                           PoissonBurstScenario(
                               in_shape=shape, n_requests=n_requests,
                               base_rps=50.0, burst_rps=800.0,
                               burst_every_s=0.1, burst_len_s=0.03,
                               dead_frac=DEAD_FRAC, seed=seed)))

        clock = SimClock()
        eng = _engine(graph, clock=clock, seed=seed)
        points.append(_run("diurnal", model_name, {"": eng},
                           DiurnalDriftScenario(
                               in_shape=shape, n_requests=n_requests,
                               rate_rps=200.0, dead_lo=DEAD_FRAC, dead_hi=0.0,
                               drift="step", t_drift=n_requests / 400.0,
                               seed=seed)))

        clock = SimClock()
        eng = _engine(graph, clock=clock, seed=seed)
        from repro.sparse_weights import prune_graph_params

        pruned, report = prune_graph_params(eng.params, 0.3, graph)

        def swap(engines, _pruned=pruned):
            engines[""].hot_swap(_pruned)

        pt = _run("hot_swap", model_name, {"": eng},
                  HotSwapScenario(in_shape=shape, n_requests=n_requests,
                                  rate_rps=200.0,
                                  t_swap=n_requests / 400.0, swap_fn=swap,
                                  dead_frac=DEAD_FRAC, seed=seed))
        pt["pruned_density"] = report.density
        points.append(pt)

    # multi-tenant: both models share ONE PlanCache and one timeline
    clock = SimClock()
    cache = PlanCache(max_entries=32)
    engines, tenants = {}, []
    for model_name, build in MODELS.items():
        graph = build()
        engines[model_name] = _engine(graph, clock=clock, cache=cache,
                                      seed=seed)
        tenants.append((model_name,
                        TenantSpec(in_shape=graph.in_shape,
                                   n_requests=n_requests // 2,
                                   rate_rps=100.0, dead_frac=DEAD_FRAC)))
    points.append(_run("multi_tenant", "+".join(MODELS), engines,
                       MultiTenantScenario(tenants=tuple(tenants), seed=seed)))
    return points


def main(reduced: bool = True, json_dir: str = ".",
         n_requests: int | None = None) -> str:
    n_requests = n_requests or (16 if reduced else 64)
    points = sweep(n_requests=n_requests)
    rows = []
    for p in points:
        rows.append({
            "name": f"scenarios/{p['scenario']}/{p['model']}",
            "us_per_call": p["mean_ms"] * 1e3,
            "derived": (f"throughput_rps={p['throughput_rps']:.1f} "
                        f"p50_ms={p['p50_ms']:.2f} p99_ms={p['p99_ms']:.2f} "
                        f"replans={p['replans']} hot_swaps={p['hot_swaps']} "
                        f"compiles={p['cache']['compiles']}"),
            **{k: v for k, v in p.items() if k != "telemetry"},
        })
        print(f"{rows[-1]['name']},{rows[-1]['us_per_call']:.1f},"
              f"{rows[-1]['derived']}")
    path = write_bench_json("scenarios", rows, json_dir, extra={
        "config": {"n_requests": n_requests, "reduced": reduced,
                   "models": list(MODELS)},
        "points": points,
    })
    print(f"_meta/scenarios_json,0,wrote {path}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduced", action="store_true",
                    help="CI-smoke scale (the default)")
    ap.add_argument("--json", default=".", metavar="DIR",
                    help="directory for BENCH_scenarios.json")
    args = ap.parse_args()
    main(reduced=True, json_dir=args.json)
