"""Cost-model accuracy regression: predicted vs MEASURED per-layer time for
all four conv impl families, on the reduced model zoo (LeNet / AlexNet /
VGG), before and after calibration (DESIGN.md §9).

Per network: prune the weights to ~0.5 block density (so the BSR rows
measure a schedule that actually skips), plan at block_c=8, then
`obs.profile_plan` times every layer under dense / ecr_pallas / pecr_pallas
/ bsr and pairs each measurement with `unit_model_us` at the DEFAULT
roofline constants. A `CalibrationDB` is fitted from those same rows and the
report is re-predicted through it — the CALIBRATED ranking agreement is the
number CI pins a floor under (`--min-agreement`): if a cost-model change
makes the planner order impls differently from the clock, this benchmark
exits nonzero before the regression ships.

One row per (network, layer, kind, impl): measured_us, predicted_us at the
defaults, predicted_us calibrated, and both ratios. The BENCH extras carry
per-network agreement (default AND calibrated, top1 + pairwise) and the
fitted per-key scales.

Run:
    PYTHONPATH=src python benchmarks/cost_model.py --json . \\
        --trace-out trace.json --min-agreement 0.5
"""
from __future__ import annotations

import argparse
import sys

import jax

from benchmarks._util import dead_band_calib, write_bench_json
from benchmarks.model_zoo import _zoo
from repro.obs import CalibrationDB, Tracer, profile_plan
from repro.pipeline import plan_network


def sweep(batch: int = 2, iters: int = 3, warmup: int = 1,
          prune_density: float = 0.5, tracer=None):
    """Profile the reduced zoo; returns (rows, per-network agreement dict,
    fitted CalibrationDB). One shared DB accumulates all three networks'
    measurements — the fit keys on (kind, impl), so more layers per key just
    means a better median."""
    from repro.graph import init_graph
    from repro.models.cnn import shift_dead_channels
    from repro.sparse_weights import prune_graph_params

    db = CalibrationDB()
    reports = []
    for graph in _zoo(reduced=True):
        params = shift_dead_channels(init_graph(jax.random.PRNGKey(0), graph))
        calib = dead_band_calib(graph, batch)
        # ~half the weight blocks zeroed: the BSR rows must measure a
        # schedule with real skips, not a degenerate all-live one
        params, _ = prune_graph_params(params, prune_density, graph)
        plan = plan_network(params, calib, graph, occ_threshold=0.75,
                            block_c=8)
        report = profile_plan(plan, params, calib, iters=iters,
                              warmup=warmup, tracer=tracer)
        db.fit_report(report)
        reports.append(report)

    rows, agreement = [], {}
    for report in reports:
        recal = report.recalibrated(db)
        agreement[report.graph_name] = {
            "default": report.agreement(),
            "calibrated": recal.agreement(),
        }
        by_key = {(t.index, t.kind, t.impl): t for t in recal.timings}
        for t in report.timings:
            c = by_key[(t.index, t.kind, t.impl)]
            rows.append({
                "name": f"cost_model/{report.graph_name}/conv{t.index + 1}"
                        f"/{t.impl}",
                "us_per_call": round(t.measured_us, 2),
                "derived": (f"kind={t.kind} occ={t.occupancy:.2f} "
                            f"wd={t.weight_density:.2f} "
                            f"ratio={t.ratio:.3g} "
                            f"ratio_cal={c.ratio:.3g}"),
                "network": report.graph_name,
                "layer": t.index,
                "kind": t.kind,
                "impl": t.impl,
                "occupancy": round(t.occupancy, 4),
                "weight_density": round(t.weight_density, 4),
                "measured_us": round(t.measured_us, 2),
                "predicted_us": round(t.predicted_us, 4),
                "predicted_us_calibrated": round(c.predicted_us, 2),
                "ratio": round(t.ratio, 6),
                "ratio_calibrated": round(c.ratio, 6),
            })
    return rows, agreement, db


def _mean_agreement(agreement: dict, which: str, metric: str) -> float:
    vals = [a[which][metric] for a in agreement.values()]
    return sum(vals) / max(len(vals), 1)


def main(batch: int = 2, iters: int = 3, warmup: int = 1,
         json_dir: str | None = None, trace_out: str | None = None,
         calib_out: str | None = None,
         min_agreement: float | None = None) -> int:
    tracer = Tracer() if trace_out else None
    rows, agreement, db = sweep(batch=batch, iters=iters, warmup=warmup,
                                tracer=tracer)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    cal_top1 = _mean_agreement(agreement, "calibrated", "top1")
    extra = {
        "agreement": agreement,
        "agreement_mean": {
            "default_top1": _mean_agreement(agreement, "default", "top1"),
            "default_pairwise": _mean_agreement(agreement, "default",
                                                "pairwise"),
            "calibrated_top1": cal_top1,
            "calibrated_pairwise": _mean_agreement(agreement, "calibrated",
                                                   "pairwise"),
        },
        "calibration": db.summary(),
        "device_kind": db.device,
    }
    for k, v in extra["agreement_mean"].items():
        print(f"_meta/agreement/{k},{v:.3f}")
    if json_dir:
        path = write_bench_json("cost_model", rows, json_dir, extra=extra)
        print(f"_meta/json,{path}")
    if trace_out:
        tracer.save(trace_out)
        print(f"_meta/trace,{trace_out}")
    if calib_out:
        db.save(calib_out)
        print(f"_meta/calibration,{calib_out}")
    if min_agreement is not None and cal_top1 < min_agreement:
        print(f"FAIL: calibrated top-1 ranking agreement {cal_top1:.3f} < "
              f"floor {min_agreement:.3f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="also write BENCH_cost_model.json (default dir: cwd)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="Chrome trace_event JSON of the profiling spans")
    ap.add_argument("--calib-out", default=None, metavar="PATH",
                    help="persist the fitted CalibrationDB as JSON")
    ap.add_argument("--min-agreement", type=float, default=None,
                    metavar="FLOOR",
                    help="exit 1 if the mean CALIBRATED top-1 ranking "
                         "agreement falls below this floor (the CI gate)")
    args = ap.parse_args()
    sys.exit(main(batch=args.batch, iters=args.iters, warmup=args.warmup,
                  json_dir=args.json, trace_out=args.trace_out,
                  calib_out=args.calib_out,
                  min_agreement=args.min_agreement))
