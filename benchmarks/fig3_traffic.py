"""Paper Fig. 3: share of data-transfer time in conv+pool, per VGG-19 CP group.

Claim checked: data movement — not MACs — dominates the unfused conv+pool
pipeline, which is the motivation for PECR's fusion (§V). The paper measures
CPU<->GPU PCIe transfer vs compute with cuDNN-style separate kernels. The TPU
mapping (DESIGN.md §2.3): the equivalent traffic is (a) host->HBM once per
network input (amortized), and (b) HBM<->VMEM between the unfused conv and
pool stages. We model both from the layer shapes and the roofline constants
and report the transfer share that PECR's fusion removes."""
from __future__ import annotations

from benchmarks._util import HBM_BW, PEAK_FLOPS, VGG19_CONVS
from repro.core.pecr import fused_traffic_bytes

PCIE_BW = 16e9  # the paper's platform-1 PCIe3 x16-class link

CP_GROUPS = [(1, "CP_1"), (3, "CP_2"), (7, "CP_3"), (11, "CP_4"), (15, "CP_5")]


def main():
    for idx, label in CP_GROUPS:
        name, c, o, res = VGG19_CONVS[idx]
        res *= 2  # model at full VGG resolution
        macs = 2 * (res - 2) ** 2 * o * c * 9
        t_compute = macs / PEAK_FLOPS
        tr = fused_traffic_bytes((c, res, res), o, 3, 3, dtype_bytes=2)
        # unfused: conv out -> HBM -> pool in (the removable intermediate)
        t_hbm_intermediate = 2 * o * (res - 2) ** 2 * 2 / HBM_BW
        # the paper's regime: the same intermediate crossing PCIe to the CPU
        t_pcie_intermediate = 2 * o * (res - 2) ** 2 * 2 / PCIE_BW
        share_gpu_paper = t_pcie_intermediate / (t_pcie_intermediate + t_compute)
        share_tpu = t_hbm_intermediate / (t_hbm_intermediate + t_compute)
        print(f"fig3/{label},0.0,paper_pcie_transfer_share={share_gpu_paper:.2f} "
              f"tpu_hbm_transfer_share={share_tpu:.2f} "
              f"fused_saved_frac={tr['saved_frac']:.2f}")


if __name__ == "__main__":
    main()
