"""Paper Fig. 2: sparsity of feature maps entering each VGG-19 conv layer.

Claim checked: feature-map sparsity grows with depth (to >0.8 in the deep
layers), and the im2col-extended matrix is sparser still because extension
repeats zeros — this is the raw material every later figure's speedup is
built on. Reproduced two ways: (a) an actual forward pass through our VGG
(random weights, ReLU + biased batch-norm-like shift to emulate a trained
net's dying channels), measuring element sparsity and the im2col-extended
sparsity (the paper's blue curve vs red); and (b) the channel-block occupancy
the TPU kernel actually exploits (DESIGN.md §2.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vgg19_sparse import CNNConfig
from repro.core import window_stats
from repro.core.sparsity import block_occupancy
from repro.models.cnn import cnn_feature_maps, init_cnn, shift_dead_channels


def main():
    ccfg = CNNConfig(img_size=64)  # reduced resolution, full depth/channels
    params = init_cnn(jax.random.PRNGKey(0), ccfg)
    # emulate trained-net activation statistics: shift convs negative so ReLU
    # kills a growing fraction of channels with depth
    shifted = shift_dead_channels(params)
    img = jax.random.uniform(jax.random.PRNGKey(1), (3, ccfg.img_size, ccfg.img_size))
    maps = cnn_feature_maps(shifted, img, ccfg)
    for i, m in enumerate(maps):
        m = np.asarray(m)
        sp = float((m == 0).mean())
        st = window_stats(m, 3, 3, 1)
        ext_sp = 1.0 - st.sparse_muls / max(st.dense_muls, 1)  # im2col (blue curve)
        c = m.shape[0]
        bc = min(128, c) if c % min(128, c) == 0 else c
        occ = float(block_occupancy(jnp.asarray(m).transpose(1, 2, 0),
                                    (m.shape[1], m.shape[2], bc)).mean())
        print(f"fig2/conv_{i+1},0.0,sparsity={sp:.3f} im2col_sparsity={ext_sp:.3f} "
              f"channel_block_occ={occ:.3f} shape={m.shape}")


if __name__ == "__main__":
    main()
