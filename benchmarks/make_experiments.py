"""Regenerate the EXPERIMENTS.md §Dry-run and §Roofline tables from
experiments/dryrun/*.json (run after the dry-run grid)."""
from __future__ import annotations

import json
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"
EXP = ROOT / "EXPERIMENTS.md"


def _recs(mesh):
    out = []
    for p in sorted(DRY.glob(f"*__{mesh}.json")):
        out.append(json.loads(p.read_text()))
    return out


def dryrun_table() -> str:
    lines = []
    for mesh in ("16x16", "2x16x16"):
        recs = _recs(mesh)
        if not recs:
            continue
        n_ok = sum(r.get("status") == "ok" for r in recs)
        n_skip = sum(r.get("status") == "skipped" for r in recs)
        n_err = len(recs) - n_ok - n_skip
        lines.append(f"\n**Mesh {mesh}** — {n_ok} compiled, {n_skip} skipped "
                     f"(per assignment), {n_err} errors.\n")
        lines.append("| arch | shape | compile s | arg GB/dev | temp GB/dev | "
                     "HLO GFLOP/dev | HBM GB/dev | coll GB/dev |")
        lines.append("|---|---|---|---|---|---|---|---|")
        for r in recs:
            if r.get("status") == "skipped":
                lines.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | |")
                continue
            if r.get("status") != "ok":
                lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
                continue
            ma = r.get("memory_analysis", {})
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} | "
                f"{ma.get('argument_size_in_bytes', 0)/1e9:.2f} | "
                f"{ma.get('temp_size_in_bytes', 0)/1e9:.2f} | "
                f"{r['hlo_flops_per_device']/1e9:.0f} | "
                f"{r['hlo_bytes_per_device']/1e9:.0f} | "
                f"{r['collectives']['total_bytes']/1e9:.1f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    lines = ["\n| arch | shape | compute s | memory s | collective s | dominant | "
             "useful | roofline frac | w/ pallas-flash |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in _recs("16x16"):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP (sub-quadratic-only shape) | | | |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        dom = max(r["compute_term_s"], r["memory_term_s"], r["collective_term_s"])
        frac = r["compute_term_s"] / dom if dom else 0.0
        pf = r.get("pallas_flash")
        if pf:
            dom_p = max(r["compute_term_s"], pf["memory_term_pallas_s"], r["collective_term_s"])
            pcol = f"mem {pf['memory_term_pallas_s']:.2f}s → frac {r['compute_term_s']/dom_p:.2f}"
        else:
            pcol = ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_term_s']:.3f} | "
            f"{r['memory_term_s']:.3f} | {r['collective_term_s']:.3f} | "
            f"{r['dominant_term']} | {r['useful_flop_ratio']:.2f} | {frac:.3f} | {pcol} |")
    return "\n".join(lines)


def inject(md: str, marker: str, content: str) -> str:
    pat = re.compile(rf"<!-- {marker} -->.*?(?=\n## |\Z)", re.S)
    return pat.sub(f"<!-- {marker} -->\n{content}\n", md)


def main():
    md = EXP.read_text()
    md = inject(md, "DRYRUN_TABLE", dryrun_table())
    md = inject(md, "ROOFLINE_TABLE", roofline_table())
    EXP.write_text(md)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
