"""Serving benchmark: request-rate sweep through the sparsity-aware engine.

Claim checked: the serving layer keeps the paper's sparse-kernel wins under a
request stream — bucketed micro-batching amortizes the per-layer kernel
launches and the weight reads across co-batched requests (Shi & Chu's
batch-level reuse), the plan cache makes steady-state serving compile-free,
and the deadline bounds queueing latency. The sweep drives an open-loop
stream at each offered rate on a simulated clock that carries REAL measured
execution wall times, and reports throughput and latency percentiles per
rate point.

Emits BENCH_serve_vgg19.json (always — this benchmark is the head of the
perf trajectory) in addition to the usual CSV rows.

Run: PYTHONPATH=src:. python benchmarks/serve_vgg19.py [--reduced] [--json DIR]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks._util import serve_replay_point, write_bench_json
from repro.configs.vgg19_sparse import CNNConfig
from repro.launch.serve_cnn import synth_requests
from repro.models.cnn import init_cnn, shift_dead_channels
from repro.serving import Engine, SimClock


def sweep(rates, n_requests: int, ccfg: CNNConfig, *, max_batch: int = 8,
          deadline_ms: float = 10.0, occ_threshold: float = 0.75,
          block_c: int = 8, seed: int = 0):
    """One engine per rate point (fresh queue/latency state), same params and
    plan inputs; buckets are pre-compiled so the sweep measures steady-state
    serving, and the compile counts are reported per point (they must equal
    the warmup count: the stream itself never compiles)."""
    params = shift_dead_channels(init_cnn(jax.random.PRNGKey(seed), ccfg))
    calib = jnp.stack(synth_requests(ccfg, 2, seed=seed + 1))
    rows = []
    points = []
    for rate in rates:
        engine = Engine(params, ccfg, calib=calib, occ_threshold=occ_threshold,
                        block_c=block_c, max_batch=max_batch,
                        deadline_s=deadline_ms * 1e-3, clock=SimClock())
        _, point = serve_replay_point(
            engine, synth_requests(ccfg, n_requests, seed=seed + 2), rate)
        points.append(point)
        rows.append({
            "name": f"serve/rate{rate:g}",
            "us_per_call": point["mean_ms"] * 1e3,
            "derived": (f"throughput_rps={point['throughput_rps']:.1f} "
                        f"p50_ms={point['p50_ms']:.2f} p95_ms={point['p95_ms']:.2f} "
                        f"fill={point['mean_fill']:.2f} "
                        f"stream_compiles={point['stream_compiles']}"),
            **point,
        })
    return rows, points, engine.plan


def main(reduced: bool = True, json_dir: str = ".", rates=None,
         n_requests: int | None = None) -> str:
    if reduced:
        ccfg = CNNConfig(name="vgg-tiny", in_channels=16, img_size=16,
                         plan=((16, 1), (32, 1)), n_classes=16)
        rates = rates or (20.0, 50.0, 200.0)
        n_requests = n_requests or 16
    else:
        ccfg = CNNConfig(img_size=64)  # full VGG-19 depth, reduced resolution
        rates = rates or (5.0, 20.0, 50.0, 200.0)
        n_requests = n_requests or 32
    rows, points, plan = sweep(rates, n_requests, ccfg)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    counts = plan.counts()
    path = write_bench_json("serve_vgg19", rows, json_dir, extra={
        "config": {"net": ccfg.name, "img_size": ccfg.img_size,
                   "n_requests": n_requests, "reduced": reduced},
        "plan_counts": counts,
        "points": points,
    })
    print(f"_meta/serve_json,0,wrote {path}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--reduced", action="store_true",
                       help="CI-smoke scale (tiny net, fewer requests; the default)")
    scale.add_argument("--full", action="store_true",
                       help="full VGG-19 depth at reduced resolution")
    ap.add_argument("--json", default=".", metavar="DIR",
                    help="directory for BENCH_serve_vgg19.json")
    args = ap.parse_args()
    main(reduced=not args.full, json_dir=args.json)
