"""Sharded serving benchmark: device count x request rate through the
data-parallel engine (DESIGN.md §6).

Claim checked: the serving spine scales out — `run_plan` under shard_map
over a 1-D "data" mesh keeps the sparse kernels' per-sample (ids, cnt)
schedules device-local (no collective in the conv path; only the occupancy
statistic crosses shards), the batcher's device-aligned buckets hand every
shard an equal >= min_bucket slice (logits stay bit-exact against the
single-device reference), and one plan cache serves the 1..N-device layouts
side by side. The sweep replays the same open-loop request stream at each
(devices, rate) point on a simulated clock carrying real measured execution
wall times, and reports throughput and latency percentiles per point.

On this CPU host the "devices" are XLA host-platform virtual devices (the
module forces `--xla_force_host_platform_device_count` before jax
initializes), so absolute scaling numbers are synthetic — the artifact
pins the harness shape (per-device throughput points, compile counts,
bit-exactness of the serving path) that a real accelerator run fills in.

Emits BENCH_serve_sharded.json (always — this is the scale-out head of the
perf trajectory) in addition to the usual CSV rows.

Run: PYTHONPATH=src:. python benchmarks/serve_sharded.py [--reduced] [--json DIR]
"""
from __future__ import annotations

import argparse
import os
import sys

# the virtual-device flag must precede jax initialization; respect an
# explicit operator setting (or an already-imported jax) and otherwise ask
# for the sweep's default of 4
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4").strip()

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import serve_replay_point, write_bench_json
from repro.graph import init_graph
from repro.launch.serve_cnn import serving_graph, synth_requests
from repro.models.cnn import shift_dead_channels
from repro.parallel import data_mesh
from repro.serving import Engine, SimClock


def sweep(device_counts, rates, n_requests: int, graph, *, max_batch: int = 8,
          deadline_ms: float = 10.0, occ_threshold: float = 0.75,
          block_c: int = 8, seed: int = 0):
    """One engine per (devices, rate) point — fresh queue/latency state, same
    params/plan inputs; buckets are pre-compiled so every point measures
    steady-state serving, and each point's logits are checked against the
    shared single-device `run_plan` reference before timing is trusted (the
    scale-out claim is exactness-preserving throughput). The check is
    float32-tight rather than bitwise: the stream chops into rate-dependent
    bucket sizes, and under `--xla_force_host_platform_device_count` XLA's
    CPU backend re-blocks its reductions PER BATCH SIZE, so even unsharded
    M=2 rows differ from the M=8 reference in low-order bits — bucket-
    composition bit-exactness at fixed batch size is pinned by
    tests/test_serving_sharded.py, where composition is controlled."""
    from repro.pipeline import plan_network, run_plan

    params = shift_dead_channels(init_graph(jax.random.PRNGKey(seed), graph))
    calib = jnp.stack(synth_requests(graph, 2, seed=seed + 1))
    imgs = synth_requests(graph, n_requests, seed=seed + 2)
    # plan once — every point serves one schedule — and run the shared
    # single-device reference once, not per sweep point
    plan = plan_network(params, calib, graph, occ_threshold=occ_threshold,
                        block_c=block_c)
    ref = np.asarray(run_plan(plan, params, jnp.stack(imgs)))
    rows, points = [], []
    for n_dev in device_counts:
        mesh = data_mesh(n_dev)
        for rate in rates:
            engine = Engine(params, graph=graph, plan=plan,
                            max_batch=max_batch, deadline_s=deadline_ms * 1e-3,
                            clock=SimClock(), mesh=mesh)
            results, point = serve_replay_point(engine, imgs, rate)
            by_id = {r.id: r.logits for r in results}
            served = np.stack([by_id[i] for i in range(len(imgs))])
            err = float(np.abs(served - ref).max())
            assert np.allclose(served, ref, rtol=1e-5, atol=1e-5), \
                f"sharded serving diverged at devices={n_dev} rate={rate}: {err}"
            point = {
                "devices": n_dev,
                **point,
                "exec_buckets": list(engine.batcher.exec_buckets()),
                "max_abs_err_vs_run_plan": err,
            }
            points.append(point)
            rows.append({
                "name": f"serve_sharded/d{n_dev}/rate{rate:g}",
                "us_per_call": point["mean_ms"] * 1e3,
                "derived": (f"devices={n_dev} "
                            f"throughput_rps={point['throughput_rps']:.1f} "
                            f"p50_ms={point['p50_ms']:.2f} p95_ms={point['p95_ms']:.2f} "
                            f"fill={point['mean_fill']:.2f} "
                            f"stream_compiles={point['stream_compiles']}"),
                **point,
            })
    return rows, points, plan


def main(reduced: bool = True, json_dir: str = ".", device_counts=None,
         rates=None, n_requests: int | None = None, max_batch: int = 8) -> str:
    graph = serving_graph("vgg19", full=not reduced)
    if reduced:
        rates = rates or (50.0, 200.0)
        n_requests = n_requests or 16
    else:
        rates = rates or (5.0, 20.0, 50.0, 200.0)
        n_requests = n_requests or 32
    avail = jax.device_count()
    device_counts = device_counts or (1, 2, 4)
    # same admissibility rule as Engine/auto_mesh: the count must divide
    # max_batch AND leave every shard >= the min_bucket=2 bit-exactness
    # floor — d == max_batch divides but MicroBatcher(align=d) would refuse
    # the 1-sample shards, aborting the sweep after the points before it
    usable = [d for d in device_counts
              if d <= avail and max_batch % d == 0
              and (d == 1 or max_batch // d >= 2)]
    dropped = sorted(set(device_counts) - set(usable))
    if dropped:
        print(f"_meta/devices,0,skipping device counts {dropped} "
              f"(host exposes {avail}, max_batch={max_batch}, min_bucket=2)")
    rows, points, plan = sweep(usable, rates, n_requests, graph,
                               max_batch=max_batch)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    path = write_bench_json("serve_sharded", rows, json_dir, extra={
        "config": {"net": graph.name, "in_shape": list(graph.in_shape),
                   "n_requests": n_requests, "max_batch": max_batch,
                   "reduced": reduced, "host_devices": avail},
        "plan_counts": plan.counts(),
        "points": points,
    })
    print(f"_meta/serve_sharded_json,0,wrote {path}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--reduced", action="store_true",
                       help="CI-smoke scale (tiny net, fewer requests; the default)")
    scale.add_argument("--full", action="store_true",
                       help="full VGG-19 depth at reduced resolution")
    ap.add_argument("--devices", type=int, nargs="+", default=None,
                    metavar="N", help="device counts to sweep (default 1 2 4)")
    ap.add_argument("--json", default=".", metavar="DIR",
                    help="directory for BENCH_serve_sharded.json")
    args = ap.parse_args()
    main(reduced=not args.full, json_dir=args.json,
         device_counts=tuple(args.devices) if args.devices else None)
