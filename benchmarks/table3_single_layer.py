"""Paper Table III: single-conv-layer ECR vs dense on the extracted layers.

Claim checked: ECR wins on single extracted layers from LeNet / AlexNet /
GoogLeNet at their published input sparsities (0.90-0.95) — i.e. the
technique is not VGG-specific.

Since the LayerGraph refactor the LeNet and AlexNet rows are EXTRACTED FROM
THE REAL NETWORK GRAPHS (`repro.configs.lenet` / `.alexnet`): each row is a
`ConvUnit` pulled out of the graph, carrying its true input shape, kernel
size, stride and padding — the 5x5 LeNet conv and AlexNet's 3x3 mid-stack
run exactly as the full network runs them. GoogLeNet's inception layers
branch (outside the linear IR), so those rows keep the published synthetic
shapes from `_util.TABLE3_LAYERS`.

Each layer's input carries the published sparsity twice over: element-level
(the paper's metric — MAC reduction from zero skipping) and as a dead-channel
band (the trained-net ReLU channel death of Fig. 2 — what the block-ECR
schedule can actually skip). Columns: measured CPU wall time of the dense
path vs the Pallas block-ECR path (interpret mode, NOT comparable to the
paper's GTX1080 numbers), the paper's own MAC-reduction metric, and the
modeled-TPU block-ECR speedup from the roofline constants.
"""
from __future__ import annotations

from functools import partial

import jax

from benchmarks._util import TABLE3_LAYERS, modeled_tpu_us, time_fn
from repro.core import conv2d, synth_feature_map, window_stats
from repro.graph.executor import pad2d
from repro.kernels.ecr_conv.ops import channel_block_occupancy

# (graph, {unit name -> published Table III input sparsity})
def _network_layers():
    from repro.configs.alexnet import ALEXNET
    from repro.configs.alexnet import TABLE3_SPARSITY as ALEXNET_SP
    from repro.configs.lenet import LENET
    from repro.configs.lenet import TABLE3_SPARSITY as LENET_SP

    return ((LENET, LENET_SP), (ALEXNET, ALEXNET_SP))


def _seed(name: str) -> jax.Array:
    """Deterministic per-row key (`hash()` is salted per process — rows must
    not change between runs of the same commit)."""
    import zlib

    return jax.random.PRNGKey(zlib.crc32(name.encode()))


def _layer_input(key, shape, sparsity):
    """Element-sparse feature map with a dead-channel band: the published
    sparsity applied at both granularities — pure element sparsity from
    `synth_feature_map` (the paper's MAC metric) plus a deterministic
    trailing band of dead channels (the block schedule the TPU kernel
    skips). channel_dead_frac=0 keeps the two contributions separable: the
    band is the only channel-level death, so the surviving channels stay
    live and the row never degenerates to an all-zero input."""
    from repro.core import dead_channel_band

    x = synth_feature_map(key, shape, sparsity, channel_dead_frac=0.0)
    return dead_channel_band(x, min(sparsity, 1.0 - 1.0 / shape[0]))


def _bench_layer(name, x, conv, o):
    """One Table III row: dense vs block-ECR on a single extracted conv."""
    c = x.shape[0]
    key = jax.random.PRNGKey(1)
    kern = jax.random.normal(key, (o, c, conv.k, conv.k)) * 0.1
    xp = pad2d(x, conv.pad)
    dense = jax.jit(partial(conv2d, stride=conv.stride, impl="dense"))
    ecr = jax.jit(partial(conv2d, stride=conv.stride, impl="ecr_pallas"))
    t_dense = time_fn(dense, xp, kern, iters=2, warmup=1)
    t_ecr = time_fn(ecr, xp, kern, iters=2, warmup=1)
    st = window_stats(jax.device_get(xp), conv.k, conv.k, conv.stride)
    occ_raw = channel_block_occupancy(x, 8)  # without compaction
    occ = channel_block_occupancy(x, 8, compact=True)  # the kernel's schedule
    m = modeled_tpu_us(c, xp.shape[1], xp.shape[2], o, conv.k, conv.k,
                       conv.stride, occ)
    return {
        "name": name,
        "us_per_call": t_ecr,
        "derived": (f"dense_us={t_dense:.0f} k={conv.k} stride={conv.stride} "
                    f"mac_red={st.mul_reduction:.2f} occ_raw={occ_raw:.2f} "
                    f"occ_compacted={occ:.2f} "
                    f"tpu_model_speedup={m['speedup']:.2f}"),
    }


def rows():
    out = []
    # LeNet / AlexNet: units extracted from the real graphs
    for graph, published in _network_layers():
        for unit in graph.units():
            layer = f"conv{unit.index + 1}"
            if layer not in published:
                continue
            sp = published[layer]
            x = _layer_input(_seed(f"{graph.name}.{layer}"), unit.in_shape, sp)
            row = _bench_layer(f"table3/{graph.name}.{layer}", x, unit.conv,
                               unit.conv.c_out)
            row["derived"] = f"sparsity={sp} in={unit.in_shape} " + row["derived"]
            out.append(row)
    # GoogLeNet: inception branches are outside the linear IR — published
    # synthetic shapes, same harness
    for net, layer, size, sp, c, o, k in TABLE3_LAYERS:
        if not net.startswith("GoogLeNet"):
            continue
        from repro.graph.ir import ConvSpec

        x = _layer_input(_seed(f"{net}.{layer}"), (c, size, size), sp)
        row = _bench_layer(f"table3/{net}.{layer}", x, ConvSpec(o, k=k, pad=0), o)
        row["derived"] = f"sparsity={sp} in=({c}, {size}, {size}) " + row["derived"]
        out.append(row)
    return out


def main():
    for r in rows():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
