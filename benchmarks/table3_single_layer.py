"""Paper Table III: single-conv-layer ECR vs dense on the extracted layers.

Claim checked: ECR wins on single extracted layers from LeNet / AlexNet /
GoogLeNet at their published sparsities (0.90-0.95) — i.e. the technique is
not VGG-specific. Columns: measured CPU wall time (jitted jnp, NOT comparable
to the paper's GTX1080 numbers), the paper's own metric (MAC reduction from
zero skipping), and the modeled-TPU block-ECR speedup from the roofline
constants (this is the number the Pallas kernel targets; the paper's speedups
are wall-clock cuDNN ratios on GPU)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from benchmarks._util import TABLE3_LAYERS, modeled_tpu_us, time_fn
from repro.core import conv2d, synth_feature_map, window_stats
from repro.kernels.ecr_conv.ops import channel_block_occupancy


def rows():
    out = []
    for net, layer, size, sp, c, o, k in TABLE3_LAYERS:
        key = jax.random.PRNGKey(hash((net, layer)) % 2**31)
        x = synth_feature_map(key, (c, size, size), sp)
        kern = jax.random.normal(jax.random.PRNGKey(1), (o, c, k, k)) * 0.1
        dense = jax.jit(partial(conv2d, stride=1, impl="dense"))
        ecr = jax.jit(partial(conv2d, stride=1, impl="ecr"))
        t_dense = time_fn(dense, x, kern, iters=2, warmup=1)
        t_ecr = time_fn(ecr, x, kern, iters=2, warmup=1)
        st = window_stats(jax.device_get(x), k, k, 1)
        occ_raw = channel_block_occupancy(x, 8)  # without compaction
        occ = channel_block_occupancy(x, 8, compact=True)  # the kernel's schedule
        m = modeled_tpu_us(c, size, size, o, k, k, 1, occ)
        out.append({
            "name": f"table3/{net}.{layer}",
            "us_per_call": t_ecr,
            "derived": (f"sparsity={sp} dense_us={t_dense:.0f} "
                        f"mac_red={st.mul_reduction:.2f} occ_raw={occ_raw:.2f} "
                        f"occ_compacted={occ:.2f} "
                        f"tpu_model_speedup={m['speedup']:.2f}"),
        })
    return out


def main():
    for r in rows():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
