"""Pallas kernel microbenchmarks: occupancy sweep -> skipped work fraction.

Interpret-mode wall time is meaningless for TPU perf; the relevant kernel
metrics are structural: fraction of MXU block-MACs and HBM->VMEM block-DMAs
the gathered schedule skips at each occupancy, plus the exactness check."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import synth_feature_map
from repro.kernels.bsr_matmul.ops import block_schedule, sparse_matmul
from repro.kernels.bsr_matmul.ref import bsr_matmul_ref


def main():
    t, f, d = 64, 1024, 512
    w = jax.random.normal(jax.random.PRNGKey(1), (f, d))
    for structured, label in ((False, "unstructured"), (True, "structured")):
        for sparsity in (0.0, 0.5, 0.8, 0.95):
            key = jax.random.PRNGKey(int(sparsity * 10) + structured)
            x = jnp.abs(jax.random.normal(key, (t, f)))
            if structured:
                # block-structured sparsity (what structured-sparsity training
                # or channel compaction produces): kill whole (8,128) blocks
                bm = jax.random.uniform(jax.random.PRNGKey(7), (t // 8, f // 128))
                mask = jnp.repeat(jnp.repeat(bm >= sparsity, 8, 0), 128, 1)
            else:
                mask = jax.random.uniform(jax.random.PRNGKey(8), (t, f)) >= sparsity
            x = jnp.where(mask, x, 0.0)
            ids, cnt = block_schedule(x, 8, 128)
            total_blocks = ids.shape[0] * ids.shape[1]
            occ = float(cnt.sum()) / total_blocks
            y = sparse_matmul(x, w)
            err = float(jnp.abs(y - bsr_matmul_ref(x, w)).max())
            skipped = 1.0 - occ
            print(f"kernels/bsr_{label}_sp{sparsity},0.0,block_occupancy={occ:.3f} "
                  f"mxu_work_skipped={skipped:.3f} dma_skipped={skipped:.3f} "
                  f"max_err={err:.2e}")


if __name__ == "__main__":
    main()
