"""Pallas kernel microbenchmarks: occupancy sweep + tile-geometry search.

Two sweeps, one BENCH_kernels_micro.json:

1. **BSR occupancy sweep** — interpret-mode wall time is meaningless for TPU
   perf; the relevant kernel metrics are structural: fraction of MXU
   block-MACs and HBM->VMEM block-DMAs the gathered schedule skips at each
   occupancy (structured vs unstructured zeros), plus the exactness check.

2. **Tile-geometry search over the reduced model zoo** (DESIGN.md §10) —
   LeNet/AlexNet/VGG reduced graphs planned by `plan_network`, every conv
   layer searched by `repro.obs.tile_search` at its planned impl, plus the
   int8 planning probe (`plan_network(int8=True)`). One row per searched
   layer (default vs winner, modeled and measured) and one summary row per
   network.

``--check-floor`` turns the sweep into a CI gate: exit non-zero unless every
searched layer's winner models AND measures no slower than its default
geometry (the winner rule's by-construction floor) and every network's int8
probe holds the 0.98 top-1 agreement budget. ``--calib-out`` saves the
merged CalibrationDB (tile winners + fitted per-tile constants) the search
produced, so a serving run can start from the searched state.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# sweep 1: BSR schedule occupancy -> skipped work fraction
# ---------------------------------------------------------------------------


def occupancy_rows() -> list:
    from repro.kernels.bsr_matmul.ops import block_schedule, sparse_matmul
    from repro.kernels.bsr_matmul.ref import bsr_matmul_ref

    t, f, d = 64, 1024, 512
    w = jax.random.normal(jax.random.PRNGKey(1), (f, d))
    rows = []
    for structured, label in ((False, "unstructured"), (True, "structured")):
        for sparsity in (0.0, 0.5, 0.8, 0.95):
            key = jax.random.PRNGKey(int(sparsity * 10) + structured)
            x = jnp.abs(jax.random.normal(key, (t, f)))
            if structured:
                # block-structured sparsity (what structured-sparsity training
                # or channel compaction produces): kill whole (8,128) blocks
                bm = jax.random.uniform(jax.random.PRNGKey(7), (t // 8, f // 128))
                mask = jnp.repeat(jnp.repeat(bm >= sparsity, 8, 0), 128, 1)
            else:
                mask = jax.random.uniform(jax.random.PRNGKey(8), (t, f)) >= sparsity
            x = jnp.where(mask, x, 0.0)
            ids, cnt = block_schedule(x, 8, 128)
            total_blocks = ids.shape[0] * ids.shape[1]
            occ = float(cnt.sum()) / total_blocks
            y = sparse_matmul(x, w)
            err = float(jnp.abs(y - bsr_matmul_ref(x, w)).max())
            skipped = 1.0 - occ
            rows.append({
                "name": f"kernels/bsr_{label}_sp{sparsity}",
                "us_per_call": 0.0,
                "derived": (f"block_occupancy={occ:.3f} "
                            f"mxu_work_skipped={skipped:.3f} "
                            f"dma_skipped={skipped:.3f} max_err={err:.2e}"),
            })
    return rows


# ---------------------------------------------------------------------------
# sweep 2: tile-geometry search + int8 probe over the reduced zoo
# ---------------------------------------------------------------------------


def _zoo():
    from repro.configs.alexnet import ALEXNET_REDUCED
    from repro.configs.lenet import LENET_REDUCED
    from repro.configs.vgg19_sparse import CNN_REDUCED, vgg19_graph

    return (LENET_REDUCED, ALEXNET_REDUCED, vgg19_graph(CNN_REDUCED))


def tile_rows(batch: int = 2, iters: int = 2, warmup: int = 1,
              max_timed: int = 2, int8: bool = True, db=None) -> tuple:
    """(rows, merged CalibrationDB, floor_ok, int8_ok) over the reduced zoo.

    One search per network at the sparse-forced planning point
    (occ_threshold=1.0, block_c=8 — the zoo smoke's "sparse" row, so the
    search exercises the Pallas kernels rather than re-timing dense XLA),
    winners accumulated into ONE shared DB across networks: the tiles table
    is keyed by layer shape, so disjoint networks only collide on shapes
    that should share a winner anyway."""
    from benchmarks._util import dead_band_calib
    from repro.graph import init_graph
    from repro.obs import tile_search
    from repro.pipeline import plan_network

    rows: list = []
    floor_ok = True
    int8_ok = True
    for graph in _zoo():
        params = init_graph(jax.random.PRNGKey(0), graph)
        calib = dead_band_calib(graph, batch)
        plan = plan_network(params, calib, graph, occ_threshold=1.0,
                            block_c=8)
        report, db = tile_search(plan, params, calib, iters=iters,
                                 warmup=warmup, max_timed=max_timed, db=db)
        s = report.summary()
        floor_ok &= bool(s["floor_holds"])
        rows.append({
            "name": f"kernels/tiles/{graph.name}",
            "us_per_call": 0.0,
            "derived": (f"layers={s['layers']} improved={s['improved']} "
                        f"floor_holds={s['floor_holds']} "
                        f"model_speedup={s['model_speedup']:.4f}"),
        })
        for r in report.layers:
            rows.append({
                "name": f"kernels/tiles/{graph.name}/L{r.index}_{r.impl}",
                "us_per_call": max(r.best.measured_us, 0.0),
                "derived": (f"tile={'x'.join(map(str, r.best.key))} "
                            f"model_us={r.best.model_us:.4f} "
                            f"default_model_us={r.default.model_us:.4f} "
                            f"default_measured_us={r.default.measured_us:.1f} "
                            f"improved={r.improved} n_timed="
                            f"{sum(c.timed for c in r.candidates)}"),
            })
        if int8:
            p8 = plan_network(params, calib, graph, occ_threshold=1.0,
                              block_c=8, tiles=db, int8=True)
            rep = p8.int8_report
            agree = rep.top1_agreement if rep is not None else 1.0
            int8_ok &= agree >= 0.98
            rows.append({
                "name": f"kernels/int8/{graph.name}",
                "us_per_call": 0.0,
                "derived": (f"int8_layers={p8.counts()['int8']} "
                            f"demoted={len(rep.demoted) if rep else 0} "
                            f"top1_agreement={agree:.3f} "
                            f"max_logit_drift="
                            f"{rep.max_logit_drift if rep else 0.0:.2e}"),
            })
    return rows, db, floor_ok, int8_ok


def main(json_dir: str | None = None, check_floor: bool = False,
         calib_out: str | None = None, batch: int = 2, iters: int = 2,
         warmup: int = 1, max_timed: int = 2, int8: bool = True) -> int:
    rows = occupancy_rows()
    trows, db, floor_ok, int8_ok = tile_rows(batch=batch, iters=iters,
                                             warmup=warmup,
                                             max_timed=max_timed, int8=int8)
    rows += trows
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if calib_out and db is not None:
        print(f"# calibration (tile winners + fits) -> {db.save(calib_out)}")
    if json_dir:
        from benchmarks._util import write_bench_json

        write_bench_json("kernels_micro", rows, json_dir,
                         extra={"floor_holds": floor_ok, "int8_ok": int8_ok})
    if check_floor and not (floor_ok and int8_ok):
        print(f"FLOOR CHECK FAILED: floor_holds={floor_ok} int8_ok={int8_ok}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const=".", default=None, metavar="DIR",
                    help="also write BENCH_kernels_micro.json (default: cwd)")
    ap.add_argument("--check-floor", action="store_true",
                    help="exit 1 unless every searched winner holds the "
                         "modeled+measured floor and int8 agreement >= 0.98")
    ap.add_argument("--calib-out", default=None, metavar="PATH",
                    help="save the merged searched CalibrationDB as JSON")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--max-timed", type=int, default=2)
    ap.add_argument("--no-int8", action="store_true",
                    help="skip the int8 planning probe")
    args = ap.parse_args()
    sys.exit(main(json_dir=args.json, check_floor=args.check_floor,
                  calib_out=args.calib_out, batch=args.batch,
                  iters=args.iters, max_timed=args.max_timed,
                  int8=not args.no_int8))
