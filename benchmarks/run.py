"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Wall times are CPU (this
container); the paper-metric (MAC reduction) and modeled-TPU columns carry the
cross-platform story — see EXPERIMENTS.md §Paper-claims."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        fig2_sparsity,
        fig3_traffic,
        fig9_vgg19,
        fig10_strides,
        fig11_theta,
        fig12_pecr,
        kernels_micro,
        roofline,
        table3_single_layer,
    )

    modules = [
        ("table3", table3_single_layer),
        ("fig2", fig2_sparsity),
        ("fig3", fig3_traffic),
        ("fig9", fig9_vgg19),
        ("fig10", fig10_strides),
        ("fig11", fig11_theta),
        ("fig12", fig12_pecr),
        ("kernels", kernels_micro),
        ("roofline", roofline),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in modules:
        if only and name != only:
            continue
        t0 = time.time()
        mod.main()
        print(f"_meta/{name}_wall_s,{(time.time()-t0)*1e6:.0f},benchmark module wall time")


if __name__ == "__main__":
    main()
