"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Wall times are CPU (this
container); the paper-metric (MAC reduction) and modeled-TPU columns carry the
cross-platform story — see EXPERIMENTS.md §Paper-claims.

``--json [DIR]`` additionally writes one machine-readable BENCH_<module>.json
per module (same rows), each stamped with the producing git SHA + UTC
timestamp + device kind (see `_util.write_bench_json`), so every run appends
an attributable point to the perf trajectory instead of scrolling away.
``--history DB`` (requires ``--json``) goes one step further: after each
module the freshly written BENCH files are ingested into the append-only
perf-history DB (`repro.obs.history.BenchDB`, DESIGN.md §13) — dedup makes
the per-module blanket re-scan free — so `repro-bench check` can gate the
run against the rolling baselines and `repro-bench report` can render the
cross-run trajectory. The
serving benchmark (`serve_vgg19`) always writes its own
BENCH_serve_vgg19.json and is part of the default set; the model-zoo smoke
(`model_zoo`) runs the reduced LeNet/AlexNet/VGG graphs through the planned
pipeline, the weight-sparsity sweep (`sparse_weights`) runs the same
zoo pruned at each target BSR density through the joint planner, and the
scenario sweep (`scenarios`) drives regime-diverse traffic — bursts,
diurnal occupancy drift, hot swap, multi-tenant — through the engine's
telemetry layer, and the kernel microbenchmarks (`kernels_micro`) add the
tile-geometry search + int8 probe over the reduced zoo (BENCH_kernels_micro
carries the floor-check verdict).
"""
from __future__ import annotations

import argparse
import contextlib
import io
import time


def main() -> None:
    from benchmarks import (
        _util,
        fig2_sparsity,
        fig3_traffic,
        fig9_vgg19,
        fig10_strides,
        fig11_theta,
        fig12_pecr,
        kernels_micro,
        model_zoo,
        roofline,
        scenarios,
        serve_sharded,
        serve_vgg19,
        sparse_weights,
        table3_single_layer,
    )

    modules = [
        ("table3", table3_single_layer),
        ("fig2", fig2_sparsity),
        ("fig3", fig3_traffic),
        ("fig9", fig9_vgg19),
        ("fig10", fig10_strides),
        ("fig11", fig11_theta),
        ("fig12", fig12_pecr),
        ("kernels", kernels_micro),
        ("roofline", roofline),
        ("zoo", model_zoo),
        ("sparse_weights", sparse_weights),
        ("serve", serve_vgg19),
        ("scenarios", scenarios),
        # jax is initialized by the imports above, so the sharded sweep sees
        # however many devices the operator's XLA_FLAGS exposed (1 by
        # default — the full 1/2/4 sweep runs in the dedicated CI job)
        ("serve_sharded", serve_sharded),
    ]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="?", default=None,
                    help="run a single module (short name, e.g. fig9)")
    ap.add_argument("--json", nargs="?", const=".", default=None, metavar="DIR",
                    help="also write BENCH_<module>.json files (default: cwd)")
    ap.add_argument("--history", default=None, metavar="DB",
                    help="perf-history BenchDB (JSONL) to auto-ingest each "
                         "module's BENCH json into (requires --json)")
    args = ap.parse_args()
    if args.history and args.json is None:
        ap.error("--history requires --json (the BENCH files are what gets "
                 "ingested)")
    history = None
    if args.history:
        from repro.obs.history import BenchDB

        history = BenchDB(args.history)

    print("name,us_per_call,derived")
    for name, mod in modules:
        if args.only and name != args.only:
            continue
        # these benchmarks write their own (richer) BENCH json; same dir
        own_json = name in ("serve", "serve_sharded", "sparse_weights",
                            "scenarios", "kernels")
        kwargs = {"json_dir": args.json} if (args.json and own_json) else {}
        t0 = time.time()
        if args.json is None:
            mod.main(**kwargs)
        else:
            buf = io.StringIO()
            try:
                with contextlib.redirect_stdout(buf):
                    mod.main(**kwargs)
            finally:
                print(buf.getvalue(), end="")  # keep partial rows on a crash
            if not own_json:  # serving benchmarks already wrote richer json
                _util.write_bench_json(name, _util.parse_csv_rows(buf.getvalue()),
                                       args.json)
            if history is not None:
                # blanket re-scan of the output dir: dedup skips everything
                # already ingested, so only this module's fresh points land
                n_new = sum(history.ingest_dir(args.json).values())
                print(f"_meta/{name}_history,{n_new},points ingested into "
                      f"{args.history}")
        # wall time in SECONDS, as the name says (it was scaled 1e6 into
        # microseconds before PR 10 while still claiming _wall_s)
        print(f"_meta/{name}_wall_s,{time.time()-t0:.3f},benchmark module wall time (seconds)")


if __name__ == "__main__":
    main()
