"""Model-zoo smoke: every LayerGraph network end-to-end through the planned
pipeline (plan_network -> run_plan), reduced shapes at CPU/CI budget.

One row per (network, occ_threshold in {1.0 sparse-forced, 0.0 all-dense}):
wall time of the jitted planned executor over a small batch, the plan's
dense/sparse/fused layer counts, and the max logits deviation of the sparse
plan from the all-dense reference — the acceptance number that says the
sparse path is numerically sound on THIS topology (LeNet's 5x5/pad-0 fused
stacks, AlexNet's strided conv + overlapping ceil-mode pools, VGG's SAME
stacks). This is the CI job that keeps LeNet/AlexNet online as first-class
scenarios, not just VGG.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks._util import time_fn, write_bench_json
from repro.pipeline import plan_network, run_plan


def _zoo(reduced: bool = True):
    from repro.configs.alexnet import ALEXNET, ALEXNET_REDUCED
    from repro.configs.lenet import LENET, LENET_REDUCED
    from repro.configs.vgg19_sparse import CNN_REDUCED, CNNConfig, vgg19_graph

    if reduced:
        return (LENET_REDUCED, ALEXNET_REDUCED, vgg19_graph(CNN_REDUCED))
    return (LENET, ALEXNET, vgg19_graph(CNNConfig()))


def _calib(graph, n: int, seed: int = 0, dead_frac: float = 0.5):
    """Shared dead-band calibration recipe (see `_util.dead_band_calib`)."""
    from benchmarks._util import dead_band_calib

    return dead_band_calib(graph, n, seed, dead_frac)


def rows(reduced: bool = True, batch: int = 2):
    out = []
    for graph in _zoo(reduced):
        from repro.graph import init_graph

        params = init_graph(jax.random.PRNGKey(0), graph)
        calib = _calib(graph, batch)
        dense_plan = plan_network(params, calib, graph, occ_threshold=0.0,
                                  block_c=8)
        sparse_plan = plan_network(params, calib, graph, occ_threshold=1.0,
                                   block_c=8)
        ref = run_plan(dense_plan, params, calib)
        got = run_plan(sparse_plan, params, calib)
        dev = float(jnp.abs(got - ref).max())
        for tag, plan in (("dense", dense_plan), ("sparse", sparse_plan)):
            t = time_fn(jax.jit(lambda p, x, pl=plan: run_plan(pl, p, x)),
                        params, calib, iters=2, warmup=1)
            c = plan.counts()
            out.append({
                "name": f"zoo/{graph.name}/{tag}",
                "us_per_call": t,
                "derived": (f"batch={batch} layers={len(plan.layers)} "
                            f"dense={c['dense']} sparse={c['sparse']} "
                            f"fused={c['fused']} max_dev_vs_dense={dev:.2e}"),
            })
    return out


def main(reduced: bool = True, batch: int = 2, json_dir: str | None = None):
    rs = rows(reduced=reduced, batch=batch)
    for r in rs:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if json_dir:
        return write_bench_json("model_zoo", rs, json_dir)
    return None


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="full-size graphs (slow; default is reduced/CI scale)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--json", nargs="?", const=".", default=None, metavar="DIR",
                    help="also write BENCH_model_zoo.json (default dir: cwd)")
    args = ap.parse_args()
    main(reduced=not args.full, batch=args.batch, json_dir=args.json)
