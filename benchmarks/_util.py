"""Benchmark helpers: timing, the paper's layer set, modeled-TPU time,
machine-readable result emission (BENCH_<name>.json)."""
from __future__ import annotations

import json
import os
import time

import jax

from repro.core import synth_feature_map

# v5e-class roofline constants — ONE definition, in repro.obs.constants
# (re-exported by the registry, the cost dispatch every planner/autotune
# decision routes through); a fitted obs.calibrate.CalibrationDB overrides
# them per (kind, impl) via the calibration= parameters, never by mutation
from repro.graph.registry import HBM_BW, PEAK_FLOPS  # noqa: E402,F401


def time_fn(f, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of a jitted callable — a thin wrapper over
    `repro.obs.profile.time_callable`, THE wall-time harness, so benchmark
    rows, autotune candidates, and profile measurements all enter the
    perf-history DB under one measurement discipline."""
    from repro.obs.profile import time_callable

    return time_callable(f, *args, iters=iters, warmup=warmup).median_us


def dead_band_calib(graph, n: int, seed: int = 0, dead_frac: float = 0.5):
    """(N,C,H,W) calibration batch with a shared dead trailing-channel band
    (the post-ReLU channel death the planner exploits; DESIGN.md §2.2) —
    the one calibration recipe the model-zoo and weight-sparsity sweeps
    share, so their plans are comparable. The first conv's input may be
    fully dense (3-channel images); deeper layers still go sparse from the
    net's own ReLU."""
    from repro.core import dead_channel_band

    c, h, w = graph.in_shape
    return dead_channel_band(
        jax.random.uniform(jax.random.PRNGKey(seed), (n, c, h, w)), dead_frac)


def serve_replay_point(engine, imgs, rate_rps: float):
    """Warm a serving engine, drive one open-loop replay at `rate_rps`, and
    return (results, point) — the throughput/latency/cache point dict the
    serving sweeps share (benchmarks/serve_vgg19.py, serve_sharded.py add
    their sweep-specific fields on top). The engine must be on a SimClock."""
    from repro.serving import replay_stream

    clock = engine.clock
    warm_compiles = engine.warmup()
    t0 = clock()
    results = replay_stream(engine, imgs, rate_rps=rate_rps)
    makespan = max(clock() - t0, 1e-9)
    stats = engine.stats()
    point = {
        "rate_rps": rate_rps,
        "throughput_rps": len(results) / makespan,
        # percentiles come from the engine's MetricsTracker reservoir — fed
        # per COMPLETED request inside the engine, so flush-tail requests
        # are aggregated exactly like poll()-completed ones
        "p50_ms": stats["p50_ms"],
        "p95_ms": stats["p95_ms"],
        "p99_ms": stats["p99_ms"],
        "mean_ms": stats["mean_ms"],
        "batches": stats["batches"],
        "mean_fill": round(stats["mean_fill"], 3),
        "warm_compiles": warm_compiles,
        "stream_compiles": stats["compiles"] - warm_compiles,
        "cache_hits": stats["hits"],
        "replans": stats["replans"],
    }
    return results, point


def git_sha() -> str:
    """Current repo HEAD (short), "unknown" outside a git checkout — stamped
    into every BENCH_*.json so the perf trajectory is attributable."""
    import subprocess

    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=5,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def jax_versions() -> dict:
    """{"jax": ..., "jaxlib": ...} of the producing environment — stamped
    into every BENCH_*.json next to the git SHA: two runs of the same commit
    on different jax/jaxlib builds are different perf points (XLA codegen
    moves between releases), and without the stamp they are
    indistinguishable in the trajectory."""
    out = {}
    for mod in ("jax", "jaxlib"):
        try:
            out[mod] = __import__(mod).__version__
        except Exception:
            out[mod] = "unknown"
    return out


def device_info() -> dict:
    """{"device_kind", "platform"} of the measuring device — stamped into
    every BENCH_*.json next to the git SHA. The perf-history DB keys its
    series on device_kind, so points from CPU-interpret runs and real-TPU
    runs form disjoint baselines instead of merging into one."""
    try:
        dev = jax.devices()[0]
        return {"device_kind": str(getattr(dev, "device_kind", dev.platform)),
                "platform": str(dev.platform)}
    except Exception:
        return {"device_kind": "unknown", "platform": "unknown"}


def write_bench_json(name: str, rows, out_dir: str = ".", extra: dict | None = None) -> str:
    """Write BENCH_<name>.json — the machine-readable twin of the CSV the
    benchmark modules print, so the perf trajectory is captured per run.
    Every payload is stamped with the git SHA, a UTC timestamp, the
    jax/jaxlib versions, and the device kind/platform, so a BENCH artifact
    is attributable to the commit AND the environment that produced it —
    and ingestible into the perf-history DB (`repro.obs.history`, DESIGN.md
    §13) as typed per-device series.

    rows: list of dicts; each needs at least name/us_per_call (derived and any
    metric keys ride along verbatim). Returns the written path.
    """
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {"name": name, "schema": "name,us_per_call,derived",
               "git_sha": git_sha(),
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
               "versions": jax_versions(),
               **device_info(),
               "rows": list(rows)}
    if extra:
        payload.update(extra)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def parse_csv_rows(text: str):
    """Parse the `name,us_per_call,derived` CSV rows a benchmark module
    prints into write_bench_json row dicts (non-conforming lines skipped)."""
    rows = []
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] in ("", "name") or parts[0].startswith("_meta/"):
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append({"name": parts[0], "us_per_call": us,
                     "derived": parts[2] if len(parts) > 2 else ""})
    return rows


def modeled_tpu_us(c, h, w, o, kh, kw, stride, occupancy: float, dtype_bytes=2,
                   batch: int = 1) -> dict:
    """Roofline-modeled TPU time (us/IMAGE) for dense vs block-ECR conv.

    dense: max(MAC-time, HBM-time) with all channel blocks.
    ecr:   same with only `occupancy` fraction of channel blocks (DMA+MXU both
           skip dead blocks — the kernel's gathered schedule).
    batch: the kernel tensor is read once per OUTPUT BLOCK, not once per
           sample (the batched grid keeps it resident across the batch), so
           its bytes amortize by 1/batch; activation and output bytes and the
           MACs are per-image.
    """
    oh, ow = (h - kh) // stride + 1, (w - kw) // stride + 1
    macs = 2 * oh * ow * o * c * kh * kw
    k_bytes = o * c * kh * kw * dtype_bytes / batch
    bytes_dense = (c * h * w + o * oh * ow) * dtype_bytes + k_bytes
    t_dense = max(macs / PEAK_FLOPS, bytes_dense / HBM_BW) * 1e6
    bytes_ecr = (occupancy * c * h * w + o * oh * ow) * dtype_bytes + occupancy * k_bytes
    t_ecr = max(occupancy * macs / PEAK_FLOPS, bytes_ecr / HBM_BW) * 1e6
    return {"dense_us": t_dense, "ecr_us": t_ecr,
            "speedup": t_dense / max(t_ecr, 1e-12)}


def feature_map_with_sparsity(key, c, h, w, sparsity):
    return synth_feature_map(key, (c, h, w), sparsity)


# paper Table III layer set: (network, layer, size, sparsity, C, O, k)
TABLE3_LAYERS = [
    ("LeNet", "Conv2", 11, 0.95, 6, 16, 5),
    ("AlexNetC", "Conv3", 6, 0.90, 192, 384, 3),
    ("AlexNetI", "Conv4", 5, 0.90, 384, 256, 3),
    ("GoogLeNet", "Incep4a.1", 14, 0.90, 480, 192, 3),
    ("GoogLeNet", "Incep4a.2", 14, 0.90, 96, 208, 3),
    ("GoogLeNet", "Incep4e.3", 14, 0.90, 160, 320, 3),
    ("GoogLeNet", "Incep5a.1", 7, 0.95, 832, 256, 3),
    ("GoogLeNet", "Incep5a.2", 7, 0.90, 160, 320, 3),
    ("GoogLeNet", "Incep5b.3", 7, 0.95, 192, 384, 3),
    ("GoogLeNet", "Incep4a.7", 7, 0.95, 512, 128, 3),
]

# paper Fig. 2 sparsity curve for VGG-19 conv inputs (approximate red curve)
VGG19_SPARSITY = [0.00, 0.35, 0.45, 0.45, 0.55, 0.60, 0.65, 0.65,
                  0.70, 0.72, 0.75, 0.75, 0.78, 0.80, 0.82, 0.85]

# VGG-19 conv shapes at half resolution (CPU-budget; MACs reported at full)
VGG19_CONVS = []
_res, _cin = 112, 3
for _stage, (_c, _n) in enumerate(((64, 2), (128, 2), (256, 4), (512, 4), (512, 4))):
    for _i in range(_n):
        VGG19_CONVS.append((f"conv_{len(VGG19_CONVS)+1}", _cin, _c, _res))
        _cin = _c
    _res //= 2
